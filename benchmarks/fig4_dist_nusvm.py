"""Paper Figure 4: distributed nu-SVM objective vs communication (k=20).
The first practical distributed nu-SVM -- emits the objective trajectory
against communication units (kd scalars)."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import distributed as dist
from repro.core import preprocess as pp
from repro.data import synthetic

K = 20
ALPHA = 0.85


def run(quick: bool = True) -> None:
    cases = [("synth_a9a_like", 3000, 123), ("synth_phishing_like",
                                             2000, 68)]
    if not quick:
        cases.append(("synth_gisette_like", 6000, 512))
    for name, n, d in cases:
        ds = synthetic.non_separable(n, d, beta2=0.25, seed=d)
        xp = ds.x[ds.y > 0]
        xm = ds.x[ds.y < 0]
        nu = 1.0 / (ALPHA * min(len(xp), len(xm)))
        pre = pp.preprocess(xp, xm, jax.random.key(0))
        XP, XM = np.asarray(pre.xp), np.asarray(pre.xm)
        unit = K * XP.shape[1]

        t0 = time.perf_counter()
        res = dist.solve_distributed(XP, XM, k=K, nu=nu, eps=1e-3,
                                     beta=0.1, num_iters=5000,
                                     record_every=1000)
        t = time.perf_counter() - t0
        traj = ";".join(f"{c / unit:.0f}:{o:.5f}"
                        for _, c, o in res.history)
        emit(f"fig4/saddle_dsvc_{name}", t, f"traj={traj}")
