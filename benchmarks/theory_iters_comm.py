"""Theorems 6 + 8 empirically: iterations-to-tolerance scale like
sqrt(d / (eps * beta)) in d, and communication is O(k) per iteration
independent of n, d."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import distributed as dist
from repro.core import preprocess as pp
from repro.core import saddle
from repro.data import synthetic


def _iters_to_tol(XP, XM, opt, tol=1.10, max_iters=30000):
    res = saddle.solve(XP, XM, eps=1e-3, beta=0.1, num_iters=max_iters,
                       record_every=500)
    for it, obj in res.history:
        if obj <= opt * tol + 1e-9:
            return it
    return max_iters


def run(quick: bool = True) -> None:
    from repro.baselines import qp_nusvm
    n = 1500
    dims = (16, 64, 256) if quick else (16, 64, 256, 1024)
    iters = []
    for d in dims:
        ds = synthetic.separable(n, d, seed=d)
        xp, xm = ds.x[ds.y > 0], ds.x[ds.y < 0]
        pre = pp.preprocess(xp, xm, jax.random.key(0))
        XP, XM = np.asarray(pre.xp), np.asarray(pre.xm)
        _, hist = qp_nusvm.solve(XP, XM, nu=1.0, num_iters=3000)
        it = _iters_to_tol(XP, XM, hist[-1][1])
        iters.append(it)
        emit(f"theory/iters_d{d}", 0.0, f"iters={it}")
    # growth ratio between largest and smallest d vs sqrt scaling
    pred = np.sqrt(dims[-1] / dims[0])
    got = iters[-1] / max(iters[0], 1)
    emit("theory/iter_growth", 0.0,
         f"measured={got:.2f};sqrt_d_prediction={pred:.2f}")

    # communication: scalars per iteration linear in k, flat in n and d
    for k in (5, 10, 20):
        c = dist.CommModel(k=k, nu_rounds_per_iter=0)
        emit(f"theory/comm_k{k}", 0.0,
             f"scalars_per_iter={c.scalars_per_iteration():.0f}")
