"""Theorems 6 + 8 empirically.

Theorem 8 (the comm part, quick mode -- this is what writes
``BENCH_comm.json`` from ``scripts/ci.sh fast``): MEASURED post-SPMD
per-iteration collective counts of the sharded packed step, for
k in {2, 8, 32} and both HM-Saddle and nu-Saddle, against the analytic
``CommModel`` -- the measurement is the real compiled HLO (via
``repro.utils.comm_audit``, in a subprocess with the host device count
forced to max k), so the O(k) scalar bound is a tracked metric, not a
docstring claim.  Every record emits measured count/bytes, the model
prediction, and the match bit; any mismatch fails the suite.

The same subprocess also pins the SERVING slot chunk
(``engine.run_chunk_slots_sharded``) for k in {2, 8}: the lanes
placement must compile with ZERO collectives anywhere
(``comm/serve_lanes_*``), the point-sharded placement must equal
``ServeCommModel`` on both the per-iteration and per-chunk multisets
(``comm/serve_points_*``).

Theorem 6 (full mode only -- it solves QPs and 30k-iteration saddle
runs): iterations-to-tolerance scale like sqrt(d / (eps * beta)) in d.

Runnable standalone like ``benchmarks/run.py``::

    python -m benchmarks.theory_iters_comm --json BENCH_comm.json
    python -m benchmarks.theory_iters_comm --full
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, emit_count, header, write_json

AUDIT_KS = (2, 8, 32)
AUDIT_N1, AUDIT_N2, AUDIT_D, AUDIT_B = 320, 384, 64, 8
NU_FRAC = 0.8

# the serving slot chunk (engine.run_chunk_slots_sharded): audit both
# placements at k in {2, 8} -- lanes must compile collective-FREE,
# point-sharded must match ServeCommModel exactly (iter AND chunk)
SERVE_AUDIT_KS = (2, 8)
SERVE_SLOTS = 2


def _audit_specs() -> list[dict]:
    specs = []
    for k in AUDIT_KS:
        for nu_frac in (0.0, NU_FRAC):
            nu = 1.0 / (nu_frac * AUDIT_N1) if nu_frac else 0.0
            specs.append({"k": k, "n1": AUDIT_N1, "n2": AUDIT_N2,
                          "d": AUDIT_D, "nu": nu,
                          "block_size": AUDIT_B,
                          # one full-chunk (production runner) audit
                          # per nu regime at the middle k
                          "runner": k == AUDIT_KS[1],
                          "chunk_steps": 8})
    return specs


def _serve_audit_specs() -> list[dict]:
    specs = []
    for k in SERVE_AUDIT_KS:
        for nu_frac in (0.0, NU_FRAC):
            nu = 1.0 / (nu_frac * AUDIT_N1) if nu_frac else 0.0
            for sharded in (False, True):
                specs.append({
                    "kind": "serve", "k": k,
                    "num_slots": SERVE_SLOTS * k if not sharded
                    else SERVE_SLOTS,
                    "n1": AUDIT_N1, "n2": AUDIT_N2, "d": AUDIT_D,
                    "nu": nu, "block_size": 1 if not sharded
                    else AUDIT_B,
                    "sharded": sharded, "chunk_steps": 8})
    return specs


def run_comm(quick: bool = True) -> None:
    """Measured-vs-CommModel collective counts (Theorem 8)."""
    from repro.utils import comm_audit

    del quick  # same matrix in both modes: one subprocess, tiny programs
    records = comm_audit.collect_audits(
        _audit_specs() + _serve_audit_specs())
    mismatches = []
    for rec in records:
        if rec.get("kind") == "serve":
            tag = (f"comm/serve_{'points' if rec['sharded'] else 'lanes'}"
                   f"_k{rec['k']}_{'nu' if rec['nu'] else 'hm'}")
            emit_count(tag, rec["per_iteration_count"],
                       f"match={rec['match']};"
                       f"bytes_per_iter={rec['per_iteration_bytes']};"
                       f"per_chunk={rec['measured_per_chunk']};"
                       f"S={rec['num_slots']};B={rec['block_size']}")
            if not rec["match"]:
                mismatches.append(tag)
            continue
        tag = (f"comm/measured_k{rec['k']}_"
               f"{'nu' if rec['nu'] else 'hm'}")
        emit_count(tag, rec["per_iteration_count"],
                   f"model={rec['model_collectives']};"
                   f"match={rec['match']};"
                   f"bytes_per_iter={rec['per_iteration_bytes']};"
                   f"model_bytes={rec['model_payload_bytes']};"
                   f"theorem8_scalars={rec['model_scalars']:.0f};"
                   f"B={rec['block_size']}")
        if not rec["match"]:
            mismatches.append(tag)
        if "runner_match" in rec:
            emit_count(tag + "_chunk", sum(
                rec["runner_measured"].values()),
                f"runner_match={rec['runner_match']};"
                f"matches_single_step={rec['runner_matches_step']};"
                f"per_chunk={rec['runner_per_chunk']}")
            if not (rec["runner_match"] and rec["runner_matches_step"]):
                mismatches.append(tag + "_chunk")
    # the model's paper-convention scalar counts, linear in k by
    # construction -- recorded alongside so the JSON carries both views
    from repro.core import distributed as dist
    from repro.core import projections
    for k in AUDIT_KS:
        for rounds, nm in ((0.0, "hm"),
                           (float(projections.BISECT_ROUNDS_SOLVER),
                            "nu")):
            c = dist.CommModel(k=k, nu_rounds_per_iter=rounds)
            emit_count(f"comm/model_scalars_k{k}_{nm}",
                       c.scalars_per_iteration(),
                       f"collectives={c.collectives_per_iteration(AUDIT_B)}")
    if mismatches:
        raise AssertionError(
            f"measured collectives != CommModel for {mismatches} -- a "
            "communication regression in the shard_map hot loop")


def _iters_to_tol(XP, XM, opt, tol=1.10, max_iters=30000):
    from repro.core import saddle
    res = saddle.solve(XP, XM, eps=1e-3, beta=0.1, num_iters=max_iters,
                       record_every=500)
    for it, obj in res.history:
        if obj <= opt * tol + 1e-9:
            return it
    return max_iters


def run_iters() -> None:
    """Iteration-count scaling in d (Theorem 6) -- the slow part."""
    import jax

    from repro.baselines import qp_nusvm
    from repro.core import preprocess as pp
    from repro.data import synthetic

    n = 1500
    dims = (16, 64, 256, 1024)
    iters = []
    for d in dims:
        ds = synthetic.separable(n, d, seed=d)
        xp, xm = ds.x[ds.y > 0], ds.x[ds.y < 0]
        pre = pp.preprocess(xp, xm, jax.random.key(0))
        XP, XM = np.asarray(pre.xp), np.asarray(pre.xm)
        _, hist = qp_nusvm.solve(XP, XM, nu=1.0, num_iters=3000)
        it = _iters_to_tol(XP, XM, hist[-1][1])
        iters.append(it)
        emit(f"theory/iters_d{d}", 0.0, f"iters={it}")
    # growth ratio between largest and smallest d vs sqrt scaling
    pred = np.sqrt(dims[-1] / dims[0])
    got = iters[-1] / max(iters[0], 1)
    emit("theory/iter_growth", 0.0,
         f"measured={got:.2f};sqrt_d_prediction={pred:.2f}")


def run(quick: bool = True) -> None:
    run_comm(quick)
    if not quick:
        run_iters()


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Theorem 8 communication audit (+ Theorem 6 "
                    "iteration scaling with --full)")
    ap.add_argument("--full", action="store_true",
                    help="also run the slow iteration-scaling study")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write every metric as JSON records "
                         "(e.g. BENCH_comm.json) for CI tracking")
    args = ap.parse_args()
    header()
    try:
        run(quick=not args.full)
    finally:
        # write the JSON even when the audit assertion fires: the
        # measured-vs-model records ARE the diagnostic for a mismatch
        if args.json:
            write_json(args.json)


if __name__ == "__main__":
    main()
