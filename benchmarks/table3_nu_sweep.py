"""Paper Table 3: effect of the nu parameter (alpha in {0.1, 0.3, 0.5})
on objective and test accuracy -- small alpha gives near-zero objective
(reduced hulls overlap) and poor prediction, matching the paper."""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.svm import SaddleNuSVC
from repro.data import synthetic


def run(quick: bool = True) -> None:
    n, d = (2500, 48) if quick else (30000, 123)
    ds = synthetic.non_separable(n, d, beta2=0.3, seed=0)
    tr, te = ds.split(0.15, seed=1)
    for alpha in (0.1, 0.3, 0.5, 0.85):
        t0 = time.perf_counter()
        clf = SaddleNuSVC(alpha=alpha, eps=1e-3, beta=0.1,
                          num_iters=6000).fit(tr.x, tr.y)
        t = time.perf_counter() - t0
        emit(f"table3/alpha_{alpha}", t,
             f"obj={clf.objective_:.2e};test_acc={clf.score(te.x, te.y):.3f}")
