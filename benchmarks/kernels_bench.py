"""Pallas kernels (interpret mode on CPU) vs the pure-jnp oracles:
correctness is in tests/; this reports us_per_call for both paths.
Note: interpret mode measures the *kernel logic* on CPU, not TPU perf --
TPU numbers come from the roofline analysis."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels import ops, ref


def run(quick: bool = True) -> None:
    rng = np.random.default_rng(0)
    n, d = (4096, 256) if quick else (65536, 1024)

    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    t, _ = timeit(lambda: ops.fwht(x))
    emit("kernels/fwht_pallas_interp", t, f"n={n};d={d}")
    fref = jax.jit(ref.fwht_ref)
    t, _ = timeit(lambda: fref(x))
    emit("kernels/fwht_jnp_ref", t, "")

    cols = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
    ll = jnp.asarray(np.log(np.ones(n) / n), jnp.float32)
    u = jnp.zeros((n,), jnp.float32)
    dw = jnp.asarray([0.01], jnp.float32)
    t, _ = timeit(lambda: ops.mwu_update(cols, ll, u, dw, sign=1.0,
                                         gamma=1e-3, tau=30.0,
                                         d_eff=float(d)))
    emit("kernels/mwu_update_pallas_interp", t, f"n={n}")

    @jax.jit
    def mwu_ref(cols, ll, u, dw):
        log_new, u_new = ref.mwu_update_ref(cols, ll, u, dw, 1.0, 1e-3,
                                            30.0, float(d))
        return log_new - jax.scipy.special.logsumexp(log_new), u_new

    t, _ = timeit(lambda: mwu_ref(cols, ll, u, dw))
    emit("kernels/mwu_update_jnp_ref", t, "")
