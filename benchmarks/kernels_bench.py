"""Pallas kernels (interpret mode on CPU) vs the pure-jnp oracles:
correctness is in tests/; this reports us_per_call for both paths and
COUNTS KERNEL LAUNCHES PER ENGINE STEP (the packed single-sweep step
must launch 2 kernels where the unpacked reference launches 4 --
asserted here so a regression fails the bench).
Note: interpret mode measures the *kernel logic* on CPU, not TPU perf --
TPU numbers come from the roofline analysis."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_count, timeit
from repro.core import engine, preprocess as pp, saddle
from repro.kernels import ops, ref


def _count_launches_per_step() -> None:
    """Trace one reference step and one packed step with the pallas
    backend and diff ``ops.launch_counts`` (wrappers tally at trace
    time; one wrapper call == one kernel launch in the compiled step)."""
    rng = np.random.default_rng(0)
    d, n1, n2, b = 16, 40, 50, 4
    xp = jnp.asarray(rng.normal(size=(n1, d)), jnp.float32)
    xm = jnp.asarray(rng.normal(size=(n2, d)), jnp.float32)
    params = saddle.make_params(n1 + n2, d, 1e-3, 0.1, block_size=b)
    key = jax.random.key(0)

    st = saddle.init_state(n1, n2, d, xp, xm)
    snap = dict(ops.launch_counts)
    jax.make_jaxpr(lambda s, k: engine.step(
        s, k, xp, xm, params, backend="pallas"))(st, key)
    ref_launches = sum(v - snap.get(name, 0)
                       for name, v in ops.launch_counts.items())

    pts = pp.pack_points(xp, xm)
    pst = engine.init_packed_state(pts.sign, n1, n2, d)
    snap = dict(ops.launch_counts)
    jax.make_jaxpr(lambda s, k: engine.step_packed(
        s, k, pts.x_t, pts.sign, params, backend="pallas"))(pst, key)
    packed_launches = sum(v - snap.get(name, 0)
                          for name, v in ops.launch_counts.items())

    assert (ref_launches, packed_launches) == (4, 2), (
        f"kernel launches per step: reference={ref_launches}, "
        f"packed={packed_launches}, expected (4, 2)")
    emit_count("kernels/launches_per_step_reference", ref_launches,
               "momentum_dot x2 + mwu_update x2")
    emit_count("kernels/launches_per_step_packed", packed_launches,
               "momentum_dot_packed + mwu_update_packed (4 -> 2)")


def run(quick: bool = True) -> None:
    _count_launches_per_step()

    rng = np.random.default_rng(0)
    n, d = (4096, 256) if quick else (65536, 1024)

    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    t, _ = timeit(lambda: ops.fwht(x))
    emit("kernels/fwht_pallas_interp", t, f"n={n};d={d}")
    fref = jax.jit(ref.fwht_ref)
    t, _ = timeit(lambda: fref(x))
    emit("kernels/fwht_jnp_ref", t, "")

    cols = jnp.asarray(rng.normal(size=(n, 1)), jnp.float32)
    ll = jnp.asarray(np.log(np.ones(n) / n), jnp.float32)
    u = jnp.zeros((n,), jnp.float32)
    dw = jnp.asarray([0.01], jnp.float32)
    t, _ = timeit(lambda: ops.mwu_update(cols, ll, u, dw, sign=1.0,
                                         gamma=1e-3, tau=30.0,
                                         d_eff=float(d)))
    emit("kernels/mwu_update_pallas_interp", t, f"n={n}")

    @jax.jit
    def mwu_ref(cols, ll, u, dw):
        log_new, u_new = ref.mwu_update_ref(cols, ll, u, dw, 1.0, 1e-3,
                                            30.0, float(d))
        return log_new - jax.scipy.special.logsumexp(log_new), u_new

    t, _ = timeit(lambda: mwu_ref(cols, ll, u, dw))
    emit("kernels/mwu_update_jnp_ref", t, "")

    # packed single-sweep kernels (interpret) vs the packed jnp oracle
    x_t = jnp.asarray(rng.normal(size=(d, 1024)), jnp.float32)
    sign = jnp.asarray(np.r_[np.ones(500), -np.ones(500), np.zeros(24)],
                       jnp.float32)
    llp = jnp.where(sign != 0, -jnp.log(500.0), engine.NEG_INF)
    up = jnp.zeros((1024,), jnp.float32)
    idx = jnp.asarray(rng.choice(d, 8, replace=False).astype(np.int32))
    dwp = jnp.asarray(rng.normal(size=8) * 0.01, jnp.float32)
    t, _ = timeit(lambda: ops.mwu_update_packed(
        x_t, idx, llp, up, dwp, sign, gamma=1e-3, tau=30.0,
        d_eff=float(d)))
    emit("kernels/mwu_update_packed_interp", t, "n_pad=1024;b=8")

    pref = jax.jit(ref.mwu_update_packed_ref)
    t, _ = timeit(lambda: pref(x_t, idx, llp, up, dwp, sign, 1e-3, 30.0,
                               float(d)))
    emit("kernels/mwu_update_packed_jnp_ref", t, "")
