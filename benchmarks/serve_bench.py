"""Multi-tenant fit-serving throughput.

Requests/sec of the continuous-batching solver service
(repro.serve.solver_service) at S in {1, 4, 8} slots against the
sequential baseline -- the same R requests solved one ``SaddleSVC.fit``
at a time.  Every path runs the SAME slot-batched engine (a sequential
fit is the S=1 degenerate batch), so the delta is pure batching: S
problems per compiled step amortize the per-iteration fixed costs
(dispatch, RNG, scalar ops) that a single tiny fit cannot.

The request shape is deliberately SMALL (n=200, d=32): the paper's
per-iteration work is O(B + n) after preprocessing, so small fits are
the overhead-dominated regime the service exists for (the motivation's
"many independent instances as the unit of work").

Besides requests/sec, the bench records per-request QUEUE-TO-RESULT
latency percentiles (p50/p95, stamped by the scheduler at submit and
release) for the default latency-aware policy AND the round-robin
policy at S=8 -- so scheduler policies are comparable on tail latency,
not just throughput, from `BENCH_serve.json`.

Also asserted here (hard, in both quick and full mode): ZERO
recompiles after bucket warm-up -- the timed phase must be 100%
compile-cache hits, checked via the service's trace accounting AND a
global engine.trace_counts snapshot.

Sharded mode (always on, subprocess): the SAME service on a forced
8-device CPU mesh (lanes placement: every device owns whole slots, zero
collectives) at EQUAL TOTAL LANES vs the single-device service --
S=32 lanes either vmapped on one device or spread 4-per-device over the
mesh.  All 8 "devices" share this host's core(s), so per-device rps
equals the mesh-vs-single wall-clock ratio at equal work; the 0.9x
floor asserts sharding overhead (shard_map partitioning, per-device
dispatch) stays under 10% (fails in full mode, warns in quick, like the
speedup floor).  Zero recompiles after warm-up is asserted HARD under
sharding, and a point-sharded big fit (points spanning the mesh's data
axis inside the slot driver) is timed alongside with its per-chunk
collective budget from ServeCommModel.  Emitted as ``serve/sharded/*``.

Streaming mode (always on): ST_TENANTS live (``stream=True``) tenants
each take ST_ROUNDS of appended points (2+2 per round -- the regime
warm starts exist for; the per-tenant point count crosses the 128-rung
boundary exactly and then JUMPS to the 256 rung in the last round),
re-fit warm (carry w + re-placed duals from the previous solution) vs
cold (same edits, fresh state), both under the same duality-gap stop.
``serve/stream/warm_iters_ratio`` = total warm update iterations over
cold -- the tentpole's sublinear-re-fit claim as a tracked number --
with a <= 0.7x floor (warn in quick mode, FAIL in full), plus
requests/sec for both passes.  ZERO recompiles across update rounds
(in-bucket re-packs AND the rung jump) is asserted HARD in both modes
via the same trace_counts snapshot discipline as above.

Chaos mode (always on): a seed-keyed fault plan
(repro.serve.faults.FaultPlan) poisons a fixed subset of the requests
mid-run and delays others' submissions; the pass asserts (hard) that
EXACTLY the poisoned requests fail (structured FAILED), that every
survivor's objective is BIT-EQUAL to its fault-free run (quarantine
invariance at bench scale), that zero recompiles happen under chaos,
and that goodput (completed requests/sec under faults) stays above a
floor fraction of the fault-free S=8 throughput.  Goodput lands in
BENCH_serve.json so the degradation trajectory is tracked per run.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import emit, emit_count
from repro.core import engine
from repro.core.svm import SaddleSVC
from repro.data import synthetic
from repro.serve import faults as faults_mod
from repro.serve.scheduler import RequestFailure
from repro.serve.solver_service import (FitRequest, SolverService,
                                        UpdateRequest)

R = 8            # requests per trial
N1 = N2 = 100    # points per class  -> (256, 32) bucket
D = 32
ITERS = 2000
CHUNK = 250      # service chunk == sequential record_every (same sync
                 # cadence for both paths)


def _requests():
    return [(synthetic.blobs(N1, N2, D, gap=0.8, spread=0.3, seed=i), i)
            for i in range(R)]


def _seq_pass(reqs) -> float:
    t0 = time.perf_counter()
    for ds, seed in reqs:
        SaddleSVC(num_iters=ITERS, seed=seed,
                  record_every=CHUNK).fit(ds.x, ds.y)
    return time.perf_counter() - t0


def _svc_pass(reqs, num_slots: int, policy: str = "oldest"):
    svc = SolverService(num_slots=num_slots, chunk_steps=CHUNK,
                        policy=policy)
    t0 = time.perf_counter()
    for ds, seed in reqs:
        svc.submit(FitRequest(x=ds.x, y=ds.y, seed=seed,
                              num_iters=ITERS))
    svc.run()
    return time.perf_counter() - t0, svc


def _lat_pcts(svc) -> tuple[float, float]:
    pcts = svc.latency_percentiles(50.0, 95.0)
    return pcts[50.0], pcts[95.0]


CHAOS_SEED = 7
GOODPUT_FLOOR = 0.3   # completed-rps under faults vs fault-free rps


def _objectives(reqs) -> dict[int, float]:
    """Fault-free reference objectives keyed by request seed."""
    svc = SolverService(num_slots=8, chunk_steps=CHUNK)
    rid2seed = {svc.submit(FitRequest(x=ds.x, y=ds.y, seed=seed,
                                      num_iters=ITERS)): seed
                for ds, seed in reqs}
    return {rid2seed[rid]: res.objective
            for rid, res in svc.run().items()}


def _chaos_pass(reqs, plan: faults_mod.FaultPlan):
    """Drive one service pass under the plan: delayed submissions feed
    in as their step comes up, poison faults fire in-service via the
    injector.  Returns (elapsed, svc, rid->seed, drained results)."""
    svc = SolverService(num_slots=8, chunk_steps=CHUNK,
                        fault_injector=faults_mod.FaultInjector(plan))
    delays = plan.delays()
    # the plan's rids are SUBMISSION-ORDER ids; sort by delay so the
    # service assigns each rid at its planned step
    order = sorted(((delays.get(i, 0), i, ds, seed)
                    for i, (ds, seed) in enumerate(reqs)))
    rid2seed: dict[int, int] = {}
    t0 = time.perf_counter()
    step_i, qi = 0, 0
    while qi < len(order) or svc._sched.has_work():
        while qi < len(order) and order[qi][0] <= step_i:
            _, _, ds, seed = order[qi]
            rid2seed[svc.submit(FitRequest(x=ds.x, y=ds.y, seed=seed,
                                           num_iters=ITERS))] = seed
            qi += 1
        svc.step()
        step_i += 1
    dt = time.perf_counter() - t0
    return dt, svc, rid2seed, svc.run()


def run(quick: bool = True) -> None:
    reqs = _requests()
    reps = 3 if quick else 4
    slots = (1, 4, 8)

    # ---- warm-up: sequential path + every bucket executable ---------
    _seq_pass(reqs)
    for s in slots:
        _svc_pass(reqs, s)
    snap = dict(engine.trace_counts)

    # ---- timed passes, INTERLEAVED so transient host load hits the
    # baseline and the service alike (wall-clock ratios on a shared
    # CPU are otherwise dominated by when, not what, you measure) ----
    t_seq = None
    best: dict[int, float] = {}
    stats: dict[int, dict] = {}
    lat: dict[int, tuple[float, float]] = {}
    for _ in range(reps):
        dt = _seq_pass(reqs)
        t_seq = dt if t_seq is None else min(t_seq, dt)
        for s in slots:
            dt, svc = _svc_pass(reqs, s)
            if s not in best or dt < best[s]:
                best[s] = dt
                lat[s] = _lat_pcts(svc)
            assert svc.stats["compiles"] == 0 and \
                svc.stats["cache_hits"] == svc.stats["chunk_calls"], \
                svc.stats
            stats[s] = svc.stats
    # policy comparison on tail latency: one round-robin pass at S=8
    # (results are policy-invariant; only queue latency differs)
    _, svc_rr = _svc_pass(reqs, 8, policy="round_robin")
    assert svc_rr.stats["compiles"] == 0, svc_rr.stats
    delta = {k: v - snap.get(k, 0) for k, v in engine.trace_counts.items()
             if v != snap.get(k, 0)}
    assert delta == {}, f"recompile after bucket warm-up: {delta}"

    emit("serve/sequential_fit_loop", t_seq / R,
         f"n={N1 + N2};d={D};iters={ITERS};R={R};rps={R / t_seq:.1f}")
    for s in slots:
        emit(f"serve/slots{s}", best[s] / R,
             f"rps={R / best[s]:.1f};speedup={t_seq / best[s]:.2f}x;"
             f"chunks={stats[s]['chunk_calls']};cache_hits=100%")
        p50, p95 = lat[s]
        emit(f"serve/slots{s}/latency_p50", p50, "queue_to_result;oldest")
        emit(f"serve/slots{s}/latency_p95", p95, "queue_to_result;oldest")
    p50, p95 = _lat_pcts(svc_rr)
    emit("serve/slots8_rr/latency_p50", p50, "queue_to_result;round_robin")
    emit("serve/slots8_rr/latency_p95", p95, "queue_to_result;round_robin")
    speedup8 = t_seq / best[8]
    emit_count("serve/recompiles_after_warmup", 0, "asserted_zero")

    # ---- acceptance floor: >= 2x over the sequential loop at S=8 ----
    if speedup8 < 2.0:
        # Wall-clock ratios are load sensitive (engine_bench precedent):
        # the quick/ci smoke only WARNS; the full run fails.
        msg = (f"S=8 serving speedup {speedup8:.2f}x < 2.0x floor "
               f"(typically measures 2.2-2.4x on an idle CPU)")
        if not quick:
            raise AssertionError(msg)
        print(f"# WARNING: {msg}")

    # ---- chaos mode: goodput + quarantine invariance under faults ----
    base_obj = _objectives(reqs)
    plan = faults_mod.FaultPlan.generate(
        CHAOS_SEED, list(range(R)), poison_frac=0.3, delay_frac=0.3,
        max_chunk=3, max_delay=2)
    assert plan.poisoned_rids(), "chaos plan degenerated: no poison"
    _chaos_pass(reqs, plan)            # warm the poison helper compile
    snap_chaos = dict(engine.trace_counts)
    dt, svc, rid2seed, results = _chaos_pass(reqs, plan)

    failed = {rid for rid, r in results.items()
              if isinstance(r, RequestFailure)}
    assert failed == plan.poisoned_rids(), \
        f"failed {failed} != poisoned {plan.poisoned_rids()}"
    for rid, res in results.items():
        if rid in failed:
            continue
        # quarantine invariance, bench scale: survivors' objectives
        # are BIT-EQUAL to their fault-free runs
        assert res.objective == base_obj[rid2seed[rid]], \
            (rid, res.objective, base_obj[rid2seed[rid]])
    assert svc.stats["compiles"] == 0, svc.stats
    delta = {k: v - snap_chaos.get(k, 0)
             for k, v in engine.trace_counts.items()
             if v != snap_chaos.get(k, 0)}
    assert delta == {}, f"recompile under chaos: {delta}"

    ok = R - len(failed)
    goodput = ok / dt
    ratio = goodput / (R / best[8])
    emit("serve/chaos/goodput_rps", dt / max(ok, 1),
         f"ok={ok}/{R};goodput_rps={goodput:.1f};"
         f"poisoned={len(failed)};seed={CHAOS_SEED}")
    emit_count("serve/chaos/failed_as_planned", len(failed),
               "failed==poisoned;survivors_bit_equal")
    emit_count("serve/chaos/recompiles", 0, "asserted_zero")
    # goodput floor: completing the survivors under faults must retain
    # at least GOODPUT_FLOOR of the fault-free S=8 request rate (the
    # quarantined requests' burned chunks are the degradation budget)
    assert ratio >= GOODPUT_FLOOR, \
        (f"chaos goodput {goodput:.2f} rps is {ratio:.2f}x of the "
         f"fault-free rate; floor {GOODPUT_FLOOR}x")
    emit_count("serve/chaos/goodput_ratio", round(ratio, 3),
               f"floor={GOODPUT_FLOOR};hard_assert")

    # ---- streaming mode: warm-start update rounds vs cold re-fits ----
    _streaming_pass(quick)

    # ---- sharded mode: mesh service in a forced-8-device subprocess --
    _sharded_pass(quick)


# -------------------------------------------------------- streaming pass
ST_TENANTS = 4
ST_ROUNDS = 3          # appends of 2+2/round walk each tenant's point
ST_N1 = ST_N2 = 60     # count 120 -> 124 -> 128 (exact boundary, same
ST_D = 16              # rung) -> 132: a JUMP to the 256 rung in the
ST_APPEND = 2          # last round -- both re-pack paths are timed
ST_ITERS = 40960       # budget; the gap stop ends every solve early
ST_GAP = 0.05
ST_CHUNK = 256
WARM_ITERS_FLOOR = 0.7   # warm updates must need <= 0.7x the cold
                         # iterations-to-gap (measures ~0.14x)


def _stream_data():
    tenants = [synthetic.blobs(ST_N1, ST_N2, ST_D, gap=1.2, spread=0.15,
                               seed=i) for i in range(ST_TENANTS)]
    rounds = [[synthetic.blobs(ST_APPEND, ST_APPEND, ST_D, gap=1.2,
                               spread=0.15, seed=1000 + 10 * r + i)
               for i in range(ST_TENANTS)]
              for r in range(ST_ROUNDS)]
    return tenants, rounds


def _stream_trial(tenants, rounds, warm: bool):
    """One streaming trial: live fits, then per-tenant append rounds
    re-fit warm or cold.  Returns (wall, total update iterations,
    svc)."""
    svc = SolverService(num_slots=ST_TENANTS, chunk_steps=ST_CHUNK)
    t0 = time.perf_counter()
    rids = [svc.submit(FitRequest(x=ds.x, y=ds.y, seed=i,
                                  num_iters=ST_ITERS, gap_tol=ST_GAP,
                                  stream=True))
            for i, ds in enumerate(tenants)]
    svc.run()
    iters = 0
    for rnd in rounds:
        upd = [svc.submit_update(UpdateRequest(tenant=rid, x=ex.x,
                                               y=ex.y, warm=warm))
               for rid, ex in zip(rids, rnd)]
        res = svc.run()
        for u in upd:
            r = res[u]
            assert not isinstance(r, RequestFailure), r
            assert r.iterations < ST_ITERS, \
                "gap stop never fired; iterations-to-gap is meaningless"
            iters += r.iterations
    return time.perf_counter() - t0, iters, svc


def _streaming_pass(quick: bool) -> None:
    tenants, rounds = _stream_data()
    # warm-up traces BOTH rung executables (128 pre-jump, 256 post)
    # and the warm-admission staging helpers for either mode
    _stream_trial(tenants, rounds, True)
    _stream_trial(tenants, rounds, False)
    snap = dict(engine.trace_counts)
    t_warm, it_warm, svc_w = _stream_trial(tenants, rounds, True)
    t_cold, it_cold, svc_c = _stream_trial(tenants, rounds, False)
    # the zero-recompile contract ACROSS update rounds, rung jump
    # included, asserted hard in quick and full mode alike
    for svc in (svc_w, svc_c):
        assert svc.stats["compiles"] == 0, svc.stats
    delta = {k: v - snap.get(k, 0) for k, v in engine.trace_counts.items()
             if v != snap.get(k, 0)}
    assert delta == {}, f"recompile across streaming updates: {delta}"

    n_req = ST_TENANTS * (1 + ST_ROUNDS)
    shape = (f"tenants={ST_TENANTS};rounds={ST_ROUNDS};"
             f"n0={ST_N1 + ST_N2};append={2 * ST_APPEND}/round;"
             f"gap_tol={ST_GAP}")
    emit("serve/stream/warm_pass", t_warm / n_req,
         f"rps={n_req / t_warm:.1f};update_iters={it_warm};{shape}")
    emit("serve/stream/cold_pass", t_cold / n_req,
         f"rps={n_req / t_cold:.1f};update_iters={it_cold};{shape}")
    ratio = it_warm / it_cold
    emit_count("serve/stream/warm_iters_ratio", round(ratio, 4),
               f"warm={it_warm};cold={it_cold};"
               f"floor<={WARM_ITERS_FLOOR};incl_rung_jump_128_to_256")
    emit_count("serve/stream/recompiles_across_updates", 0,
               "asserted_zero;incl_rung_jump")
    if ratio > WARM_ITERS_FLOOR:
        msg = (f"warm-start update rounds took {ratio:.2f}x the cold "
               f"iterations-to-gap, floor {WARM_ITERS_FLOOR}x "
               f"(typically ~0.14x at 2+2-point appends)")
        if not quick:
            raise AssertionError(msg)
        print(f"# WARNING: {msg}")


# ---------------------------------------------------------- sharded pass
SHARD_DEVS = 8
SHARD_SLOTS = 32       # total lanes, both placements: 4/dev vs 32 vmapped
SHARD_N1 = SHARD_N2 = 384          # -> (1024, 32) bucket
SHARD_ITERS = 2000     # nu fits: heavy enough chunks that the mesh's
SHARD_CHUNK = 500      # fixed dispatch overhead stays under the floor
SHARD_POINTS_ITERS = 500
SHARD_RATIO_FLOOR = 0.9

_SHARDED_SUBPROCESS = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
sys.path.insert(0, sys.argv[1])
cfg = json.loads(sys.argv[2])

import jax
from repro.core import engine
from repro.data import synthetic
from repro.serve.solver_service import FitRequest, SolverService

S, N1, N2, D = cfg["slots"], cfg["n1"], cfg["n2"], cfg["d"]
ITERS, CHUNK, REPS = cfg["iters"], cfg["chunk"], cfg["reps"]
NU = 1.0 / (0.8 * N1)      # nu-Saddle lanes: the projecting executable
reqs = [(synthetic.blobs(N1, N2, D, gap=0.8, spread=0.3, seed=i), i)
        for i in range(S)]
mesh = jax.make_mesh((len(jax.devices()),), ("data",))

def svc_pass(mesh_arg):
    svc = SolverService(num_slots=S, chunk_steps=CHUNK, mesh=mesh_arg)
    t0 = time.perf_counter()
    for ds, seed in reqs:
        svc.submit(FitRequest(x=ds.x, y=ds.y, seed=seed,
                              num_iters=ITERS, nu=NU))
    svc.run()
    return time.perf_counter() - t0, svc

svc_pass(None)
svc_pass(mesh)
snap = dict(engine.trace_counts)
t_single = t_mesh = None
for _ in range(REPS):
    dt, svc = svc_pass(None)
    t_single = dt if t_single is None else min(t_single, dt)
    assert svc.stats["compiles"] == 0, svc.stats
    dt, svc = svc_pass(mesh)
    t_mesh = dt if t_mesh is None else min(t_mesh, dt)
    assert svc.stats["compiles"] == 0, svc.stats
delta = {k: v - snap.get(k, 0) for k, v in engine.trace_counts.items()
         if v != snap.get(k, 0)}
assert delta == {}, f"recompile after warm-up under sharding: {delta}"

# point-sharded big fit (nu-Saddle: the audited 29-collective regime):
# points span the mesh's data axis in-slot
big = synthetic.blobs(4 * N1, 4 * N2, D, gap=0.8, spread=0.3, seed=99)

def points_pass():
    svc = SolverService(num_slots=S, chunk_steps=CHUNK, mesh=mesh,
                        shard_points_above=N1 + N2)
    svc.submit(FitRequest(x=big.x, y=big.y, seed=99,
                          num_iters=cfg["points_iters"],
                          nu=1.0 / (0.8 * 4 * N1)))
    t0 = time.perf_counter()
    svc.run()
    return time.perf_counter() - t0, svc

points_pass()
t_points, svc = points_pass()
assert svc.stats["compiles"] == 0, svc.stats

print("SERVE_SHARDED_JSON=" + json.dumps(
    {"t_single": t_single, "t_mesh": t_mesh, "t_points": t_points,
     "stats_mesh": svc.stats}))
"""


def _sharded_pass(quick: bool) -> None:
    from repro.core import distributed, projections

    cfg = {"slots": SHARD_SLOTS, "n1": SHARD_N1, "n2": SHARD_N2,
           "d": D, "iters": SHARD_ITERS, "chunk": SHARD_CHUNK,
           "points_iters": SHARD_POINTS_ITERS,
           "reps": 2 if quick else 3}
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_SUBPROCESS, src,
         json.dumps(cfg)],
        capture_output=True, text=True, timeout=1200)
    payload = None
    for line in out.stdout.splitlines():
        if line.startswith("SERVE_SHARDED_JSON="):
            payload = json.loads(line[len("SERVE_SHARDED_JSON="):])
    if payload is None:
        raise RuntimeError(
            f"sharded serve subprocess produced no result (exit "
            f"{out.returncode}):\n{out.stdout[-2000:]}\n"
            f"{out.stderr[-4000:]}")

    r = SHARD_SLOTS                       # one request per lane
    t_single, t_mesh = payload["t_single"], payload["t_mesh"]
    ratio = t_single / t_mesh
    # all 8 forced devices share this host's core(s): at equal total
    # lanes the wall-clock ratio IS per-device rps vs the single device
    emit(f"serve/sharded/slots{SHARD_SLOTS}_dev{SHARD_DEVS}",
         t_mesh / r,
         f"rps={r / t_mesh:.1f};single_rps={r / t_single:.1f};"
         f"ratio_vs_single={ratio:.2f};placement=lanes;"
         f"n={SHARD_N1 + SHARD_N2};iters={SHARD_ITERS}")
    emit_count("serve/sharded/recompiles_after_warmup", 0,
               "asserted_zero_in_subprocess")
    # per-chunk collective budget, pinned by comm_audit in CI: lanes
    # placement is collective-free; the point-sharded big fit runs the
    # vmap-batched Theorem-8 rounds
    emit_count("serve/sharded/lanes_collectives_per_chunk", 0,
               "audited==model;see comm/serve_lanes_*")
    # the big fit runs in a shard_num_slots=2 point-sharded group
    model = distributed.ServeCommModel(
        k=SHARD_DEVS, num_slots=2,
        nu_rounds_per_iter=float(projections.BISECT_ROUNDS_SOLVER))
    per_chunk = (model.collectives_per_iteration(1) * SHARD_CHUNK
                 + sum(model.per_chunk_multiset(D).values()))
    emit_count("serve/sharded/points_collectives_per_chunk", per_chunk,
               f"iter={model.collectives_per_iteration(1)}x{SHARD_CHUNK}"
               f"+boundary=2;audited==model;see comm/serve_points_*")
    emit("serve/sharded/points_big_fit",
         payload["t_points"],
         f"n={4 * (SHARD_N1 + SHARD_N2)};iters={SHARD_POINTS_ITERS};"
         f"placement=points;k={SHARD_DEVS}")

    if ratio < SHARD_RATIO_FLOOR:
        msg = (f"sharded serving at equal total lanes is {ratio:.2f}x "
               f"the single-device rate, floor {SHARD_RATIO_FLOOR}x "
               f"(typically 0.90-0.95 on an idle CPU)")
        if not quick:
            raise AssertionError(msg)
        print(f"# WARNING: {msg}")
