"""Paper Figure 1: nu-SVM convergence, Saddle-SVC vs the QP baseline
(NuSVC stand-in).  Emits time-to-5%-of-optimum for both solvers plus
test accuracy."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.baselines import qp_nusvm
from repro.core import preprocess as pp
from repro.core import saddle
from repro.core.svm import SaddleNuSVC
from repro.data import synthetic

ALPHA = 0.85


def run(quick: bool = True) -> None:
    n, d = (3000, 64) if quick else (20000, 128)
    ds = synthetic.non_separable(n, d, beta2=0.2, seed=0)
    tr, te = ds.split(0.1, seed=0)
    xp = tr.x[tr.y > 0]
    xm = tr.x[tr.y < 0]
    nu = 1.0 / (ALPHA * min(len(xp), len(xm)))
    pre = pp.preprocess(xp, xm, jax.random.key(0))
    XP, XM = np.asarray(pre.xp), np.asarray(pre.xm)

    # reference optimum from a long QP run
    _, hist_ref = qp_nusvm.solve(XP, XM, nu=nu, num_iters=4000)
    opt = hist_ref[-1][1]
    target = opt * 1.05 + 1e-9

    t0 = time.perf_counter()
    res = saddle.solve(XP, XM, eps=1e-3, beta=0.1, nu=nu,
                       num_iters=12000, record_every=1000)
    t_saddle = time.perf_counter() - t0
    reached = [h for h in res.history if h[1] <= target]
    emit("fig1/saddle_nusvm", t_saddle,
         f"obj={res.history[-1][1]:.6f};opt={opt:.6f};"
         f"hit5pct_iter={reached[0][0] if reached else -1}")

    t0 = time.perf_counter()
    _, hist_qp = qp_nusvm.solve(XP, XM, nu=nu, num_iters=2000,
                                record_every=200)
    t_qp = time.perf_counter() - t0
    emit("fig1/qp_nusvm", t_qp, f"obj={hist_qp[-1][1]:.6f}")

    # accuracy parity
    clf = SaddleNuSVC(alpha=ALPHA, num_iters=8000).fit(tr.x, tr.y)
    emit("fig1/saddle_accuracy", 0.0, f"test_acc={clf.score(te.x, te.y):.3f}")
