# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import sys
import time
import traceback

from benchmarks import (engine_bench, fig1_nusvm_convergence,
                        fig2_size_scaling, fig3_dist_hard_margin,
                        fig4_dist_nusvm, kernels_bench, lm_serve_bench,
                        roofline, serve_bench, table1_hard_margin,
                        table3_nu_sweep, table4_density,
                        theory_iters_comm)
from benchmarks.common import emit, header, write_json

SUITES = [
    ("table1", table1_hard_margin),
    ("fig1", fig1_nusvm_convergence),
    ("fig2", fig2_size_scaling),
    ("fig3", fig3_dist_hard_margin),
    ("fig4", fig4_dist_nusvm),
    ("table3", table3_nu_sweep),
    ("table4", table4_density),
    ("theory", theory_iters_comm),
    ("kernels", kernels_bench),
    ("engine", engine_bench),
    ("serve", serve_bench),
    ("lm_serve", lm_serve_bench),
    ("roofline", roofline),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write every metric as JSON records "
                         "(e.g. BENCH_engine.json) for CI tracking")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    header()
    failures = []
    for name, mod in SUITES:
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            mod.run(quick=not args.full)
        except Exception as e:      # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
            emit(f"{name}/ERROR", 0.0, str(e)[:80])
        emit(f"{name}/suite_total", time.perf_counter() - t0, "")
    if args.json:
        write_json(args.json)
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
