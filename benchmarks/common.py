"""Shared benchmark helpers: timing + the ``name,us_per_call,derived``
CSV convention."""

from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Median wall time in seconds (fn must block or return jax arrays)."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        _block(r)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        _block(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), r


def _block(r):
    for leaf in jax.tree.leaves(r):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def header() -> None:
    print("name,us_per_call,derived")
