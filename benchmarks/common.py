"""Shared benchmark helpers: timing, the ``name,us_per_call,derived``
CSV convention, and a machine-readable JSON mirror of every emitted
metric (``benchmarks/run.py --json`` writes it to ``BENCH_engine.json``
so CI can track the perf trajectory)."""

from __future__ import annotations

import json
import time

import jax
import numpy as np

# Every emit() appends here; run.py serializes it with write_json().
RESULTS: list[dict] = []


def timeit(fn, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Median wall time in seconds (fn must block or return jax arrays)."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        _block(r)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        _block(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), r


def _block(r):
    for leaf in jax.tree.leaves(r):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def emit(name: str, seconds: float, derived: str = "") -> None:
    RESULTS.append({"name": name, "us_per_call": seconds * 1e6,
                    "notes": derived})
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def emit_count(name: str, count: float, derived: str = "") -> None:
    """Dimensionless metric (launch counts, ratios): recorded under
    ``count`` so JSON consumers never mistake it for a timing."""
    RESULTS.append({"name": name, "count": count, "notes": derived})
    print(f"{name},{count},{derived}")


def header() -> None:
    print("name,us_per_call,derived")


def write_json(path: str) -> None:
    """Serialize every metric emitted so far as a JSON list of
    {name, us_per_call, notes} records."""
    with open(path, "w") as f:
        json.dump(RESULTS, f, indent=2)
    print(f"# wrote {len(RESULTS)} metrics to {path}")
