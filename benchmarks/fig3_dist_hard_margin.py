"""Paper Figure 3: distributed hard-margin -- margin vs communication,
Saddle-DSVC vs distributed Gilbert, k=20 clients.  Derived: scalars sent
to reach within 5% of the converged margin (the paper's x-axis unit is
kd scalars)."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.baselines import dist_gilbert
from repro.core import distributed as dist
from repro.core import preprocess as pp
from repro.data import synthetic

K = 20


def run(quick: bool = True) -> None:
    n, d = (2000, 64) if quick else (10000, 256)
    ds = synthetic.separable(n, d, seed=0)
    xp = ds.x[ds.y > 0]
    xm = ds.x[ds.y < 0]
    pre = pp.preprocess(xp, xm, jax.random.key(0))
    XP, XM = np.asarray(pre.xp), np.asarray(pre.xm)
    unit = K * XP.shape[1]      # paper: one unit = k*d scalars

    t0 = time.perf_counter()
    res = dist.solve_distributed(XP, XM, k=K, eps=1e-3, beta=0.1,
                                 num_iters=6000, record_every=1000)
    t = time.perf_counter() - t0
    final = res.history[-1][2]
    hit = [h for h in res.history if h[2] <= final * 1.05]
    emit("fig3/saddle_dsvc", t,
         f"obj={final:.6f};comm_units={hit[0][1] / unit:.1f};"
         f"total_units={res.scalars_sent / unit:.1f}")

    t0 = time.perf_counter()
    st, hist, comm = dist_gilbert.solve(XP, XM, k=K,
                                        num_iters=1500,
                                        record_every=300)
    t = time.perf_counter() - t0
    final_g = hist[-1][2]
    hit_g = [h for h in hist if h[2] <= final_g * 1.05]
    emit("fig3/dist_gilbert", t,
         f"obj={final_g:.6f};comm_units={hit_g[0][1] / unit:.1f};"
         f"total_units={comm.total(1500) / unit:.1f}")
