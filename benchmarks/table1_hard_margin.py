"""Paper Table 1: Saddle-SVC vs Gilbert on hard-margin SVM.

The paper shows Saddle-SVC overtaking Gilbert as d grows (d=128: 64s vs
152s; d=512: 189s vs 2327s).  We reproduce the trend with CPU-sized
instances: objective parity at matched epsilon + wall time per solve.
Derived column: obj_saddle/obj_gilbert (should be <= ~1.01)."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.baselines import gilbert
from repro.core import preprocess as pp
from repro.core import saddle
from repro.data import synthetic

CASES = [(2000, 8), (2000, 32), (2000, 128)]
EPS, BETA = 1e-3, 0.1


def run(quick: bool = True) -> None:
    cases = CASES if quick else CASES + [(10000, 512)]
    for n, d in cases:
        ds = synthetic.separable(n, d, seed=d)
        xp = ds.x[ds.y > 0]
        xm = ds.x[ds.y < 0]
        pre = pp.preprocess(xp, xm, jax.random.key(0))
        XP, XM = np.asarray(pre.xp), np.asarray(pre.xm)

        t0 = time.perf_counter()
        iters = min(saddle.default_iterations(XP.shape[1], EPS, BETA, n),
                    20000 if quick else 200000)
        res = saddle.solve(XP, XM, eps=EPS, beta=BETA, num_iters=iters)
        t_saddle = time.perf_counter() - t0
        obj_s = res.history[-1][1]

        t0 = time.perf_counter()
        g = gilbert.solve(XP, XM, num_iters=2000 if quick else 20000,
                          tol=EPS * 1e-3, record_every=200)
        t_gilbert = time.perf_counter() - t0
        obj_g = g.history[-1][1]

        emit(f"table1/saddle_n{n}_d{d}", t_saddle,
             f"obj={obj_s:.5f}")
        emit(f"table1/gilbert_n{n}_d{d}", t_gilbert,
             f"obj={obj_g:.5f};ratio={obj_s / max(obj_g, 1e-12):.3f}")
