"""Continuous-batching LM serving throughput.

Tokens/sec of the slot-granular LM service (repro.serve.lm_service) at
S in {1, 2, 4} decode lanes against the sequential baseline -- the
same R generation requests run one solo ``generate`` at a time.  The
service's decode chunk is the solo single-token forward vmapped over
lanes, so the delta is pure continuous batching: S sequences per
compiled decode step amortize the per-token fixed costs (dispatch,
sampling, cache bookkeeping) a single small decode cannot, and freed
KV lanes are refilled MID-DECODE from the queue (staggered arrivals --
the sequential loop cannot overlap requests at all).

The model is deliberately tiny (a reduced full-attention config): like
the solver bench's n=200 fits, small-model decode is the
overhead-dominated regime continuous batching exists for.

Also asserted here (hard, in both quick and full mode, mirroring
serve_bench): ZERO recompiles after warm-up -- one decode-chunk
executable plus one prefill per pow-2 prompt bucket, and the timed
phase must be 100% compile-cache hits via the service's scheduler
accounting AND a global serve-engine trace snapshot.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_count
from repro.configs import get_config
from repro.models import transformer as tf
from repro.serve import engine
from repro.serve.lm_service import LMService

ARCH = "gemma-7b"        # GQA full-attention cache: slot-mode eligible
R = 6                    # requests per trial
STEPS = 24               # generated tokens per request
PROMPT_LENS = (5, 7, 12, 6, 11, 7)   # buckets 8 and 16 only
MAX_LEN = 48
CHUNK = 8

# S=1 runs every request through the slot driver's admission/refill
# machinery with no batch-mates to amortize it, so it ships slightly
# BELOW sequential (~0.8x).  The floor only guards against that
# overhead growing into a real regression.
SLOTS1_FLOOR = 0.7


def _setup():
    cfg = get_config(ARCH).reduced()
    params = tf.init_lm(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, s) for s in PROMPT_LENS]
    return cfg, params, prompts


def _seq_pass(cfg, params, prompts) -> float:
    t0 = time.perf_counter()
    for i, p in enumerate(prompts):
        toks = engine.generate(params, cfg, jnp.asarray(p, jnp.int32)[None],
                               steps=STEPS, seed=i, max_len=MAX_LEN)
        jax.block_until_ready(toks)
    return time.perf_counter() - t0


def _svc_pass(cfg, params, prompts, num_slots: int):
    """Staggered arrivals: half the requests are submitted up front,
    the rest one per decode chunk -- every late request is admitted
    into a freed (or still-free) lane MID-decode."""
    svc = LMService(params, cfg, num_slots=num_slots, chunk_steps=CHUNK,
                    max_len=MAX_LEN)
    t0 = time.perf_counter()
    late = list(enumerate(prompts))[R // 2:]
    for i, p in list(enumerate(prompts))[:R // 2]:
        svc.submit(p, steps=STEPS, seed=i)
    while late:
        svc.step()
        i, p = late.pop(0)
        svc.submit(p, steps=STEPS, seed=i)
    svc.run()
    return time.perf_counter() - t0, svc


def run(quick: bool = True) -> None:
    cfg, params, prompts = _setup()
    reps = 2 if quick else 4
    slots = (1, 2, 4)

    # ---- warm-up: solo path + the service executables ---------------
    _seq_pass(cfg, params, prompts)
    for s in slots:
        _svc_pass(cfg, params, prompts, s)
    snap = dict(engine.trace_counts)

    # ---- timed passes, interleaved (serve_bench discipline) ---------
    t_seq = None
    best: dict[int, float] = {}
    lat: dict[int, dict] = {}
    for _ in range(reps):
        dt = _seq_pass(cfg, params, prompts)
        t_seq = dt if t_seq is None else min(t_seq, dt)
        for s in slots:
            dt, svc = _svc_pass(cfg, params, prompts, s)
            if s not in best or dt < best[s]:
                best[s] = dt
                lat[s] = svc.latency_percentiles(50.0, 95.0)
            assert svc.stats["compiles"] == 0 and \
                svc.stats["cache_hits"] == svc.stats["chunk_calls"], \
                svc.stats
    delta = {k: v - snap.get(k, 0) for k, v in engine.trace_counts.items()
             if v != snap.get(k, 0)}
    assert delta == {}, f"recompile after warm-up: {delta}"

    toks = R * STEPS
    emit("lm_serve/sequential_generate_loop", t_seq / toks,
         f"arch={ARCH};steps={STEPS};R={R};tps={toks / t_seq:.1f}")
    for s in slots:
        note = (f"tps={toks / best[s]:.1f};"
                f"speedup={t_seq / best[s]:.2f}x;cache_hits=100%")
        if s == 1:
            # expected < 1x: slot-driver overhead, nothing to batch
            note += f";s1_overhead_expected;floor={SLOTS1_FLOOR}"
        emit(f"lm_serve/slots{s}", best[s] / toks, note)
        emit(f"lm_serve/slots{s}/latency_p50", lat[s][50.0],
             "queue_to_result")
        emit(f"lm_serve/slots{s}/latency_p95", lat[s][95.0],
             "queue_to_result")
    emit_count("lm_serve/recompiles_after_warmup", 0, "asserted_zero")
    speedup = t_seq / best[max(slots)]
    if speedup < 1.0:
        # wall-clock ratios are load sensitive; quick/ci smoke warns
        msg = (f"S={max(slots)} LM serving speedup {speedup:.2f}x < 1.0x "
               f"(continuous batching should never lose to sequential)")
        if not quick:
            raise AssertionError(msg)
        print(f"# WARNING: {msg}")
    ratio1 = t_seq / best[1]
    if ratio1 < SLOTS1_FLOOR:
        msg = (f"S=1 LM serving at {ratio1:.2f}x sequential, floor "
               f"{SLOTS1_FLOOR}x (slot-driver overhead without "
               f"batch-mates is expected ~0.8x, not worse)")
        if not quick:
            raise AssertionError(msg)
        print(f"# WARNING: {msg}")
