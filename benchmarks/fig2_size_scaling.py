"""Paper Figure 2: convergence vs data size (paper: n = 5k/20k/50k at
d=512; CPU-scaled here).  Derived: iterations and seconds to reach 5% of
the QP optimum -- the paper's point is that time grows ~linearly in n
while QP grows ~quadratically."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.baselines import qp_nusvm
from repro.core import preprocess as pp
from repro.core import saddle
from repro.data import synthetic

ALPHA = 0.85


def run(quick: bool = True) -> None:
    sizes = [1000, 4000, 8000] if quick else [5000, 20000, 50000]
    d = 64 if quick else 512
    for n in sizes:
        ds = synthetic.non_separable(n, d, beta2=0.2, seed=n)
        xp = ds.x[ds.y > 0]
        xm = ds.x[ds.y < 0]
        nu = 1.0 / (ALPHA * min(len(xp), len(xm)))
        pre = pp.preprocess(xp, xm, jax.random.key(0))
        XP, XM = np.asarray(pre.xp), np.asarray(pre.xm)

        t0 = time.perf_counter()
        res = saddle.solve(XP, XM, eps=1e-3, beta=0.1, nu=nu,
                           num_iters=8000, record_every=2000)
        t = time.perf_counter() - t0
        emit(f"fig2/saddle_n{n}", t, f"obj={res.history[-1][1]:.6f}")

        t0 = time.perf_counter()
        _, hist = qp_nusvm.solve(XP, XM, nu=nu, num_iters=1500)
        t_qp = time.perf_counter() - t0
        emit(f"fig2/qp_n{n}", t_qp, f"obj={hist[-1][1]:.6f}")
