"""Paper Table 4: dense vs sparse data -- Saddle-SVC is barely affected
by density (it always does O(n) dense work per iteration) while
primal-SGD baselines exploit sparsity.  Pegasos is the LinearSVC
stand-in; we compare test accuracy and wall time across nnz ratios."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.baselines import pegasos
from repro.core.svm import SaddleNuSVC
from repro.data import synthetic


def run(quick: bool = True) -> None:
    n, d = (4000, 128) if quick else (100000, 128)
    for frac in (0.1, 0.5, 0.9):
        nnz = max(1, int(d * frac))
        ds = synthetic.sparse_non_separable(n, d, nnz=nnz, seed=nnz)
        tr, te = ds.split(0.1, seed=0)

        t0 = time.perf_counter()
        clf = SaddleNuSVC(alpha=0.85, num_iters=6000).fit(tr.x, tr.y)
        t_s = time.perf_counter() - t0
        emit(f"table4/saddle_nnz{frac}", t_s,
             f"test_acc={clf.score(te.x, te.y):.3f}")

        t0 = time.perf_counter()
        st, hist = pegasos.solve(tr.x, tr.y, num_iters=4000, lam=1e-4)
        t_p = time.perf_counter() - t0
        pred = pegasos.predict(st, te.x)
        emit(f"table4/pegasos_nnz{frac}", t_p,
             f"test_acc={float(np.mean(pred == te.y)):.3f}")
