"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json).

Emits one row per (arch x shape x mesh): the three roofline terms,
the dominant bottleneck, and MODEL_FLOPS / HLO_FLOPs.  If the sweep has
not been run, prints a pointer instead of failing."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def run(quick: bool = True) -> None:
    # roofline-accurate unrolled artifacts first, then the scanned sweep
    files = sorted(glob.glob("experiments/dryrun_unrolled/*.json")) + \
        sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        emit("roofline/missing", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun "
             "--both-meshes")
        return
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        suffix = "_unrolled" if rec.get("unrolled") else ""
        name = f"roofline/{rec['arch']}_{rec['shape']}_{rec['mesh']}" \
            + suffix
        if rec.get("error"):
            emit(name, 0.0, f"ERROR={rec['error'][:60]}")
            continue
        if not rec.get("applicable", True):
            emit(name, 0.0, "SKIP")
            continue
        step = max(rec["compute_s"], rec["memory_s"],
                   rec["collective_s"])
        emit(name, step,
             f"bottleneck={rec['bottleneck']};"
             f"compute_ms={rec['compute_s'] * 1e3:.2f};"
             f"memory_ms={rec['memory_s'] * 1e3:.2f};"
             f"collective_ms={rec['collective_s'] * 1e3:.2f};"
             f"useful_flops_ratio={rec.get('useful_flops_ratio', 0):.3f}")
