"""Solver engine benchmarks.

Two comparisons:

1. PACKED vs REFERENCE step (the headline).  Identical chunk driver,
   identical sampling; the delta is the packed +- single-sweep layout:
   one signed momentum pass + one signed MWU pass over the packed
   points instead of two each over the per-class matrices, contiguous
   row gathers from the column-major mirror instead of strided column
   gathers, and (nu > 0) the fixed-round sort-free bisection projection
   instead of one argsort + scatter per class per iteration.  Measured
   warm, per iteration, at the ISSUE target shape n=20k, d=256, B=128
   for the nu>0 block mode (plus the hard-margin mode for reference).

2. Fused chunk driver vs the seed driver (retained from PR 1): the
   seed ``run_chunk`` path (reproduced locally as ``_legacy_*`` below)
   re-jits for every distinct chunk length and syncs to host per chunk;
   the fused driver compiles once and transfers history once.
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import engine
from repro.core import preprocess as pp
from repro.core import saddle
from repro.data import synthetic


@functools.partial(jax.jit, static_argnames=("params", "num_steps"))
def _legacy_chunk(state, key, xp, xm, params, num_steps: int):
    """Seed-style chunk: variable-length scan (one compile per distinct
    num_steps), no donation, no on-device recording."""
    def body(st, k):
        return engine.step(st, k, xp, xm, params), None

    keys = jax.random.split(key, num_steps)
    state, _ = jax.lax.scan(body, state, keys)
    return state


def _legacy_solve(xp, xm, params, num_iters: int, record: int):
    state = saddle.init_state(xp.shape[0], xm.shape[0], xp.shape[1],
                              None, None)
    key = jax.random.key(0)
    history = []
    done = 0
    while done < num_iters:
        key, sub = jax.random.split(key)
        ns = min(record, num_iters - done)
        state = _legacy_chunk(state, sub, xp, xm, params, ns)
        done += ns
        # blocking host sync per chunk + eager (unjitted) objective
        history.append((done, float(saddle.objective(
            state.log_eta, state.log_xi, xp, xm))))
    return state, history


def _packed_vs_reference(n: int, d: int, block: int, nu_frac: float,
                         iters: int, tag: str, enforce: bool) -> None:
    """Warm per-iteration time of one fused chunk, reference (unpacked,
    two passes per class, sort projection) vs packed (single sweep,
    bisection projection).  Same keys, same sampler, same driver."""
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    n1 = n // 2
    xp = rng.normal(size=(n1, d)).astype(np.float32) * 0.1 + 0.2
    xm = rng.normal(size=(n - n1, d)).astype(np.float32) * 0.1 - 0.2
    nu = nu_frac and 1.0 / (nu_frac * n1)
    params = saddle.make_params(n, d, 1e-3, 0.1, nu=nu, block_size=block)
    xp_j, xm_j = jnp.asarray(xp), jnp.asarray(xm)
    pts = pp.pack_points(xp_j, xm_j)
    key = jax.random.key(0)

    def ref_run():
        st = saddle.init_state(n1, n - n1, d, None, None)
        return engine.run_chunk(st, key, xp_j, xm_j, iters,
                                params=params, chunk_steps=iters)

    def packed_run():
        st = engine.init_packed_state(pts.sign, n1, n - n1, d)
        return engine.run_chunk_packed(st, key, pts.x_t, pts.sign, iters,
                                       params=params, chunk_steps=iters)

    reps = 2 if iters <= 50 else 3          # quick mode: ci smoke budget
    t_ref, _ = timeit(ref_run, repeats=reps)
    t_packed, _ = timeit(packed_run, repeats=reps)
    shape = f"n={n};d={d};B={block};nu={nu:.2e};iters={iters}"
    emit(f"engine/reference_step_{tag}", t_ref / iters, shape)
    speedup = t_ref / t_packed
    emit(f"engine/packed_step_{tag}", t_packed / iters,
         f"{shape};speedup={speedup:.2f}x")
    if tag == "nu_block" and speedup < 1.5:
        # acceptance floor for the packed single-sweep step (typically
        # measures 2-3x on an idle CPU).  Wall-clock ratios are load
        # sensitive, so the quick/ci smoke only WARNS; the full
        # (dedicated perf) run fails.
        msg = f"packed step speedup {speedup:.2f}x < 1.5x floor ({shape})"
        if enforce:
            raise AssertionError(msg)
        print(f"# WARNING: {msg}")


def run(quick: bool = True) -> None:
    # ---- headline: packed single-sweep step vs reference, warm -------
    # The nu>0 block mode at n=20k, d=256, B=128 is the acceptance
    # target (>= 1.5x); run it in BOTH quick and full so the ci smoke
    # records the trajectory.
    iters = 40 if quick else 200
    _packed_vs_reference(20000, 256, 128, 0.8, iters, "nu_block",
                         enforce=not quick)
    if not quick:
        _packed_vs_reference(20000, 256, 128, 0.0, iters, "hm_block",
                             enforce=False)
        _packed_vs_reference(20000, 256, 1, 0.8, iters, "nu_b1",
                             enforce=False)

    # ---- chunk driver comparison (PR-1 metric, small shape) ----------
    n, d = (2000, 64) if quick else (20000, 256)
    ds = synthetic.separable(n, d, seed=0)
    xp, xm = ds.x[ds.y > 0], ds.x[ds.y < 0]
    pre = pp.preprocess(xp, xm, jax.random.key(0))
    XP, XM = np.asarray(pre.xp), np.asarray(pre.xm)
    import jax.numpy as jnp
    xp_j, xm_j = jnp.asarray(XP), jnp.asarray(XM)

    # record_every-chunked solve with a partial final chunk (1203 % 50)
    num_iters, record = (1203, 50) if quick else (4003, 250)
    params = saddle.make_params(XP.shape[0] + XM.shape[0], XP.shape[1],
                                1e-3, 0.1)

    # COLD: one solve from empty jit caches (full mode only -- the
    # forced recompiles are the most expensive part of the quick ci
    # smoke and the cold trajectory moves rarely).  The seed driver
    # compiles its scan once per distinct chunk length (here: 50 and
    # the partial 3); the fused driver compiles its dynamic-trip-count
    # chunk once.
    if not quick:
        import time as _time

        _legacy_chunk.clear_cache()
        t0 = _time.perf_counter()
        _, hist_l = _legacy_solve(xp_j, xm_j, params, num_iters, record)
        t_legacy_cold = _time.perf_counter() - t0

        engine.run_chunk_packed.clear_cache()
        t0 = _time.perf_counter()
        res = saddle.solve(XP, XM, num_iters=num_iters,
                           record_every=record)
        t_fused_cold = _time.perf_counter() - t0
        emit("engine/seed_chunk_driver_cold", t_legacy_cold,
             f"n={n};d={XP.shape[1]};iters={num_iters};record={record};"
             f"chunks={len(hist_l)};compiles=2_distinct_lengths")
        emit("engine/fused_engine_cold", t_fused_cold,
             f"chunks={len(res.history)};compiles=1;"
             f"speedup={t_legacy_cold / t_fused_cold:.2f}x")

    # WARM: steady-state repeats (compiles cached for both).  The fused
    # path now also includes the packed single-sweep step, so the delta
    # is driver overhead + packed step win combined.
    t_legacy, (_, hist_l) = timeit(
        lambda: _legacy_solve(xp_j, xm_j, params, num_iters, record),
        repeats=2)
    emit("engine/seed_chunk_driver_warm", t_legacy, "")

    t_fused, res = timeit(
        lambda: saddle.solve(XP, XM, num_iters=num_iters,
                             record_every=record),
        repeats=2)
    emit("engine/fused_engine_warm", t_fused,
         f"speedup={t_legacy / t_fused:.2f}x")

    # sanity: both drivers converge to the same optimum (key schedules
    # differ only on the padded final chunk, so a tiny drift is expected)
    drift = abs(hist_l[-1][1] - res.history[-1][1])
    emit("engine/final_obj_drift", drift,
         f"legacy={hist_l[-1][1]:.6f};fused={res.history[-1][1]:.6f}")
