"""Fused solver engine vs the seed chunk driver.

The seed ``run_chunk`` path (reproduced locally as ``_legacy_*`` below)
re-jits for every distinct chunk length, synchronizes to host with a
blocking ``float(objective(...))`` after every recorded chunk, computes
that objective eagerly outside jit, and copies the state on every call
(no buffer donation).  The fused engine path scans a fixed-shape chunk
(partial final chunk masked, ONE executable), records the objective on
device inside the jitted chunk, donates the state buffers, and does a
single host transfer at the end of the solve.

Both run the identical engine step, so the delta is pure driver
overhead -- the thing this benchmark isolates.
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import engine
from repro.core import preprocess as pp
from repro.core import saddle
from repro.data import synthetic


@functools.partial(jax.jit, static_argnames=("params", "num_steps"))
def _legacy_chunk(state, key, xp, xm, params, num_steps: int):
    """Seed-style chunk: variable-length scan (one compile per distinct
    num_steps), no donation, no on-device recording."""
    def body(st, k):
        return engine.step(st, k, xp, xm, params), None

    keys = jax.random.split(key, num_steps)
    state, _ = jax.lax.scan(body, state, keys)
    return state


def _legacy_solve(xp, xm, params, num_iters: int, record: int):
    state = saddle.init_state(xp.shape[0], xm.shape[0], xp.shape[1],
                              None, None)
    key = jax.random.key(0)
    history = []
    done = 0
    while done < num_iters:
        key, sub = jax.random.split(key)
        ns = min(record, num_iters - done)
        state = _legacy_chunk(state, sub, xp, xm, params, ns)
        done += ns
        # blocking host sync per chunk + eager (unjitted) objective
        history.append((done, float(saddle.objective(
            state.log_eta, state.log_xi, xp, xm))))
    return state, history


def run(quick: bool = True) -> None:
    n, d = (2000, 64) if quick else (20000, 256)
    ds = synthetic.separable(n, d, seed=0)
    xp, xm = ds.x[ds.y > 0], ds.x[ds.y < 0]
    pre = pp.preprocess(xp, xm, jax.random.key(0))
    XP, XM = np.asarray(pre.xp), np.asarray(pre.xm)
    import jax.numpy as jnp
    xp_j, xm_j = jnp.asarray(XP), jnp.asarray(XM)

    # record_every-chunked solve with a partial final chunk (1203 % 50)
    num_iters, record = (1203, 50) if quick else (4003, 250)
    params = saddle.make_params(XP.shape[0] + XM.shape[0], XP.shape[1],
                                1e-3, 0.1)

    # COLD: one solve from empty jit caches.  The seed driver compiles
    # its scan once per distinct chunk length (here: 50 and the partial
    # 3); the fused driver compiles its dynamic-trip-count chunk once.
    # This is the user-facing cost of the first solve at a new shape.
    import time as _time

    _legacy_chunk.clear_cache()
    t0 = _time.perf_counter()
    _, hist_l = _legacy_solve(xp_j, xm_j, params, num_iters, record)
    t_legacy_cold = _time.perf_counter() - t0

    engine.run_chunk.clear_cache()
    t0 = _time.perf_counter()
    res = saddle.solve(XP, XM, num_iters=num_iters, record_every=record)
    t_fused_cold = _time.perf_counter() - t0
    emit("engine/seed_chunk_driver_cold", t_legacy_cold,
         f"n={n};d={XP.shape[1]};iters={num_iters};record={record};"
         f"chunks={len(hist_l)};compiles=2_distinct_lengths")
    emit("engine/fused_engine_cold", t_fused_cold,
         f"chunks={len(res.history)};compiles=1;"
         f"speedup={t_legacy_cold / t_fused_cold:.2f}x")

    # WARM: steady-state repeats (compiles cached for both).  The fused
    # win here is the removed per-chunk host sync + eager objective +
    # state copy (donation); on CPU this is small, on accelerators the
    # sync dominates.
    t_legacy, (_, hist_l) = timeit(
        lambda: _legacy_solve(xp_j, xm_j, params, num_iters, record))
    emit("engine/seed_chunk_driver_warm", t_legacy, "")

    t_fused, res = timeit(
        lambda: saddle.solve(XP, XM, num_iters=num_iters,
                             record_every=record))
    emit("engine/fused_engine_warm", t_fused,
         f"speedup={t_legacy / t_fused:.2f}x")

    # sanity: both drivers converge to the same optimum (key schedules
    # differ only on the padded final chunk, so a tiny drift is expected)
    drift = abs(hist_l[-1][1] - res.history[-1][1])
    emit("engine/final_obj_drift", drift,
         f"legacy={hist_l[-1][1]:.6f};fused={res.history[-1][1]:.6f}")
