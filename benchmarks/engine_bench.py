"""Solver engine benchmarks.

Two comparisons:

1. PACKED vs REFERENCE step (the headline).  Identical chunk driver,
   identical sampling; the delta is the packed +- single-sweep layout:
   one signed momentum pass + one signed MWU pass over the packed
   points instead of two each over the per-class matrices, contiguous
   row gathers from the column-major mirror instead of strided column
   gathers, and (nu > 0) the fixed-round sort-free bisection projection
   instead of one argsort + scatter per class per iteration.  Measured
   warm, per iteration, at the ISSUE target shape n=20k, d=256, B=128
   for the nu>0 block mode (plus the hard-margin mode for reference).

2. Fused DEVICE-RESIDENT driver vs the seed driver (the end-to-end
   gate).  The seed path (reproduced locally as ``_legacy_*`` below)
   re-jits its scan for every distinct chunk length, runs the unpacked
   reference step, and blocks on an eager host-side objective at every
   record boundary; the fused driver runs the whole chunked solve as
   ONE executable (``engine.run_solve_slots``) with the history in a
   device buffer transferred once.  Both get the same problem, budget
   and record cadence, so the ratio is the end-to-end win a user sees:
   driver overhead removed + the packed single-sweep step.  Measured at
   the nu>0 block-mode shape family where the packed step win lives
   (the pre-PR-8 comparison ran hard-margin B=1 at d=64 -- a shape
   with NO step win to surface, which is how a 3.3x packed step showed
   up as 1.04x end to end).  Floor: fused >= 1.5x seed, warn in quick
   mode (wall ratios are load sensitive), FAIL in full.

3. Knob tuning, predict-then-verify (full mode): roofline-predicted
   block size (per-coordinate step time) and duality-gap check cadence
   (boundary-check cost vs overshoot) against their measured
   counterparts -- the study behind the shipped defaults (B=128 at
   d=256, saddle.GAP_CHECK_EVERY=256).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_count, timeit
from repro.core import engine
from repro.core import preprocess as pp
from repro.core import saddle
from repro.utils import roofline

# Acceptance floor for the end-to-end driver comparison (ISSUE 8):
# the fused device-resident driver must beat the seed chunk driver by
# >= this factor warm at the nu>0 block shapes.
DRIVER_GAP_FLOOR = 1.5

# The two drivers draw different coordinate-key schedules, so their
# final objectives differ by genuine stochastic drift (~0.5 at the
# quick shape's short budgets); the tolerance only guards against a
# diverged run, not bit-parity.
DRIFT_TOL = 1.0


@functools.partial(jax.jit, static_argnames=("params", "num_steps"))
def _legacy_chunk(state, key, xp, xm, params, num_steps: int):
    """Seed-style chunk: variable-length scan (one compile per distinct
    num_steps), no donation, no on-device recording."""
    def body(st, k):
        return engine.step(st, k, xp, xm, params), None

    keys = jax.random.split(key, num_steps)
    state, _ = jax.lax.scan(body, state, keys)
    return state


def _legacy_solve(xp, xm, params, num_iters: int, record: int):
    state = saddle.init_state(xp.shape[0], xm.shape[0], xp.shape[1],
                              None, None)
    key = jax.random.key(0)
    history = []
    done = 0
    while done < num_iters:
        key, sub = jax.random.split(key)
        ns = min(record, num_iters - done)
        state = _legacy_chunk(state, sub, xp, xm, params, ns)
        done += ns
        # blocking host sync per chunk + eager (unjitted) objective
        history.append((done, float(saddle.objective(
            state.log_eta, state.log_xi, xp, xm))))
    return state, history


def _packed_vs_reference(n: int, d: int, block: int, nu_frac: float,
                         iters: int, tag: str, enforce: bool) -> None:
    """Warm per-iteration time of one fused chunk, reference (unpacked,
    two passes per class, sort projection) vs packed (single sweep,
    bisection projection).  Same keys, same sampler, same driver."""
    rng = np.random.default_rng(0)
    n1 = n // 2
    xp = rng.normal(size=(n1, d)).astype(np.float32) * 0.1 + 0.2
    xm = rng.normal(size=(n - n1, d)).astype(np.float32) * 0.1 - 0.2
    nu = nu_frac and 1.0 / (nu_frac * n1)
    params = saddle.make_params(n, d, 1e-3, 0.1, nu=nu, block_size=block)
    xp_j, xm_j = jnp.asarray(xp), jnp.asarray(xm)
    pts = pp.pack_points(xp_j, xm_j)
    key = jax.random.key(0)

    def ref_run():
        st = saddle.init_state(n1, n - n1, d, None, None)
        return engine.run_chunk(st, key, xp_j, xm_j, iters,
                                params=params, chunk_steps=iters)

    def packed_run():
        st = engine.init_packed_state(pts.sign, n1, n - n1, d)
        return engine.run_chunk_packed(st, key, pts.x_t, pts.sign, iters,
                                       params=params, chunk_steps=iters)

    reps = 2 if iters <= 50 else 3          # quick mode: ci smoke budget
    t_ref, _ = timeit(ref_run, repeats=reps)
    t_packed, _ = timeit(packed_run, repeats=reps)
    shape = f"n={n};d={d};B={block};nu={nu:.2e};iters={iters}"
    emit(f"engine/reference_step_{tag}", t_ref / iters, shape)
    speedup = t_ref / t_packed
    emit(f"engine/packed_step_{tag}", t_packed / iters,
         f"{shape};speedup={speedup:.2f}x")
    if tag == "nu_block" and speedup < 1.5:
        # acceptance floor for the packed single-sweep step (typically
        # measures 2-3x on an idle CPU).  Wall-clock ratios are load
        # sensitive, so the quick/ci smoke only WARNS; the full
        # (dedicated perf) run fails.
        msg = f"packed step speedup {speedup:.2f}x < 1.5x floor ({shape})"
        if enforce:
            raise AssertionError(msg)
        print(f"# WARNING: {msg}")


def _driver_data(n: int, d: int, nu_frac: float):
    rng = np.random.default_rng(0)
    n1 = n // 2
    XP = (rng.normal(size=(n1, d)) * 0.1 + 0.2).astype(np.float32)
    XM = (rng.normal(size=(n - n1, d)) * 0.1 - 0.2).astype(np.float32)
    nu = nu_frac and 1.0 / (nu_frac * n1)
    return XP, XM, nu


def _driver_comparison(n: int, d: int, B: int, nu_frac: float,
                       iters: int, record: int, enforce: bool,
                       cold: bool = False) -> None:
    """Seed chunk driver vs fused device-resident driver, end to end:
    same problem, same per-iteration params (block_size=B, same nu),
    same iteration budget and record cadence.  ``iters`` counts BLOCK
    iterations for both (``solve`` gets ``iters * B`` raw so
    resolve_num_iters lands on the same schedule length)."""
    XP, XM, nu = _driver_data(n, d, nu_frac)
    params = saddle.make_params(n, d, 1e-3, 0.1, nu=nu, block_size=B)
    xp_j, xm_j = jnp.asarray(XP), jnp.asarray(XM)
    shape = f"n={n};d={d};B={B};nu={nu:.2e};iters={iters};record={record}"

    def legacy():
        return _legacy_solve(xp_j, xm_j, params, iters, record)

    def fused():
        return saddle.solve(XP, XM, nu=nu, block_size=B,
                            num_iters=iters * B, record_every=record)

    # COLD (full mode only): one solve from empty jit caches.  The seed
    # driver compiles its scan once per distinct chunk length (full
    # chunk + the partial tail); the fused driver compiles its whole-
    # solve while_loop executable once.
    if cold:
        import time as _time

        _legacy_chunk.clear_cache()
        t0 = _time.perf_counter()
        _, hist_l = legacy()
        t_legacy_cold = _time.perf_counter() - t0

        engine.run_solve_slots.clear_cache()
        t0 = _time.perf_counter()
        res = fused()
        jax.block_until_ready(res.state.w)
        t_fused_cold = _time.perf_counter() - t0
        emit("engine/seed_chunk_driver_cold", t_legacy_cold,
             f"{shape};chunks={len(hist_l)};compiles=2_distinct_lengths")
        emit("engine/fused_engine_cold", t_fused_cold,
             f"chunks={len(res.history)};compiles=1;"
             f"speedup={t_legacy_cold / t_fused_cold:.2f}x")

    # WARM: steady-state repeats (compiles cached for both).
    t_legacy, (_, hist_l) = timeit(legacy, repeats=2)
    emit("engine/seed_chunk_driver_warm", t_legacy, shape)

    t_fused, res = timeit(fused, repeats=2)
    gap_ratio = t_legacy / t_fused
    emit("engine/fused_engine_warm", t_fused,
         f"{shape};speedup={gap_ratio:.2f}x")
    emit_count("engine/driver_gap", round(gap_ratio, 4),
               f"fused_over_seed;{shape};floor={DRIVER_GAP_FLOOR}x")

    # sanity: both drivers converge toward the same optimum (their key
    # schedules differ, so stochastic drift is expected).  The drift is
    # a DIMENSIONLESS objective gap -- it must go through emit_count,
    # never emit(), which would relabel it as microseconds.
    drift = abs(hist_l[-1][1] - res.history[-1][1])
    emit_count("engine/final_obj_drift", round(drift, 6),
               f"legacy={hist_l[-1][1]:.6f};fused={res.history[-1][1]:.6f};"
               f"tol={DRIFT_TOL};objective_gap_dimensionless")
    if drift > DRIFT_TOL:
        print(f"# WARNING: legacy-vs-fused final objective drift "
              f"{drift:.4f} exceeds tol {DRIFT_TOL} ({shape})")

    if gap_ratio < DRIVER_GAP_FLOOR:
        msg = (f"end-to-end driver gap {gap_ratio:.2f}x < "
               f"{DRIVER_GAP_FLOOR}x floor ({shape})")
        if enforce:
            raise AssertionError(msg)
        print(f"# WARNING: {msg}")


def _host_vs_device_driver(n: int, d: int, B: int, nu_frac: float,
                           iters: int, record: int) -> None:
    """The tentpole's own contribution, isolated: the SAME fused solve
    under the retained host chunk loop vs the device-resident driver,
    gap off (pure dispatch overhead) and gap on (adds the host loop's
    per-boundary blocking device_get(active); the device driver
    consumes convergence in its while condition instead)."""
    XP, XM, nu = _driver_data(n, d, nu_frac)
    for tag, tol in (("gap_off", 0.0), ("gap_on", 1e-9)):
        t_host, _ = timeit(
            lambda tol=tol: saddle.solve(
                XP, XM, nu=nu, block_size=B, num_iters=iters * B,
                record_every=record, gap_tol=tol, driver="host"),
            repeats=2)
        t_dev, _ = timeit(
            lambda tol=tol: saddle.solve(
                XP, XM, nu=nu, block_size=B, num_iters=iters * B,
                record_every=record, gap_tol=tol, driver="device"),
            repeats=2)
        emit(f"engine/host_loop_driver_{tag}", t_host,
             f"n={n};d={d};B={B};iters={iters};record={record}")
        emit(f"engine/device_loop_driver_{tag}", t_dev,
             f"speedup={t_host / t_dev:.2f}x")


def _slot_chunk_compiled(n_pad: int, d: int, B: int, chunk_steps: int,
                         check_gap: bool):
    """AOT-compile one S=1 slot chunk against ShapeDtypeStructs (no
    device allocation) for the roofline knob predictions."""
    state = jax.eval_shape(lambda: engine.init_slot_state(1, n_pad, d))
    sp = engine.SlotParams(*(jax.ShapeDtypeStruct((1,), jnp.float32)
                             for _ in engine.SlotParams._fields))
    return engine.run_chunk_slots.lower(
        state, jax.ShapeDtypeStruct((1, d, n_pad), jnp.float32),
        jax.ShapeDtypeStruct((1, n_pad), jnp.float32), sp,
        jax.ShapeDtypeStruct((), jnp.int32),
        chunk_steps=chunk_steps, d=d, block_size=B, project=True,
        check_gap=check_gap).compile()


def _tune_knobs() -> None:
    """Predict-then-verify the driver knobs (full mode).

    Block size B: XLA's cost analysis counts a dynamic-trip loop body
    ONCE, so the roofline of a chunk_steps=1 executable is ~one step +
    one boundary; at a fixed total coordinate budget the best B
    minimizes per-COORDINATE time, predicted via
    ``roofline.pick_block_size`` over step_time(B)/B and verified by
    timing real solves at iters*B = const.

    Gap cadence: the boundary check cost is the roofline DELTA between
    the check_gap=True and =False compilations of the same chunk
    (predict) / the timed ``jit(vmap(saddle_gap_packed))`` (verify);
    ``roofline.gap_check_cadence`` turns (step, check, horizon) into
    the pow-2 cadence -- the study behind saddle.GAP_CHECK_EVERY.
    """
    n, d, nu_frac, coords = 20000, 256, 0.8, 12800
    XP, XM, nu = _driver_data(n, d, nu_frac)
    n_pad = pp.packed_length(n)

    pred_per_iter, meas_per_iter = {}, {}
    for B in (32, 64, 128):
        pred_per_iter[B] = roofline.analyze(
            _slot_chunk_compiled(n_pad, d, B, 1, False)).step_time_s
        t_b, _ = timeit(
            lambda B=B: saddle.solve(XP, XM, nu=nu, block_size=B,
                                     num_iters=coords),
            repeats=2)
        meas_per_iter[B] = t_b / (coords // B)
        emit(f"engine/tune_step_B{B}", meas_per_iter[B],
             f"per_iter;coords={coords};"
             f"roofline_pred={pred_per_iter[B] * 1e6:.2f}us")
    pred_b = roofline.pick_block_size(pred_per_iter)
    meas_b = roofline.pick_block_size(meas_per_iter)
    emit_count("engine/tune_block_size", meas_b,
               f"measured_best;predicted_best={pred_b};candidates=32_64_128")
    if pred_b != meas_b:
        print(f"# WARNING: roofline predicts B={pred_b}, measured best "
              f"B={meas_b} (CPU timings vs TPU model -- expected off-target)")

    # gap-check cadence at the serving bucket shape of the quick driver
    # comparison (n_pad=4096, d=128): horizon ~= a typical gap-stop.
    n2, d2, B2 = 4000, 128, 32
    XP2, XM2, nu2 = _driver_data(n2, d2, nu_frac)
    n_pad2 = pp.packed_length(n2)
    pred_check = roofline.delta(
        roofline.analyze(_slot_chunk_compiled(n_pad2, d2, B2, 1, True)),
        roofline.analyze(_slot_chunk_compiled(n_pad2, d2, B2, 1, False)),
    ).step_time_s
    t_solve, _ = timeit(
        lambda: saddle.solve(XP2, XM2, nu=nu2, block_size=B2,
                             num_iters=256 * B2),
        repeats=2)
    step_meas = t_solve / 256
    pts = pp.pack_points_to(jnp.asarray(XP2), jnp.asarray(XM2),
                            n_pad2, d2)
    gap_fn = jax.jit(jax.vmap(engine.saddle_gap_packed))
    w = jnp.zeros((1, d2), jnp.float32)
    nu_v = jnp.full((1,), nu2, jnp.float32)
    check_meas, _ = timeit(
        lambda: gap_fn(w, pts.x_t[None], pts.sign[None], nu_v), repeats=3)
    horizon = 8192
    pred_c = roofline.gap_check_cadence(
        roofline.analyze(
            _slot_chunk_compiled(n_pad2, d2, B2, 1, False)).step_time_s,
        pred_check, horizon)
    meas_c = roofline.gap_check_cadence(step_meas, check_meas, horizon)
    emit("engine/tune_gap_check", check_meas,
         f"per_boundary;roofline_pred={pred_check * 1e6:.2f}us")
    emit_count("engine/tune_gap_cadence", meas_c,
               f"measured;predicted={pred_c};horizon={horizon};"
               f"default={saddle.GAP_CHECK_EVERY}")


# Driver-comparison shapes: quick rides every ci.sh fast; full is the
# enforcing run.  Both sit in the nu>0 block mode -- the regime the
# packed single-sweep step was built for (ISSUE target family).
DRIVER_SHAPE_QUICK = dict(n=4000, d=128, B=32, nu_frac=0.8,
                          iters=403, record=50)
DRIVER_SHAPE_FULL = dict(n=20000, d=256, B=128, nu_frac=0.8,
                         iters=203, record=50)


def run(quick: bool = True) -> None:
    # ---- headline: packed single-sweep step vs reference, warm -------
    # The nu>0 block mode at n=20k, d=256, B=128 is the acceptance
    # target (>= 1.5x); run it in BOTH quick and full so the ci smoke
    # records the trajectory.
    iters = 40 if quick else 200
    _packed_vs_reference(20000, 256, 128, 0.8, iters, "nu_block",
                         enforce=not quick)
    if not quick:
        _packed_vs_reference(20000, 256, 128, 0.0, iters, "hm_block",
                             enforce=False)
        _packed_vs_reference(20000, 256, 1, 0.8, iters, "nu_b1",
                             enforce=False)

    # ---- end-to-end driver comparison (the ISSUE 8 gate) -------------
    # iters % record != 0 keeps a partial final chunk in the measured
    # path.  Quick warns on a floor miss, full fails.
    shape = DRIVER_SHAPE_QUICK if quick else DRIVER_SHAPE_FULL
    _driver_comparison(**shape, enforce=not quick, cold=not quick)

    if not quick:
        # the device-resident loop's own contribution, host vs device
        _host_vs_device_driver(**DRIVER_SHAPE_QUICK)
        # knob study behind the shipped defaults
        _tune_knobs()
