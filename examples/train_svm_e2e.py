"""End-to-end training driver (the paper's kind is SVM training): run
Saddle-SVC at the paper's experimental scale on synthetic data with the
full pipeline -- generation, preprocessing (Hadamard), solver with the
theory-driven iteration budget, evaluation, checkpointing.

    PYTHONPATH=src python examples/train_svm_e2e.py \
        --n 20000 --d 256 --variant nu
"""

import argparse
import time

import numpy as np

from repro.core.svm import SaddleNuSVC, SaddleSVC
from repro.data import synthetic
from repro.train import checkpoint


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument("--variant", choices=("hard", "nu"), default="nu")
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--beta", type=float, default=0.1)
    ap.add_argument("--iters", type=int, default=20000)
    ap.add_argument("--block-size", type=int, default=1,
                    help=">1 enables the beyond-paper TPU block mode")
    ap.add_argument("--ckpt", default="experiments/svm_e2e.npz")
    args = ap.parse_args()

    if args.variant == "hard":
        ds = synthetic.separable(args.n, args.d, seed=0)
        clf = SaddleSVC(eps=args.eps, beta=args.beta,
                        num_iters=args.iters,
                        block_size=args.block_size,
                        record_every=max(args.iters // 10, 1))
    else:
        ds = synthetic.non_separable(args.n, args.d, beta2=0.2, seed=0)
        clf = SaddleNuSVC(alpha=0.85, eps=args.eps, beta=args.beta,
                          num_iters=args.iters,
                          block_size=args.block_size,
                          record_every=max(args.iters // 10, 1))
    tr, te = ds.split(0.1, seed=0)
    print(f"n={len(tr.y)} d={args.d} variant={args.variant} "
          f"block_size={args.block_size}")

    t0 = time.time()
    clf.fit(tr.x, tr.y)
    t = time.time() - t0
    for it, obj in clf.history_:
        print(f"  iter {it:7d}   objective {obj:.6f}")
    print(f"trained in {t:.1f}s   train acc "
          f"{clf.score(tr.x, tr.y):.3f}   test acc "
          f"{clf.score(te.x, te.y):.3f}")

    checkpoint.save(args.ckpt, {"w": clf.w_, "b": np.asarray(clf.b_)})
    print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
