"""Model-zoo training driver: train any --arch (reduced by default so it
runs on this CPU container; pass --full on real hardware) for a few
hundred steps on the synthetic token pipeline.

    PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m \
        --steps 200 --batch 8 --seq 64
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.models import transformer as tf
from repro.train import checkpoint, optimizer as opt, steps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="full (unreduced) config -- real hardware only")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=20,
                           state_dtype=cfg.optimizer_state_dtype)
    state = steps.init_train_state(jax.random.key(0), cfg, ocfg)
    n_params = tf.count_params(state.params)
    print(f"arch={cfg.name} params={n_params:,} "
          f"steps={args.steps} batch={args.batch} seq={args.seq}")

    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=0)
    train_step = jax.jit(steps.make_train_step(cfg, ocfg))

    t0 = time.time()
    for step in range(args.steps):
        nb = pipe.next_batch()
        batch = {"tokens": jnp.asarray(nb.tokens),
                 "targets": jnp.asarray(nb.targets)}
        if cfg.vision_embeds:
            b, s = nb.tokens.shape
            batch["vision_embeds"] = jnp.zeros((b, s, cfg.d_model))
            batch["vision_mask"] = jnp.zeros((b, s), bool)
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, s))
        if cfg.is_encoder_decoder:
            batch["enc_frames"] = jnp.zeros(
                (nb.tokens.shape[0], cfg.enc_frames, cfg.d_model))
        state, metrics = train_step(state, batch)
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"  step {step:5d}  loss {float(metrics['loss']):.4f}"
                  f"  grad_norm {float(metrics['grad_norm']):.3f}"
                  f"  ({(time.time() - t0):.1f}s)")
    if args.ckpt:
        checkpoint.save(args.ckpt, state.params)
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
