"""Serving walkthrough: batched prefill + decode with per-family caches.

Shows the cache footprint difference between a full-KV dense arch, a
sliding-window arch and a recurrent arch at the same history length --
the long_500k story at example scale.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as tf
from repro.serve import engine


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache)
               if hasattr(x, "size"))


def main() -> None:
    prompt_len, gen = 48, 16
    for arch in ("gemma-7b", "h2o-danube-1.8b", "xlstm-125m",
                 "recurrentgemma-2b"):
        cfg = get_config(arch).reduced()
        params = tf.init_lm(jax.random.key(0), cfg)
        prompt = jax.random.randint(jax.random.key(1), (4, prompt_len),
                                    0, cfg.vocab_size)
        t0 = time.time()
        st = engine.prefill(params, cfg, prompt,
                            max_len=prompt_len + gen)
        toks = engine.generate(params, cfg, prompt, steps=gen,
                               temperature=0.8, seed=2)
        dt = time.time() - t0
        kb = cache_bytes(st.cache) / 1024
        kinds = "/".join(sorted(set(cfg.block_pattern)))
        print(f"{arch:20s} blocks={kinds:22s} cache {kb:9.1f} KiB "
              f"({'ring' if cfg.window else 'full' if 'attn' in kinds else 'state'})  "
              f"generated {toks.shape[1]} toks/seq x {toks.shape[0]} seqs "
              f"in {dt:.1f}s")


if __name__ == "__main__":
    main()
