"""LM serving walkthrough: continuous batching with MID-DECODE admission.

Drives the slot-granular LM service (repro.serve.lm_service): requests
arrive STAGGERED while earlier sequences are mid-decode, each is
admitted into a freed (or still-free) KV-cache lane between decode
chunks, and every result is verified TOKEN-FOR-TOKEN against a solo
``engine.generate`` at the same seed -- batching never changes what a
request generates, only when it runs.

Also shows the fallback path: a recurrent-cache arch cannot share
decode lanes (state absorbs prompts order-dependently), so the service
routes its requests through exact solo generation while keeping the
same scheduler queue.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tf
from repro.serve import engine
from repro.serve.lm_service import LMService


def main() -> None:
    cfg = get_config("gemma-7b").reduced()
    params = tf.init_lm(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, cfg.vocab_size, s), steps, seed)
            for s, steps, seed in [(6, 20, 3), (7, 12, 5), (11, 10, 7),
                                   (5, 8, 9)]]

    svc = LMService(params, cfg, num_slots=2, chunk_steps=4, max_len=64)
    print(f"service: arch={cfg.name} slots=2 chunk=4 "
          f"slot_mode={svc.slot_mode}")

    # staggered arrivals: submit two up front, the rest mid-decode
    t0 = time.time()
    rids = [svc.submit(p, steps=n, seed=s) for p, n, s in reqs[:2]]
    results = {}
    for p, n, s in reqs[2:]:
        for res in svc.step():            # decode chunks keep running...
            results[res.request_id] = res
        rids.append(svc.submit(p, steps=n, seed=s))   # ...as work arrives
    results.update(svc.run())
    dt = time.time() - t0

    print(f"\n{'req':>4} {'prompt':>7} {'bucket':>7} {'steps':>6} "
          f"{'admitted@chunk':>14}  solo-parity")
    for rid, (p, n, s) in zip(rids, reqs):
        res = results[rid]
        solo = np.asarray(engine.generate(
            params, cfg, jnp.asarray(p, jnp.int32)[None],
            steps=n, seed=s))[0]
        ok = np.array_equal(res.tokens, solo)
        tag = ("mid-decode" if res.admitted_chunk > 0 else "at start")
        print(f"{rid:>4} {res.prompt_len:>7} {res.bucket:>7} {n:>6} "
              f"{res.admitted_chunk:>4} ({tag:>10})  "
              f"{'EXACT' if ok else 'MISMATCH'}")
        assert ok, (res.tokens, solo)
    tot = sum(n for _, n, _ in reqs)
    print(f"\n{tot} tokens across {len(reqs)} staggered requests in "
          f"{dt:.1f}s; stats={svc.stats}")
    for rid, lat in svc.latencies:
        print(f"  req {rid}: queue-to-result {lat * 1e3:.0f} ms")

    # ---- fallback: recurrent state cannot share decode lanes --------
    cfg_r = get_config("recurrentgemma-2b").reduced()
    params_r = tf.init_lm(jax.random.key(0), cfg_r)
    svc_r = LMService(params_r, cfg_r, num_slots=2, chunk_steps=4)
    prompt = rng.integers(0, cfg_r.vocab_size, 6)
    res = svc_r.generate(prompt, 6, seed=1)
    solo = np.asarray(engine.generate(
        params_r, cfg_r, jnp.asarray(prompt, jnp.int32)[None],
        steps=6, seed=1))[0]
    print(f"\nfallback: arch={cfg_r.name} slot_mode={svc_r.slot_mode} "
          f"solo-parity={'EXACT' if np.array_equal(res.tokens, solo) else 'MISMATCH'}")


if __name__ == "__main__":
    main()
