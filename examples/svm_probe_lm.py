"""The integration example: a nu-SVM probe (Saddle-SVC) trained on
frozen transformer features -- the standard "linear probe on LM
representations" workflow, with the paper's solver as the probe trainer.

Any of the 10 assigned architectures can produce the features
(--arch), demonstrating that the solver layer composes with the whole
model zoo.

    PYTHONPATH=src python examples/svm_probe_lm.py --arch xlstm-125m
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.svm import SaddleNuSVC
from repro.models import transformer as tf


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--n-per-class", type=int, default=100)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = tf.init_lm(jax.random.key(0), cfg)
    print(f"feature producer: {cfg.name} (reduced, "
          f"{tf.count_params(params):,} params)")

    # synthetic "topics": two classes drawing tokens from different
    # vocabulary ranges
    rng = np.random.default_rng(0)
    n = args.n_per_class
    toks_a = rng.integers(0, cfg.vocab_size // 4, size=(n, 24))
    toks_b = rng.integers(cfg.vocab_size // 2, cfg.vocab_size - 1,
                          size=(n, 24))
    toks = jnp.asarray(np.vstack([toks_a, toks_b]), jnp.int32)
    y = np.r_[np.ones(n), -np.ones(n)]

    @jax.jit
    def features(t):
        kw = {}
        if cfg.vision_embeds:
            b, s = t.shape
            kw["vision_embeds"] = jnp.zeros((b, s, cfg.d_model))
            kw["vision_mask"] = jnp.zeros((b, s), bool)
        if cfg.is_encoder_decoder:
            kw["enc_frames"] = jnp.zeros((t.shape[0], cfg.enc_frames,
                                          cfg.d_model))
        logits, _, _ = tf.forward(params, cfg, t, **kw)
        return logits.mean(axis=1)

    feats = np.asarray(features(toks))[:, :128]
    perm = rng.permutation(2 * n)
    split = int(1.6 * n)
    tr, te = perm[:split], perm[split:]

    clf = SaddleNuSVC(alpha=0.6, eps=1e-3, beta=0.1, num_iters=6000)
    clf.fit(feats[tr], y[tr])
    print(f"probe train acc {clf.score(feats[tr], y[tr]):.3f}   "
          f"test acc {clf.score(feats[te], y[te]):.3f}")


if __name__ == "__main__":
    main()
