"""Saddle-DSVC (Algorithm 4): k=20 clients, with the paper's
communication accounting -- and the comparison against distributed
Gilbert (Liu et al.), reproducing the Figure 3 setup.

    PYTHONPATH=src python examples/distributed_svm.py
"""

import jax
import numpy as np

from repro.baselines import dist_gilbert
from repro.core import distributed as dist
from repro.core import preprocess as pp
from repro.data import synthetic

K = 20


def main() -> None:
    ds = synthetic.separable(4000, 128, seed=0)
    xp, xm = ds.x[ds.y > 0], ds.x[ds.y < 0]
    pre = pp.preprocess(xp, xm, jax.random.key(0))
    XP, XM = np.asarray(pre.xp), np.asarray(pre.xm)
    unit = K * XP.shape[1]          # paper unit: k*d scalars

    print(f"n={len(ds.y)} d=128 k={K}   (one comm unit = k*d scalars)")
    print("== Saddle-DSVC (this paper: O(k) scalars/iteration) ==")
    res = dist.solve_distributed(XP, XM, k=K, eps=1e-3, beta=0.1,
                                 num_iters=8000, record_every=2000)
    for it, comm, obj in res.history:
        print(f"  iter {it:6d}  comm {comm / unit:8.1f} units   "
              f"obj {obj:.6f}")

    print("== distributed Gilbert (Liu et al.: O(kd)/iteration) ==")
    st, hist, comm = dist_gilbert.solve(XP, XM, k=K, num_iters=2000,
                                        record_every=500)
    for it, c, obj in hist:
        print(f"  iter {it:6d}  comm {c / unit:8.1f} units   "
              f"obj {obj:.6f}")

    # nu-SVM, the first practical distributed algorithm (paper claim)
    print("== Saddle-DSVC nu-SVM ==")
    nu = 1.0 / (0.85 * min(len(xp), len(xm)))
    res = dist.solve_distributed(XP, XM, k=K, nu=nu, eps=1e-3, beta=0.1,
                                 num_iters=6000, record_every=2000)
    for it, comm, obj in res.history:
        print(f"  iter {it:6d}  comm {comm / unit:8.1f} units   "
              f"obj {obj:.6f}")


if __name__ == "__main__":
    main()
