"""Quickstart: train the paper's two SVM variants on synthetic data.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.baselines import gilbert
from repro.core.svm import SaddleNuSVC, SaddleSVC
from repro.data import synthetic


def main() -> None:
    # --- hard-margin SVM (linearly separable) ------------------------
    ds = synthetic.separable(2000, 64, seed=0)
    tr, te = ds.split(0.2, seed=0)
    clf = SaddleSVC(eps=1e-3, beta=0.1, num_iters=20000)
    clf.fit(tr.x, tr.y)
    print(f"[hard-margin] test acc {clf.score(te.x, te.y):.3f}  "
          f"margin {clf.margin_:.4f}")

    # cross-check against Gilbert (the paper's baseline)
    scale = 1.0 / np.linalg.norm(tr.x, axis=1).max()
    g = gilbert.solve(tr.x[tr.y > 0] * scale, tr.x[tr.y < 0] * scale,
                      num_iters=3000)
    print(f"[hard-margin] gilbert distance "
          f"{np.sqrt(2 * g.history[-1][1]):.4f} (should match margin)")

    # --- nu-SVM (non-separable) --------------------------------------
    ds = synthetic.non_separable(3000, 64, beta2=0.1, seed=1)
    tr, te = ds.split(0.2, seed=0)
    clf = SaddleNuSVC(alpha=0.85, eps=1e-3, beta=0.1, num_iters=10000)
    clf.fit(tr.x, tr.y)
    print(f"[nu-svm]      test acc {clf.score(te.x, te.y):.3f}  "
          f"objective {clf.objective_:.5f}")


if __name__ == "__main__":
    main()
