"""AdamW with mixed-precision discipline:

  * bf16 parameters (what the model computes with),
  * fp32 master copy,
  * (m, v) in a configurable dtype (bf16 for the >=67B configs --
    DESIGN.md notes the single-pod fp32-Adam 236B config does not fit).

Pure-pytree, no optax dependency.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100


class OptState(NamedTuple):
    master: Any      # fp32 copy of params
    m: Any
    v: Any
    step: jax.Array


def init(params, cfg: AdamWConfig) -> OptState:
    sd = jnp.dtype(cfg.state_dtype)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = lambda p: jnp.zeros_like(p, dtype=sd)  # noqa: E731
    return OptState(master=master,
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def apply(grads, opt_state: OptState, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, grad_norm)."""
    sd = jnp.dtype(cfg.state_dtype)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    step = opt_state.step + 1
    lr = _schedule(cfg, opt_state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        update = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        new_master = master - lr * (update + cfg.weight_decay * master)
        return m32.astype(sd), v32.astype(sd), new_master

    m_new, v_new, master_new = [], [], []
    flat_g, tree = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(opt_state.m)
    flat_v = jax.tree.leaves(opt_state.v)
    flat_ma = jax.tree.leaves(opt_state.master)
    for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma):
        mm, vv, nm = upd(g, m, v, ma)
        m_new.append(mm)
        v_new.append(vv)
        master_new.append(nm)
    master_t = jax.tree.unflatten(tree, master_new)
    params_new = jax.tree.map(
        lambda ma, p: ma.astype(p.dtype), master_t, params)
    new_state = OptState(master=master_t,
                         m=jax.tree.unflatten(tree, m_new),
                         v=jax.tree.unflatten(tree, v_new),
                         step=step)
    return params_new, new_state, gnorm
