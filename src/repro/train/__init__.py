"""Training runtime: AdamW, train_step, loop, checkpointing."""
