"""Flat-npz checkpointing for arbitrary pytrees (params / opt states /
solver states).  Paths are '/'-joined tree keys; restore rebuilds into a
reference pytree structure."""

from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    out = {}

    def visit(path, leaf):
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        out["/".join(keys)] = np.asarray(leaf)

    jax.tree_util.tree_map_with_path(visit, tree)
    return out


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(path: str, like):
    """Load into the structure of ``like`` (dtypes preserved from disk)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())
    assert len(keys) == len(leaves_like)
    new_leaves = [jax.numpy.asarray(data[k]) for k in keys]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
