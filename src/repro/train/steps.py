"""train_step / eval_step for the model zoo.

``Batch`` mirrors what input_specs() provides per architecture family:
tokens/targets always; vision embeddings for VLM; encoder frames for
audio.  Loss is next-token CE with the padded-vocab tail masked out.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.train import optimizer as opt

AUX_WEIGHT = 0.01


class TrainState(NamedTuple):
    params: Any
    opt_state: opt.OptState


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  vocab_size: int) -> jax.Array:
    """Mean next-token CE; ignores the padded-vocab tail.

    Deliberately gather-free: the vocab axis is model-sharded, and a
    take_along_axis over a sharded axis makes GSPMD all-gather the full
    fp32 logits (measured: +8 GiB/device on the train_4k dry-run).  The
    iota-mask formulation keeps every op elementwise/reduce, which
    partitions cleanly."""
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (v,), 0)
    if v != vocab_size:
        logits = jnp.where(iota < vocab_size, logits, -1e30)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = iota == targets[..., None]
    picked = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return jnp.mean(lse - picked)


def loss_fn(params, cfg, batch: dict):
    kw = {}
    for k in ("vision_embeds", "vision_mask", "enc_frames", "positions"):
        if k in batch:
            kw[k] = batch[k]
    logits, _, aux = tf.forward(params, cfg, batch["tokens"], **kw)
    ce = cross_entropy(logits, batch["targets"], cfg.vocab_size)
    return ce + AUX_WEIGHT * aux, (ce, aux)


def make_train_step(cfg, opt_cfg: opt.AdamWConfig):
    def train_step(state: TrainState, batch: dict):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, cfg, batch)
        params, opt_state, gnorm = opt.apply(
            grads, state.opt_state, state.params, opt_cfg)
        metrics = {"loss": loss, "ce": ce, "aux": aux,
                   "grad_norm": gnorm}
        return TrainState(params, opt_state), metrics

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        loss, (ce, aux) = loss_fn(params, cfg, batch)
        return {"loss": loss, "ce": ce}
    return eval_step


def init_train_state(key, cfg, opt_cfg: opt.AdamWConfig) -> TrainState:
    params = tf.init_lm(key, cfg)
    return TrainState(params=params, opt_state=opt.init(params, opt_cfg))
