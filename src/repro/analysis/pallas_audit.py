"""Layer 1: static audit of every Pallas kernel program in the repo.

The kernel modules (:mod:`repro.kernels.saddle_update`,
:mod:`repro.kernels.fwht`) build their ``pl.pallas_call`` launches
from ``*_program`` dicts; :func:`registry` maps kernel names to those
SAME builders, so the auditor evaluates the launched BlockSpecs, not a
copy.  For every registered shape case (:func:`audit_cases` -- the
serving bucket rungs plus the per-client dry-run shard shapes of both
production meshes) the auditor CONCRETELY evaluates each index map at
every grid point -- for scalar-prefetched kernels under a family of
adversarial index vectors spanning ``[0, d)`` -- and checks:

BLOCK-001  every selected block lies inside its operand/result shape
COVER-001  every output block is written by at least one grid point
RACE-001   an output block revisited by multiple grid points is a
           declared accumulation (``accum_axes``): the revisit group
           spans exactly the accumulation axes and is constant along
           every other grid axis; anything else is a write-write race
           on TPU's revisit-flush output semantics
VMEM-001   double-buffered blocks + scratch + kernel temporaries fit
           the 16 MiB per-core VMEM budget at 4 bytes/element

Zero findings over :func:`audit_cases` is a CI gate
(``python -m repro.analysis.run``; see scripts/ci.sh).
"""

from __future__ import annotations

import math
from typing import Callable, NamedTuple

import numpy as np

VMEM_BUDGET = 16 * 1024 * 1024     # bytes of VMEM per TensorCore
ELEM_BYTES = 4                     # f32; upper bound for bf16 operands

#: serving bucket rungs: preprocess.bucket_length pads every fit() to
#: 128 * 2^k, so these are exactly the n_pad values the slot engine
#: can launch kernels at (16384 covers the largest CI/bench bucket).
SERVING_RUNGS = tuple(128 * 2 ** k for k in range(8))

DEFAULT_TILE = 1024                # engine launch default (kernels cap it)


class Finding(NamedTuple):
    rule: str          # BLOCK-001 / COVER-001 / RACE-001 / VMEM-001
    kernel: str
    case: str
    detail: str


class AuditCase(NamedTuple):
    kernel: str        # registry key
    case: str          # human-readable shape label
    kwargs: dict       # builder kwargs


def registry() -> dict[str, Callable[..., dict]]:
    """Kernel name -> program builder, covering every pl.pallas_call
    in the repo (grep for ``pallas_call`` when adding a kernel)."""
    from repro.kernels import fwht, saddle_update

    return {
        "momentum_dot": saddle_update.momentum_dot_program,
        "mwu_update": saddle_update.mwu_update_program,
        "momentum_dot_packed": saddle_update.momentum_dot_packed_program,
        "mwu_update_packed": saddle_update.mwu_update_packed_program,
        "fwht": fwht.fwht_program,
    }


# ------------------------------------------------------------- evaluation

def _grid_points(grid: tuple[int, ...]) -> list[np.ndarray]:
    """Flattened coordinate arrays, one (G,) array per grid axis, in
    pallas iteration order (last axis fastest)."""
    mesh = np.meshgrid(*[np.arange(g, dtype=np.int64) for g in grid],
                       indexing="ij")
    return [m.reshape(-1) for m in mesh]


def _eval_index_map(spec, coords: list[np.ndarray],
                    idx: np.ndarray | None) -> np.ndarray:
    """Evaluate a BlockSpec index map at every grid point at once
    (index maps are arithmetic over the grid coordinates, so they
    vectorize over numpy arrays).  Returns (G, block_rank) block
    indices."""
    args = list(coords)
    if idx is not None:
        args.append(idx)
    res = spec.index_map(*args)
    if not isinstance(res, tuple):
        res = (res,)
    g = coords[0].shape[0] if coords else 1
    comps = [np.broadcast_to(np.asarray(c, dtype=np.int64), (g,))
             for c in res]
    return np.stack(comps, axis=1)


def _idx_variants(prog: dict) -> list[tuple[str, np.ndarray | None]]:
    """Adversarial scalar-prefetch vectors: every entry in [0, d),
    exercising the extremes and non-monotone permutation-ish patterns
    of the sampled coordinate block."""
    if not prog["num_scalar_prefetch"]:
        return [("", None)]
    b, d = prog["prefetch_length"], prog["prefetch_bound"]
    ar = np.arange(b, dtype=np.int64)
    return [
        ("idx=zeros", np.zeros(b, dtype=np.int64)),
        ("idx=max", np.full(b, d - 1, dtype=np.int64)),
        ("idx=ramp", ar % d),
        ("idx=reversed", (d - 1 - ar) % d),
        ("idx=strided", (ar * 37 + d // 2) % d),
    ]


def _check_blocks(prog, coords, idx, variant, case, findings) -> None:
    for role, specs, fulls in (
            ("in", prog["in_specs"], prog["in_shapes"]),
            ("out", prog["out_specs"], prog["out_shapes"])):
        for pos, (spec, full) in enumerate(zip(specs, fulls)):
            block = tuple(spec.block_shape)
            binds = _eval_index_map(spec, coords, idx)
            if binds.shape[1] != len(block) or len(block) != len(full):
                findings.append(Finding(
                    "BLOCK-001", prog["name"], case,
                    f"{role}[{pos}]{variant}: index map rank "
                    f"{binds.shape[1]} vs block {block} vs shape {full}"))
                continue
            off = binds * np.asarray(block, dtype=np.int64)
            over = (off < 0) | (off + np.asarray(block) >
                                np.asarray(full, dtype=np.int64))
            if over.any():
                g = int(np.flatnonzero(over.any(axis=1))[0])
                findings.append(Finding(
                    "BLOCK-001", prog["name"], case,
                    f"{role}[{pos}]{variant}: grid point "
                    f"{tuple(int(c[g]) for c in coords)} selects block "
                    f"{tuple(int(v) for v in binds[g])} x {block}, "
                    f"outside shape {full}"))


def _check_outputs(prog, coords, idx, variant, case, findings) -> None:
    grid = prog["grid"]
    for pos, (spec, full) in enumerate(zip(prog["out_specs"],
                                           prog["out_shapes"])):
        block = tuple(spec.block_shape)
        if len(block) != len(full):
            continue                       # already a BLOCK-001
        binds = _eval_index_map(spec, coords, idx)
        space = tuple(-(-f // b) for f, b in zip(full, block))

        # COVER-001: every output block written at least once
        seen = np.zeros(space, dtype=bool)
        inb = ((binds >= 0) &
               (binds < np.asarray(space, dtype=np.int64))).all(axis=1)
        if inb.any():
            seen[tuple(binds[inb].T)] = True
        if not seen.all():
            miss = tuple(int(v) for v in np.argwhere(~seen)[0])
            findings.append(Finding(
                "COVER-001", prog["name"], case,
                f"out[{pos}]{variant}: output block {miss} of {space} "
                "is never written (stale garbage in the result)"))

        # RACE-001: multi-writer blocks must be declared accumulation
        uniq, inverse, counts = np.unique(
            binds, axis=0, return_inverse=True, return_counts=True)
        if counts.max(initial=0) <= 1:
            continue
        accum = tuple(prog["accum_axes"].get(pos, ()))
        expect = int(math.prod(grid[a] for a in accum)) if accum else 1
        multi = counts > 1
        bad = multi & (counts != expect)
        reason = (f"group size != accumulation extent {expect}"
                  if bad.any() else "")
        if not bad.any():
            # the revisit group must be constant along every
            # non-accumulation grid axis (same tile, walked only
            # along the declared axes -> consecutive revisits)
            for ax in range(len(grid)):
                if ax in accum:
                    continue
                lo = np.full(len(uniq), np.iinfo(np.int64).max)
                hi = np.full(len(uniq), np.iinfo(np.int64).min)
                np.minimum.at(lo, inverse, coords[ax])
                np.maximum.at(hi, inverse, coords[ax])
                varies = multi & (lo != hi)
                if varies.any():
                    bad = varies
                    reason = f"revisit group varies along grid axis {ax}"
                    break
        if bad.any():
            blk = tuple(int(v) for v in uniq[np.flatnonzero(bad)[0]])
            n_writers = int(counts[np.flatnonzero(bad)[0]])
            findings.append(Finding(
                "RACE-001", prog["name"], case,
                f"out[{pos}]{variant}: block {blk} written by "
                f"{n_writers} grid points but {reason} "
                f"(accum_axes={accum}) -- write-write race"))


def _check_vmem(prog, case, findings) -> None:
    block_bytes = sum(
        int(math.prod(spec.block_shape)) * ELEM_BYTES
        for spec in (*prog["in_specs"], *prog["out_specs"]))
    total = (2 * block_bytes                     # double-buffered DMA
             + prog["scratch_bytes"] + prog["extra_vmem_bytes"])
    if total > VMEM_BUDGET:
        findings.append(Finding(
            "VMEM-001", prog["name"], case,
            f"per-grid-point VMEM {total} B (2x{block_bytes} blocks + "
            f"{prog['scratch_bytes']} scratch + "
            f"{prog['extra_vmem_bytes']} temps) exceeds "
            f"{VMEM_BUDGET} B budget"))


def audit_program(prog: dict, *, case: str = "") -> list[Finding]:
    """All four checks over one concrete kernel program."""
    findings: list[Finding] = []
    coords = _grid_points(prog["grid"])
    for variant, idx in _idx_variants(prog):
        tag = f" {variant}" if variant else ""
        if idx is not None and (
                (idx < 0).any() or (idx >= prog["prefetch_bound"]).any()):
            raise ValueError("adversarial idx escapes prefetch_bound")
        _check_blocks(prog, coords, idx, tag, case, findings)
        _check_outputs(prog, coords, idx, tag, case, findings)
    _check_vmem(prog, case, findings)
    return findings


# ------------------------------------------------------------- case sweep

def _packed_bs(d: int) -> tuple[int, ...]:
    return tuple(dict.fromkeys((1, 8, min(128, d))))


def audit_cases(*, dryrun_mesh_sizes: tuple[int, ...] = (256, 512),
                ) -> list[AuditCase]:
    """The full shape matrix the gate proves clean: every serving
    bucket rung (times the block sizes the engines launch), the
    per-client dry-run shard shapes of both production meshes, and the
    preprocessing FWHT tiles."""
    from repro.kernels.fwht import auto_tile_n
    from repro.kernels.saddle_update import _packed_tile
    from repro.launch.specs import (SADDLE_DSVC_SHAPES,
                                    saddle_dsvc_client_shape)

    cases: list[AuditCase] = []
    for n_pad in SERVING_RUNGS:
        tile = min(DEFAULT_TILE, n_pad)
        for b in (1, 8, 128):
            kw = dict(n_pad=n_pad, b=b, tile=tile)
            lbl = f"rung n_pad={n_pad} b={b} tile={tile}"
            cases.append(AuditCase("momentum_dot", lbl, dict(kw)))
            cases.append(AuditCase("mwu_update", lbl, dict(kw)))
        ptile = _packed_tile(n_pad, DEFAULT_TILE)
        for d in (32, 256):
            for b in _packed_bs(d):
                kw = dict(n_pad=n_pad, d=d, b=b, tile=ptile)
                lbl = (f"rung n_pad={n_pad} d={d} b={b} tile={ptile}")
                cases.append(AuditCase("momentum_dot_packed", lbl,
                                       dict(kw)))
                cases.append(AuditCase("mwu_update_packed", lbl,
                                       dict(kw)))
    for k in dryrun_mesh_sizes:
        for shape in SADDLE_DSVC_SHAPES.values():
            cs = saddle_dsvc_client_shape(shape, k)
            ptile = _packed_tile(cs["n_pad"], DEFAULT_TILE)
            kw = dict(n_pad=cs["n_pad"], d=cs["d"], b=cs["b"],
                      tile=ptile)
            lbl = (f"dryrun {shape.name} k={k} n_pad={cs['n_pad']} "
                   f"d={cs['d']} b={cs['b']}")
            cases.append(AuditCase("momentum_dot_packed", lbl, dict(kw)))
            cases.append(AuditCase("mwu_update_packed", lbl, dict(kw)))
    for n in (128, 1024, 16384):
        for d in (32, 256, 1024):
            tile_n = min(auto_tile_n(n, d), n)
            cases.append(AuditCase(
                "fwht", f"fwht n={n} d={d} tile_n={tile_n}",
                dict(n_pad=n, d=d, tile_n=tile_n)))
    return cases


def audit_all(cases: list[AuditCase] | None = None,
              ) -> tuple[list[dict], list[Finding]]:
    """Run the full sweep.  Returns (per-case records, findings)."""
    reg = registry()
    if cases is None:
        cases = audit_cases()
    records: list[dict] = []
    findings: list[Finding] = []
    for c in cases:
        prog = reg[c.kernel](**c.kwargs)
        fs = audit_program(prog, case=c.case)
        findings.extend(fs)
        records.append({
            "kernel": c.kernel, "case": c.case,
            "grid": list(prog["grid"]),
            "idx_variants": len(_idx_variants(prog)),
            "findings": len(fs),
        })
    return records, findings
