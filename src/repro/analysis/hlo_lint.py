"""Layer 2: rule-based lint over the AOT-compiled HLO of the hot paths.

Each lint target compiles one production entry point against
ShapeDtypeStruct arguments (zero device allocation beyond compile) and
runs five rules over the optimized module text, via
:mod:`repro.utils.hlo_analysis`:

DONATE-001  donated buffers survive to ``input_output_alias`` -- a
            dropped alias silently doubles the state memory of every
            chunk (the regression class PR 4 fixed by hand)
HOST-001    no infeed/outfeed/send/recv or host/callback custom-calls
            inside any while body -- a host round-trip in the chunk
            loop serializes the device
DTYPE-001   no f64/c128 ops anywhere -- an accidental promotion (x64
            weak types) halves TPU throughput
COMM-001    loop-body collectives are a sub-multiset of the analytic
            ``CommModel`` budget (distributed targets), or absent
            entirely (serial targets) -- Theorem 8's O(k) as a lint
TRIP-001    statically-sized chunk loops carry ``known_trip_count``
            and the number of dynamic-trip whiles matches the design
            (the one num_steps fori_loop; zero for the decode scan)

Findings can only be waived through :data:`SUPPRESSIONS`, each entry
carrying a non-empty justification string; an unsuppressed finding
fails the CI gate (``python -m repro.analysis.run``).
"""

from __future__ import annotations

import re
from typing import Callable, NamedTuple

from repro.utils import hlo_analysis as ha

RULES = {
    "DONATE-001": "donated buffers appear in input_output_alias",
    "HOST-001": "no host transfers inside while bodies",
    "DTYPE-001": "no f64/c128 ops in compiled modules",
    "COMM-001": "loop collectives within the CommModel budget",
    "TRIP-001": "static chunk loops carry known_trip_count",
}


class Finding(NamedTuple):
    rule: str
    target: str
    detail: str


class Suppression(NamedTuple):
    rule: str
    target: str
    justification: str


#: The ONLY way to waive a finding.  Every entry must carry a real
#: justification; an empty one is itself an error (enforced in
#: apply_suppressions), so waivers stay reviewable.
SUPPRESSIONS: tuple[Suppression, ...] = ()


def apply_suppressions(
        findings: list[Finding],
        suppressions: tuple[Suppression, ...] = SUPPRESSIONS,
) -> tuple[list[Finding], list[dict]]:
    """Split findings into (unsuppressed, suppressed-records)."""
    for s in suppressions:
        if not s.justification.strip():
            raise ValueError(
                f"suppression {s.rule}/{s.target} has no justification")
    live, waived = [], []
    for f in findings:
        match = next((s for s in suppressions
                      if s.rule == f.rule and s.target == f.target), None)
        if match is None:
            live.append(f)
        else:
            waived.append({**f._asdict(),
                           "justification": match.justification})
    return live, waived


# ----------------------------------------------------------------- rules

def donated_params(hlo_text: str) -> set[int]:
    """Parameter numbers aliased to outputs in the compiled module
    header (``input_output_alias={ {i}: (p, {...}, may-alias), ... }``,
    balanced-brace scanned)."""
    i = hlo_text.find("input_output_alias=")
    if i < 0:
        return set()
    j = hlo_text.index("{", i)
    depth, k = 0, j
    while True:
        if hlo_text[k] == "{":
            depth += 1
        elif hlo_text[k] == "}":
            depth -= 1
            if depth == 0:
                break
        k += 1
    return {int(m) for m in re.findall(r"\(\s*(\d+)\s*,",
                                       hlo_text[j:k + 1])}


def check_donation(hlo_text: str, target: str,
                   min_donated: int) -> list[Finding]:
    got = len(donated_params(hlo_text))
    if got < min_donated:
        return [Finding(
            "DONATE-001", target,
            f"only {got} parameters aliased to outputs, expected >= "
            f"{min_donated} donated state leaves (donation dropped -> "
            "state memory doubled per chunk)")]
    return []


_HOST_OP_RE = re.compile(
    r"\s(infeed|outfeed|send|recv)(?:-done)?\(")
_CUSTOM_RE = re.compile(r'custom-call.*custom_call_target="([^"]+)"')
_HOST_TARGET_RE = re.compile(r"host|callback|python", re.I)
_CALLEE_RE = re.compile(r"(?:to_apply|body|condition)=%?([\w.\-]+)")


def check_host(hlo_text: str, target: str) -> list[Finding]:
    """Walk every while body (transitively through called
    computations) looking for host transfers."""
    comps = ha.split_computations(hlo_text)
    findings: list[Finding] = []
    seen: set[str] = set()
    stack = [w.body for w in ha.while_records(hlo_text)]
    while stack:
        name = stack.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        for line in comps[name]:
            m = _HOST_OP_RE.search(line)
            if m:
                findings.append(Finding(
                    "HOST-001", target,
                    f"{m.group(1)} inside loop body {name}: "
                    f"{line[:100]}"))
            m = _CUSTOM_RE.search(line)
            if m and _HOST_TARGET_RE.search(m.group(1)):
                findings.append(Finding(
                    "HOST-001", target,
                    f"host custom-call {m.group(1)!r} inside loop "
                    f"body {name}"))
            stack.extend(_CALLEE_RE.findall(line))
    return findings


_WIDE_DTYPE_RE = re.compile(r"\b(f64|c128)\[")


def check_dtype(hlo_text: str, target: str) -> list[Finding]:
    for line in hlo_text.splitlines():
        m = _WIDE_DTYPE_RE.search(line)
        if m:
            return [Finding(
                "DTYPE-001", target,
                f"{m.group(1)} op in compiled module: "
                f"{line.strip()[:100]}")]
    return []


def check_comm_serial(hlo_text: str, target: str) -> list[Finding]:
    recs = ha.collective_records(hlo_text)
    if recs:
        ops = sorted({r.op for r in recs})
        return [Finding(
            "COMM-001", target,
            f"serial target compiles {len(recs)} collectives "
            f"({', '.join(ops)}); expected none")]
    return []


def check_comm_model(hlo_text: str, target: str, model,
                     block_size: int) -> list[Finding]:
    """Measured per-iteration collectives must be a sub-multiset of
    the analytic CommModel prediction (Theorem 8's O(k) budget)."""
    from repro.utils import comm_audit

    counts = comm_audit.audit_hlo(hlo_text, has_step_loop=True)
    predicted = model.collective_multiset(block_size)
    excess = {k: (v, predicted.get(k, 0))
              for k, v in counts.per_iteration.items()
              if v > predicted.get(k, 0)}
    if excess:
        return [Finding(
            "COMM-001", target,
            "per-iteration collectives exceed the CommModel budget: "
            + "; ".join(
                f"{k} measured {v} > budget {b}"
                for k, (v, b) in sorted(excess.items(), key=str)))]
    return []


def check_trips(hlo_text: str, target: str,
                static_trips: tuple[int, ...],
                max_dynamic_whiles: int) -> list[Finding]:
    whiles = ha.while_records(hlo_text)
    known = [w.trip_count for w in whiles if w.trip_count is not None]
    findings = []
    for t in static_trips:
        if t not in known:
            findings.append(Finding(
                "TRIP-001", target,
                f"no while carries known_trip_count={t} (static chunk "
                f"loop lost its bound; known trips: {sorted(known)})"))
    dynamic = sum(1 for w in whiles if w.trip_count is None)
    if dynamic > max_dynamic_whiles:
        findings.append(Finding(
            "TRIP-001", target,
            f"{dynamic} dynamic-trip while loops, design allows "
            f"{max_dynamic_whiles} (the num_steps chunk loop)"))
    return findings


# --------------------------------------------------------------- targets

class LintTarget(NamedTuple):
    name: str
    build: Callable[[], str]          # -> compiled HLO text
    min_donated: int
    comm: object                      # "serial" | (CommModel, block)
    static_trips: tuple[int, ...]
    max_dynamic_whiles: int


def _build_run_chunk_packed() -> str:
    import jax
    import jax.numpy as jnp

    from repro.core import engine, saddle
    from repro.core import preprocess as pp

    n1, n2, d = 500, 460, 256
    params = saddle.make_params(n1 + n2, d, 1e-3, 0.1,
                                nu=1.0 / (0.8 * n1), block_size=128)
    n_pad = pp.packed_length(n1 + n2)
    state = jax.eval_shape(
        lambda: engine.init_packed_state(jnp.ones((n_pad,)), n1, n2, d))
    key = jax.eval_shape(lambda: jax.random.key(0))
    return engine.run_chunk_packed.lower(
        state, key,
        jax.ShapeDtypeStruct((d, n_pad), jnp.float32),
        jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
        params=params, chunk_steps=8).compile().as_text()


def _build_run_chunk_slots() -> str:
    import jax
    import jax.numpy as jnp

    from repro.core import engine

    s, n_pad, d = 2, 256, 32
    state = jax.eval_shape(lambda: engine.init_slot_state(s, n_pad, d))
    sp = engine.SlotParams(*(jax.ShapeDtypeStruct((s,), jnp.float32)
                             for _ in engine.SlotParams._fields))
    return engine.run_chunk_slots.lower(
        state,
        jax.ShapeDtypeStruct((s, d, n_pad), jnp.float32),
        jax.ShapeDtypeStruct((s, n_pad), jnp.float32),
        sp,
        jax.ShapeDtypeStruct((), jnp.int32),
        chunk_steps=4, d=d, block_size=1, project=True,
        check_gap=True).compile().as_text()


def _build_run_solve_slots() -> str:
    import jax
    import jax.numpy as jnp

    from repro.core import engine

    s, n_pad, d = 2, 256, 32
    state = jax.eval_shape(lambda: engine.init_slot_state(s, n_pad, d))
    sp = engine.SlotParams(*(jax.ShapeDtypeStruct((s,), jnp.float32)
                             for _ in engine.SlotParams._fields))
    return engine.run_solve_slots.lower(
        state,
        jax.ShapeDtypeStruct((s, d, n_pad), jnp.float32),
        jax.ShapeDtypeStruct((s, n_pad), jnp.float32),
        sp,
        jax.ShapeDtypeStruct((), jnp.int32),
        chunk_steps=4, num_chunks=3, d=d, block_size=1, project=True,
        check_gap=True).compile().as_text()


def _build_warm_packed_state() -> str:
    import jax
    import jax.numpy as jnp

    from repro.core import engine

    n_pad, d = 256, 32
    return engine.warm_packed_state.lower(
        jax.ShapeDtypeStruct((d, n_pad), jnp.float32),
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        jax.ShapeDtypeStruct((n_pad,), jnp.float32)).compile().as_text()


def _build_sharded_runner(k: int = 8) -> str:
    import jax

    from repro.core.engine import CLIENT_AXIS
    from repro.utils import comm_audit

    fn, args = comm_audit.runner_lowerable(
        comm_audit.client_mesh(k), CLIENT_AXIS, n1=1000, n2=900, d=128,
        nu=1.0 / (0.8 * 1000), block_size=128, chunk_steps=8)
    # donate like distributed.make_sharded_runner does in production
    return jax.jit(fn, donate_argnums=(0,)).lower(
        *args).compile().as_text()


def _build_serve_chunk(k: int, *, sharded: bool) -> str:
    """The mesh-sharded serving slot chunk
    (``engine.run_chunk_slots_sharded`` via the shared comm_audit
    lowering recipe).  Lanes placement: 8 slots spread 1-per-device;
    point-sharded placement: 2 large-n lanes spanning all k devices."""
    from repro.core import preprocess as pp
    from repro.utils import comm_audit

    if sharded:
        n_pad = k * pp.bucket_length(-(-(300 + 280) // k))
        return comm_audit.lower_serve_chunk(
            k, num_slots=2, n_pad=n_pad, d=32, nu=1.0,
            block_size=1, chunk_steps=4, sharded=True)
    return comm_audit.lower_serve_chunk(
        k, num_slots=8, n_pad=pp.bucket_length(100 + 90), d=32, nu=1.0,
        block_size=1, chunk_steps=4, sharded=False)


LM_ARCH = "gemma-7b"      # smallest bucketable (all-attn) config
LM_SLOTS = 2
LM_CHUNK = 4
LM_MAX_LEN = 32


def _lm_structs():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import transformer as tf
    from repro.serve import engine as serve_engine

    cfg = get_config(LM_ARCH).reduced()
    params = jax.eval_shape(lambda: tf.init_lm(jax.random.key(0), cfg))
    toks = jax.ShapeDtypeStruct((1, 8), jnp.int32)
    true_len = jax.ShapeDtypeStruct((), jnp.int32)
    pre = jax.eval_shape(
        lambda p, t, n: serve_engine._prefill_bucketed(
            p, cfg, t, n, max_len=LM_MAX_LEN), params, toks, true_len)
    state = jax.eval_shape(
        lambda p: serve_engine.init_lm_slot_state(p, LM_SLOTS), pre)
    return cfg, params, toks, true_len, state


def _build_prefill_bucketed() -> str:
    from repro.serve import engine as serve_engine

    cfg, params, toks, true_len, _ = _lm_structs()
    return serve_engine._prefill_bucketed.lower(
        params, cfg, toks, true_len,
        max_len=LM_MAX_LEN).compile().as_text()


def _build_decode_chunk_slots() -> str:
    from repro.serve import engine as serve_engine

    cfg, params, _, _, state = _lm_structs()
    return serve_engine.decode_chunk_slots.lower(
        params, state, cfg=cfg, chunk_steps=LM_CHUNK, temperature=0.0,
        max_len=LM_MAX_LEN).compile().as_text()


def _lm_state_leaves() -> int:
    import jax

    return len(jax.tree.leaves(_lm_structs()[4]))


def _comm_model(k: int, nu: float):
    from repro.core import projections
    from repro.core.distributed import CommModel

    rounds = float(projections.BISECT_ROUNDS_SOLVER) if nu > 0 else 0.0
    return CommModel(k=k, nu_rounds_per_iter=rounds)


def _serve_comm_model(k: int, num_slots: int, nu: float):
    from repro.core import projections
    from repro.core.distributed import ServeCommModel

    rounds = float(projections.BISECT_ROUNDS_SOLVER) if nu > 0 else 0.0
    return ServeCommModel(k=k, num_slots=num_slots,
                          nu_rounds_per_iter=rounds)


def default_targets() -> list[LintTarget]:
    """The hot paths linted on every gate run.  Expected counts:
    PackedState has 5 leaves, SlotState 8, the sharded runner donates
    the 5-leaf replicated-state pytree, the warm-start admission step
    donates its 3 carried leaves (w + both dual copies); the decode
    chunk is a static ``scan`` (zero dynamic whiles), the solver chunks
    one dynamic num_steps fori_loop (the whole-solve driver adds the
    outer chunk while, so 2); 24 = projections.BISECT_ROUNDS_SOLVER."""
    from repro.core import projections

    rounds = int(projections.BISECT_ROUNDS_SOLVER)
    return [
        LintTarget("engine.run_chunk_packed", _build_run_chunk_packed,
                   min_donated=5, comm="serial",
                   static_trips=(rounds,), max_dynamic_whiles=1),
        LintTarget("engine.run_chunk_slots", _build_run_chunk_slots,
                   min_donated=8, comm="serial",
                   static_trips=(rounds,), max_dynamic_whiles=1),
        # the device-resident whole-solve driver: the outer
        # while_loop over chunks (dynamic: keyed on budget AND the
        # slot-active flag, so gap stops end it early) plus the inner
        # dynamic num_steps fori inside the chunk body = 2.  HOST-001
        # on this target is the ISSUE 8 regression pin in HLO form:
        # no transfer may survive inside either loop.
        LintTarget("engine.run_solve_slots", _build_run_solve_slots,
                   min_donated=8, comm="serial",
                   static_trips=(rounds,), max_dynamic_whiles=2),
        # the streaming warm-start admission step: w + both dual leaves
        # donated (3) so re-admitting a live tenant allocates nothing
        # new, no loops at all, and -- being host-free -- the re-pack
        # never bounces state through the host between update rounds.
        LintTarget("engine.warm_packed_state", _build_warm_packed_state,
                   min_donated=3, comm="serial",
                   static_trips=(), max_dynamic_whiles=0),
        LintTarget("distributed.sharded_run_fn[k=8]",
                   lambda: _build_sharded_runner(8),
                   min_donated=5,
                   comm=(_comm_model(8, 1.0), 128),
                   static_trips=(rounds,), max_dynamic_whiles=1),
        # the two serving placements of the mesh slot chunk.  Lanes:
        # every device owns whole slots, so the module must compile
        # collective-FREE end to end ("serial" comm even though it runs
        # under shard_map).  Points: 2 big lanes span all 8 devices and
        # the step loop must stay inside the vmap-batched Theorem-8
        # budget (ServeCommModel).
        LintTarget("engine.run_chunk_slots_sharded[lanes,k=8]",
                   lambda: _build_serve_chunk(8, sharded=False),
                   min_donated=8, comm="serial",
                   static_trips=(rounds,), max_dynamic_whiles=1),
        LintTarget("engine.run_chunk_slots_sharded[points,k=8]",
                   lambda: _build_serve_chunk(8, sharded=True),
                   min_donated=8,
                   comm=(_serve_comm_model(8, 2, 1.0), 1),
                   static_trips=(rounds,), max_dynamic_whiles=1),
        LintTarget(f"serve._prefill_bucketed[{LM_ARCH}]",
                   _build_prefill_bucketed,
                   min_donated=0, comm="serial",
                   static_trips=(), max_dynamic_whiles=0),
        LintTarget(f"serve.decode_chunk_slots[{LM_ARCH}]",
                   _build_decode_chunk_slots,
                   min_donated=_lm_state_leaves(), comm="serial",
                   static_trips=(LM_CHUNK,), max_dynamic_whiles=0),
    ]


def dryrun_mesh_targets() -> list[LintTarget]:
    """Production-mesh lowerings of both dry-run shapes (k=256 single
    pod, k=512 multi-pod).  Needs 512 forced host devices
    (run.py --dryrun-meshes sets XLA_FLAGS before importing jax)."""
    import math

    from repro.launch import mesh as mesh_mod
    from repro.launch.specs import (SADDLE_DSVC_SHAPES,
                                    build_saddle_dsvc_lowerable)

    targets = []
    for multi_pod in (False, True):
        mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
        k = int(math.prod(mesh.devices.shape))
        for shape in SADDLE_DSVC_SHAPES.values():

            def build(mesh=mesh, shape=shape):
                import jax

                fn, args, _ = build_saddle_dsvc_lowerable(mesh, shape)
                return jax.jit(fn, donate_argnums=(0,)).lower(
                    *args).compile().as_text()

            nu = 1.0 if shape.nu_frac else 0.0
            trips = ((int(_comm_model(k, nu).nu_rounds_per_iter),)
                     if nu else ())
            targets.append(LintTarget(
                f"dryrun.{shape.name}[k={k}]", build,
                min_donated=5,
                comm=(_comm_model(k, nu), shape.block_size),
                static_trips=trips, max_dynamic_whiles=1))
    return targets


def lint_target(t: LintTarget) -> tuple[dict, list[Finding]]:
    hlo = t.build()
    findings: list[Finding] = []
    findings += check_donation(hlo, t.name, t.min_donated)
    findings += check_host(hlo, t.name)
    findings += check_dtype(hlo, t.name)
    if t.comm == "serial":
        findings += check_comm_serial(hlo, t.name)
    elif t.comm is not None:
        model, block = t.comm
        findings += check_comm_model(hlo, t.name, model, block)
    findings += check_trips(hlo, t.name, t.static_trips,
                            t.max_dynamic_whiles)
    record = {
        "target": t.name,
        "donated": len(donated_params(hlo)),
        "whiles": [w.trip_count for w in ha.while_records(hlo)],
        "collectives": len(ha.collective_records(hlo)),
        "findings": len(findings),
    }
    return record, findings


def lint_all(targets: list[LintTarget] | None = None,
             ) -> tuple[list[dict], list[Finding]]:
    if targets is None:
        targets = default_targets()
    records, findings = [], []
    for t in targets:
        rec, fs = lint_target(t)
        records.append(rec)
        findings.extend(fs)
    return records, findings
