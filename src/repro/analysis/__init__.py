"""Static analysis for the Pallas kernels and the compiled hot paths.

Two layers, one gate (``python -m repro.analysis.run``):

* :mod:`repro.analysis.pallas_audit` -- Layer 1.  A registry of every
  ``pl.pallas_call`` kernel program in the repo, audited by CONCRETE
  evaluation of each BlockSpec index map over the full grid (including
  adversarial scalar-prefetched index vectors spanning ``[0, d)``):
  block bounds (BLOCK-001), output coverage (COVER-001), write-write
  races across grid points (RACE-001) and the per-grid-point VMEM
  footprint against the 16 MiB TPU budget (VMEM-001).

* :mod:`repro.analysis.hlo_lint` -- Layer 2.  A rule-based lint over
  the AOT-lowered (compiled, post-optimization) HLO of the serving /
  distributed hot paths: donation survives to ``input_output_alias``
  (DONATE-001), no host round-trips inside chunk loops (HOST-001), no
  f64 ops (DTYPE-001), loop-body collectives within the analytic
  ``CommModel`` budget (COMM-001), static loops carry
  ``known_trip_count`` (TRIP-001).

Registry contract (how to add a kernel)
---------------------------------------

A kernel module exposes a ``<name>_program(**shape_params) -> dict``
builder, and its ``pl.pallas_call`` launch consumes THAT dict for the
grid, in/out BlockSpecs, out shapes and scratch allocations -- the
auditor then verifies the very objects the launch uses, so the audit
cannot drift from the kernel.  The dict keys:

``name``                  kernel name (registry key)
``grid``                  the pallas grid tuple
``num_scalar_prefetch``   0, or 1 when the index maps take a trailing
                          scalar-prefetched index-vector argument
``prefetch_length``       length of that vector (None when 0)
``prefetch_bound``        exclusive upper bound of its values (None)
``in_shapes``/``out_shapes``  full unblocked operand/result shapes
                          (element counts; the auditor budgets 4
                          bytes/element -- f32, an upper bound for the
                          bf16 variants)
``in_specs``/``out_specs``    the exact pl.BlockSpec lists launched
``scratch_shapes``        pltpu scratch allocations for the launch
``scratch_bytes``         their total byte footprint
``extra_vmem_bytes``      kernel-private temporaries beyond
                          blocks + scratch (butterfly stacks etc.)
``accum_axes``            ``{out position: (grid axes,)}`` along which
                          output-block revisits are declared legal
                          accumulation; any other revisit is RACE-001

Register the builder plus its shape cases in
``pallas_audit.registry()`` / ``pallas_audit.audit_cases()``.
"""
