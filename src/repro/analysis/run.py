"""CI gate: run both analysis layers and fail on unsuppressed findings.

    PYTHONPATH=src python -m repro.analysis.run --json BENCH_analysis.json

Layer 1 (pallas_audit) sweeps every registered kernel program over the
serving bucket rungs AND the per-client dry-run shard shapes of both
production meshes (k=256, k=512) -- pure index-map evaluation, no
devices.  Layer 2 (hlo_lint) compiles the hot paths and lints the
optimized HLO; ``--dryrun-meshes`` additionally lowers the full
production-mesh dry-run entries, which needs 512 forced host devices
-- so XLA_FLAGS is set HERE, before jax is imported (the same pattern
as launch/dryrun.py; jax pins the device count at first init)."""

from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="static kernel + compiled-HLO analysis gate")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the full report to PATH")
    ap.add_argument("--dryrun-meshes", action="store_true",
                    help="also lint the k=256/k=512 production-mesh "
                         "lowerings (slow; forces 512 host devices)")
    ap.add_argument("--skip-hlo", action="store_true",
                    help="Layer 1 only (no compilation)")
    args = ap.parse_args(argv)

    # before ANY jax import: device count is pinned at first init
    n_dev = 512 if args.dryrun_meshes else 8
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", ""))
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from repro.analysis import hlo_lint, pallas_audit

    kernel_records, kernel_findings = pallas_audit.audit_all()
    print(f"[analysis] layer 1: {len(kernel_records)} kernel cases, "
          f"{len(kernel_findings)} findings")

    hlo_records: list[dict] = []
    hlo_findings: list[hlo_lint.Finding] = []
    if not args.skip_hlo:
        targets = hlo_lint.default_targets()
        if args.dryrun_meshes:
            targets += hlo_lint.dryrun_mesh_targets()
        hlo_records, hlo_findings = hlo_lint.lint_all(targets)
        print(f"[analysis] layer 2: {len(hlo_records)} lint targets, "
              f"{len(hlo_findings)} findings")

    all_findings = ([{"rule": f.rule, "target": f.kernel,
                      "case": f.case, "detail": f.detail}
                     for f in kernel_findings]
                    + [dict(f._asdict()) for f in hlo_findings])
    live_hlo, waived = hlo_lint.apply_suppressions(hlo_findings)
    live = len(kernel_findings) + len(live_hlo)

    report = {
        "rules": dict(hlo_lint.RULES,
                      **{"BLOCK-001": "every block in bounds",
                         "COVER-001": "every output block written",
                         "RACE-001": "revisits are declared accumulation",
                         "VMEM-001": "blocks+scratch fit 16 MiB"}),
        "kernel_cases": kernel_records,
        "hlo_targets": hlo_records,
        "findings": all_findings,
        "suppressed": waived,
        "unsuppressed_count": live,
    }
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=1, sort_keys=True)
        print(f"[analysis] report -> {args.json}")

    for f in kernel_findings:
        print(f"FINDING {f.rule} {f.kernel} [{f.case}]: {f.detail}")
    for f in live_hlo:
        print(f"FINDING {f.rule} {f.target}: {f.detail}")
    for w in waived:
        print(f"suppressed {w['rule']} {w['target']}: "
              f"{w['justification']}")

    if live:
        print(f"[analysis] FAIL: {live} unsuppressed findings")
        return 1
    print("[analysis] OK: zero unsuppressed findings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
