"""Shared utilities: HLO collective parsing, roofline math."""
