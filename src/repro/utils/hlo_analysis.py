"""Parse collective traffic out of (post-SPMD, per-device) HLO text.

cost_analysis() reports FLOPs and bytes but NOT collective traffic, so
we scan the partitioned module for all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops and sum their
result-shape bytes (a per-device proxy for link traffic; ring
algorithms move ~(n-1)/n of that per hop, which we fold into the link
bandwidth constant).

Two granularities:

* :func:`collective_stats` -- flat module-wide byte/count totals (the
  roofline view; a collective inside a loop body is counted ONCE).
* the structured view used by :mod:`repro.utils.comm_audit` --
  :func:`collective_records` attributes every collective to its
  enclosing computation and recovers the applied reduction (add/max)
  from the ``to_apply`` region, and :func:`while_records` lists the
  while ops with their body computation and XLA's
  ``known_trip_count`` backend config, so callers can expand loop
  bodies by their real trip counts and report PER-ITERATION counts.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import NamedTuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "f8e4m3b11fnuz": 1, "s2": 1, "u2": 1, "f4e2m1fn": 1, "f8e8m0fnu": 1,
}

# dtypes that legitimately carry no payload bytes (sequencing values)
_ZERO_BYTE_DTYPES = frozenset({"token", "opaque"})

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_parts(shape_str: str) -> list[tuple[str, int]]:
    """(dtype, element_count) per array in ``shape_str`` (tuple shapes
    yield one entry per component; zero-byte token/opaque entries are
    dropped).  An unrecognized dtype is an ERROR, not a skip -- silently
    under-counting a collective's payload would quietly void every
    byte-budget downstream."""
    parts = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype in _ZERO_BYTE_DTYPES:
            continue
        if dtype not in _DTYPE_BYTES:
            raise ValueError(
                f"unknown HLO dtype {dtype!r} in shape {shape_str!r}; "
                "add it to hlo_analysis._DTYPE_BYTES")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        parts.append((dtype, n))
    return parts


def _shape_bytes(shape_str: str) -> int:
    return sum(n * _DTYPE_BYTES[dtype]
               for dtype, n in _shape_parts(shape_str))


class CollectiveStats(NamedTuple):
    bytes_by_op: dict[str, int]
    count_by_op: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    bytes_by = defaultdict(int)
    count_by = defaultdict(int)
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start|-done)?\(", line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if "-done(" in line:
            continue                     # avoid double counting start/done
        b = _shape_bytes(shape_str)
        if b:
            bytes_by[op] += b
            count_by[op] += 1
    return CollectiveStats(dict(bytes_by), dict(count_by))


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\s{re.escape(opname)}\(", hlo_text))


# ==========================================================================
# Structured (per-computation) view, used by the communication audit.
# ==========================================================================

_COMP_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_COLLECTIVE_RE = re.compile(
    r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_WHILE_RE = re.compile(
    r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*.*?\swhile\(")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?"n"\s*:\s*"?(\d+)"?')


class HloCollective(NamedTuple):
    """One collective op, attributed to its enclosing computation."""
    op: str               # all-reduce / all-gather / ...
    reduce_kind: str      # "add" | "max" | "min" | "" (no to_apply)
    elements: int         # total result elements (tuple shapes summed)
    bytes: int            # result-shape bytes (per device)
    computation: str      # name of the enclosing computation


class HloWhile(NamedTuple):
    """One while op: where it lives, its body, and the trip count XLA
    proved (None when dynamic -- e.g. the engine's chunk loop, whose
    trip count is a runtime operand)."""
    computation: str
    body: str
    trip_count: int | None


def split_computations(hlo_text: str) -> dict[str, list[str]]:
    """Map computation name -> its body lines.  HLO text prints one
    computation per ``%name (...) -> ... {`` block; nesting never
    occurs (bodies are separate top-level computations)."""
    comps: dict[str, list[str]] = {}
    current: str | None = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if current is None:
            m = _COMP_HEADER_RE.match(stripped)
            if m:
                current = m.group(2)
                comps[current] = []
        elif stripped == "}":
            current = None
        else:
            comps[current].append(stripped)
    return comps


def entry_computation(hlo_text: str) -> str:
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("ENTRY"):
            m = _COMP_HEADER_RE.match(stripped)
            if m:
                return m.group(2)
    raise ValueError("no ENTRY computation found in HLO text")


def _shape_elements(shape_str: str) -> int:
    return sum(n for _, n in _shape_parts(shape_str))


def _reduce_kind(region_lines: list[str]) -> str:
    text = "\n".join(region_lines)
    for kind, opname in (("add", " add("), ("max", " maximum("),
                         ("min", " minimum(")):
        if opname in text:
            return kind
    return ""


def collective_records(hlo_text: str) -> list[HloCollective]:
    """Every collective op (start/done pairs deduplicated), attributed
    to its computation, with the applied reduction recovered from its
    ``to_apply`` region."""
    comps = split_computations(hlo_text)
    out = []
    for comp, lines in comps.items():
        for line in lines:
            m = _COLLECTIVE_RE.match(line)
            if not m or m.group(3) == "-done":
                continue
            shape_str, op = m.group(1), m.group(2)
            kind = ""
            ta = _TO_APPLY_RE.search(line)
            if ta and ta.group(1) in comps:
                kind = _reduce_kind(comps[ta.group(1)])
            out.append(HloCollective(
                op=op, reduce_kind=kind,
                elements=_shape_elements(shape_str),
                bytes=_shape_bytes(shape_str), computation=comp))
    return out


def while_records(hlo_text: str) -> list[HloWhile]:
    """Every while op: enclosing computation, body computation, and the
    ``known_trip_count`` XLA attached (None when it could not prove
    one -- a dynamic trip count)."""
    out = []
    for comp, lines in split_computations(hlo_text).items():
        for line in lines:
            if not _WHILE_RE.match(line):
                continue
            b = _BODY_RE.search(line)
            if not b:
                continue
            t = _TRIP_RE.search(line)
            out.append(HloWhile(
                computation=comp, body=b.group(1),
                trip_count=int(t.group(1)) if t else None))
    return out
