"""Parse collective traffic out of (post-SPMD, per-device) HLO text.

cost_analysis() reports FLOPs and bytes but NOT collective traffic, so
we scan the partitioned module for all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops and sum their
result-shape bytes (a per-device proxy for link traffic; ring
algorithms move ~(n-1)/n of that per hop, which we fold into the link
bandwidth constant)."""

from __future__ import annotations

import re
from collections import defaultdict
from typing import NamedTuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


class CollectiveStats(NamedTuple):
    bytes_by_op: dict[str, int]
    count_by_op: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    bytes_by = defaultdict(int)
    count_by = defaultdict(int)
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start|-done)?\(", line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if "-done(" in line:
            continue                     # avoid double counting start/done
        b = _shape_bytes(shape_str)
        if b:
            bytes_by[op] += b
            count_by[op] += 1
    return CollectiveStats(dict(bytes_by), dict(count_by))


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\s{re.escape(opname)}\(", hlo_text))
