"""Roofline terms for TPU v5e from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / peak_FLOP/s          (per device)
    memory term     = HLO_bytes / HBM_bw               (per device)
    collective term = collective_bytes / link_bw       (per device)

cost_analysis() and as_text() both describe the post-SPMD per-device
module, so no further division by chip count is needed; the "chips x"
normalization in the brief is already folded in by partitioning.
"""

from __future__ import annotations

from typing import NamedTuple

from repro.utils.hlo_analysis import CollectiveStats, collective_stats

PEAK_FLOPS = 197e12          # bf16 FLOP/s per v5e chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (x ~3 usable links/chip)
ICI_LINKS = 3.0


class Roofline(NamedTuple):
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collectives: CollectiveStats
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower bound assuming perfect overlap."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def mfu(self, model_flops_per_device: float) -> float:
        """model FLOPs utilization against the roofline step time."""
        t = self.step_time_s
        return model_flops_per_device / (t * PEAK_FLOPS) if t else 0.0


def delta(a: Roofline, b: Roofline) -> Roofline:
    """Roofline of the work ``a`` does beyond ``b`` (clamped at 0):
    isolate the cost of an optional stage by differencing two compiled
    variants -- e.g. the per-boundary duality-gap check as
    analyze(chunk with check_gap) - analyze(chunk without)."""
    flops = max(a.flops - b.flops, 0.0)
    hbm = max(a.hbm_bytes - b.hbm_bytes, 0.0)
    coll = max(a.collective_bytes - b.collective_bytes, 0.0)
    return Roofline(
        flops=flops, hbm_bytes=hbm, collective_bytes=coll,
        collectives=a.collectives,
        compute_s=flops / PEAK_FLOPS, memory_s=hbm / HBM_BW,
        collective_s=coll / (ICI_BW * ICI_LINKS))


def pick_block_size(per_iter_s: dict[int, float]) -> int:
    """Choose B from {B: per-iteration cost}: at a FIXED total
    coordinate budget (iters x B held constant) the best block size
    minimizes the per-COORDINATE time step(B) / B.  Works on predicted
    (``Roofline.step_time_s``) and measured costs alike -- the
    predict-then-verify knob study feeds it both and compares."""
    if not per_iter_s:
        raise ValueError("no block-size candidates")
    return min(per_iter_s, key=lambda b: per_iter_s[b] / b)


def gap_check_cadence(step_s: float, check_s: float, total_iters: int,
                      ladder: tuple[int, ...] = (32, 64, 128, 256, 512,
                                                 1024, 2048)) -> int:
    """Choose the duality-gap check cadence c minimizing the expected
    overhead of a run that converges after ~``total_iters`` steps:

        cost(c) = (total_iters / c) * check_s   (boundary evaluations)
                + (c / 2) * step_s              (mean post-convergence
                                                 overshoot to the next
                                                 boundary)

    The unconstrained optimum is sqrt(2 * T * check / step); the ladder
    keeps the choice pow-2 so gap solves share bucket executables.
    Like :func:`pick_block_size` this is cost-source agnostic: feed it
    roofline-predicted times to predict, measured times to verify."""
    if step_s <= 0 or check_s < 0 or total_iters <= 0:
        raise ValueError("costs must be positive")
    return min(ladder, key=lambda c: total_iters / c * check_s
               + 0.5 * c * step_s)


def analyze(compiled, lowered_text: str | None = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = lowered_text or compiled.as_text()
    coll = collective_stats(text)
    return Roofline(
        flops=flops, hbm_bytes=hbm, collective_bytes=coll.total_bytes,
        collectives=coll,
        compute_s=flops / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=coll.total_bytes / (ICI_BW * ICI_LINKS),
    )


def model_flops(cfg, shape_kind: str, tokens: int) -> float:
    """6 * N_active * tokens (training) or 2 * N_active * tokens
    (forward-only: prefill/decode)."""
    n = active_param_count(cfg)
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n * tokens


def active_param_count(cfg) -> float:
    """Approximate active parameters per token (MoE: top-k + shared)."""
    d, l = cfg.d_model, cfg.num_layers
    emb = cfg.padded_vocab * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0.0
    kinds = list(cfg.block_pattern)
    for i in range(l):
        kind = kinds[i % len(kinds)] if i >= cfg.first_dense_layers \
            else kinds[0]
        if kind in ("attn", "local_attn"):
            if cfg.attention_kind == "mla" and kind == "attn":
                lora, rope = cfg.mla_kv_lora, cfg.mla_rope_dim
                vd = cfg.mla_v_dim or cfg.head_dim
                h = cfg.num_heads
                qp = (d * cfg.mla_q_lora
                      + cfg.mla_q_lora * h * (cfg.head_dim + rope)) \
                    if cfg.mla_q_lora else d * h * (cfg.head_dim + rope)
                per_layer += (qp + d * (lora + rope)
                              + lora * h * (cfg.head_dim + vd)
                              + h * vd * d)
            else:
                per_layer += d * cfg.head_dim * (
                    cfg.num_heads * 2 + cfg.num_kv_heads * 2)
            if i < cfg.first_dense_layers or cfg.mlp_kind != "moe":
                per_layer += 3 * d * cfg.d_ff
            else:
                active_e = cfg.moe_top_k + cfg.moe_num_shared
                per_layer += 3 * d * cfg.moe_d_ff * active_e
        elif kind == "rglru":
            w = cfg.rglru_width or d
            per_layer += d * w * 2 + w * w * 2 + w * d + 3 * d * cfg.d_ff
        elif kind == "mlstm":
            inner = int(d * cfg.mlstm_proj_factor)
            per_layer += d * 2 * inner + 3 * inner * inner // max(
                cfg.num_heads, 1) * cfg.num_heads + inner * d
        elif kind == "slstm":
            dh = d // cfg.num_heads
            up = int(d * cfg.slstm_proj_factor)
            per_layer += d * 4 * d + cfg.num_heads * dh * 4 * dh \
                + d * 2 * up + up * d
    if cfg.is_encoder_decoder:
        per_layer += 0  # encoder counted separately below
        enc = cfg.enc_layers * (4 * d * cfg.num_heads * cfg.head_dim
                                + 3 * d * cfg.d_ff)
    else:
        enc = 0
    return emb + per_layer + enc
