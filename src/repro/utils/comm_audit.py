"""Communication audit: Theorem 8 against the collectives XLA emits.

The paper's headline distributed result (Theorem 8) bounds Saddle-DSVC
communication by O~(k(d + sqrt(d/eps))) -- realized here as a CONSTANT
number of tiny all-reduces per iteration (see
:class:`repro.core.distributed.CommModel`).  Until this module, the
repo only *asserted* that via the analytic model; nothing ever counted
the collectives the compiler actually emits, so a regression that
sneaks a per-point all-gather into the shard_map hot loop (the classic
failure mode of sublinear optimization implementations) would pass the
whole suite.

This module closes the loop from theory to compiler output:

* :func:`lower_step` AOT-lowers ONE ``engine.step_packed`` iteration
  under ``shard_map`` on a k-client mesh (ShapeDtypeStructs only -- no
  device allocation) and compiles it to post-SPMD HLO.
* :func:`lower_runner` does the same for the FULL production chunk
  (``distributed.sharded_run_fn``, the multi-pod dry-run path).
* :func:`audit_hlo` parses the compiled module with
  :mod:`repro.utils.hlo_analysis`, expands while bodies by the trip
  counts XLA proved (``known_trip_count``), and returns the measured
  per-iteration / per-chunk collective multisets keyed
  ``(op, reduce_kind, result_elements)`` -- directly comparable to
  ``CommModel.collective_multiset``.
* :func:`run_specs` / :func:`collect_audits` run a batch of audits in
  a subprocess with ``--xla_force_host_platform_device_count`` forced
  high enough for the largest k (jax pins the device count at first
  init, so in-process tests cannot raise it).

The per-iteration boundary in the chunk lowering is structural: the
engine's chunk loop is the ONLY collective-bearing while with a
DYNAMIC trip count (``num_steps`` is a runtime operand), while the
bisection loop inside it carries ``known_trip_count = BISECT_ROUNDS``.
Anything XLA hoists out of the loop (e.g. the once-per-chunk objective
psum) lands in the per-chunk multiset instead.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import NamedTuple

import numpy as np

from repro.utils import hlo_analysis as ha

CHANNEL_SENTINEL = "COMM_AUDIT_JSON="


def _key_str(key: tuple) -> str:
    op, kind, elems = key
    return f"{op}|{kind}|{elems}"


def multiset_to_json(ms: dict) -> dict:
    return {_key_str(k): v for k, v in sorted(ms.items())}


class HloCommCounts(NamedTuple):
    """Collective multisets recovered from one compiled module."""
    per_iteration: dict      # (op, reduce_kind, elements) -> count
    per_chunk: dict          # collectives OUTSIDE the dynamic step loop
    per_iteration_count: int
    per_iteration_bytes: int

    def to_json(self) -> dict:
        return {
            "per_iteration": multiset_to_json(self.per_iteration),
            "per_chunk": multiset_to_json(self.per_chunk),
            "per_iteration_count": self.per_iteration_count,
            "per_iteration_bytes": self.per_iteration_bytes,
        }


def _expand(comp: str, colls_by_comp: dict, whiles_by_comp: dict,
            depth: int = 0) -> dict:
    """Collectives of ``comp`` with every known-trip-count while body
    expanded (body x trip count), recursively.  Returns
    ``(op, reduce_kind, elements) -> [count, bytes]`` -- bytes carry
    the dtype-aware result sizes from hlo_analysis, not an assumed
    element width."""
    if depth > 8:
        raise ValueError("while nesting too deep -- unexpected HLO "
                         "structure, refusing to audit")
    ms: dict = {}

    def bump(key, cnt, nbytes):
        ent = ms.setdefault(key, [0, 0])
        ent[0] += cnt
        ent[1] += nbytes

    for c in colls_by_comp.get(comp, []):
        bump((c.op, c.reduce_kind, c.elements), 1, c.bytes)
    for w in whiles_by_comp.get(comp, []):
        body_ms = _expand(w.body, colls_by_comp, whiles_by_comp,
                          depth + 1)
        if not body_ms:
            continue
        if w.trip_count is None:
            raise ValueError(
                f"collective-bearing while body {w.body} has no "
                "known_trip_count -- cannot expand to per-iteration "
                "counts (unexpected dynamic loop below the step loop)")
        for key, (cnt, nbytes) in body_ms.items():
            bump(key, cnt * w.trip_count, nbytes * w.trip_count)
    return ms


def _counts(ms: dict) -> dict:
    return {key: cnt for key, (cnt, _) in ms.items()}


def _bytes(ms: dict) -> int:
    return sum(nbytes for _, nbytes in ms.values())


def audit_hlo(hlo_text: str, *, has_step_loop: bool) -> HloCommCounts:
    """Measured collective multisets of a compiled module.

    ``has_step_loop=False``: the module IS one iteration (a single
    ``step_packed`` lowering); everything (with known-trip-count whiles
    such as the bisection expanded) is per-iteration, and per_chunk is
    empty.

    ``has_step_loop=True``: the module is a chunk; the unique dynamic
    collective-bearing while is the step loop -- its expanded body is
    the per-iteration multiset, everything outside it per-chunk.
    """
    colls = ha.collective_records(hlo_text)
    whiles = ha.while_records(hlo_text)
    entry = ha.entry_computation(hlo_text)

    colls_by_comp: dict = {}
    for c in colls:
        colls_by_comp.setdefault(c.computation, []).append(c)
    whiles_by_comp: dict = {}
    for w in whiles:
        whiles_by_comp.setdefault(w.computation, []).append(w)

    # sanity: every collective-bearing computation must be reachable
    # from the entry through while bodies (no collectives hidden in
    # call/fusion computations this walk would miss)
    reachable = set()
    stack = [entry]
    while stack:
        comp = stack.pop()
        if comp in reachable:
            continue
        reachable.add(comp)
        stack.extend(w.body for w in whiles_by_comp.get(comp, []))
    hidden = sorted(set(colls_by_comp) - reachable)
    if hidden:
        raise ValueError(
            f"collectives in computations not reachable from entry via "
            f"while bodies: {hidden} -- audit walk would undercount")

    if not has_step_loop:
        per_iter = _expand(entry, colls_by_comp, whiles_by_comp)
        per_chunk: dict = {}
    else:
        def bears_collectives(body):
            if colls_by_comp.get(body):
                return True
            return any(bears_collectives(w.body)
                       for w in whiles_by_comp.get(body, []))

        step_loops = [w for w in whiles_by_comp.get(entry, [])
                      if w.trip_count is None and bears_collectives(w.body)]
        if len(step_loops) != 1:
            raise ValueError(
                f"expected exactly one dynamic collective-bearing while "
                f"(the engine chunk loop), found {len(step_loops)}")
        per_iter = _expand(step_loops[0].body, colls_by_comp,
                           whiles_by_comp)
        # per-chunk = the entry expansion with the step loop removed;
        # any OTHER dynamic collective-bearing while still fails loudly
        # inside _expand
        minus_step = {comp: [w for w in ws if w is not step_loops[0]]
                      for comp, ws in whiles_by_comp.items()}
        per_chunk = _expand(entry, colls_by_comp, minus_step)

    return HloCommCounts(
        per_iteration=_counts(per_iter), per_chunk=_counts(per_chunk),
        per_iteration_count=sum(cnt for cnt, _ in per_iter.values()),
        per_iteration_bytes=_bytes(per_iter))


# ==========================================================================
# Lowering helpers (require >= k jax devices; see collect_audits for the
# subprocess path that forces the host device count).
# ==========================================================================

def client_mesh(k: int):
    """A (k,)-device mesh over the first k local devices, axis name =
    the engine's client axis."""
    import jax
    from repro.core.engine import CLIENT_AXIS

    devs = jax.devices()
    if len(devs) < k:
        raise ValueError(
            f"need {k} devices for a k={k} client mesh, have "
            f"{len(devs)}; run under --xla_force_host_platform_"
            f"device_count (see comm_audit.collect_audits)")
    return jax.sharding.Mesh(np.array(devs[:k]), (CLIENT_AXIS,))


def problem_structs(mesh, axis, *, n1: int, n2: int, d: int):
    """ShapeDtypeStruct stand-ins for the packed sharded problem:
    (state, x_t, sign, key) with dim-0 client sharding."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.core import engine, preprocess

    k = int(np.prod([mesh.shape[a] for a in
                     (axis if isinstance(axis, tuple) else (axis,))]))
    m1, m2 = -(-n1 // k), -(-n2 // k)
    m_pad = preprocess.packed_length(m1 + m2)
    shard = NamedSharding(mesh, P(axis))
    repl = NamedSharding(mesh, P())

    def sds(shape, dtype=jnp.float32, sharding=shard):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)

    state = engine.PackedState(
        w=sds((k, d)), log_lam=sds((k, m_pad)),
        log_lam_prev=sds((k, m_pad)), u=sds((k, m_pad)),
        t=sds((k,), jnp.int32))
    x_t = sds((k, d, m_pad))
    sign = sds((k, m_pad))
    key_aval = jax.eval_shape(lambda: jax.random.key(0))
    key = jax.ShapeDtypeStruct(key_aval.shape, key_aval.dtype,
                               sharding=repl)
    return state, x_t, sign, key, repl


def lower_step(k: int, *, n1: int, n2: int, d: int, nu: float,
               block_size: int = 1, backend: str = "jnp",
               mesh=None, axis=None) -> str:
    """Compile ONE ``engine.step_packed`` iteration under shard_map on a
    k-client mesh and return the post-SPMD HLO text."""
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core import engine, saddle
    from repro.core.engine import CLIENT_AXIS

    mesh = mesh if mesh is not None else client_mesh(k)
    axis = axis if axis is not None else CLIENT_AXIS
    params = saddle.make_params(n1 + n2, d, 1e-3, 0.1, nu=nu,
                                block_size=block_size)
    state, x_t, sign, key, _ = problem_structs(mesh, axis, n1=n1,
                                                n2=n2, d=d)

    def client(st, x_t_c, sign_c, key_r):
        st = jax.tree.map(lambda a: a[0], st)
        st = engine.step_packed(st, key_r, x_t_c[0], sign_c[0], params,
                                axis_name=axis, backend=backend)
        return jax.tree.map(lambda a: a[None], st)

    spec = P(axis)
    fn = shard_map(client, mesh=mesh,
                   in_specs=(spec, spec, spec, P()), out_specs=spec,
                   check_rep=False)
    return jax.jit(fn).lower(state, x_t, sign, key).compile().as_text()


def runner_lowerable(mesh, axis, *, n1: int, n2: int, d: int, nu: float,
                     block_size: int = 1, chunk_steps: int = 8,
                     backend: str = "jnp"):
    """(fn, args) for ``jit(fn).lower(*args)``: the FULL production
    chunk (distributed.sharded_run_fn -- the multi-pod dry-run path)
    over ShapeDtypeStructs.  Single source of the chunk-lowering
    recipe, shared with ``launch.specs.build_saddle_dsvc_lowerable``."""
    import jax
    import jax.numpy as jnp

    from repro.core import distributed, saddle

    params = saddle.make_params(n1 + n2, d, 1e-3, 0.1, nu=nu,
                                block_size=block_size)
    state, x_t, sign, key, repl = problem_structs(mesh, axis, n1=n1,
                                                  n2=n2, d=d)
    num_steps = jax.ShapeDtypeStruct((), jnp.int32, sharding=repl)
    fn = distributed.sharded_run_fn(mesh, axis, backend, params=params,
                                    chunk_steps=chunk_steps)
    return fn, (state, key, x_t, sign, num_steps)


def lower_runner(k: int, *, n1: int, n2: int, d: int, nu: float,
                 block_size: int = 1, chunk_steps: int = 8,
                 backend: str = "jnp", mesh=None, axis=None) -> str:
    """Compile the full production chunk and return its post-SPMD HLO
    text."""
    import jax

    from repro.core.engine import CLIENT_AXIS

    mesh = mesh if mesh is not None else client_mesh(k)
    axis = axis if axis is not None else CLIENT_AXIS
    fn, args = runner_lowerable(mesh, axis, n1=n1, n2=n2, d=d, nu=nu,
                                block_size=block_size,
                                chunk_steps=chunk_steps, backend=backend)
    return jax.jit(fn).lower(*args).compile().as_text()


def serve_structs(mesh, *, num_slots: int, n_pad: int, d: int,
                  slot_axes=(), point_axes=()):
    """ShapeDtypeStruct stand-ins for one serving slot chunk:
    (state, x_t, sign, sp, num_steps) with the placement's
    NamedShardings (slot dim over ``slot_axes``, point dim over
    ``point_axes``) -- the exact argument layout
    ``engine.run_chunk_slots_sharded`` dispatches with."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.core import engine

    s = tuple(slot_axes) or None
    p = tuple(point_axes) or None

    def sds(shape, dtype=jnp.float32, spec=P()):
        return jax.ShapeDtypeStruct(
            shape, dtype, sharding=NamedSharding(mesh, spec))

    key_aval = jax.eval_shape(
        lambda: jax.random.split(jax.random.key(0), num_slots))
    state = engine.SlotState(
        w=sds((num_slots, d), spec=P(s)),
        log_lam=sds((num_slots, n_pad), spec=P(s, p)),
        log_lam_prev=sds((num_slots, n_pad), spec=P(s, p)),
        u=sds((num_slots, n_pad), spec=P(s, p)),
        t=sds((num_slots,), jnp.int32, spec=P(s)),
        max_t=sds((num_slots,), jnp.int32, spec=P(s)),
        key=sds(key_aval.shape, key_aval.dtype, spec=P(s)),
        active=sds((num_slots,), jnp.bool_, spec=P(s)))
    x_t = sds((num_slots, d, n_pad), spec=P(s, None, p))
    sign = sds((num_slots, n_pad), spec=P(s, p))
    sp = engine.SlotParams(*(sds((num_slots,), spec=P(s))
                             for _ in engine.SlotParams._fields))
    num_steps = sds((), jnp.int32)
    return state, x_t, sign, sp, num_steps


def serve_runner_lowerable(mesh, *, num_slots: int, n_pad: int, d: int,
                           nu: float, block_size: int = 1,
                           chunk_steps: int = 8, backend: str = "jnp",
                           slot_axes=(), point_axes=()):
    """(fn, args) for ``jit(fn, donate_argnums=(0,)).lower(*args)``: the
    serving slot chunk (``engine.sharded_slot_run_fn``) over
    ShapeDtypeStructs.  Single source of the serve-chunk lowering
    recipe, shared with ``launch.specs.build_saddle_serve_lowerable``.
    ``project`` follows the service rule (nu > 0)."""
    from repro.core import engine

    fn = engine.sharded_slot_run_fn(
        mesh, slot_axes=tuple(slot_axes), point_axes=tuple(point_axes),
        chunk_steps=chunk_steps, d=d, block_size=block_size,
        project=nu > 0.0, check_gap=False, backend=backend)
    args = serve_structs(mesh, num_slots=num_slots, n_pad=n_pad, d=d,
                         slot_axes=slot_axes, point_axes=point_axes)
    return fn, args


def lower_serve_chunk(k: int, *, num_slots: int, n_pad: int, d: int,
                      nu: float, block_size: int = 1,
                      chunk_steps: int = 8, backend: str = "jnp",
                      sharded: bool, mesh=None) -> str:
    """Compile one serving slot chunk on a k-client mesh and return the
    post-SPMD HLO text.  ``sharded=False`` is the lanes placement (slot
    dim over the mesh, zero collectives anywhere); ``sharded=True`` is
    the point-sharded placement (point dim over the mesh, Theorem-8
    rounds).  ``num_slots``/``n_pad`` are GLOBAL extents."""
    import jax

    mesh = mesh if mesh is not None else client_mesh(k)
    axes = tuple(mesh.axis_names)
    slot_axes, point_axes = ((), axes) if sharded else (axes, ())
    fn, args = serve_runner_lowerable(
        mesh, num_slots=num_slots, n_pad=n_pad, d=d, nu=nu,
        block_size=block_size, chunk_steps=chunk_steps, backend=backend,
        slot_axes=slot_axes, point_axes=point_axes)
    return (jax.jit(fn, donate_argnums=(0,))
            .lower(*args).compile().as_text())


# ==========================================================================
# Spec-driven audits (subprocess-friendly records).
# ==========================================================================

def audit_spec(spec: dict) -> dict:
    """Run one audit spec and return a JSON-able record.

    Spec keys: k, n1, n2, d, nu, block_size (default 1), backend
    (default jnp), runner (bool: also audit the full chunk lowering),
    chunk_steps (runner only, default 8).  ``kind="serve"`` audits a
    serving slot chunk instead (see :func:`audit_serve_spec`): extra
    keys num_slots and sharded (lanes vs point-sharded placement).
    """
    from repro.core import projections
    from repro.core.distributed import CommModel

    if spec.get("kind") == "serve":
        return audit_serve_spec(spec)

    k = int(spec["k"])
    n1, n2, d = int(spec["n1"]), int(spec["n2"]), int(spec["d"])
    nu = float(spec.get("nu", 0.0))
    block_size = int(spec.get("block_size", 1))
    backend = spec.get("backend", "jnp")
    rounds = float(projections.BISECT_ROUNDS_SOLVER) if nu > 0 else 0.0
    model = CommModel(k=k, nu_rounds_per_iter=rounds)
    predicted = model.collective_multiset(block_size)

    hlo = lower_step(k, n1=n1, n2=n2, d=d, nu=nu,
                     block_size=block_size, backend=backend)
    step = audit_hlo(hlo, has_step_loop=False)

    rec = {
        "k": k, "n1": n1, "n2": n2, "d": d, "nu": nu,
        "block_size": block_size, "backend": backend,
        "predicted": multiset_to_json(predicted),
        "measured": multiset_to_json(step.per_iteration),
        "match": step.per_iteration == predicted,
        "per_iteration_count": step.per_iteration_count,
        "per_iteration_bytes": step.per_iteration_bytes,
        "model_collectives": model.collectives_per_iteration(block_size),
        "model_payload_bytes":
            4 * model.payload_elements_per_iteration(block_size),
        "model_scalars": model.scalars_per_iteration(),
    }

    if spec.get("runner"):
        chunk_steps = int(spec.get("chunk_steps", 8))
        rhlo = lower_runner(k, n1=n1, n2=n2, d=d, nu=nu,
                            block_size=block_size,
                            chunk_steps=chunk_steps, backend=backend)
        run = audit_hlo(rhlo, has_step_loop=True)
        rec.update({
            "chunk_steps": chunk_steps,
            "runner_measured": multiset_to_json(run.per_iteration),
            "runner_per_chunk": multiset_to_json(run.per_chunk),
            "runner_match": run.per_iteration == predicted,
            "runner_matches_step":
                run.per_iteration == step.per_iteration,
        })
    return rec


def audit_serve_spec(spec: dict) -> dict:
    """Audit one SERVING slot chunk against :class:`ServeCommModel`.

    Spec keys: kind="serve", k, num_slots (global), n1, n2 (per-slot
    point counts), d, nu, sharded (bool placement switch), block_size
    (default 1), chunk_steps (default 8), backend (default jnp).

    The bucket rule mirrors ``SolverService.submit``: lanes placement
    pads to ``bucket_length(n1 + n2)``; the point-sharded placement to
    ``k * bucket_length(ceil((n1 + n2) / k))`` so every shard holds a
    lane-aligned power-of-2 rung.

    Contract pinned here: the lanes placement compiles to ZERO
    collectives anywhere in the module (``has_step_loop=False``, both
    multisets empty -- slot groups never talk across devices); the
    point-sharded placement's step loop carries EXACTLY
    ``ServeCommModel.collective_multiset`` and its chunk boundary
    EXACTLY ``ServeCommModel.per_chunk_multiset``."""
    from repro.core import preprocess, projections
    from repro.core.distributed import ServeCommModel

    k = int(spec["k"])
    num_slots = int(spec["num_slots"])
    n1, n2, d = int(spec["n1"]), int(spec["n2"]), int(spec["d"])
    nu = float(spec.get("nu", 0.0))
    block_size = int(spec.get("block_size", 1))
    chunk_steps = int(spec.get("chunk_steps", 8))
    backend = spec.get("backend", "jnp")
    sharded = bool(spec["sharded"])

    n = n1 + n2
    if sharded:
        n_pad = k * preprocess.bucket_length(-(-n // k))
        # point-sharded groups keep their full slot extent per device
        s_local = num_slots
        rounds = (float(projections.BISECT_ROUNDS_SOLVER)
                  if nu > 0 else 0.0)
        model = ServeCommModel(k=k, num_slots=s_local,
                               nu_rounds_per_iter=rounds)
        predicted_iter = model.collective_multiset(block_size)
        predicted_chunk = model.per_chunk_multiset(d)
    else:
        n_pad = preprocess.bucket_length(n)
        if num_slots % k:
            raise ValueError(
                f"lanes placement needs k | num_slots, got "
                f"{num_slots} over k={k}")
        model = None
        predicted_iter, predicted_chunk = {}, {}

    hlo = lower_serve_chunk(k, num_slots=num_slots, n_pad=n_pad, d=d,
                            nu=nu, block_size=block_size,
                            chunk_steps=chunk_steps, backend=backend,
                            sharded=sharded)
    # the lanes placement has no collective-bearing while AT ALL -- the
    # step-loop walk would fail to find one, which is exactly the
    # property we pin by auditing the whole module as one flat scope
    counts = audit_hlo(hlo, has_step_loop=sharded)

    rec = {
        "kind": "serve", "k": k, "num_slots": num_slots,
        "n1": n1, "n2": n2, "n_pad": n_pad, "d": d, "nu": nu,
        "block_size": block_size, "chunk_steps": chunk_steps,
        "backend": backend, "sharded": sharded,
        "predicted": multiset_to_json(predicted_iter),
        "measured": multiset_to_json(counts.per_iteration),
        "predicted_per_chunk": multiset_to_json(predicted_chunk),
        "measured_per_chunk": multiset_to_json(counts.per_chunk),
        "match": (counts.per_iteration == predicted_iter
                  and counts.per_chunk == predicted_chunk),
        "per_iteration_count": counts.per_iteration_count,
        "per_iteration_bytes": counts.per_iteration_bytes,
    }
    if model is not None:
        rec.update({
            "model_collectives":
                model.collectives_per_iteration(block_size),
            "model_payload_bytes":
                4 * model.payload_elements_per_iteration(block_size),
        })
    return rec


def run_specs(specs: list[dict]) -> list[dict]:
    return [audit_spec(s) for s in specs]


_SUBPROCESS_CODE = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=" + sys.argv[1])
os.environ["JAX_PLATFORMS"] = "cpu"
sys.path.insert(0, sys.argv[2])
from repro.utils import comm_audit
specs = json.loads(sys.stdin.read())
recs = comm_audit.run_specs(specs)
print(comm_audit.CHANNEL_SENTINEL + json.dumps(recs))
"""


def collect_audits(specs: list[dict], *, device_count: int | None = None,
                   timeout: int = 900) -> list[dict]:
    """Run a batch of audit specs in a fresh subprocess with the host
    device count forced to max(k) (jax locks the device count at first
    init, so the calling process usually cannot lower k-client meshes
    itself).  Returns the list of :func:`audit_spec` records."""
    if not specs:
        return []
    devs = device_count or max(int(s["k"]) for s in specs)
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_CODE, str(devs), src],
        input=json.dumps(specs), capture_output=True, text=True,
        timeout=timeout)
    for line in out.stdout.splitlines():
        if line.startswith(CHANNEL_SENTINEL):
            return json.loads(line[len(CHANNEL_SENTINEL):])
    raise RuntimeError(
        f"comm audit subprocess produced no result (exit "
        f"{out.returncode}):\n{out.stdout[-2000:]}\n{out.stderr[-4000:]}")
