"""DeepSeek-V2 (236B) [arXiv:2405.04434] -- MLA + 2 shared / 160 routed
experts top-6.  Optimizer m/v kept in bf16 (DESIGN.md): fp32 Adam states
for 236B do not fit a single 256-chip v5e pod."""

from repro.configs.base import ModelConfig, register


@register
def deepseek_v2_236b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        citation="arXiv:2405.04434 (DeepSeek-V2)",
        num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
        head_dim=128, d_ff=12288, vocab_size=102400,
        attention_kind="mla", rope_kind="full",
        mla_kv_lora=512, mla_q_lora=1536, mla_rope_dim=64, mla_v_dim=128,
        mlp_kind="moe", moe_num_experts=160, moe_top_k=6,
        moe_num_shared=2, moe_d_ff=1536, first_dense_layers=1,
        optimizer_state_dtype="bfloat16",
    )
