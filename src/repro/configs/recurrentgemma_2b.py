"""RecurrentGemma-2B [arXiv:2402.19427] (Griffin) -- RG-LRU + local
attention, pattern (recurrent, recurrent, local-attn), MQA kv=1."""

from repro.configs.base import ModelConfig, register


@register
def recurrentgemma_2b() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        citation="arXiv:2402.19427 (Griffin / RecurrentGemma)",
        num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
        head_dim=256, d_ff=7680, vocab_size=256000,
        mlp_kind="geglu", rope_kind="full",
        block_pattern=("rglru", "rglru", "local_attn"),
        local_window=2048, rglru_width=2560,
        emb_scale=True, tie_embeddings=True,
    )
