"""DeepSeek-V2-Lite (16B) [arXiv:2405.04434] -- MLA (no q-lora),
2 shared / 64 routed experts top-6."""

from repro.configs.base import ModelConfig, register


@register
def deepseek_v2_lite_16b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        citation="arXiv:2405.04434 (DeepSeek-V2)",
        num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=10944, vocab_size=102400,
        attention_kind="mla", rope_kind="full",
        mla_kv_lora=512, mla_q_lora=0, mla_rope_dim=64, mla_v_dim=128,
        mlp_kind="moe", moe_num_experts=64, moe_top_k=6,
        moe_num_shared=2, moe_d_ff=1408, first_dense_layers=1,
    )
