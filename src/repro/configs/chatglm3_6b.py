"""ChatGLM3-6B [arXiv:2406.12793] -- GQA kv=2, 2d (half-dim) RoPE."""

from repro.configs.base import ModelConfig, register


@register
def chatglm3_6b() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense",
        citation="arXiv:2406.12793 (ChatGLM)",
        num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
        head_dim=128, d_ff=13696, vocab_size=65024,
        attention_kind="gqa", rope_kind="partial", rope_fraction=0.5,
        mlp_kind="swiglu",
    )
