"""Architecture configs.  ``get_config(name)`` resolves any assigned
architecture id (plus variants) to a ModelConfig."""

from repro.configs.base import (ModelConfig, get_config, list_configs,
                                register)

__all__ = ["ModelConfig", "get_config", "list_configs", "register"]
