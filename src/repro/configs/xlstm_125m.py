"""xLSTM-125M [arXiv:2405.04517] -- alternating mLSTM / sLSTM blocks.

The blocks carry their own up/down projections (d_ff=0: no separate
FFN), matching the paper's pre-up-projection mLSTM and post-up sLSTM."""

from repro.configs.base import ModelConfig, register


@register
def xlstm_125m() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        citation="arXiv:2405.04517 (xLSTM)",
        num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
        head_dim=192, d_ff=0, vocab_size=50304,
        rope_kind="none",
        block_pattern=("mlstm", "slstm"),
        mlstm_proj_factor=2.0, slstm_proj_factor=4.0 / 3.0,
    )
