"""Whisper-medium [arXiv:2212.04356] -- encoder-decoder.  The
mel-spectrogram + conv frontend is a STUB per the brief: input_specs
provides 1500 precomputed frame embeddings of width d_model."""

from repro.configs.base import ModelConfig, register


@register
def whisper_medium() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="audio",
        citation="arXiv:2212.04356 (Whisper)",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        head_dim=64, d_ff=4096, vocab_size=51865,
        rope_kind="none",                 # sinusoidal positions
        is_encoder_decoder=True, enc_layers=24, enc_frames=1500,
    )
