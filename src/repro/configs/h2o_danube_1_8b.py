"""H2O-Danube-1.8B [arXiv:2401.16818] -- llama/mistral mix with
sliding-window attention (the mistral-style 4096 window)."""

from repro.configs.base import ModelConfig, register


@register
def h2o_danube_1_8b() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        citation="arXiv:2401.16818 (H2O-Danube)",
        num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
        head_dim=80, d_ff=6912, vocab_size=32000,
        mlp_kind="swiglu", rope_kind="full", window=4096,
    )
