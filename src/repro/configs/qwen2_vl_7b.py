"""Qwen2-VL-7B backbone [arXiv:2409.12191]."""

from repro.configs.base import ModelConfig, register


@register
def qwen2_vl_7b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        citation="arXiv:2409.12191 (Qwen2-VL)",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        head_dim=128, d_ff=18944, vocab_size=152064,
        attention_kind="gqa", rope_kind="mrope", rope_theta=1e6,
        mlp_kind="swiglu",
        vision_embeds=True, num_patches=1024,
    )
