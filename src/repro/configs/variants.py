"""Variant configs beyond the assigned list.

gemma-7b-swa: gemma-7b with a 4096 sliding window -- the explicit
dense->SWA path that licenses the long_500k shape for a dense arch
(DESIGN.md shape-applicability)."""

import dataclasses

from repro.configs.base import register
from repro.configs.gemma_7b import gemma_7b


@register
def gemma_7b_swa():
    return dataclasses.replace(gemma_7b(), name="gemma-7b-swa",
                               window=4096)
