"""ModelConfig: one declarative description per architecture.

Every assigned architecture registers itself via :func:`register`; the
launcher resolves ``--arch <id>`` with :func:`get_config`.  Each config
cites its source in ``citation``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

_REGISTRY: dict[str, Callable[[], "ModelConfig"]] = {}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    citation: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- attention ---
    attention_kind: str = "gqa"    # gqa | mla
    rope_kind: str = "full"        # full | partial | mrope | none
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0     # fraction of head_dim rotated
    window: int = 0                # sliding-window size (0 = full attn)
    logits_softcap: float = 0.0
    qk_norm: bool = False
    # --- MLA (deepseek-v2) ---
    mla_kv_lora: int = 0
    mla_q_lora: int = 0
    mla_rope_dim: int = 0
    mla_v_dim: int = 0
    # --- mlp ---
    mlp_kind: str = "swiglu"       # swiglu | geglu | moe
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_d_ff: int = 0              # per-(routed)-expert hidden
    moe_capacity_factor: float = 1.25
    first_dense_layers: int = 0    # deepseek-v2: layer 0 is dense FFN
    # --- block pattern (period repeated over layers) ---
    block_pattern: tuple[str, ...] = ("attn",)
    local_window: int = 2048       # window for "local_attn" blocks
    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    enc_frames: int = 0
    # --- vlm ---
    vision_embeds: bool = False
    num_patches: int = 1024
    # --- ssm / hybrid ---
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    rglru_width: int = 0           # recurrence width (0 -> d_model)
    conv1d_width: int = 4
    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    emb_scale: bool = False        # gemma: embeddings * sqrt(d_model)
    max_seq_len: int = 1 << 20
    # layer-stack lowering: scan (compact HLO; XLA cost_analysis counts
    # the body ONCE) vs unrolled (accurate per-step costs for roofline)
    scan_layers: bool = True
    # --- sharding knobs (EXPERIMENTS.md section Perf) ---
    # fsdp_params=False -> ZeRO-2: compute weights replicated over the
    # FSDP axes, optimizer states stay sharded (one gather per step)
    fsdp_params: bool = True
    # embed_fsdp=False -> embedding/lm_head sharded over vocab only
    embed_fsdp: bool = True
    # shard_acts=False -> keep the residual stream replicated across
    # 'model' at layer boundaries (skip the act_embed constraint).
    # Right when L x B_loc x S x D x 2B of scan checkpoints fits HBM;
    # saves the per-layer x all-gather/reduce-scatter round trips.
    shard_acts: bool = True
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    optimizer_state_dtype: str = "float32"   # bf16 for the huge configs

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (clean 16-way sharding)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    def supports_long_context(self) -> bool:
        """True iff serve cost per token is sub-linear in history
        (recurrent state or bounded sliding window)."""
        kinds = set(self.block_pattern)
        recurrent = kinds & {"mlstm", "slstm", "rglru"}
        attn_kinds = kinds & {"attn", "local_attn"}
        if "attn" in attn_kinds and self.window == 0:
            return False
        return bool(recurrent) or self.window > 0 or \
            attn_kinds <= {"local_attn"}

    def decode_supported(self) -> bool:
        return True   # all assigned archs are decoders (whisper: dec side)

    def reduced(self, *, layers: int = 2, d_model: int | None = None,
                vocab: int = 512, experts: int = 4) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        period = self.pattern_period
        nl = max(layers, period)
        nl = -(-nl // period) * period
        dm = min(self.d_model, d_model or 256)
        heads = max(1, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, heads))
        hd = max(8, dm // heads)
        changes = dict(
            num_layers=nl, d_model=dm, num_heads=heads, num_kv_heads=kv,
            head_dim=hd, d_ff=max(8, dm * 2), vocab_size=vocab,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            enc_frames=min(self.enc_frames, 64) if self.enc_frames else 0,
            num_patches=min(self.num_patches, 16),
            moe_num_experts=min(self.moe_num_experts, experts)
            if self.moe_num_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            moe_num_shared=min(self.moe_num_shared, 1)
            if self.moe_num_shared else 0,
            moe_d_ff=min(self.moe_d_ff, dm) if self.moe_d_ff else 0,
            mla_kv_lora=min(self.mla_kv_lora, 32) if self.mla_kv_lora else 0,
            mla_q_lora=min(self.mla_q_lora, 32) if self.mla_q_lora else 0,
            mla_rope_dim=min(self.mla_rope_dim, hd // 2)
            if self.mla_rope_dim else 0,
            mla_v_dim=hd if self.mla_v_dim else 0,
            rglru_width=min(self.rglru_width, dm) if self.rglru_width else 0,
            window=min(self.window, 64) if self.window else 0,
            local_window=min(self.local_window, 32),
            first_dense_layers=min(self.first_dense_layers, 1),
            param_dtype="float32", compute_dtype="float32",
            max_seq_len=4096,
        )
        return dataclasses.replace(self, **changes)


def register(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # import every per-arch module once so registrations run
    import importlib
    for mod in ("qwen2_vl_7b", "chatglm3_6b", "xlstm_125m",
                "recurrentgemma_2b", "deepseek_v2_236b",
                "deepseek_v2_lite_16b", "gemma_7b", "deepseek_67b",
                "whisper_medium", "h2o_danube_1_8b", "variants"):
        importlib.import_module(f"repro.configs.{mod}")
