"""Gemma-7B [arXiv:2403.08295] -- GeGLU, head_dim=256, vocab 256k."""

from repro.configs.base import ModelConfig, register


@register
def gemma_7b() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense",
        citation="arXiv:2403.08295 (Gemma)",
        num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
        head_dim=256, d_ff=24576, vocab_size=256000,
        mlp_kind="geglu", rope_kind="full",
        emb_scale=True, tie_embeddings=True,
    )
