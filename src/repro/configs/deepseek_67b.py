"""DeepSeek-67B [arXiv:2401.02954] -- llama-arch, 95 layers, GQA kv=8."""

from repro.configs.base import ModelConfig, register


@register
def deepseek_67b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b", family="dense",
        citation="arXiv:2401.02954 (DeepSeek LLM)",
        num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=22016, vocab_size=102400,
        mlp_kind="swiglu", rope_kind="full",
        optimizer_state_dtype="bfloat16",
    )
