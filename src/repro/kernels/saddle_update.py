"""Pallas TPU kernels for the Saddle-SVC per-iteration hot loop.

Theorem 6's O(n)-per-iteration bound comes from two passes over the n
points; these kernels fuse each pass into a single VMEM-resident sweep:

  * ``momentum_dot``  (lines 2-3 of Algorithm 2):
        delta = cols^T (lam + theta (lam - lam_prev))
    one read of (cols, log_lam, log_lam_prev) per tile; emits per-tile
    partial sums that the host-side wrapper reduces.

  * ``mwu_update``    (lines 5-6 + the incremental u maintenance):
        u_new    = u + cols @ dw
        log_new  = c ((d_eff/tau) log_lam - sign (u + d_eff (cols @ dw)))
    plus per-tile (max, sum-exp) partials so the simplex normalizer
    (one logsumexp) is computed without a second pass over HBM.

Both kernels take cols of shape (n, B): B = 1 is the paper-faithful
single-coordinate mode; B = 128 is the beyond-paper lane-aligned block
mode where the inner product becomes an MXU matvec.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _momentum_dot_kernel(cols_ref, log_lam_ref, log_prev_ref, theta_ref,
                         part_ref):
    cols = cols_ref[...]                          # (TILE, B)
    lam = jnp.exp(log_lam_ref[...])               # (TILE,)
    lam_prev = jnp.exp(log_prev_ref[...])
    theta = theta_ref[0]
    mom = lam + theta * (lam - lam_prev)
    part_ref[...] = (cols * mom[:, None]).sum(axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def momentum_dot(cols: jax.Array, log_lam: jax.Array, log_prev: jax.Array,
                 theta: jax.Array, *, tile: int = 1024,
                 interpret: bool = True) -> jax.Array:
    """delta (B,) = cols^T (lam + theta (lam - lam_prev)), tiled over n."""
    n, b = cols.shape
    tile = min(tile, max(n, 1))
    pad = (-n) % tile
    if pad:
        cols = jnp.pad(cols, ((0, pad), (0, 0)))
        log_lam = jnp.pad(log_lam, (0, pad), constant_values=NEG)
        log_prev = jnp.pad(log_prev, (0, pad), constant_values=NEG)
    grid = (cols.shape[0] // tile,)
    theta = jnp.asarray(theta, cols.dtype).reshape(1)
    parts = pl.pallas_call(
        _momentum_dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], b), cols.dtype),
        interpret=interpret,
    )(cols, log_lam, log_prev, theta)
    return parts.sum(axis=0)


def _mwu_kernel(cols_ref, log_lam_ref, u_ref, dw_ref, scal_ref,
                log_new_ref, u_new_ref, pmax_ref, psum_ref):
    cols = cols_ref[...]                          # (TILE, B)
    log_lam = log_lam_ref[...]                    # (TILE,)
    u = u_ref[...]
    dw = dw_ref[...]                              # (B,)
    sign, gamma, tau, d_eff = (scal_ref[0], scal_ref[1], scal_ref[2],
                               scal_ref[3])
    dv = cols @ dw                                # MXU matvec when B=128
    v = sign * (u + d_eff * dv)
    c = 1.0 / (gamma + d_eff / tau)
    log_new = c * ((d_eff / tau) * log_lam - v)
    u_new_ref[...] = u + dv
    log_new_ref[...] = log_new
    tile_max = jnp.max(log_new)
    pmax_ref[...] = tile_max.reshape(1)
    psum_ref[...] = jnp.sum(jnp.exp(log_new - tile_max)).reshape(1)


@functools.partial(jax.jit,
                   static_argnames=("tile", "interpret", "normalize"))
def mwu_update(cols: jax.Array, log_lam: jax.Array, u: jax.Array,
               dw: jax.Array, sign: jax.Array, gamma: jax.Array,
               tau: jax.Array, d_eff: jax.Array, *, tile: int = 1024,
               interpret: bool = True, normalize: bool = True):
    """Fused dual update.  Returns (log_new_normalized, u_new), or --
    with ``normalize=False`` -- (log_new_unnormalized, u_new, m, s)
    where lse = m + log(s), so a caller can combine the normalizer
    partials across clients (distributed rounds 2-3) before applying."""
    n, b = cols.shape
    tile = min(tile, max(n, 1))
    pad = (-n) % tile
    if pad:
        cols = jnp.pad(cols, ((0, pad), (0, 0)))
        log_lam = jnp.pad(log_lam, (0, pad), constant_values=NEG)
        u = jnp.pad(u, (0, pad))
    npad = cols.shape[0]
    grid = (npad // tile,)
    scal = jnp.stack([jnp.asarray(s, cols.dtype)
                      for s in (sign, gamma, tau, d_eff)])
    log_new, u_new, pmax, psum = pl.pallas_call(
        _mwu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), cols.dtype),
            jax.ShapeDtypeStruct((npad,), cols.dtype),
            jax.ShapeDtypeStruct((grid[0],), cols.dtype),
            jax.ShapeDtypeStruct((grid[0],), cols.dtype),
        ],
        interpret=interpret,
    )(cols, log_lam, u, dw, scal)
    # combine per-tile (max, sumexp) partials into the global logsumexp
    m = jnp.max(pmax)
    s = jnp.sum(psum * jnp.exp(pmax - m))
    if not normalize:
        return log_new[:n], u_new[:n], m, s
    return (log_new - (m + jnp.log(s)))[:n], u_new[:n]
