"""Pallas TPU kernels for the Saddle-SVC per-iteration hot loop.

Theorem 6's O(n)-per-iteration bound comes from two passes over the n
points.  The PACKED kernels (``momentum_dot_packed``/``mwu_update_packed``
-- the ones the single-sweep engine launches, 2 launches per step) run
each pass ONCE over both classes: the operand is the packed layout of
:func:`repro.core.preprocess.pack_points` -- one lane-padded point set
with a +-1 ``sign`` vector -- and the sampled coordinate block is
gathered INSIDE the kernel from the raw column-major mirror ``x_t``
(d, n_pad) via scalar-prefetched block indices
(``pltpu.PrefetchScalarGridSpec``): grid dimension j walks the b block
coordinates, and the BlockSpec index map ``(i, j, idx) -> (idx[j], i)``
DMAs one CONTIGUOUS (1, tile) row slice per step.  No (n, B) ``cols``
intermediate is ever materialized.

  * ``momentum_dot_packed``  (lines 2-3 of Algorithm 2, both classes):
        delta = sum_i sign_i (lam_i + theta (lam_i - lam_prev_i)) x_t[idx, i]
    The sign folds the paper's delta+ - delta- difference into one sweep;
    the signed momentum weights are computed once per tile (at j == 0)
    into VMEM scratch and reused for all b block rows.

  * ``mwu_update_packed``    (lines 5-6 + incremental u, both classes):
        dv accumulates rank-1 over the j grid dimension in VMEM scratch;
        at j == b-1 the tile emits u_new, the unnormalized log weights,
        and PER-CLASS (max, sum-exp) normalizer partials -- the two
        simplex logsumexps come out of the same sweep, masked by sign.

The unpacked per-class kernels (``momentum_dot``/``mwu_update``, 4
launches per step over materialized (n, B) cols) are retained as the
reference/legacy path the packed engine is parity-tested against.

B = 1 is the paper-faithful single-coordinate mode; B = 128 is the
beyond-paper lane-aligned block mode where the inner product becomes an
MXU matvec.

Every ``pl.pallas_call`` here builds its grid/BlockSpecs through a
``*_program`` builder (the registry contract of
:mod:`repro.analysis.pallas_audit`): the builder returns the EXACT grid,
in/out specs, shapes, scratch and accumulation metadata the launch uses,
so the static auditor proves properties of the real kernel programs, not
of a parallel description that could drift.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import default_interpret

NEG = -1e30

F32_BYTES = 4


def _check_tiling(n_pad: int, tile: int) -> None:
    if tile <= 0 or n_pad % tile:
        raise ValueError(
            f"tile {tile} must evenly divide padded length {n_pad}")


# ==========================================================================
# Program builders -- single source of truth for grid + BlockSpecs.
#
# Each returns a dict (a "kernel program") consumed BOTH by the
# pallas_call launch below and by repro.analysis.pallas_audit:
#   grid                 -- pallas grid tuple
#   num_scalar_prefetch  -- 0, or 1 when index maps take a prefetched idx
#   prefetch_length/bound-- idx vector length b and exclusive value bound d
#   in_shapes/out_shapes -- full (unblocked) operand/result shapes
#   in_specs/out_specs   -- the pl.BlockSpec lists passed to pallas_call
#   scratch_shapes       -- pltpu scratch allocations for the launch
#   scratch_bytes        -- their total VMEM footprint
#   extra_vmem_bytes     -- kernel-private temporaries beyond blocks+scratch
#   accum_axes           -- {out position: grid axes along which output
#                           block revisits are legal accumulation}
# Shapes are element counts; the auditor budgets 4 bytes/element (f32 --
# an upper bound for the bf16 variants).
# ==========================================================================


def momentum_dot_program(*, n_pad: int, b: int, tile: int) -> dict:
    _check_tiling(n_pad, tile)
    grid = (n_pad // tile,)
    return dict(
        name="momentum_dot",
        grid=grid,
        num_scalar_prefetch=0,
        prefetch_length=None,
        prefetch_bound=None,
        in_shapes=[(n_pad, b), (n_pad,), (n_pad,), (1,)],
        in_specs=[
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shapes=[(grid[0], b)],
        out_specs=[pl.BlockSpec((1, b), lambda i: (i, 0))],
        scratch_shapes=[],
        scratch_bytes=0,
        extra_vmem_bytes=F32_BYTES * tile * b,    # mom-weighted cols temp
        accum_axes={},
    )


def mwu_update_program(*, n_pad: int, b: int, tile: int) -> dict:
    _check_tiling(n_pad, tile)
    grid = (n_pad // tile,)
    return dict(
        name="mwu_update",
        grid=grid,
        num_scalar_prefetch=0,
        prefetch_length=None,
        prefetch_bound=None,
        in_shapes=[(n_pad, b), (n_pad,), (n_pad,), (b,), (4,)],
        in_specs=[
            pl.BlockSpec((tile, b), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_shapes=[(n_pad,), (n_pad,), (grid[0],), (grid[0],)],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        scratch_shapes=[],
        scratch_bytes=0,
        extra_vmem_bytes=F32_BYTES * tile * 3,    # dv, v, log_new temps
        accum_axes={},
    )


def momentum_dot_packed_program(*, n_pad: int, d: int, b: int,
                                tile: int) -> dict:
    _check_tiling(n_pad, tile)
    grid = (n_pad // tile, b)
    return dict(
        name="momentum_dot_packed",
        grid=grid,
        num_scalar_prefetch=1,
        prefetch_length=b,
        prefetch_bound=d,
        in_shapes=[(d, n_pad), (n_pad,), (n_pad,), (n_pad,), (1,)],
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, j, idx: (idx[j], i)),
            pl.BlockSpec((tile,), lambda i, j, idx: (i,)),
            pl.BlockSpec((tile,), lambda i, j, idx: (i,)),
            pl.BlockSpec((tile,), lambda i, j, idx: (i,)),
            pl.BlockSpec((1,), lambda i, j, idx: (0,)),
        ],
        out_shapes=[(grid[0], b)],
        out_specs=[pl.BlockSpec((1, 1), lambda i, j, idx: (i, j))],
        scratch_shapes=[pltpu.VMEM((tile,), jnp.float32)],
        scratch_bytes=F32_BYTES * tile,
        extra_vmem_bytes=F32_BYTES * tile,        # x_row * mom product temp
        accum_axes={},
    )


def mwu_update_packed_program(*, n_pad: int, d: int, b: int,
                              tile: int) -> dict:
    _check_tiling(n_pad, tile)
    grid = (n_pad // tile, b)
    return dict(
        name="mwu_update_packed",
        grid=grid,
        num_scalar_prefetch=1,
        prefetch_length=b,
        prefetch_bound=d,
        in_shapes=[(d, n_pad), (b,), (n_pad,), (n_pad,), (n_pad,), (3,)],
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, j, idx: (idx[j], i)),
            pl.BlockSpec((b,), lambda i, j, idx: (0,)),
            pl.BlockSpec((tile,), lambda i, j, idx: (i,)),
            pl.BlockSpec((tile,), lambda i, j, idx: (i,)),
            pl.BlockSpec((tile,), lambda i, j, idx: (i,)),
            pl.BlockSpec((3,), lambda i, j, idx: (0,)),
        ],
        out_shapes=[(n_pad,), (n_pad,), (grid[0], 4)],
        out_specs=[
            pl.BlockSpec((tile,), lambda i, j, idx: (i,)),
            pl.BlockSpec((tile,), lambda i, j, idx: (i,)),
            pl.BlockSpec((1, 4), lambda i, j, idx: (i, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((tile,), jnp.float32)],
        scratch_bytes=F32_BYTES * tile,
        # j == nb-1 epilogue: v, log_new, per-class masks/exp temps
        extra_vmem_bytes=F32_BYTES * tile * 4,
        # every output is written once per tile row i (at j == nb-1 /
        # identically revisited), so revisits along grid axis 1 (the b
        # block-coordinate walk) are declared accumulation, not races
        accum_axes={0: (1,), 1: (1,), 2: (1,)},
    )


# ==========================================================================
# Unpacked per-class kernels (legacy/reference path, 4 launches per step)
# ==========================================================================


def _momentum_dot_kernel(cols_ref, log_lam_ref, log_prev_ref, theta_ref,
                         part_ref):
    cols = cols_ref[...]                          # (TILE, B)
    lam = jnp.exp(log_lam_ref[...])               # (TILE,)
    lam_prev = jnp.exp(log_prev_ref[...])
    theta = theta_ref[0]
    mom = lam + theta * (lam - lam_prev)
    part_ref[...] = (cols * mom[:, None]).sum(axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _momentum_dot_jit(cols, log_lam, log_prev, theta, *, tile: int,
                      interpret: bool) -> jax.Array:
    n, b = cols.shape
    tile = min(tile, max(n, 1))
    pad = (-n) % tile
    if pad:
        cols = jnp.pad(cols, ((0, pad), (0, 0)))
        log_lam = jnp.pad(log_lam, (0, pad), constant_values=NEG)
        log_prev = jnp.pad(log_prev, (0, pad), constant_values=NEG)
    prog = momentum_dot_program(n_pad=cols.shape[0], b=b, tile=tile)
    theta = jnp.asarray(theta, cols.dtype).reshape(1)
    parts = pl.pallas_call(
        _momentum_dot_kernel,
        grid=prog["grid"],
        in_specs=prog["in_specs"],
        out_specs=prog["out_specs"][0],
        out_shape=jax.ShapeDtypeStruct(prog["out_shapes"][0], cols.dtype),
        interpret=interpret,
    )(cols, log_lam, log_prev, theta)
    return parts.sum(axis=0)


def momentum_dot(cols: jax.Array, log_lam: jax.Array, log_prev: jax.Array,
                 theta: jax.Array, *, tile: int = 1024,
                 interpret: bool | None = None) -> jax.Array:
    """delta (B,) = cols^T (lam + theta (lam - lam_prev)), tiled over n."""
    if interpret is None:
        interpret = default_interpret()
    return _momentum_dot_jit(cols, log_lam, log_prev, theta, tile=tile,
                             interpret=interpret)


def _mwu_kernel(cols_ref, log_lam_ref, u_ref, dw_ref, scal_ref,
                log_new_ref, u_new_ref, pmax_ref, psum_ref):
    cols = cols_ref[...]                          # (TILE, B)
    log_lam = log_lam_ref[...]                    # (TILE,)
    u = u_ref[...]
    dw = dw_ref[...]                              # (B,)
    sign, gamma, tau, d_eff = (scal_ref[0], scal_ref[1], scal_ref[2],
                               scal_ref[3])
    dv = cols @ dw                                # MXU matvec when B=128
    v = sign * (u + d_eff * dv)
    c = 1.0 / (gamma + d_eff / tau)
    log_new = c * ((d_eff / tau) * log_lam - v)
    u_new_ref[...] = u + dv
    log_new_ref[...] = log_new
    tile_max = jnp.max(log_new)
    pmax_ref[...] = tile_max.reshape(1)
    psum_ref[...] = jnp.sum(jnp.exp(log_new - tile_max)).reshape(1)


@functools.partial(jax.jit,
                   static_argnames=("tile", "interpret", "normalize"))
def _mwu_update_jit(cols, log_lam, u, dw, sign, gamma, tau, d_eff, *,
                    tile: int, interpret: bool, normalize: bool):
    n, b = cols.shape
    tile = min(tile, max(n, 1))
    pad = (-n) % tile
    if pad:
        cols = jnp.pad(cols, ((0, pad), (0, 0)))
        log_lam = jnp.pad(log_lam, (0, pad), constant_values=NEG)
        u = jnp.pad(u, (0, pad))
    prog = mwu_update_program(n_pad=cols.shape[0], b=b, tile=tile)
    scal = jnp.stack([jnp.asarray(s, cols.dtype)
                      for s in (sign, gamma, tau, d_eff)])
    log_new, u_new, pmax, psum = pl.pallas_call(
        _mwu_kernel,
        grid=prog["grid"],
        in_specs=prog["in_specs"],
        out_specs=prog["out_specs"],
        out_shape=[jax.ShapeDtypeStruct(s, cols.dtype)
                   for s in prog["out_shapes"]],
        interpret=interpret,
    )(cols, log_lam, u, dw, scal)
    # combine per-tile (max, sumexp) partials into the global logsumexp
    m = jnp.max(pmax)
    s = jnp.sum(psum * jnp.exp(pmax - m))
    if not normalize:
        return log_new[:n], u_new[:n], m, s
    return (log_new - (m + jnp.log(s)))[:n], u_new[:n]


def mwu_update(cols: jax.Array, log_lam: jax.Array, u: jax.Array,
               dw: jax.Array, sign: jax.Array, gamma: jax.Array,
               tau: jax.Array, d_eff: jax.Array, *, tile: int = 1024,
               interpret: bool | None = None, normalize: bool = True):
    """Fused dual update.  Returns (log_new_normalized, u_new), or --
    with ``normalize=False`` -- (log_new_unnormalized, u_new, m, s)
    where lse = m + log(s), so a caller can combine the normalizer
    partials across clients (distributed rounds 2-3) before applying."""
    if interpret is None:
        interpret = default_interpret()
    return _mwu_update_jit(cols, log_lam, u, dw, sign, gamma, tau, d_eff,
                           tile=tile, interpret=interpret,
                           normalize=normalize)


# --------------------------------------------------------------------------
# Packed single-sweep kernels (2 launches per engine step)
# --------------------------------------------------------------------------

def _packed_tile(n_pad: int, tile: int) -> int:
    """Largest power-of-two tile <= ``tile`` dividing the lane-padded
    point count, so the kernels never re-pad the packed operand.
    128 is the TPU lane width (preprocess.LANE); a non-aligned length
    would silently degrade to tiny tiles, so reject it."""
    if n_pad % 128:
        raise ValueError(
            f"packed length {n_pad} must be lane-aligned (multiple of "
            "128); use preprocess.pack_points / packed_length")
    return math.gcd(n_pad, tile)


def _momentum_dot_packed_kernel(idx_ref, x_row_ref, log_lam_ref,
                                log_prev_ref, sign_ref, theta_ref,
                                part_ref, mom_ref):
    del idx_ref  # consumed by the BlockSpec index maps
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():                       # signed momentum weights, once per tile
        lam = jnp.exp(log_lam_ref[...])
        lam_prev = jnp.exp(log_prev_ref[...])
        mom_ref[...] = sign_ref[...] * (
            lam + theta_ref[0] * (lam - lam_prev))

    part_ref[0, 0] = jnp.sum(x_row_ref[0, :] * mom_ref[...])


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _momentum_dot_packed_jit(x_t, idx, log_lam, log_prev, sign, theta, *,
                             tile: int, interpret: bool) -> jax.Array:
    d, n_pad = x_t.shape
    b = idx.shape[0]
    tile = _packed_tile(n_pad, tile)
    prog = momentum_dot_packed_program(n_pad=n_pad, d=d, b=b, tile=tile)
    theta = jnp.asarray(theta, x_t.dtype).reshape(1)
    parts = pl.pallas_call(
        _momentum_dot_packed_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=prog["num_scalar_prefetch"],
            grid=prog["grid"],
            in_specs=prog["in_specs"],
            out_specs=prog["out_specs"][0],
            scratch_shapes=prog["scratch_shapes"],
        ),
        out_shape=jax.ShapeDtypeStruct(prog["out_shapes"][0], x_t.dtype),
        interpret=interpret,
    )(idx, x_t, log_lam, log_prev, sign, theta)
    return parts.sum(axis=0)


def momentum_dot_packed(x_t: jax.Array, idx: jax.Array, log_lam: jax.Array,
                        log_prev: jax.Array, sign: jax.Array,
                        theta: jax.Array, *, tile: int = 1024,
                        interpret: bool | None = None) -> jax.Array:
    """delta (b,) = sum_i sign_i mom_i x_t[idx, i] -- lines 2-3 of
    Algorithm 2 for BOTH classes in one sweep, gathering the coordinate
    block from the raw column-major mirror inside the kernel."""
    if interpret is None:
        interpret = default_interpret()
    return _momentum_dot_packed_jit(x_t, idx, log_lam, log_prev, sign,
                                    theta, tile=tile, interpret=interpret)


def _mwu_packed_kernel(idx_ref, x_row_ref, dw_ref, log_lam_ref, u_ref,
                       sign_ref, scal_ref, log_new_ref, u_new_ref,
                       part_ref, dv_ref):
    del idx_ref
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _():
        dv_ref[...] = jnp.zeros_like(dv_ref)

    dv_ref[...] += x_row_ref[0, :] * dw_ref[j]   # rank-1 accumulate

    @pl.when(j == nb - 1)
    def _():
        gamma, tau, d_eff = scal_ref[0], scal_ref[1], scal_ref[2]
        sign = sign_ref[...]
        dv = dv_ref[...]
        u = u_ref[...]
        v = sign * (u + d_eff * dv)
        c = 1.0 / (gamma + d_eff / tau)
        log_new = c * ((d_eff / tau) * log_lam_ref[...] - v)
        u_new_ref[...] = u + dv
        log_new_ref[...] = log_new
        # per-class (max, sumexp) normalizer partials in the same sweep;
        # the sum is masked (not filled with NEG) so an all-padding /
        # single-class tile contributes (NEG, 0) instead of (NEG, inf)
        is_p = sign > 0
        is_m = sign < 0
        m_p = jnp.max(jnp.where(is_p, log_new, NEG))
        m_m = jnp.max(jnp.where(is_m, log_new, NEG))
        s_p = jnp.sum(jnp.where(is_p, jnp.exp(log_new - m_p), 0.0))
        s_m = jnp.sum(jnp.where(is_m, jnp.exp(log_new - m_m), 0.0))
        part_ref[0, :] = jnp.stack([m_p, s_p, m_m, s_m])


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _mwu_update_packed_jit(x_t, idx, log_lam, u, dw, sign, gamma, tau,
                           d_eff, *, tile: int, interpret: bool):
    d, n_pad = x_t.shape
    b = idx.shape[0]
    tile = _packed_tile(n_pad, tile)
    prog = mwu_update_packed_program(n_pad=n_pad, d=d, b=b, tile=tile)
    scal = jnp.stack([jnp.asarray(s, x_t.dtype)
                      for s in (gamma, tau, d_eff)])
    log_new, u_new, parts = pl.pallas_call(
        _mwu_packed_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=prog["num_scalar_prefetch"],
            grid=prog["grid"],
            in_specs=prog["in_specs"],
            out_specs=prog["out_specs"],
            scratch_shapes=prog["scratch_shapes"],
        ),
        out_shape=[jax.ShapeDtypeStruct(s, x_t.dtype)
                   for s in prog["out_shapes"]],
        interpret=interpret,
    )(idx, x_t, dw, log_lam, u, sign, scal)
    # combine per-tile per-class partials into the two global logsumexps
    m_p = jnp.max(parts[:, 0])
    s_p = jnp.sum(parts[:, 1] * jnp.exp(parts[:, 0] - m_p))
    m_m = jnp.max(parts[:, 2])
    s_m = jnp.sum(parts[:, 3] * jnp.exp(parts[:, 2] - m_m))
    return log_new, u_new, m_p, s_p, m_m, s_m


def mwu_update_packed(x_t: jax.Array, idx: jax.Array, log_lam: jax.Array,
                      u: jax.Array, dw: jax.Array, sign: jax.Array,
                      gamma: jax.Array, tau: jax.Array, d_eff: jax.Array,
                      *, tile: int = 1024, interpret: bool | None = None):
    """Fused packed dual update (lines 5-6 + incremental u for BOTH
    classes).  Returns (log_new_unnormalized, u_new, m_p, s_p, m_m, s_m)
    with per-class lse = m + log(s); the caller combines the partials
    across clients (distributed rounds 2-3) and normalizes per class."""
    if interpret is None:
        interpret = default_interpret()
    return _mwu_update_packed_jit(x_t, idx, log_lam, u, dw, sign, gamma,
                                  tau, d_eff, tile=tile,
                                  interpret=interpret)
