"""Pallas TPU kernel: tiled Fast Walsh--Hadamard Transform.

The paper's pre-processing (Algorithm 1) applies ``WD`` to every point:
O(n d log d) -- the single largest dense sweep in Saddle-SVC
(everything after it is O(n) per iteration).  GPU/CPU implementations
recurse in place; on TPU we instead keep a (TILE_N, d) block of points
resident in VMEM and run all log2(d) butterfly stages on it before
writing back, so HBM traffic is one read + one write per point instead
of log d round trips (DESIGN.md section 2).

Grid: one program per tile of TILE_N points; the full d axis lives in
the block (d is a power of two, padded by the caller).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht_kernel(x_ref, o_ref, *, d: int, normalize: bool):
    x = x_ref[...]                      # (TILE_N, d) block in VMEM
    t = x.shape[0]
    h = 1
    while h < d:
        x = x.reshape(t, d // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2)
        x = x.reshape(t, d)
        h *= 2
    if normalize:
        x = x * (1.0 / (d ** 0.5))
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "normalize",
                                             "interpret"))
def fwht_pallas(x: jax.Array, *, tile_n: int = 0, normalize: bool = True,
                interpret: bool = True) -> jax.Array:
    """Walsh--Hadamard transform along the last axis of (n, d) ``x``.

    d must be a power of two.  ``tile_n=0`` picks the largest tile that
    keeps the working set under ~4 MiB of VMEM (x + butterfly temps).
    """
    n, d = x.shape
    if d & (d - 1):
        raise ValueError(f"d must be a power of two, got {d}")
    if tile_n == 0:
        budget = 4 * 1024 * 1024 // (4 * max(d, 1))  # fp32 bytes per row
        tile_n = max(8, min(256, 1 << max(budget - 1, 1).bit_length() - 1))
        tile_n = min(tile_n, max(8, budget))
    tile_n = min(tile_n, n) if n >= 8 else n
    pad = (-n) % tile_n
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    grid = (xp.shape[0] // tile_n,)
    out = pl.pallas_call(
        functools.partial(_fwht_kernel, d=d, normalize=normalize),
        grid=grid,
        in_specs=[pl.BlockSpec((tile_n, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp)
    return out[:n] if pad else out
