"""Pallas TPU kernel: tiled Fast Walsh--Hadamard Transform.

The paper's pre-processing (Algorithm 1) applies ``WD`` to every point:
O(n d log d) -- the single largest dense sweep in Saddle-SVC
(everything after it is O(n) per iteration).  GPU/CPU implementations
recurse in place; on TPU we instead keep a (TILE_N, d) block of points
resident in VMEM and run all log2(d) butterfly stages on it before
writing back, so HBM traffic is one read + one write per point instead
of log d round trips (DESIGN.md section 2).

Grid: one program per tile of TILE_N points; the full d axis lives in
the block (d is a power of two, padded by the caller).

Like :mod:`repro.kernels.saddle_update`, the launch consumes
:func:`fwht_program` -- the registry entry the static auditor
(:mod:`repro.analysis.pallas_audit`) verifies -- so the audited
BlockSpecs are the launched BlockSpecs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import default_interpret

F32_BYTES = 4


def auto_tile_n(n: int, d: int) -> int:
    """Largest row tile keeping the (TILE_N, d) working set (block +
    butterfly temps) under ~4 MiB of VMEM, floored at 8 rows."""
    budget = 4 * 1024 * 1024 // (F32_BYTES * max(d, 1))  # fp32 rows
    tile_n = max(8, min(256, 1 << max(budget - 1, 1).bit_length() - 1))
    tile_n = min(tile_n, max(8, budget))
    return tile_n


def fwht_program(*, n_pad: int, d: int, tile_n: int) -> dict:
    """Kernel program (see pallas_audit's registry contract): one grid
    step per TILE_N-row block, identity in->out blocking over the full
    d axis.  ``extra_vmem_bytes`` covers the butterfly's a+b / a-b
    stack temporaries (~2 extra block copies live at a stage boundary).
    """
    if tile_n <= 0 or n_pad % tile_n:
        raise ValueError(
            f"tile_n {tile_n} must evenly divide padded length {n_pad}")
    if d & (d - 1) or d <= 0:
        raise ValueError(f"d must be a power of two, got {d}")
    grid = (n_pad // tile_n,)
    return dict(
        name="fwht",
        grid=grid,
        num_scalar_prefetch=0,
        prefetch_length=None,
        prefetch_bound=None,
        in_shapes=[(n_pad, d)],
        in_specs=[pl.BlockSpec((tile_n, d), lambda i: (i, 0))],
        out_shapes=[(n_pad, d)],
        out_specs=[pl.BlockSpec((tile_n, d), lambda i: (i, 0))],
        scratch_shapes=[],
        scratch_bytes=0,
        extra_vmem_bytes=2 * F32_BYTES * tile_n * d,
        accum_axes={},
    )


def _fwht_kernel(x_ref, o_ref, *, d: int, normalize: bool):
    x = x_ref[...]                      # (TILE_N, d) block in VMEM
    t = x.shape[0]
    h = 1
    while h < d:
        x = x.reshape(t, d // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2)
        x = x.reshape(t, d)
        h *= 2
    if normalize:
        x = x * (1.0 / (d ** 0.5))
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "normalize",
                                             "interpret"))
def _fwht_jit(x: jax.Array, *, tile_n: int, normalize: bool,
              interpret: bool) -> jax.Array:
    n, d = x.shape
    tile_n = min(tile_n, n) if n >= 8 else n
    pad = (-n) % tile_n
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    prog = fwht_program(n_pad=xp.shape[0], d=d, tile_n=tile_n)
    out = pl.pallas_call(
        functools.partial(_fwht_kernel, d=d, normalize=normalize),
        grid=prog["grid"],
        in_specs=prog["in_specs"],
        out_specs=prog["out_specs"][0],
        out_shape=jax.ShapeDtypeStruct(prog["out_shapes"][0], x.dtype),
        interpret=interpret,
    )(xp)
    return out[:n] if pad else out


def fwht_pallas(x: jax.Array, *, tile_n: int = 0, normalize: bool = True,
                interpret: bool | None = None) -> jax.Array:
    """Walsh--Hadamard transform along the last axis of (n, d) ``x``.

    d must be a power of two (fail-fast ValueError otherwise).
    ``tile_n=0`` picks :func:`auto_tile_n`; ``interpret=None`` resolves
    via :func:`repro.kernels.default_interpret` (real kernel on TPU).
    """
    n, d = x.shape
    if d & (d - 1) or d <= 0:
        raise ValueError(f"d must be a power of two, got {d}")
    if tile_n == 0:
        tile_n = auto_tile_n(n, d)
    if interpret is None:
        interpret = default_interpret()
    return _fwht_jit(x, tile_n=tile_n, normalize=normalize,
                     interpret=interpret)
