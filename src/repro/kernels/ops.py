"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a
real TPU build, pass interpret=False (the BlockSpecs are TPU-shaped:
lane-aligned tiles, full-d VMEM blocks for the FWHT butterfly).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import fwht as _fwht
from repro.kernels import saddle_update as _su
from repro.kernels import ref as ref  # noqa: F401  (re-exported oracle)


def fwht(x: jax.Array, *, normalize: bool = True,
         interpret: bool = True) -> jax.Array:
    """Tiled Walsh--Hadamard transform (rows of (n, d), d a power of 2)."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    out = _fwht.fwht_pallas(x, normalize=normalize, interpret=interpret)
    return out[0] if squeeze else out


def momentum_dot(cols, log_lam, log_prev, theta, *, interpret=True):
    return _su.momentum_dot(cols, log_lam, log_prev, theta,
                            interpret=interpret)


def mwu_update(cols, log_lam, u, dw, *, sign, gamma, tau, d_eff,
               interpret=True, normalize=True):
    """Fused dual update; ``normalize=False`` returns the unnormalized
    log weights plus (m, s) normalizer partials with lse = m + log(s)
    (used by the solver engine to all-reduce across clients)."""
    return _su.mwu_update(cols, log_lam, u, dw,
                          jnp.asarray(sign), jnp.asarray(gamma),
                          jnp.asarray(tau), jnp.asarray(d_eff),
                          interpret=interpret, normalize=normalize)
