"""Public wrappers around the Pallas kernels.

``interpret=None`` (the default everywhere) resolves through
:func:`repro.kernels.default_interpret`: real compiled kernels when
``jax.default_backend() == "tpu"``, the Pallas interpreter otherwise
(this container is CPU-only).  The resolution happens OUTSIDE the jitted
kernel impls, so the static ``interpret`` cache key is always a concrete
bool.  The TPU-shaped BlockSpec discipline the compiled path relies on
is statically verified by ``repro.analysis.pallas_audit`` over the same
program builders the launches use.

``launch_counts`` tallies pallas_call launches per wrapper at TRACE
time (one wrapper call == one kernel launch in the compiled step).
``benchmarks/kernels_bench.py`` uses it to assert the packed engine's
4 -> 2 launches-per-step reduction.
"""

from __future__ import annotations

import collections

import jax
import jax.numpy as jnp

from repro.kernels import fwht as _fwht
from repro.kernels import saddle_update as _su
from repro.kernels import ref as ref  # noqa: F401  (re-exported oracle)

launch_counts: collections.Counter = collections.Counter()


def fwht(x: jax.Array, *, normalize: bool = True,
         interpret: bool | None = None) -> jax.Array:
    """Tiled Walsh--Hadamard transform (rows of (n, d), d a power of 2)."""
    launch_counts["fwht"] += 1
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    out = _fwht.fwht_pallas(x, normalize=normalize, interpret=interpret)
    return out[0] if squeeze else out


def momentum_dot(cols, log_lam, log_prev, theta, *, interpret=None):
    launch_counts["momentum_dot"] += 1
    return _su.momentum_dot(cols, log_lam, log_prev, theta,
                            interpret=interpret)


def mwu_update(cols, log_lam, u, dw, *, sign, gamma, tau, d_eff,
               interpret=None, normalize=True):
    """Fused dual update; ``normalize=False`` returns the unnormalized
    log weights plus (m, s) normalizer partials with lse = m + log(s)
    (used by the solver engine to all-reduce across clients)."""
    launch_counts["mwu_update"] += 1
    return _su.mwu_update(cols, log_lam, u, dw,
                          jnp.asarray(sign), jnp.asarray(gamma),
                          jnp.asarray(tau), jnp.asarray(d_eff),
                          interpret=interpret, normalize=normalize)


def momentum_dot_packed(x_t, idx, log_lam, log_prev, sign, theta, *,
                        interpret=None):
    """Single-sweep signed momentum dot over the packed operand; the
    coordinate block is gathered from the raw column-major mirror
    inside the kernel (scalar-prefetched indices)."""
    launch_counts["momentum_dot_packed"] += 1
    return _su.momentum_dot_packed(x_t, idx, log_lam, log_prev, sign,
                                   theta, interpret=interpret)


def mwu_update_packed(x_t, idx, log_lam, u, dw, sign, *, gamma, tau,
                      d_eff, interpret=None):
    """Single-sweep packed dual update.  Returns (log_new_unnormalized,
    u_new, m_p, s_p, m_m, s_m) with per-class lse = m + log(s)."""
    launch_counts["mwu_update_packed"] += 1
    return _su.mwu_update_packed(x_t, idx, log_lam, u, dw, sign,
                                 jnp.asarray(gamma), jnp.asarray(tau),
                                 jnp.asarray(d_eff), interpret=interpret)
