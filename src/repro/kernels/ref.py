"""Pure-jnp oracles for the Pallas kernels (the ground truth every
kernel is allclose-tested against, per shape/dtype sweep)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fwht_ref(x: jax.Array) -> jax.Array:
    """Normalized Walsh--Hadamard transform along the last axis."""
    from repro.core.preprocess import fwht
    return fwht(x, normalize=True)


def momentum_dot_ref(cols: jax.Array, lam: jax.Array, lam_prev: jax.Array,
                     theta: jax.Array | float) -> jax.Array:
    """delta = cols^T (lam + theta (lam - lam_prev)).

    cols: (n, B) sampled coordinate rows; lam: (n,).  Returns (B,)."""
    mom = lam + theta * (lam - lam_prev)
    return cols.T @ mom


def mwu_update_ref(cols: jax.Array, log_lam: jax.Array, u: jax.Array,
                   dw: jax.Array, sign: float, gamma: jax.Array | float,
                   tau: jax.Array | float, d_eff: jax.Array | float):
    """Fused Algorithm-2 dual update (lines 5-6) + incremental u.

    cols: (n, B), dw: (B,).  Returns:
      log_new  (n,) UNNORMALIZED new log-weights
      u_new    (n,) = u + cols @ dw
      (the caller normalizes with a logsumexp -- the kernel emits
       per-tile max/sumexp partials for that)
    """
    dv = cols @ dw
    v = sign * (u + d_eff * dv)
    c = 1.0 / (gamma + d_eff / tau)
    log_new = c * ((d_eff / tau) * log_lam - v)
    return log_new, u + dv
