"""Pure-jnp oracles for the Pallas kernels (the ground truth every
kernel is allclose-tested against, per shape/dtype sweep)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fwht_ref(x: jax.Array) -> jax.Array:
    """Normalized Walsh--Hadamard transform along the last axis."""
    from repro.core.preprocess import fwht
    return fwht(x, normalize=True)


def momentum_dot_ref(cols: jax.Array, lam: jax.Array, lam_prev: jax.Array,
                     theta: jax.Array | float) -> jax.Array:
    """delta = cols^T (lam + theta (lam - lam_prev)).

    cols: (n, B) sampled coordinate rows; lam: (n,).  Returns (B,)."""
    mom = lam + theta * (lam - lam_prev)
    return cols.T @ mom


def mwu_update_ref(cols: jax.Array, log_lam: jax.Array, u: jax.Array,
                   dw: jax.Array, sign: float, gamma: jax.Array | float,
                   tau: jax.Array | float, d_eff: jax.Array | float):
    """Fused Algorithm-2 dual update (lines 5-6) + incremental u.

    cols: (n, B), dw: (B,).  Returns:
      log_new  (n,) UNNORMALIZED new log-weights
      u_new    (n,) = u + cols @ dw
      (the caller normalizes with a logsumexp -- the kernel emits
       per-tile max/sumexp partials for that)
    """
    dv = cols @ dw
    v = sign * (u + d_eff * dv)
    c = 1.0 / (gamma + d_eff / tau)
    log_new = c * ((d_eff / tau) * log_lam - v)
    return log_new, u + dv


NEG = -1e30


def momentum_dot_packed_ref(x_t: jax.Array, idx: jax.Array,
                            log_lam: jax.Array, log_prev: jax.Array,
                            sign: jax.Array,
                            theta: jax.Array | float) -> jax.Array:
    """Signed single-sweep momentum dot over the packed operand:
    delta (b,) = sum_i sign_i (lam_i + theta (lam_i - lam_prev_i))
                 x_t[idx, i]."""
    lam = jnp.exp(log_lam)
    lam_prev = jnp.exp(log_prev)
    mom = sign * (lam + theta * (lam - lam_prev))
    return jnp.take(x_t, idx, axis=0) @ mom


def mwu_update_packed_ref(x_t: jax.Array, idx: jax.Array,
                          log_lam: jax.Array, u: jax.Array, dw: jax.Array,
                          sign: jax.Array, gamma: jax.Array | float,
                          tau: jax.Array | float,
                          d_eff: jax.Array | float):
    """Packed single-sweep dual update for both classes.  Returns
    (log_new UNNORMALIZED, u_new, m_p, s_p, m_m, s_m) where the
    per-class logsumexp is m + log(s), masked by the sign vector
    (padding slots, sign == 0, belong to neither class)."""
    dv = dw @ jnp.take(x_t, idx, axis=0)
    v = sign * (u + d_eff * dv)
    c = 1.0 / (gamma + d_eff / tau)
    log_new = c * ((d_eff / tau) * log_lam - v)
    is_p = sign > 0
    is_m = sign < 0
    m_p = jnp.max(jnp.where(is_p, log_new, NEG))
    m_m = jnp.max(jnp.where(is_m, log_new, NEG))
    s_p = jnp.sum(jnp.where(is_p, jnp.exp(log_new - m_p), 0.0))
    s_m = jnp.sum(jnp.where(is_m, jnp.exp(log_new - m_m), 0.0))
    return log_new, u + dv, m_p, s_p, m_m, s_m
