# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def default_interpret() -> bool:
    """Pallas ``interpret=`` default: real kernels on TPU, interpreter
    everywhere else.

    The BlockSpecs are TPU-shaped (lane-aligned tiles, full-d VMEM
    blocks), so on a TPU build the kernels compile for real without any
    flag threading; CPU/GPU hosts (this container) fall back to the
    interpreter, which is what every parity test runs against.  The
    static shape discipline the compiled path needs is proven
    separately by :mod:`repro.analysis.pallas_audit` over the same
    program builders the launches use.
    """
    import jax

    return jax.default_backend() != "tpu"
