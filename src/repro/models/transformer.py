"""Model assembly: decoder-only (all families) and encoder-decoder
(whisper).  Layers are scanned over the block-pattern period with remat;
per-layer parameters are stacked (L/period leading axis) so the HLO
stays one-period-sized regardless of depth (95-layer deepseek-67b
compiles as one scanned block).

Block kinds (cfg.block_pattern):
  attn        GQA or MLA self-attention + MLP   (window = cfg.window)
  local_attn  GQA with cfg.local_window sliding window + MLP
  rglru       RG-LRU recurrent mixer + MLP      (Griffin residual pair)
  mlstm       mLSTM block (carries its own projections, no MLP)
  slstm       sLSTM block (ditto)
"""

from __future__ import annotations

import functools
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models import sharding as shd
from repro.models.layers import (embed, glu_mlp, init_embedding,
                                 init_glu_mlp, init_rmsnorm, rmsnorm,
                                 sinusoidal_positions, unembed)

# ------------------------------------------------------------------ blocks
_HAS_MLP = {"attn", "local_attn", "rglru"}


def _init_mixer(key, kind: str, cfg):
    if kind in ("attn", "local_attn"):
        if cfg.attention_kind == "mla" and kind == "attn":
            return attn.init_mla(key, cfg)
        return attn.init_gqa(key, cfg)
    if kind == "rglru":
        return ssm.init_rglru(key, cfg)
    if kind == "mlstm":
        return ssm.init_mlstm(key, cfg)
    if kind == "slstm":
        return ssm.init_slstm(key, cfg)
    raise ValueError(kind)


def init_block(key, kind: str, cfg, *, mlp: str | None = None,
               cross: bool = False):
    """mlp: None -> cfg.mlp_kind; "dense" forces a dense GLU (deepseek
    first layer); "moe" forces MoE."""
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {
        "norm1": init_rmsnorm(cfg.d_model, cfg),
        "mixer": _init_mixer(ks[0], kind, cfg),
    }
    if cross:
        p["norm_x"] = init_rmsnorm(cfg.d_model, cfg)
        p["cross"] = attn.init_cross(ks[1], cfg)
    if kind in _HAS_MLP:
        p["norm2"] = init_rmsnorm(cfg.d_model, cfg)
        mlp_kind = mlp or cfg.mlp_kind
        if mlp_kind == "moe":
            p["mlp"] = moe_mod.init_moe(ks[2], cfg)
        else:
            p["mlp"] = init_glu_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg)
    return p


def apply_block(params, kind: str, x, cfg, *, positions, cache=None,
                enc_out=None, mlp_kind: str | None = None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    new_cache = dict(cache) if cache is not None else None
    if kind in ("attn", "local_attn"):
        window = cfg.local_window if kind == "local_attn" else cfg.window
        sub = cache.get("self") if cache else None
        if cfg.attention_kind == "mla" and kind == "attn":
            out, sub_new = attn.mla_attention(
                params["mixer"], h, cfg=cfg, positions=positions,
                cache=sub)
        else:
            out, sub_new = attn.gqa_attention(
                params["mixer"], h, cfg=cfg, positions=positions,
                causal=True, window=window, cache=sub)
        if cache is not None:
            new_cache["self"] = sub_new
    elif kind == "rglru":
        out, sub_new = ssm.rglru_block(params["mixer"], h, cfg,
                                       cache=cache.get("self")
                                       if cache else None)
        if cache is not None:
            new_cache["self"] = sub_new
    elif kind == "mlstm":
        out, sub_new = ssm.mlstm_block(params["mixer"], h, cfg,
                                       cache=cache.get("self")
                                       if cache else None)
        if cache is not None:
            new_cache["self"] = sub_new
    elif kind == "slstm":
        out, sub_new = ssm.slstm_block(params["mixer"], h, cfg,
                                       cache=cache.get("self")
                                       if cache else None)
        if cache is not None:
            new_cache["self"] = sub_new
    else:
        raise ValueError(kind)
    x = x + out

    if "cross" in params:
        h = rmsnorm(params["norm_x"], x, cfg.norm_eps)
        if enc_out is None and cache is not None and "cross_kv" in cache:
            ckv = (cache["cross_kv"]["k"], cache["cross_kv"]["v"])
        else:
            ck = jnp.einsum("btd,dhk->bthk", enc_out,
                            params["cross"]["wk"])
            cv = jnp.einsum("btd,dhk->bthk", enc_out,
                            params["cross"]["wv"])
            ckv = (ck, cv)
            if cache is not None:
                new_cache["cross_kv"] = {"k": ck, "v": cv}
        out, _ = attn.gqa_attention(params["cross"], h, cfg=cfg,
                                    positions=positions, cross_kv=ckv)
        x = x + out

    if kind in _HAS_MLP:
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        if (mlp_kind or cfg.mlp_kind) == "moe" and "router" in params["mlp"]:
            out, aux = moe_mod.moe_block(params["mlp"], h, cfg)
        else:
            out = glu_mlp(params["mlp"], h,
                          "geglu" if cfg.mlp_kind == "geglu" else "swiglu")
        x = x + out
    return x, new_cache, aux


def init_block_cache(kind: str, cfg, batch: int, t_max: int, *,
                     cross_len: int = 0, cache_dtype=jnp.bfloat16):
    c: dict[str, Any] = {}
    if kind in ("attn", "local_attn"):
        window = cfg.local_window if kind == "local_attn" else cfg.window
        if cfg.attention_kind == "mla" and kind == "attn":
            c["self"] = attn.init_mla_cache(cfg, batch, t_max, cache_dtype)
        else:
            c["self"] = attn.init_gqa_cache(cfg, batch, t_max,
                                            window=window,
                                            dtype=cache_dtype)
    elif kind == "rglru":
        c["self"] = ssm.init_rglru_cache(cfg, batch)
    elif kind == "mlstm":
        c["self"] = ssm.init_mlstm_cache(cfg, batch)
    elif kind == "slstm":
        c["self"] = ssm.init_slstm_cache(cfg, batch)
    if cross_len:
        kv, dh = cfg.num_kv_heads, cfg.head_dim
        c["cross_kv"] = {"k": jnp.zeros((batch, cross_len, kv, dh),
                                        cache_dtype),
                         "v": jnp.zeros((batch, cross_len, kv, dh),
                                        cache_dtype)}
    return c


# ------------------------------------------------------------------- model
class LayerPlan(NamedTuple):
    """Static layout of the layer stack."""
    head: tuple[tuple[str, str | None], ...]   # (kind, mlp) unscanned
    period: tuple[str, ...]                    # scanned pattern
    n_periods: int
    tail: tuple[str, ...]                      # remainder (kind only)
    scan_mlp: str | None                       # mlp kind inside the scan


def layer_plan(cfg) -> LayerPlan:
    head: list[tuple[str, str | None]] = []
    n_layers = cfg.num_layers
    if cfg.first_dense_layers:
        for _ in range(cfg.first_dense_layers):
            head.append((cfg.block_pattern[0], "dense"))
        n_layers -= cfg.first_dense_layers
    p = len(cfg.block_pattern)
    n_periods = n_layers // p
    rem = n_layers - n_periods * p
    tail = cfg.block_pattern[:rem]
    return LayerPlan(head=tuple(head), period=cfg.block_pattern,
                     n_periods=n_periods, tail=tail,
                     scan_mlp=cfg.mlp_kind)


def init_params(key, cfg, *, is_encoder: bool = False,
                cross: bool = False, num_layers: int | None = None):
    """Parameters for one block stack (+ embeddings at top level)."""
    plan = layer_plan(cfg)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    params["head"] = [
        init_block(jax.random.fold_in(keys[0], i), kind, cfg, mlp=mlp,
                   cross=cross)
        for i, (kind, mlp) in enumerate(plan.head)]
    stacked = []
    for j, kind in enumerate(plan.period):
        def make(i, j=j, kind=kind):
            return init_block(jax.random.fold_in(keys[1], i * 31 + j),
                              kind, cfg, cross=cross)
        if plan.n_periods:
            stacked.append(jax.vmap(make)(jnp.arange(plan.n_periods)))
        else:
            stacked.append(None)
    params["blocks"] = stacked
    params["tail"] = [
        init_block(jax.random.fold_in(keys[2], i), kind, cfg, cross=cross)
        for i, kind in enumerate(plan.tail)]
    params["final_norm"] = init_rmsnorm(cfg.d_model, cfg)
    return params


def apply_stack(params, cfg, x, *, positions, cache=None, enc_out=None,
                remat: bool = True):
    """Run head + scanned periods + tail.  Returns (x, new_cache, aux)."""
    plan = layer_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {} if cache is not None else None

    for i, (kind, mlp) in enumerate(plan.head):
        sub = cache["head"][i] if cache is not None else None
        x, c_new, aux = apply_block(params["head"][i], kind, x, cfg,
                                    positions=positions, cache=sub,
                                    enc_out=enc_out, mlp_kind=mlp)
        aux_total += aux
        if cache is not None:
            new_cache.setdefault("head", []).append(c_new)

    if plan.n_periods and not cfg.scan_layers:
        # UNROLLED path: same math as the scan below, but each period is
        # emitted separately so cost_analysis / collective counts scale
        # with depth (the dry-run roofline uses this; scan counts the
        # body once).  Remat per period keeps activation memory equal.
        def one_period(xx, aux_c, p_stack, c_stack):
            if cfg.shard_acts:
                xx = shd.shard(xx, "batch", None, "act_embed")
            c_out = []
            for j, kind in enumerate(plan.period):
                xx, c_new, aux = apply_block(
                    p_stack[j], kind, xx, cfg, positions=positions,
                    cache=c_stack[j] if c_stack is not None else None,
                    enc_out=enc_out)
                c_out.append(c_new)
                aux_c = aux_c + aux
            return xx, aux_c, c_out

        body = jax.checkpoint(one_period,
                              static_argnums=()) if remat else one_period
        cache_outs = []
        for i in range(plan.n_periods):
            p_i = jax.tree.map(lambda a: a[i], params["blocks"])
            c_i = (jax.tree.map(lambda a: a[i], cache["blocks"])
                   if cache is not None else None)
            x, aux_total, c_out = body(x, aux_total, p_i, c_i)
            cache_outs.append(c_out)
        if cache is not None:
            new_cache["blocks"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *cache_outs)
    elif plan.n_periods:
        def period_fn(carry, xs):
            xx, aux_c = carry
            if cfg.shard_acts:
                xx = shd.shard(xx, "batch", None, "act_embed")
            p_stack = xs[0]
            c_stack = xs[1] if cache is not None else [None] * len(
                plan.period)
            c_out = []
            for j, kind in enumerate(plan.period):
                xx, c_new, aux = apply_block(
                    p_stack[j], kind, xx, cfg, positions=positions,
                    cache=c_stack[j], enc_out=enc_out)
                c_out.append(c_new)
                aux_c = aux_c + aux
            ys = c_out if cache is not None else 0
            return (xx, aux_c), ys

        body = jax.checkpoint(period_fn) if remat else period_fn
        xs = (params["blocks"],
              cache["blocks"] if cache is not None else None)
        if cache is None:
            xs = (params["blocks"], None)

            def body2(carry, p_stack):
                return body(carry, (p_stack, None))
            (x, aux_total), _ = jax.lax.scan(body2, (x, aux_total),
                                             params["blocks"])
        else:
            (x, aux_total), cache_out = jax.lax.scan(
                body, (x, aux_total),
                (params["blocks"], cache["blocks"]))
            new_cache["blocks"] = cache_out

    for i, kind in enumerate(plan.tail):
        sub = cache["tail"][i] if cache is not None else None
        x, c_new, aux = apply_block(params["tail"][i], kind, x, cfg,
                                    positions=positions, cache=sub,
                                    enc_out=enc_out)
        aux_total += aux
        if cache is not None:
            new_cache.setdefault("tail", []).append(c_new)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, new_cache, aux_total


def init_stack_cache(cfg, batch: int, t_max: int, *, cross_len: int = 0,
                     cache_dtype=jnp.bfloat16):
    plan = layer_plan(cfg)
    cache: dict[str, Any] = {}
    cache["head"] = [init_block_cache(kind, cfg, batch, t_max,
                                      cross_len=cross_len,
                                      cache_dtype=cache_dtype)
                     for kind, _ in plan.head]
    stacked = []
    for j, kind in enumerate(plan.period):
        def make(_i, kind=kind):
            return init_block_cache(kind, cfg, batch, t_max,
                                    cross_len=cross_len,
                                    cache_dtype=cache_dtype)
        stacked.append(jax.vmap(make)(jnp.arange(plan.n_periods))
                       if plan.n_periods else None)
    cache["blocks"] = stacked
    cache["tail"] = [init_block_cache(kind, cfg, batch, t_max,
                                      cross_len=cross_len,
                                      cache_dtype=cache_dtype)
                     for kind in plan.tail]
    return cache


# ------------------------------------------------------------- full models
def init_lm(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    params = {"embed": init_embedding(k1, cfg),
              "decoder": init_params(k2, cfg,
                                     cross=cfg.is_encoder_decoder)}
    if cfg.is_encoder_decoder:
        import dataclasses
        enc_cfg = dataclasses.replace(
            cfg, num_layers=cfg.enc_layers, block_pattern=("attn",),
            first_dense_layers=0, window=0)
        params["encoder"] = init_params(k3, enc_cfg)
    return params


def _encoder_cfg(cfg):
    import dataclasses
    return dataclasses.replace(cfg, num_layers=cfg.enc_layers,
                               block_pattern=("attn",),
                               first_dense_layers=0, window=0,
                               rope_kind="none")


def encode(params, cfg, frames: jax.Array):
    """Whisper encoder over stub frame embeddings (B, T_enc, D):
    bidirectional self-attention (mask trick: huge window + non-causal
    positions) + sinusoidal positions."""
    enc_cfg = _encoder_cfg(cfg)
    b, t, _ = frames.shape
    pe = sinusoidal_positions(t, cfg.d_model).astype(frames.dtype)
    x = frames + pe[None]
    # bidirectional: feed positions that make causal masking a no-op
    positions = jnp.broadcast_to(jnp.full((t,), t - 1, jnp.int32)[None],
                                 (b, t))
    x, _, _ = apply_stack(params["encoder"], enc_cfg, x,
                          positions=positions)
    return x


def forward(params, cfg, tokens, *, positions=None, vision_embeds=None,
            vision_mask=None, enc_frames=None, cache=None,
            pos_offset=None):
    """Full forward.  Returns (logits, new_cache, aux)."""
    b, s = tokens.shape
    if positions is None:
        base = jnp.arange(s, dtype=jnp.int32)
        if pos_offset is not None:
            base = base + pos_offset
        positions = jnp.broadcast_to(base[None], (b, s))
        if cfg.rope_kind == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, b, s))
    x = embed(params["embed"], tokens, cfg)
    if cfg.vision_embeds and vision_embeds is not None:
        x = jnp.where(vision_mask[..., None],
                      vision_embeds.astype(x.dtype), x)
    if cfg.is_encoder_decoder:
        pe = sinusoidal_positions(cfg.max_seq_len
                                  if cfg.max_seq_len < 1 << 17
                                  else 1 << 17, cfg.d_model)
        off = pos_offset if pos_offset is not None else 0
        pe_s = jax.lax.dynamic_slice_in_dim(pe, off, s, axis=0)
        x = x + pe_s[None].astype(x.dtype)
    enc_out = None
    if cfg.is_encoder_decoder and enc_frames is not None:
        enc_out = encode(params, cfg, enc_frames)
    x, new_cache, aux = apply_stack(params["decoder"], cfg, x,
                                    positions=positions, cache=cache,
                                    enc_out=enc_out)
    logits = unembed(params["embed"], x, cfg)
    return logits, new_cache, aux


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params)
               if hasattr(x, "size"))
