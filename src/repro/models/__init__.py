"""Model zoo: the 10 assigned architectures as composable JAX modules.

Plain-pytree parameters (no framework dependency), scan-over-layers with
remat, GSPMD sharding constraints via repro.models.sharding.
"""
