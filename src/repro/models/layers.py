"""Common layers: RMSNorm, rotary embeddings (full / partial / M-RoPE),
GLU MLPs, embeddings.  Plain-pytree params; initializers are truncated
normals scaled like standard LM inits."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import sharding as shd


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def _cdtype(cfg):
    return jnp.dtype(cfg.compute_dtype)


def normal(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# --------------------------------------------------------------------- norms
def init_rmsnorm(d: int, cfg) -> dict:
    return {"scale": jnp.zeros((d,), _dtype(cfg))}


def rmsnorm(params: dict, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------- rope
def rope_frequencies(head_rot: int, theta: float) -> jnp.ndarray:
    exponent = jnp.arange(0, head_rot, 2, dtype=jnp.float32) / head_rot
    return 1.0 / (theta ** exponent)                  # (head_rot/2,)


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float,
               fraction: float = 1.0,
               mrope_sections: tuple[int, ...] | None = None) -> jax.Array:
    """Rotary embedding on (B, S, H, Dh).

    positions: (B, S) int32, or (3, B, S) for M-RoPE (t/h/w streams).
    fraction < 1 rotates only the first ``fraction * Dh`` dims
    (ChatGLM's 2d/partial RoPE).  mrope_sections splits the rotated
    half-dims into per-stream sections (Qwen2-VL M-RoPE).
    """
    dh = x.shape[-1]
    rot = int(dh * fraction)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    inv_freq = rope_frequencies(rot, theta)           # (rot/2,)
    if mrope_sections is None:
        if positions.ndim == 3:
            positions = positions[0]
        angles = positions[..., None].astype(jnp.float32) * inv_freq
        # (B, S, rot/2) -> broadcast over heads
        angles = angles[:, :, None, :]
    else:
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) positions"
        parts = []
        start = 0
        for sec, pos in zip(mrope_sections, positions):
            f = inv_freq[start:start + sec]
            parts.append(pos[..., None].astype(jnp.float32) * f)
            start += sec
        angles = jnp.concatenate(parts, axis=-1)[:, :, None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x_rot[..., ::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y.astype(x.dtype), x_pass], axis=-1)


def default_mrope_sections(head_rot_half: int) -> tuple[int, int, int]:
    """Qwen2-VL uses (16, 24, 24) for half-dim 64; scale proportionally."""
    t = head_rot_half // 4
    h = (head_rot_half - t) // 2
    return (t, h, head_rot_half - t - h)


def sinusoidal_positions(seq_len: int, d_model: int) -> jnp.ndarray:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d_model, 2, jnp.float32)
                  * (-math.log(10000.0) / d_model))
    pe = jnp.zeros((seq_len, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ----------------------------------------------------------------------- mlp
def init_glu_mlp(key, d_model: int, d_ff: int, cfg) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "w_gate": normal(k1, (d_model, d_ff), s_in, _dtype(cfg)),
        "w_up": normal(k2, (d_model, d_ff), s_in, _dtype(cfg)),
        "w_down": normal(k3, (d_ff, d_model), s_out, _dtype(cfg)),
    }


def glu_mlp(params: dict, x: jax.Array, kind: str) -> jax.Array:
    act = jax.nn.silu if kind == "swiglu" else \
        (lambda v: jax.nn.gelu(v, approximate=True))
    h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    h = shd.shard(h, "batch", None, "mlp")
    return h @ params["w_down"]


# ----------------------------------------------------------------- embedding
def init_embedding(key, cfg) -> dict:
    v = cfg.padded_vocab
    emb = normal(key, (v, cfg.d_model), 1.0, _dtype(cfg))
    p = {"embedding": emb}
    if not cfg.tie_embeddings:
        p["lm_head"] = normal(jax.random.fold_in(key, 1),
                              (v, cfg.d_model),
                              1.0 / math.sqrt(cfg.d_model), _dtype(cfg))
    return p


def embed(params: dict, tokens: jax.Array, cfg) -> jax.Array:
    x = params["embedding"][tokens].astype(_cdtype(cfg))
    if cfg.emb_scale:
        x = x * math.sqrt(cfg.d_model)
    return shd.shard(x, "batch", None, "embed")


def unembed(params: dict, x: jax.Array, cfg) -> jax.Array:
    table = params.get("lm_head", params["embedding"])
    logits = x @ table.T.astype(_cdtype(cfg))
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    return shd.shard(logits, "batch", None, "vocab")
