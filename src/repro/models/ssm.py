"""Recurrent sequence mixers:

  * mLSTM  (xLSTM [arXiv:2405.04517]) -- matrix-memory LSTM.  Training /
    prefill uses the stabilized PARALLEL (quadratic) form chunked like
    attention; decode uses the O(1) recurrent form with (C, n, m) state.
  * sLSTM  (xLSTM) -- scalar-memory LSTM with exponential gating and
    block-diagonal (per-head) recurrence; inherently sequential ->
    lax.scan over time, O(1) decode.
  * RG-LRU (Griffin / RecurrentGemma [arXiv:2402.19427]) -- gated linear
    recurrence, parallelized with jax.lax.associative_scan (the
    TPU-native replacement for the paper's CUDA linear-scan kernel).

All blocks carry a causal conv1d where the source arch has one; decode
keeps the last (width-1) inputs in the cache.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import sharding as shd
from repro.models.layers import init_rmsnorm, normal, rmsnorm


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


# ----------------------------------------------------------- causal conv1d
def init_conv1d(key, width: int, channels: int, cfg):
    return {"w": normal(key, (width, channels), 1.0 / math.sqrt(width),
                        _dt(cfg)),
            "b": jnp.zeros((channels,), _dt(cfg))}


def causal_conv1d(params, x, buf=None):
    """Depthwise causal conv.  x: (B,S,C).  buf: (B,W-1,C) history for
    decode.  Returns (y, new_buf)."""
    w = params["w"].shape[0]
    if buf is None:
        xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([buf.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * params["w"][i] for i in range(w))
    y = y + params["b"]
    new_buf = xp[:, -(w - 1):] if w > 1 else buf
    return y, new_buf


# ================================================================== mLSTM
def init_mlstm(key, cfg):
    d = cfg.d_model
    inner = int(d * cfg.mlstm_proj_factor)
    h = cfg.num_heads
    dh = inner // h
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    si = 1.0 / math.sqrt(inner)
    return {
        "w_up": normal(ks[0], (d, 2 * inner), s, _dt(cfg)),
        "conv": init_conv1d(ks[1], cfg.conv1d_width, inner, cfg),
        "wq": normal(ks[2], (inner, h, dh), si, _dt(cfg)),
        "wk": normal(ks[3], (inner, h, dh), si, _dt(cfg)),
        "wv": normal(ks[4], (inner, h, dh), si, _dt(cfg)),
        "w_gates": normal(ks[5], (inner, h, 2), si, _dt(cfg)),
        "head_norm": init_rmsnorm(dh, cfg),
        "w_down": normal(ks[6], (inner, d), si, _dt(cfg)),
    }


def _mlstm_parallel(q, k, v, i_pre, f_pre, chunk: int = 1024):
    """Stabilized parallel mLSTM.  q,k,v: (B,S,H,Dh); gates: (B,S,H)."""
    b, s, h, dh = q.shape
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))     # (B,S,H)
    cumf = jnp.cumsum(logf, axis=1)                          # F_t
    a = i_pre.astype(jnp.float32) - cumf + logf              # i_s - F_{s-1}
    # m_t = F_{t-1}+logf_t? Use convention d_{t,s} = (F_t - F_s) + i_s for
    # s <= t where F includes s's own gate once:  F_t - F_s + i_s
    #   = cumf_t - cumf_s + i_s.
    src = i_pre.astype(jnp.float32) - cumf                   # i_s - F_s
    run_max = jax.lax.cummax(src, axis=1)                    # max_{s<=t}
    m = cumf + run_max                                       # (B,S,H)
    scale = 1.0 / math.sqrt(dh)
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cumf_q = jnp.pad(cumf, ((0, 0), (0, pad), (0, 0)))
        m_q = jnp.pad(m, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    else:
        cumf_q, m_q = cumf, m
    nc = q.shape[1] // chunk
    qc = jnp.moveaxis(q.reshape(b, nc, chunk, h, dh), 1, 0)
    cumf_c = jnp.moveaxis(cumf_q.reshape(b, nc, chunk, h), 1, 0)
    m_c = jnp.moveaxis(m_q.reshape(b, nc, chunk, h), 1, 0)
    t_pos = jnp.arange(s)
    chunk_pos = jnp.arange(nc * chunk).reshape(nc, chunk)

    def one_chunk(args):
        qq, ff, mm, qp = args                # (B,C,H,Dh),(B,C,H),(B,C,H)
        dmat = (ff[:, :, None, :] - cumf[:, None, :, :]
                + i_pre[:, None, :, :].astype(jnp.float32)
                - mm[:, :, None, :])         # (B,C,S,H)
        mask = t_pos[None, :] <= qp[:, None]
        dmat = jnp.where(mask[None, :, :, None], dmat, -jnp.inf)
        dec = jnp.exp(dmat)
        scores = jnp.einsum("bchd,bshd->bcsh", qq, k,
                            preferred_element_type=jnp.float32) * scale
        sd = scores * dec
        denom = jnp.maximum(jnp.abs(jnp.sum(sd, axis=2)),
                            jnp.exp(-mm)) + 1e-6        # (B,C,H)
        return jnp.einsum("bcsh,bshd->bchd",
                          (sd / denom[:, :, None, :]).astype(v.dtype), v)

    if nc == 1:
        out = one_chunk((qc[0], cumf_c[0], m_c[0], chunk_pos[0]))[:, None]
        out = jnp.moveaxis(out, 1, 0)
    else:
        # remat per chunk (same residency argument as dot_attention)
        out = jax.lax.map(jax.checkpoint(one_chunk),
                          (qc, cumf_c, m_c, chunk_pos))
    out = jnp.moveaxis(out, 0, 1).reshape(b, nc * chunk, h, dh)
    return out[:, :s]


def _mlstm_recurrent(q, k, v, i_pre, f_pre, state):
    """One-step recurrent mLSTM.  q,k,v: (B,1,H,Dh)."""
    b, _, h, dh = q.shape
    qq, kk, vv = q[:, 0], k[:, 0], v[:, 0]
    i_t = i_pre[:, 0].astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_pre[:, 0].astype(jnp.float32))
    m_new = jnp.maximum(logf + state["m"], i_t)
    i_s = jnp.exp(i_t - m_new)
    f_s = jnp.exp(logf + state["m"] - m_new)
    c = (f_s[..., None, None] * state["C"]
         + i_s[..., None, None] * kk[..., :, None] * vv[..., None, :])
    n = f_s[..., None] * state["n"] + i_s[..., None] * kk
    scale = 1.0 / math.sqrt(dh)
    num = jnp.einsum("bhd,bhdv->bhv", qq * scale, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qq * scale, n)),
                      jnp.exp(-m_new)) + 1e-6
    out = (num / den[..., None]).astype(v.dtype)[:, None]
    return out, {"C": c, "n": n, "m": m_new}


def _mlstm_final_state(k, v, i_pre, f_pre):
    """Closed-form (C_T, n_T, m_T) after consuming a whole prompt --
    the same stabilized sums the recurrence accumulates step by step:
      m_T = F_T + max_s (i_s - F_s)
      C_T = sum_s exp(F_T - F_s + i_s - m_T) k_s v_s^T
    """
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))   # (B,S,H)
    cumf = jnp.cumsum(logf, axis=1)
    f_total = cumf[:, -1]                                  # (B,H)
    src = i_pre.astype(jnp.float32) - cumf                 # i_s - F_s
    m = f_total + jnp.max(src, axis=1)                     # (B,H)
    wgt = jnp.exp(f_total[:, None] + src - m[:, None])     # (B,S,H)
    c = jnp.einsum("bsh,bshd,bshv->bhdv", wgt,
                   k.astype(jnp.float32), v.astype(jnp.float32))
    n = jnp.einsum("bsh,bshd->bhd", wgt, k.astype(jnp.float32))
    return {"C": c, "n": n, "m": m}


def mlstm_block(params, x, cfg, cache=None):
    b, s, d = x.shape
    up = x @ params["w_up"]
    inner = up.shape[-1] // 2
    x_in, z = up[..., :inner], up[..., inner:]
    buf = cache.get("conv") if cache else None
    xc, new_buf = causal_conv1d(params["conv"], x_in, buf)
    xc = jax.nn.silu(xc)
    h = cfg.num_heads
    q = jnp.einsum("bsi,ihd->bshd", xc, params["wq"])
    k = jnp.einsum("bsi,ihd->bshd", xc, params["wk"])
    v = jnp.einsum("bsi,ihd->bshd", x_in, params["wv"])
    gates = jnp.einsum("bsi,ihg->bshg", xc, params["w_gates"])
    i_pre, f_pre = gates[..., 0], gates[..., 1]

    if cache is None:
        out = _mlstm_parallel(q, k, v, i_pre, f_pre)
        new_cache = None
    elif s > 1:
        # prefill: parallel output + closed-form final recurrent state
        out = _mlstm_parallel(q, k, v, i_pre, f_pre)
        new_cache = {"state": _mlstm_final_state(k, v, i_pre, f_pre),
                     "conv": new_buf}
    else:
        out, new_state = _mlstm_recurrent(q, k, v, i_pre, f_pre,
                                          cache["state"])
        new_cache = {"state": new_state, "conv": new_buf}
    out = rmsnorm(params["head_norm"], out, cfg.norm_eps)
    out = out.reshape(b, s, inner) * jax.nn.silu(z)
    return out @ params["w_down"], new_cache


def init_mlstm_cache(cfg, batch: int, dtype=jnp.float32):
    inner = int(cfg.d_model * cfg.mlstm_proj_factor)
    h = cfg.num_heads
    dh = inner // h
    return {"state": {"C": jnp.zeros((batch, h, dh, dh), dtype),
                      "n": jnp.zeros((batch, h, dh), dtype),
                      "m": jnp.full((batch, h), -1e30, dtype)},
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, inner),
                              dtype)}


# ================================================================== sLSTM
def init_slstm(key, cfg):
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    up = int(d * cfg.slstm_proj_factor)
    return {
        "w_x": normal(ks[0], (d, h, 4 * dh), s, _dt(cfg)),      # i,f,z,o
        "w_rec": normal(ks[1], (h, dh, 4 * dh), 1.0 / math.sqrt(dh),
                        _dt(cfg)),
        "head_norm": init_rmsnorm(dh, cfg),
        "w_in": normal(ks[2], (d, 2 * up), s, _dt(cfg)),
        "w_out": normal(ks[3], (up, d), 1.0 / math.sqrt(up), _dt(cfg)),
    }


def _slstm_cell(pre, state, dh):
    """pre: (B,H,4*Dh) gate pre-activations (x-part + R h already added).
    state: dict(c,n,h,m) each (B,H,Dh)."""
    i_pre = pre[..., :dh].astype(jnp.float32)
    f_pre = pre[..., dh:2 * dh].astype(jnp.float32)
    z_pre = pre[..., 2 * dh:3 * dh]
    o_pre = pre[..., 3 * dh:]
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(logf + state["m"] - m_new)
    c = f_s * state["c"] + i_s * jnp.tanh(z_pre.astype(jnp.float32))
    n = f_s * state["n"] + i_s
    hid = (jax.nn.sigmoid(o_pre.astype(jnp.float32)) * c
           / jnp.maximum(n, 1.0))
    return {"c": c, "n": n, "h": hid, "m": m_new}


def slstm_block(params, x, cfg, cache=None):
    b, s, d = x.shape
    h = cfg.num_heads
    dh = d // h
    pre_x = jnp.einsum("bsd,dhg->bshg", x, params["w_x"])   # (B,S,H,4Dh)

    def scan_from(state0):
        def step(st, pre_t):
            pre = pre_t + jnp.einsum("bhd,hdg->bhg",
                                     st["h"].astype(pre_t.dtype),
                                     params["w_rec"])
            st = _slstm_cell(pre, st, dh)
            return st, st["h"]

        fin, hs = jax.lax.scan(step, state0, jnp.moveaxis(pre_x, 1, 0))
        return fin, jnp.moveaxis(hs, 0, 1)                  # (B,S,H,Dh)

    if cache is None or s > 1:
        state0 = cache["state"] if cache is not None else None
        if state0 is None:
            state0 = {k: jnp.zeros((b, h, dh), jnp.float32)
                      for k in ("c", "n", "h")}
            state0["m"] = jnp.full((b, h, dh), -1e30, jnp.float32)
        fin, hidden = scan_from(state0)
        new_cache = {"state": fin} if cache is not None else None
    else:
        st = cache["state"]
        pre = pre_x[:, 0] + jnp.einsum("bhd,hdg->bhg",
                                       st["h"].astype(pre_x.dtype),
                                       params["w_rec"])
        st = _slstm_cell(pre, st, dh)
        hidden = st["h"][:, None]
        new_cache = {"state": st}
    hidden = rmsnorm(params["head_norm"], hidden.astype(x.dtype),
                     cfg.norm_eps).reshape(b, -1, d)
    up = hidden @ params["w_in"]
    half = up.shape[-1] // 2
    out = jax.nn.gelu(up[..., :half], approximate=True) * up[..., half:]
    return out @ params["w_out"], new_cache


def init_slstm_cache(cfg, batch: int, dtype=jnp.float32):
    h = cfg.num_heads
    dh = cfg.d_model // h
    st = {k: jnp.zeros((batch, h, dh), dtype) for k in ("c", "n", "h")}
    st["m"] = jnp.full((batch, h, dh), -1e30, dtype)
    return {"state": st}


# ================================================================== RG-LRU
RGLRU_C = 8.0


def init_rglru(key, cfg):
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    sw = 1.0 / math.sqrt(w)
    # Lambda init so a = exp(-c*softplus(L)) is in (0.9, 0.999)
    lam0 = jnp.linspace(-4.0, -1.0, w)
    return {
        "w_in": normal(ks[0], (d, w), s, _dt(cfg)),
        "w_gate_branch": normal(ks[1], (d, w), s, _dt(cfg)),
        "conv": init_conv1d(ks[2], cfg.conv1d_width, w, cfg),
        "w_r": normal(ks[3], (w, w), sw, _dt(cfg)),
        "w_i": normal(ks[4], (w, w), sw, _dt(cfg)),
        "lam": lam0.astype(jnp.float32),
        "w_out": normal(ks[5], (w, d), sw, _dt(cfg)),
    }


def _rglru_scan(x, r, i, lam):
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t), associative scan."""
    log_a = (-RGLRU_C * jax.nn.softplus(lam)
             * jax.nn.sigmoid(r.astype(jnp.float32)))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * jax.nn.sigmoid(i.astype(jnp.float32)) * x.astype(jnp.float32)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h


def rglru_block(params, x, cfg, cache=None):
    b, s, d = x.shape
    branch = jax.nn.gelu(x @ params["w_gate_branch"], approximate=True)
    xi = x @ params["w_in"]
    buf = cache.get("conv") if cache else None
    xc, new_buf = causal_conv1d(params["conv"], xi, buf)
    r = xc @ params["w_r"]
    i = xc @ params["w_i"]
    if cache is None or s > 1:
        h = _rglru_scan(xc, r, i, params["lam"])
        new_cache = None
        if cache is not None:                # prefill: keep final state
            new_cache = {"h": h[:, -1], "conv": new_buf}
    else:
        log_a = (-RGLRU_C * jax.nn.softplus(params["lam"])
                 * jax.nn.sigmoid(r[:, 0].astype(jnp.float32)))
        a = jnp.exp(log_a)
        gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
            * jax.nn.sigmoid(i[:, 0].astype(jnp.float32)) \
            * xc[:, 0].astype(jnp.float32)
        h_new = a * cache["h"] + gated
        h = h_new[:, None]
        new_cache = {"h": h_new, "conv": new_buf}
    out = (h.astype(x.dtype) * branch) @ params["w_out"]
    return out, new_cache


def init_rglru_cache(cfg, batch: int, dtype=jnp.float32):
    w = cfg.rglru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), dtype),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype)}
