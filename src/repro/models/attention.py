"""Attention: GQA (with full / sliding-window / local masking, RoPE
variants) and MLA (DeepSeek-V2 multi-head latent attention with the
compressed-KV cache and the absorbed decode path).

Memory discipline: training/prefill attention is QUERY-CHUNKED (exact,
per-chunk row softmax) so a 32k prefill never materializes an S x S
score tensor; decode is a single-row attention against the cache.

Caches:
  GQA full   {k, v: (B, T_max, KV, Dh), index}
  GQA window {k, v: (B, W, KV, Dh), pos: (W,), index}   (ring buffer)
  MLA        {c_kv: (B, T, lora), k_rope: (B, T, rope), index}
  cross      {k, v: (B, T_enc, KV, Dh)}                 (static)
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import sharding as shd
from repro.models.layers import (apply_rope, default_mrope_sections,
                                 normal, init_rmsnorm, rmsnorm)

NEG_INF = -2.0 ** 30


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


# ------------------------------------------------------------------ init
def init_gqa(key, cfg, *, head_dim=None, num_heads=None, num_kv=None):
    h = num_heads or cfg.num_heads
    kv = num_kv or cfg.num_kv_heads
    dh = head_dim or cfg.head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "wq": normal(ks[0], (d, h, dh), s, _dt(cfg)),
        "wk": normal(ks[1], (d, kv, dh), s, _dt(cfg)),
        "wv": normal(ks[2], (d, kv, dh), s, _dt(cfg)),
        "wo": normal(ks[3], (h, dh, d), 1.0 / math.sqrt(h * dh), _dt(cfg)),
    }


def init_mla(key, cfg):
    d, h = cfg.d_model, cfg.num_heads
    nope = cfg.head_dim
    rope = cfg.mla_rope_dim
    vd = cfg.mla_v_dim or cfg.head_dim
    lora = cfg.mla_kv_lora
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    p = {
        "wkv_a": normal(ks[0], (d, lora + rope), s, _dt(cfg)),
        "wkv_b_k": normal(ks[1], (lora, h, nope),
                          1.0 / math.sqrt(lora), _dt(cfg)),
        "wkv_b_v": normal(ks[2], (lora, h, vd),
                          1.0 / math.sqrt(lora), _dt(cfg)),
        "wo": normal(ks[3], (h, vd, d), 1.0 / math.sqrt(h * vd), _dt(cfg)),
        "kv_norm": init_rmsnorm(lora, cfg),
    }
    if cfg.mla_q_lora:
        p["wq_a"] = normal(ks[4], (d, cfg.mla_q_lora), s, _dt(cfg))
        p["wq_b"] = normal(ks[5], (cfg.mla_q_lora, h, nope + rope),
                           1.0 / math.sqrt(cfg.mla_q_lora), _dt(cfg))
        p["q_norm"] = init_rmsnorm(cfg.mla_q_lora, cfg)
    else:
        p["wq"] = normal(ks[4], (d, h, nope + rope), s, _dt(cfg))
    return p


def init_cross(key, cfg):
    return init_gqa(key, cfg)


# -------------------------------------------------------- chunked attention
def _pick_chunk(sq: int, t: int) -> int:
    if sq <= 1024:
        return sq
    return 256 if t >= 16384 else 1024


def dot_attention(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
                  chunk: int | None = None):
    """Exact chunked attention.

    q: (B, Sq, H, Dh); k, v: (B, T, KV, Dh); q_pos: (Sq,), k_pos: (T,).
    k_pos entries < 0 are invalid (empty ring-buffer slots)."""
    b, sq, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, sq, kv, g, dh)
    chunk = chunk or _pick_chunk(sq, t)
    chunk = min(chunk, sq)
    pad = (-sq) % chunk
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=q_pos[-1])
    nc = qg.shape[1] // chunk
    qg = qg.reshape(b, nc, chunk, kv, g, dh)
    q_pos_c = q_pos.reshape(nc, chunk)

    def attend_chunk(args):
        qc, qpc = args                       # (B, C, KV, G, Dh), (C,)
        scores = jnp.einsum("bckgd,btkd->bkgct", qc, k,
                            preferred_element_type=jnp.float32) * scale
        mask = k_pos[None, :] >= 0
        if causal:
            mask &= k_pos[None, :] <= qpc[:, None]
        if window > 0:
            mask &= k_pos[None, :] > qpc[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgct,btkd->bckgd", probs, v)

    if nc == 1:
        out = attend_chunk((qg[:, 0], q_pos_c[0]))[:, None]
    else:
        # remat each chunk: without this, the VJP keeps every chunk's
        # (B,KV,G,C,T) softmax residents simultaneously (measured
        # +16 GiB/device on train_4k) -- flash-attention-style recompute
        out = jax.lax.map(jax.checkpoint(attend_chunk),
                          (jnp.moveaxis(qg, 1, 0), q_pos_c))
        out = jnp.moveaxis(out, 0, 1)
    out = out.reshape(b, nc * chunk, h, v.shape[-1])
    return out[:, :sq]


# ------------------------------------------------------------- GQA block
def gqa_attention(params, x, *, cfg, positions, causal=True, window=0,
                  cache=None, cross_kv=None):
    """Returns (out (B,S,D), new_cache).  positions: (B,S) or (3,B,S)."""
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if cross_kv is not None:
        k, v = cross_kv
        t = k.shape[1]
        k_pos = jnp.arange(t)
        q_pos = jnp.zeros((s,), jnp.int32)   # no causal mask for cross
        out = dot_attention(q, k, v, q_pos, k_pos, causal=False, window=0)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), cache

    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.rope_kind != "none":
        sections = None
        if cfg.rope_kind == "mrope":
            rot = int(cfg.head_dim * cfg.rope_fraction)
            sections = default_mrope_sections(rot // 2)
            q = apply_rope(q, positions, theta=cfg.rope_theta,
                           fraction=cfg.rope_fraction,
                           mrope_sections=sections)
            k = apply_rope(k, positions, theta=cfg.rope_theta,
                           fraction=cfg.rope_fraction,
                           mrope_sections=sections)
        else:
            frac = cfg.rope_fraction if cfg.rope_kind == "partial" else 1.0
            q = apply_rope(q, positions, theta=cfg.rope_theta,
                           fraction=frac)
            k = apply_rope(k, positions, theta=cfg.rope_theta,
                           fraction=frac)
    q = shd.shard(q, "batch", None, "heads", None)
    k = shd.shard(k, "batch", "seq_shard" if b == 1 else None,
                  "kv_heads", None)
    v = shd.shard(v, "batch", "seq_shard" if b == 1 else None,
                  "kv_heads", None)

    pos1d = positions[0] if positions.ndim == 3 else positions
    q_pos = pos1d[0]                          # (S,) same across batch

    if cache is None:
        out = dot_attention(q, k, v, q_pos, q_pos, causal=causal,
                            window=window)
        new_cache = None
    elif s > 1:
        # PREFILL (assumes an empty cache, index == 0): attend within the
        # prompt directly and write the cache for subsequent decode.
        out = dot_attention(q, k, v, q_pos, q_pos, causal=causal,
                            window=window)
        new_cache = dict(cache)
        k = k.astype(cache["k"].dtype)
        v = v.astype(cache["v"].dtype)
        if "pos" in cache:                    # ring buffer (SWA)
            w = cache["k"].shape[1]
            if s >= w:
                # keep the last window, laid out so slot == pos % w (the
                # decode ring invariant: the write at index % w always
                # evicts the oldest entry)
                shift = s % w
                new_cache["k"] = jnp.roll(k[:, -w:], shift, axis=1)
                new_cache["v"] = jnp.roll(v[:, -w:], shift, axis=1)
                new_cache["pos"] = jnp.roll(q_pos[-w:], shift, axis=0)
            else:
                new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k, 0, axis=1)
                new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v, 0, axis=1)
                new_cache["pos"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["pos"], q_pos, 0, axis=0)
            new_cache["index"] = cache["index"] + s
        else:
            new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k, cache["index"], axis=1)
            new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v, cache["index"], axis=1)
            new_cache["index"] = cache["index"] + s
    else:
        # DECODE: single query against the cache.
        new_cache = dict(cache)
        k = k.astype(cache["k"].dtype)
        v = v.astype(cache["v"].dtype)
        if "pos" in cache:                    # ring buffer (SWA)
            w = cache["k"].shape[1]
            slot = cache["index"] % w
            new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k, slot, axis=1)
            new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v, slot, axis=1)
            new_cache["pos"] = jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], q_pos, slot, axis=0)
            new_cache["index"] = cache["index"] + s
            out = dot_attention(q, new_cache["k"], new_cache["v"], q_pos,
                                new_cache["pos"], causal=True,
                                window=window)
        else:
            idx = cache["index"]
            new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k, idx, axis=1)
            new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v, idx, axis=1)
            new_cache["index"] = idx + s
            t_max = cache["k"].shape[1]
            k_pos = jnp.arange(t_max)
            k_pos = jnp.where(k_pos < idx + s, k_pos, -1)
            out = dot_attention(q, new_cache["k"], new_cache["v"], q_pos,
                                k_pos, causal=True, window=window)
    out = shd.shard(out, "batch", None, "heads", None)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), new_cache


def init_gqa_cache(cfg, batch: int, t_max: int, *, window: int = 0,
                   dtype=jnp.bfloat16):
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    if window > 0:
        w = min(window, t_max)
        return {"k": jnp.zeros((batch, w, kv, dh), dtype),
                "v": jnp.zeros((batch, w, kv, dh), dtype),
                "pos": jnp.full((w,), -1, jnp.int32),
                "index": jnp.zeros((), jnp.int32)}
    return {"k": jnp.zeros((batch, t_max, kv, dh), dtype),
            "v": jnp.zeros((batch, t_max, kv, dh), dtype),
            "index": jnp.zeros((), jnp.int32)}


# ------------------------------------------------------------- MLA block
def _mla_q(params, x, cfg):
    if cfg.mla_q_lora:
        cq = rmsnorm(params["q_norm"], x @ params["wq_a"], cfg.norm_eps)
        return jnp.einsum("bsl,lhk->bshk", cq, params["wq_b"])
    return jnp.einsum("bsd,dhk->bshk", x, params["wq"])


def mla_attention(params, x, *, cfg, positions, cache=None):
    """DeepSeek-V2 MLA.  Prefill/train: expanded K/V (chunked exact
    attention).  Decode (cache + S small): the ABSORBED path -- scores
    and values computed directly against the compressed c_kv cache."""
    b, s, _ = x.shape
    nope, rope = cfg.head_dim, cfg.mla_rope_dim
    pos1d = positions[0] if positions.ndim == 3 else positions
    q_pos = pos1d[0]

    q = _mla_q(params, x, cfg)                      # (B,S,H,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, pos1d, theta=cfg.rope_theta)

    ckv_full = x @ params["wkv_a"]                  # (B,S,lora+rope)
    c_kv = rmsnorm(params["kv_norm"], ckv_full[..., :cfg.mla_kv_lora],
                   cfg.norm_eps)
    k_rope = apply_rope(ckv_full[..., cfg.mla_kv_lora:][:, :, None, :],
                        pos1d, theta=cfg.rope_theta)[:, :, 0]  # (B,S,rope)

    scale = 1.0 / math.sqrt(nope + rope)
    if cache is None or s > 1:
        # expanded path (training / prefill)
        k_nope = jnp.einsum("btl,lhk->bthk", c_kv, params["wkv_b_k"])
        vv = jnp.einsum("btl,lhv->bthv", c_kv, params["wkv_b_v"])
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (*k_nope.shape[:3], rope))], -1)
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        out = dot_attention(q_full, k_full, vv, q_pos, q_pos,
                            causal=True, window=0)
        new_cache = None
        if cache is not None:                 # prefill: fill the cache
            new_cache = dict(cache)
            new_cache["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype),
                cache["index"], axis=1)
            new_cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                cache["index"], axis=1)
            new_cache["index"] = cache["index"] + s
    else:
        idx = cache["index"]
        new_cache = dict(cache)
        new_cache["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), idx, axis=1)
        new_cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), idx,
            axis=1)
        new_cache["index"] = idx + s
        t_max = cache["c_kv"].shape[1]
        k_pos = jnp.arange(t_max)
        valid = (k_pos < idx + s) & (k_pos[None, :] <= q_pos[:, None])
        # absorbed scores: q_nope through wkv_b_k once, then vs c_kv
        q_abs = jnp.einsum("bshk,lhk->bshl", q_nope, params["wkv_b_k"])
        scores = (jnp.einsum("bshl,btl->bhst", q_abs, new_cache["c_kv"],
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshr,btr->bhst", q_rope,
                               new_cache["k_rope"],
                               preferred_element_type=jnp.float32)) * scale
        scores = jnp.where(valid[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
        ctx = jnp.einsum("bhst,btl->bshl", probs, new_cache["c_kv"])
        out = jnp.einsum("bshl,lhv->bshv", ctx, params["wkv_b_v"])
    out = shd.shard(out, "batch", None, "heads", None)
    return jnp.einsum("bshv,hvd->bsd", out, params["wo"]), new_cache


def init_mla_cache(cfg, batch: int, t_max: int, dtype=jnp.bfloat16):
    return {"c_kv": jnp.zeros((batch, t_max, cfg.mla_kv_lora), dtype),
            "k_rope": jnp.zeros((batch, t_max, cfg.mla_rope_dim), dtype),
            "index": jnp.zeros((), jnp.int32)}
