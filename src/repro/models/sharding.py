"""Logical->physical sharding for the model zoo.

A tiny logical-axis system (MaxText-style "logical axis rules" reduced
to what this zoo needs).  Model code annotates activations with
:func:`shard` using LOGICAL axis names; the launcher installs a mapping
to PHYSICAL mesh axes with :func:`set_mesh_axes`.  Outside a mesh (unit
tests on one device) everything is a no-op.

Physical axes:
  pod    -- slowest axis, across pods (multi-pod mesh only)
  data   -- batch / FSDP axis (16-way per pod)
  model  -- tensor/expert/vocab-parallel axis (16-way)

An axis is only applied when it divides the dimension (e.g. qwen2-vl's
28 heads are NOT sharded over the 16-way model axis; its FFN is)."""

from __future__ import annotations

import threading
from typing import Sequence

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()

# logical axis -> tuple of physical mesh axes (in priority order)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": (),                 # sequence usually replicated...
    "seq_shard": ("data",),    # ...except long-context decode KV/state
    # KV-cache sequence axis: flash-decoding style -- each model shard
    # holds a slice of the history and computes partial attention (the
    # softmax combine is an all-reduce GSPMD inserts).  Falls back to
    # data/pod when batch doesn't occupy them (long_500k B=1 -> 512-way)
    "kv_seq": ("model", "data", "pod"),
    # GQA cache layout: batch + kv-heads sharding preferred; the seq dim
    # only takes data/pod leftovers.  A seq dim sharded over 'model'
    # forces GSPMD to reshard the WHOLE cache through an all-to-all on
    # every decode step (dynamic-update-slice at a traced index cannot
    # stay shard-local) -- measured 14 GiB/step on gemma-7b decode_32k.
    "kv_seq_bp": ("data", "pod"),
    "embed": (),               # activations keep d_model replicated
    # residual stream at layer boundaries: d_model sharded over 'model'
    # (Megatron-style) so the per-layer scan checkpoints stay small --
    # without this, 95-layer deepseek-67b holds ~100 GiB of saved x
    "act_embed": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "param_embed": ("data", "pod"),  # FSDP/ZeRO axes for parameters
    "expert_capacity": (),
}


def set_mesh_axes(mesh: jax.sharding.Mesh | None,
                  rules: dict | None = None) -> None:
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES if rules is None else rules)


def get_mesh() -> jax.sharding.Mesh | None:
    return getattr(_state, "mesh", None)


def _rules() -> dict:
    return getattr(_state, "rules", DEFAULT_RULES)


def spec_for(logical: Sequence[str | None],
             shape: Sequence[int] | None = None) -> P:
    """Resolve logical names to a PartitionSpec against the active mesh.

    Divisibility-guarded: a physical axis is dropped when it does not
    divide the corresponding dim (if ``shape`` is given)."""
    mesh = get_mesh()
    if mesh is None:
        return P()
    rules = _rules()
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    used: set[str] = set()
    for i, name in enumerate(logical):
        if name is None:
            out.append(None)
            continue
        phys = [a for a in rules.get(name, ()) if a in axis_sizes
                and a not in used]
        if shape is not None:
            size = shape[i]
            keep = []
            prod = 1
            for a in phys:
                if size % (prod * axis_sizes[a]) == 0:
                    keep.append(a)
                    prod *= axis_sizes[a]
            phys = keep
        used.update(phys)
        if not phys:
            out.append(None)
        elif len(phys) == 1:
            out.append(phys[0])
        else:
            out.append(tuple(phys))
    return P(*out)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint on logical axes (no-op without a mesh)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = spec_for(logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def param_spec(path: str, shape: Sequence[int], *, fsdp: bool = True,
               embed_fsdp: bool = True) -> P:
    """PartitionSpec for a parameter, keyed on its tree path.

    Conventions (leading scan axis 'L' handled by the caller):
      embedding (V, D)         -> (vocab, param_embed)
      attn wq   (D, H, Dh)     -> (param_embed, heads, None)
      attn wkv  (D, KV, Dh)    -> (param_embed, kv_heads, None)
      attn wo   (H, Dh, D)     -> (heads, None, param_embed)
      mlp w_in  (D, F)         -> (param_embed, mlp)
      mlp w_out (F, D)         -> (mlp, param_embed)
      moe experts (E, ...)     -> (experts,) + per-matrix rule
      biases/norms (D,)        -> replicated
    """
    leaf = path.split("/")[-1]
    rank = len(shape)
    logical: list[str | None]
    if leaf in ("embedding", "lm_head"):
        logical = ["vocab", "param_embed" if embed_fsdp else None]
    elif leaf in ("wq", "wk", "wv"):
        logical = ["param_embed", "heads", None]
    elif leaf == "wo":
        logical = ["heads", None, "param_embed"]
    elif leaf in ("w_gate", "w_up", "w_in"):
        logical = ["param_embed", "mlp"]
    elif leaf in ("w_down", "w_out"):
        logical = ["mlp", "param_embed"]
    elif leaf.startswith("expert_"):
        sub = {"expert_gate": ["param_embed", "mlp"],
               "expert_up": ["param_embed", "mlp"],
               "expert_down": ["mlp", "param_embed"]}[leaf]
        logical = ["experts"] + sub
    elif leaf == "router":
        logical = ["param_embed", "experts"]
    elif leaf in ("wkv_a", "wq_a"):          # MLA down-projections
        logical = ["param_embed", None]
    elif leaf.startswith("wkv_b") or leaf == "wq_b":
        # MLA up-projections (lora, H, Dh)
        logical = ["param_embed", "heads", None]
    elif leaf in ("w_rec", "w_x", "w_gates"):  # ssm mixers
        logical = ["param_embed", "heads", None][:rank]
    else:
        logical = [None] * rank
    if not fsdp:
        # ZeRO-2 compute layout: weights NOT sharded over the FSDP axis
        # (the optimizer tree keeps full FSDP sharding; GSPMD then emits
        # ONE params all-gather per step instead of per-layer regathers)
        logical = [x if x != "param_embed" else None for x in logical]
    if len(logical) < rank:                   # scanned leading L axis
        logical = [None] * (rank - len(logical)) + logical
    return spec_for(logical, shape)


def param_sharding_tree(params, mesh: jax.sharding.Mesh, *,
                        fsdp: bool = True, embed_fsdp: bool = True):
    """NamedSharding tree for a params pytree (paths joined with '/').

    Arrays under a ``blocks`` list are scanned: their leading layer axis
    is never sharded.  ``fsdp=False`` gives the ZeRO-2 compute layout
    (see param_spec)."""
    def visit(path, leaf):
        keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        pathstr = "/".join(str(k) for k in keys)
        shape = leaf.shape
        scanned = "/blocks/" in f"/{pathstr}/"
        if scanned and len(shape) >= 1:
            spec = param_spec(pathstr, shape[1:], fsdp=fsdp,
                              embed_fsdp=embed_fsdp)
            spec = P(None, *spec)
        else:
            spec = param_spec(pathstr, shape, fsdp=fsdp,
                              embed_fsdp=embed_fsdp)
        return jax.sharding.NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, params)
