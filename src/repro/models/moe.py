"""Mixture-of-Experts block (DeepSeek-V2 style: shared + routed experts,
token-choice top-k routing) with GROUPED capacity dispatch.

Tokens are reshaped into G groups (G ~ the number of data shards) and
each group routes independently:

  router -> top-k -> per-group argsort by expert -> capacity gather
  -> batched expert GLU (einsum over the expert axis, which is sharded
     over the 'model' mesh axis = expert parallelism)
  -> weighted scatter-combine (GSPMD inserts the all-reduce over
     'model'; hillclimbing this collective is one of the §Perf targets)

Everything is static-shape: per-group capacity C = ceil(Ng*K/E * cf);
overflow tokens drop to a dummy slot (standard dropping MoE).  The
group axis is sharded over (pod, data); the expert axis over model.
Load-balance aux loss follows Switch/DeepSeek: E * sum_e f_e p_e.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import sharding as shd
from repro.models.layers import normal


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def init_moe(key, cfg):
    d = cfg.d_model
    e = cfg.moe_num_experts
    f = cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    p = {
        "router": normal(ks[0], (d, e), s_in, jnp.float32),
        "expert_gate": normal(ks[1], (e, d, f), s_in, _dt(cfg)),
        "expert_up": normal(ks[2], (e, d, f), s_in, _dt(cfg)),
        "expert_down": normal(ks[3], (e, f, d), s_out, _dt(cfg)),
    }
    if cfg.moe_num_shared:
        fs = f * cfg.moe_num_shared
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": normal(kk[0], (d, fs), s_in, _dt(cfg)),
            "w_up": normal(kk[1], (d, fs), s_in, _dt(cfg)),
            "w_down": normal(kk[2], (fs, d), 1.0 / math.sqrt(fs),
                             _dt(cfg)),
        }
    return p


def _num_groups(n: int, target: int) -> int:
    g = min(target, n)
    while n % g:
        g -= 1
    return g


def moe_block(params, x, cfg, *, group_target: int = 32):
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    n = b * s
    g = _num_groups(n, group_target)
    ng = n // g
    cap = max(1, int(math.ceil(ng * k / e * cfg.moe_capacity_factor)))

    xf = x.reshape(g, ng, d)
    xf = shd.shard(xf, "batch", None, None)

    logits = (xf.astype(jnp.float32) @ params["router"])      # (G,Ng,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                    # (G,Ng,K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch): E * sum_e fraction_e * prob_e
    me = jnp.mean(probs, axis=(0, 1))                         # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=2),
        axis=(0, 1)) / k
    aux = e * jnp.sum(me * ce)

    # --- per-group dispatch ---------------------------------------
    ef = top_e.reshape(g, ng * k)                             # flat choices
    wf = top_w.reshape(g, ng * k).astype(x.dtype)
    order = jnp.argsort(ef, axis=-1)
    sorted_e = jnp.take_along_axis(ef, order, axis=-1)
    sorted_tok = order // k                                   # token ids
    sorted_w = jnp.take_along_axis(wf, order, axis=-1)
    counts = jax.vmap(lambda se: jnp.bincount(se, length=e))(sorted_e)
    start = jnp.cumsum(counts, axis=-1) - counts              # (G,E)
    pos = (jnp.arange(ng * k)[None, :]
           - jnp.take_along_axis(start, sorted_e, axis=-1))
    keep = pos < cap
    dst = jnp.where(keep, sorted_e * cap + pos, e * cap)      # dummy slot

    def scatter_i32(dstg, valg):
        return jnp.zeros((e * cap + 1,), valg.dtype).at[dstg].set(valg)

    disp_tok = jax.vmap(scatter_i32)(
        dst, jnp.where(keep, sorted_tok, ng))[:, :-1]         # (G,E*C)
    disp_w = jax.vmap(scatter_i32)(
        dst, jnp.where(keep, sorted_w, 0.0))[:, :-1]

    xpad = jnp.concatenate([xf, jnp.zeros((g, 1, d), xf.dtype)], axis=1)
    xs = jnp.take_along_axis(xpad, disp_tok[..., None], axis=1)
    xs = xs.reshape(g, e, cap, d)
    xs = shd.shard(xs, "batch", "experts", None, None)

    # --- expert computation (expert axis sharded over 'model') ----
    h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", xs,
                                params["expert_gate"]))
         * jnp.einsum("gecd,edf->gecf", xs, params["expert_up"]))
    h = shd.shard(h, "batch", "experts", None, "mlp")
    ys = jnp.einsum("gecf,efd->gecd", h, params["expert_down"])
    ys = ys.reshape(g, e * cap, d) * disp_w[..., None]

    # --- combine ----------------------------------------------------
    def combine(tok_g, ys_g):
        out = jnp.zeros((ng + 1, d), ys_g.dtype)
        return out.at[tok_g].add(ys_g)[:ng]

    out = jax.vmap(combine)(disp_tok, ys)
    out = shd.shard(out, "batch", None, None)
    out = out.reshape(b, s, d)

    if "shared" in params:
        sp = params["shared"]
        hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        out = out + hs @ sp["w_down"]
    return out, aux
