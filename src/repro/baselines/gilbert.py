"""Gilbert's algorithm [18] for the polytope distance / C-Hull problem,
as analyzed for hard-margin SVM by Gartner & Jaggi [17].

We seek the min-norm point of the Minkowski-difference polytope
S = conv{x_i^+} (-) conv{x_j^-}.  Gilbert iterates:

    z_t            current point (= A eta - B xi, weights maintained)
    v_t            support vertex: argmin_{s in S} <z_t, s>
                   = a_{i*} - b_{j*},  i* = argmin_i <z, a_i>,
                                       j* = argmax_j <z, b_j>
    z_{t+1}        nearest point to origin on segment [z_t, v_t]

Each iteration is O(nd) (the two argext scans) -- the paper's stated
O(nd / eps beta^2) total.  The convex weights (eta, xi) are carried so
the SVM (w, b, margin) can be reported exactly like Saddle-SVC.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class GilbertState(NamedTuple):
    z: jax.Array       # (d,) current point of S
    eta: jax.Array     # (n1,)
    xi: jax.Array      # (n2,)
    t: jax.Array


def init_state(xp: jax.Array, xm: jax.Array) -> GilbertState:
    n1, n2 = xp.shape[0], xm.shape[0]
    eta = jnp.zeros((n1,)).at[0].set(1.0)
    xi = jnp.zeros((n2,)).at[0].set(1.0)
    return GilbertState(z=xp[0] - xm[0], eta=eta, xi=xi,
                        t=jnp.zeros((), jnp.int32))


def gilbert_step(state: GilbertState, xp: jax.Array,
                 xm: jax.Array) -> GilbertState:
    z = state.z
    sp = xp @ z                       # (n1,)
    sm = xm @ z                       # (n2,)
    i_star = jnp.argmin(sp)
    j_star = jnp.argmax(sm)
    v = xp[i_star] - xm[j_star]
    dzv = z - v
    denom = jnp.sum(dzv * dzv)
    t_step = jnp.where(denom > 1e-30,
                       jnp.clip(jnp.dot(z, dzv) / denom, 0.0, 1.0), 0.0)
    z_new = (1.0 - t_step) * z + t_step * v
    eta = (1.0 - t_step) * state.eta
    eta = eta.at[i_star].add(t_step)
    xi = (1.0 - t_step) * state.xi
    xi = xi.at[j_star].add(t_step)
    return GilbertState(z=z_new, eta=eta, xi=xi, t=state.t + 1)


@functools.partial(jax.jit, static_argnames=("num_steps",))
def run_chunk(state: GilbertState, xp: jax.Array, xm: jax.Array,
              num_steps: int) -> GilbertState:
    def body(st, _):
        return gilbert_step(st, xp, xm), None
    state, _ = jax.lax.scan(body, state, None, length=num_steps)
    return state


class GilbertResult(NamedTuple):
    state: GilbertState
    history: list          # [(iter, objective)]


def solve(xp, xm, *, num_iters: int = 1000, tol: float = 0.0,
          record_every: int | None = None) -> GilbertResult:
    xp = jnp.asarray(xp, jnp.float32)
    xm = jnp.asarray(xm, jnp.float32)
    state = init_state(xp, xm)
    chunk = record_every or num_iters
    history = []
    done = 0
    prev_obj = np.inf
    while done < num_iters:
        ns = min(chunk, num_iters - done)
        state = run_chunk(state, xp, xm, ns)
        done += ns
        obj = float(0.5 * jnp.sum(state.z ** 2))
        history.append((done, obj))
        if tol > 0.0 and prev_obj - obj < tol:
            break
        prev_obj = obj
    return GilbertResult(state=state, history=history)


def objective(state: GilbertState) -> float:
    return float(0.5 * jnp.sum(state.z ** 2))
