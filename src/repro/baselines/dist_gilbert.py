"""Distributed Gilbert algorithm (Liu et al. [28]) -- the prior-art
distributed hard-margin baseline with O(kd / eps) communication.

Protocol per iteration (server/clients):
  1. server broadcasts the current point z           (k * d scalars)
  2. each client scans its local points and returns its best support
     candidates a_i* (argmin <z, a>) and b_j* (argmax <z, b>)
                                                     (k * 2d scalars)
  3. server picks the global extrema, line-searches, updates z (local)

So each iteration costs 3kd scalars -- contrast with Saddle-DSVC's O(k).
Implemented over stacked (k, m, d) client shards with masks (single-host
simulation, same partitioning helper as Saddle-DSVC).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import shard_points


class DistGilbertState(NamedTuple):
    z: jax.Array
    t: jax.Array


@functools.partial(jax.jit, static_argnames=("num_steps",))
def run_chunk(state, xp_sh, mask_p, xm_sh, mask_m, num_steps: int):
    def body(st, _):
        z = st.z
        # each client: local candidates (masked scan)
        sp = jnp.einsum("kmd,d->km", xp_sh, z)
        sm = jnp.einsum("kmd,d->km", xm_sh, z)
        sp = jnp.where(mask_p, sp, jnp.inf)
        sm = jnp.where(mask_m, sm, -jnp.inf)
        # server: global extrema over the k candidates
        ip = jnp.argmin(sp.min(axis=1))
        jp = jnp.argmin(sp[ip])
        im = jnp.argmax(sm.max(axis=1))
        jm = jnp.argmax(sm[im])
        v = xp_sh[ip, jp] - xm_sh[im, jm]
        dzv = z - v
        denom = jnp.sum(dzv * dzv)
        t_step = jnp.where(denom > 1e-30,
                           jnp.clip(jnp.dot(z, dzv) / denom, 0.0, 1.0), 0.0)
        return DistGilbertState(z=(1 - t_step) * z + t_step * v,
                                t=st.t + 1), None

    state, _ = jax.lax.scan(body, state, None, length=num_steps)
    return state


class CommModel(NamedTuple):
    k: int
    d: int

    def scalars_per_iteration(self) -> float:
        return 3.0 * self.k * self.d

    def total(self, iters: int) -> float:
        return self.scalars_per_iteration() * iters


def solve(xp, xm, *, k: int = 20, num_iters: int = 1000,
          record_every: int | None = None):
    xp = np.asarray(xp, np.float32)
    xm = np.asarray(xm, np.float32)
    d = xp.shape[1]
    xp_sh, mask_p = shard_points(xp, k)
    xm_sh, mask_m = shard_points(xm, k)
    xp_sh, xm_sh = jnp.asarray(xp_sh), jnp.asarray(xm_sh)
    mask_p, mask_m = jnp.asarray(mask_p), jnp.asarray(mask_m)
    state = DistGilbertState(z=xp_sh[0, 0] - xm_sh[0, 0],
                             t=jnp.zeros((), jnp.int32))
    comm = CommModel(k=k, d=d)
    history = []
    chunk = record_every or num_iters
    done = 0
    while done < num_iters:
        ns = min(chunk, num_iters - done)
        state = run_chunk(state, xp_sh, mask_p, xm_sh, mask_m, ns)
        done += ns
        obj = float(0.5 * jnp.sum(state.z ** 2))
        history.append((done, comm.total(done), obj))
    return state, history, comm
