"""MDM (Mitchell--Demyanov--Malozemov [31]) for the min-norm-point
problem over a single polytope conv{p_1..p_n} -- the related-work
baseline analyzed by Lopez & Dorronsoro [29] (O(n^2 d log 1/eps)).

Each iteration moves weight from the *support* vertex most aligned with
z to the vertex least aligned with z (a pairwise exchange), with an
exact line search.  For the two-class SVM experiments the paper's
baseline is Gilbert; MDM is validated against Gilbert on min-norm-point
instances (see tests).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class MDMState(NamedTuple):
    lam: jax.Array     # (n,) convex weights
    z: jax.Array       # (d,) = P^T lam
    t: jax.Array


def init_state(points: jax.Array) -> MDMState:
    n = points.shape[0]
    lam = jnp.full((n,), 1.0 / n)
    return MDMState(lam=lam, z=lam @ points, t=jnp.zeros((), jnp.int32))


def mdm_step(state: MDMState, points: jax.Array) -> MDMState:
    z, lam = state.z, state.lam
    scores = points @ z                           # (n,)
    # worst support vertex (max score among lam > 0), best overall (min).
    masked = jnp.where(lam > 1e-12, scores, -jnp.inf)
    i_max = jnp.argmax(masked)
    i_min = jnp.argmin(scores)
    diff = points[i_min] - points[i_max]          # transfer direction
    denom = jnp.sum(diff * diff)
    t_unc = jnp.where(denom > 1e-30, -jnp.dot(z, diff) / denom, 0.0)
    t_step = jnp.clip(t_unc, 0.0, lam[i_max])     # cannot exceed donor mass
    lam = lam.at[i_max].add(-t_step).at[i_min].add(t_step)
    return MDMState(lam=lam, z=z + t_step * diff, t=state.t + 1)


@functools.partial(jax.jit, static_argnames=("num_steps",))
def run_chunk(state: MDMState, points: jax.Array, num_steps: int):
    def body(st, _):
        return mdm_step(st, points), None
    state, _ = jax.lax.scan(body, state, None, length=num_steps)
    return state


def solve(points, *, num_iters: int = 1000,
          record_every: int | None = None):
    points = jnp.asarray(points, jnp.float32)
    state = init_state(points)
    chunk = record_every or num_iters
    history = []
    done = 0
    while done < num_iters:
        ns = min(chunk, num_iters - done)
        state = run_chunk(state, points, ns)
        done += ns
        history.append((done, float(0.5 * jnp.sum(state.z ** 2))))
    return state, history
