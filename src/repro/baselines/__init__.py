"""Baseline algorithms the paper compares against (all in JAX):

gilbert        -- Gilbert algorithm for polytope distance (hard margin)
mdm            -- Mitchell-Demyanov-Malozemov min-norm-point (related work)
qp_nusvm       -- projected-gradient QP for RC-Hull (NuSVC stand-in)
pegasos        -- primal SGD for C-SVM (LinearSVC stand-in)
dist_gilbert   -- distributed Gilbert (Liu et al. 16) with comm counting
hogwild        -- stale-gradient simulation of HOGWILD! (semantic port)
"""
