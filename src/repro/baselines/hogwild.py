"""HOGWILD!-style [37] asynchronous SGD for C-SVM -- semantic port.

True HOGWILD! relies on lock-free shared-memory races between CPU
threads; XLA/TPU has no analogue (DESIGN.md assumption log #5).  We
implement the standard *stale-gradient simulation*: k workers each
compute a hinge-loss gradient against a parameter snapshot that is
``staleness`` updates old, and the server applies the k updates
sequentially.  Communication per round: each worker ships a gradient
(d scalars) and reads w back (d scalars) -> 2kd scalars, the quantity
plotted against Saddle-DSVC's O(k) in Figure 6.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class HogwildState(NamedTuple):
    w: jax.Array          # (d,) current
    w_stale: jax.Array    # (d,) snapshot workers read
    b: jax.Array
    t: jax.Array


@functools.partial(jax.jit,
                   static_argnames=("lam", "batch", "k", "num_steps",
                                    "staleness"))
def run_chunk(state, key, x, y, lam: float, batch: int, k: int,
              staleness: int, num_steps: int):
    n = x.shape[0]

    def body(st, kk):
        # k workers compute gradients against the stale snapshot
        idx = jax.random.randint(kk, (k, batch), 0, n)
        xb = x[idx]                       # (k, batch, d)
        yb = y[idx]
        margin = yb * (jnp.einsum("kbd,d->kb", xb, st.w_stale) - st.b)
        viol = (margin < 1.0).astype(jnp.float32)
        gw = lam * st.w_stale - jnp.einsum("kb,kbd->kd", viol * yb,
                                           xb) / batch
        gb = jnp.sum(viol * yb, axis=1) / batch
        step = 1.0 / (lam * (st.t + 1.0))
        # server applies the k updates sequentially (sum)
        w = st.w - step * jnp.sum(gw, axis=0) / k
        b = st.b - step * jnp.sum(gb) / k
        # snapshot refresh every `staleness` rounds
        refresh = (jnp.mod(st.t, staleness) == staleness - 1)
        w_stale = jnp.where(refresh, w, st.w_stale)
        return HogwildState(w, w_stale, b, st.t + 1.0), None

    keys = jax.random.split(key, num_steps)
    state, _ = jax.lax.scan(body, state, keys)
    return state


class CommModel(NamedTuple):
    k: int
    d: int

    def scalars_per_iteration(self) -> float:
        return 2.0 * self.k * self.d

    def total(self, iters: int) -> float:
        return self.scalars_per_iteration() * iters


def solve(x, y, *, k: int = 20, lam: float = 1e-3, batch: int = 8,
          staleness: int = 4, num_iters: int = 2000, seed: int = 0,
          record_every: int | None = None):
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    d = x.shape[1]
    state = HogwildState(jnp.zeros((d,)), jnp.zeros((d,)), jnp.zeros(()),
                         jnp.zeros(()))
    comm = CommModel(k=k, d=d)
    key = jax.random.key(seed)
    history = []
    chunk = record_every or num_iters
    done = 0
    while done < num_iters:
        key, sub = jax.random.split(key)
        ns = min(chunk, num_iters - done)
        state = run_chunk(state, sub, x, y, float(lam), batch, k,
                          staleness, ns)
        done += ns
        margin = y * (x @ state.w - state.b)
        acc = float(jnp.mean((margin > 0).astype(jnp.float32)))
        history.append((done, comm.total(done), acc))
    return state, history, comm
