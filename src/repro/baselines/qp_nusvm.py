"""Projected-gradient QP solver for the RC-Hull problem (6) -- the
stand-in for LIBSVM's NuSVC (QP-based, Omega(n^2 d) worst case).

    min_{eta, xi}  0.5 || A eta - B xi ||^2
    s.t.  ||eta||_1 = ||xi||_1 = 1,  0 <= eta_i, xi_j <= nu

Accelerated projected gradient (FISTA) with EXACT Euclidean projection
onto the capped simplex {0 <= v <= nu, sum v = 1} via bisection on the
shift lambda in  v_i = clip(y_i - lambda, 0, nu).

Setting nu >= 1 recovers plain C-Hull (hard-margin dual), so this also
serves as the generic QP oracle in tests.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


def project_capped_simplex(y: jax.Array, nu: float,
                           iters: int = 60) -> jax.Array:
    """Euclidean projection onto {0 <= v <= nu, sum v = 1} (bisection)."""
    lo = jnp.min(y) - 1.0
    hi = jnp.max(y)

    def body(_, bounds):
        lo, hi = bounds
        mid = 0.5 * (lo + hi)
        s = jnp.sum(jnp.clip(y - mid, 0.0, nu))
        # s is decreasing in mid; want s == 1
        lo = jnp.where(s > 1.0, mid, lo)
        hi = jnp.where(s > 1.0, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return jnp.clip(y - 0.5 * (lo + hi), 0.0, nu)


class QPState(NamedTuple):
    eta: jax.Array
    xi: jax.Array
    eta_m: jax.Array    # FISTA extrapolation point
    xi_m: jax.Array
    tk: jax.Array


@functools.partial(jax.jit, static_argnames=("num_steps", "nu", "lr"))
def run_chunk(state: QPState, xp: jax.Array, xm: jax.Array, nu: float,
              lr: float, num_steps: int) -> QPState:
    def body(st, _):
        diff = st.eta_m @ xp - st.xi_m @ xm        # A eta - B xi
        g_eta = xp @ diff
        g_xi = -(xm @ diff)
        eta_new = project_capped_simplex(st.eta_m - lr * g_eta, nu)
        xi_new = project_capped_simplex(st.xi_m - lr * g_xi, nu)
        tk_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * st.tk ** 2))
        mom = (st.tk - 1.0) / tk_new
        eta_m = eta_new + mom * (eta_new - st.eta)
        xi_m = xi_new + mom * (xi_new - st.xi)
        return QPState(eta_new, xi_new, eta_m, xi_m, tk_new), None

    state, _ = jax.lax.scan(body, state, None, length=num_steps)
    return state


def solve(xp, xm, nu: float = 1.0, *, num_iters: int = 2000,
          lr: float | None = None, record_every: int | None = None):
    """FISTA on RC-Hull.  lr defaults to 1/L with L = lambda_max estimated
    by power iteration on [A;B]^T[A;B] (cheap, one-time)."""
    xp = jnp.asarray(xp, jnp.float32)
    xm = jnp.asarray(xm, jnp.float32)
    n1, n2 = xp.shape[0], xm.shape[0]
    if lr is None:
        v = jnp.ones((xp.shape[1],)) / jnp.sqrt(xp.shape[1])
        for _ in range(20):
            v2 = xp.T @ (xp @ v) + xm.T @ (xm @ v)
            v = v2 / jnp.maximum(jnp.linalg.norm(v2), 1e-30)
        L = float(jnp.dot(v, xp.T @ (xp @ v) + xm.T @ (xm @ v)))
        lr = 1.0 / max(L, 1e-12)
    eta0 = jnp.full((n1,), 1.0 / n1)
    xi0 = jnp.full((n2,), 1.0 / n2)
    state = QPState(eta0, xi0, eta0, xi0, jnp.ones(()))
    history = []
    chunk = record_every or num_iters
    done = 0
    while done < num_iters:
        ns = min(chunk, num_iters - done)
        state = run_chunk(state, xp, xm, float(nu), float(lr), ns)
        done += ns
        diff = state.eta @ xp - state.xi @ xm
        history.append((done, float(0.5 * jnp.sum(diff * diff))))
    return state, history
