"""Pegasos [39]: primal stochastic sub-gradient solver for C-SVM
(hinge loss + l2), the LinearSVC / primal-SGD stand-in.

    min_w  lambda/2 ||w||^2 + (1/n) sum_i max(0, 1 - y_i w.x_i)

Mini-batch variant with the 1/(lambda t) step size and the optional
1/sqrt(lambda) ball projection from the paper.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class PegasosState(NamedTuple):
    w: jax.Array
    b: jax.Array
    t: jax.Array


@functools.partial(jax.jit, static_argnames=("lam", "batch", "num_steps"))
def run_chunk(state: PegasosState, key: jax.Array, x: jax.Array,
              y: jax.Array, lam: float, batch: int,
              num_steps: int) -> PegasosState:
    n = x.shape[0]

    def body(st, k):
        idx = jax.random.randint(k, (batch,), 0, n)
        xb, yb = x[idx], y[idx]
        margin = yb * (xb @ st.w - st.b)
        viol = (margin < 1.0).astype(jnp.float32)
        step = 1.0 / (lam * (st.t + 1.0))
        grad_w = lam * st.w - (viol * yb) @ xb / batch
        grad_b = jnp.sum(viol * yb) / batch
        w = st.w - step * grad_w
        b = st.b - step * grad_b
        # optional projection onto the 1/sqrt(lam) ball
        norm = jnp.linalg.norm(w)
        w = w * jnp.minimum(1.0, 1.0 / (jnp.sqrt(lam) * norm + 1e-30))
        return PegasosState(w, b, st.t + 1.0), None

    keys = jax.random.split(key, num_steps)
    state, _ = jax.lax.scan(body, state, keys)
    return state


def solve(x, y, *, lam: float = 1e-3, batch: int = 32,
          num_iters: int = 2000, seed: int = 0,
          record_every: int | None = None):
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    d = x.shape[1]
    state = PegasosState(jnp.zeros((d,)), jnp.zeros(()), jnp.zeros(()))
    key = jax.random.key(seed)
    history = []
    chunk = record_every or num_iters
    done = 0
    while done < num_iters:
        key, sub = jax.random.split(key)
        ns = min(chunk, num_iters - done)
        state = run_chunk(state, sub, x, y, float(lam), batch, ns)
        done += ns
        margin = y * (x @ state.w - state.b)
        obj = float(0.5 * lam * jnp.sum(state.w ** 2)
                    + jnp.mean(jnp.maximum(0.0, 1.0 - margin)))
        acc = float(jnp.mean((margin > 0).astype(jnp.float32)))
        history.append((done, obj, acc))
    return state, history


def predict(state: PegasosState, x) -> np.ndarray:
    s = np.asarray(jnp.asarray(x, jnp.float32) @ state.w - state.b)
    return np.where(s >= 0, 1, -1)
