"""The four assigned input shapes + per-architecture applicability.

Decode shapes lower ``serve_step`` (ONE token against a seq_len cache),
not train_step.  long_500k requires sub-quadratic serving; the skip list
(full-attention archs, whisper) is asserted here so the dry-run reports
skips explicitly (DESIGN.md section 4)."""

from __future__ import annotations

from typing import NamedTuple


class InputShape(NamedTuple):
    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def applicability(cfg, shape: InputShape) -> tuple[bool, str]:
    """(runs?, reason)."""
    if shape.name == "long_500k":
        if cfg.is_encoder_decoder:
            return False, ("skip: encoder-decoder (whisper) has no 500k "
                           "target-side decode; max target length << 500k")
        if not cfg.supports_long_context():
            return False, ("skip: pure full-attention arch -- long_500k "
                           "requires sub-quadratic serving (SSM/hybrid/"
                           "SWA); see gemma-7b-swa for the dense variant")
        return True, "ok: sub-quadratic (recurrent state / sliding window)"
    if cfg.is_encoder_decoder and shape.name in ("prefill_32k",
                                                 "decode_32k"):
        return True, ("ok (structural): beyond whisper's native 448 "
                      "positions; sinusoidal positions extend")
    return True, "ok"
