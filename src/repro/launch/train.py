"""Production training launcher: pjit the train step over the local
device mesh (or the forced-host-device production mesh) and run.

On this CPU container it runs reduced configs on a 1-device mesh; on a
real pod slice the same entrypoint shards over (data, model).

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --steps 20 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import make_test_mesh
from repro.models import sharding as shd
from repro.models import transformer as tf
from repro.train import optimizer as opt
from repro.train import steps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    mesh = make_test_mesh()
    shd.set_mesh_axes(mesh)
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=10,
                           state_dtype=cfg.optimizer_state_dtype)

    with mesh:
        state = steps.init_train_state(jax.random.key(0), cfg, ocfg)
        # NB: no donation -- with float32 params the fp32 master aliases
        # the param buffers (astype is a no-op copy) and XLA rejects
        # donating the same buffer twice
        train_step = jax.jit(steps.make_train_step(cfg, ocfg))
        pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch)
        print(f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
              f"arch={cfg.name} "
              f"params={tf.count_params(state.params):,}")
        t0 = time.time()
        for step in range(args.steps):
            nb = pipe.next_batch()
            batch = {"tokens": jnp.asarray(nb.tokens),
                     "targets": jnp.asarray(nb.targets)}
            if cfg.vision_embeds:
                b, s = nb.tokens.shape
                batch["vision_embeds"] = jnp.zeros((b, s, cfg.d_model))
                batch["vision_mask"] = jnp.zeros((b, s), bool)
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(s, dtype=jnp.int32)[None, None],
                    (3, b, s))
            if cfg.is_encoder_decoder:
                batch["enc_frames"] = jnp.zeros(
                    (nb.tokens.shape[0], cfg.enc_frames, cfg.d_model))
            state, m = train_step(state, batch)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {float(m['loss']):.4f} "
                      f"({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()
