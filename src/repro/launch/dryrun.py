import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# (the two lines above MUST run before any jax import -- jax locks the
#  device count on first init; see the brief / DESIGN.md)

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import get_config, list_configs          # noqa: E402
from repro.launch import specs as specs_mod                 # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.launch.shapes import SHAPES, applicability       # noqa: E402
from repro.utils import roofline as rl                      # noqa: E402

ASSIGNED = [
    "qwen2-vl-7b", "chatglm3-6b", "xlstm-125m", "recurrentgemma-2b",
    "deepseek-v2-236b", "deepseek-v2-lite-16b", "gemma-7b",
    "deepseek-67b", "whisper-medium", "h2o-danube-1.8b",
]


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool,
            verbose: bool = True, unroll: bool = False) -> dict:
    import dataclasses
    cfg = get_config(arch)
    if unroll:
        # unrolled layer stack: XLA's cost_analysis counts a scan body
        # ONCE, so roofline-accurate runs emit every period explicitly
        cfg = dataclasses.replace(cfg, scan_layers=False)
    shape = SHAPES[shape_name]
    ok, reason = applicability(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "applicable": ok, "reason": reason, "unrolled": unroll}
    if not ok:
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
                  f"SKIP ({reason})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        fn, args = specs_mod.build_lowerable(cfg, shape, mesh)
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        roof = rl.analyze(compiled)

    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    mflops_global = rl.model_flops(cfg, shape.kind, tokens)
    n_chips = 512 if multi_pod else 256
    mflops_dev = mflops_global / n_chips

    rec.update({
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": _mem_dict(mem),
        "hlo_flops_per_device": roof.flops,
        "hlo_bytes_per_device": roof.hbm_bytes,
        "collective_bytes_per_device": roof.collective_bytes,
        "collective_breakdown": roof.collectives.bytes_by_op,
        "collective_counts": roof.collectives.count_by_op,
        "compute_s": roof.compute_s,
        "memory_s": roof.memory_s,
        "collective_s": roof.collective_s,
        "bottleneck": roof.bottleneck,
        "model_flops_per_device": mflops_dev,
        "useful_flops_ratio": (mflops_dev / roof.flops
                               if roof.flops else 0.0),
        "mfu_bound": roof.mfu(mflops_dev),
    })
    if verbose:
        mb = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
        arg_gb = rec["memory"].get("argument_size_in_bytes", 0) / 2**30
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK  "
              f"args {arg_gb:.2f} GiB  temps {mb:.2f} GiB/dev  "
              f"compute {roof.compute_s*1e3:.2f} ms  "
              f"memory {roof.memory_s*1e3:.2f} ms  "
              f"collective {roof.collective_s*1e3:.2f} ms  "
              f"-> {roof.bottleneck}-bound  "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
        print("  memory_analysis:", json.dumps(rec["memory"]))
        print("  cost_analysis: flops=%.3e bytes=%.3e coll=%.3e"
              % (roof.flops, roof.hbm_bytes, roof.collective_bytes))
    return rec


def run_one_saddle(shape_name: str, multi_pod: bool,
                   verbose: bool = True) -> dict:
    """Lower + compile the Saddle-DSVC production chunk on the dry-run
    mesh and audit its collectives against the CommModel (Theorem 8):
    the record carries measured-vs-predicted per-iteration collective
    multisets alongside the usual roofline terms."""
    from repro.utils import comm_audit

    shape = specs_mod.SADDLE_DSVC_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": specs_mod.SOLVER_ARCH, "shape": shape_name,
           "mesh": mesh_name, "applicable": True, "reason": "ok"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        fn, args, meta = specs_mod.build_saddle_dsvc_lowerable(mesh, shape)
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        roof = rl.analyze(compiled)

    model = meta["model"]
    counts = comm_audit.audit_hlo(compiled.as_text(), has_step_loop=True)
    predicted = model.collective_multiset(meta["block_size"])
    rec.update({
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": _mem_dict(mem),
        "hlo_flops_per_device": roof.flops,
        "hlo_bytes_per_device": roof.hbm_bytes,
        "collective_bytes_per_device": roof.collective_bytes,
        "collective_breakdown": roof.collectives.bytes_by_op,
        "collective_counts": roof.collectives.count_by_op,
        "comm_audit": {
            "k": meta["k"], "nu": meta["nu"],
            "block_size": meta["block_size"],
            "chunk_steps": meta["chunk_steps"],
            "measured_per_iteration":
                comm_audit.multiset_to_json(counts.per_iteration),
            "predicted_per_iteration":
                comm_audit.multiset_to_json(predicted),
            "match": counts.per_iteration == predicted,
            "per_iteration_count": counts.per_iteration_count,
            "per_iteration_bytes": counts.per_iteration_bytes,
            "per_chunk": comm_audit.multiset_to_json(counts.per_chunk),
            "model_scalars_per_iteration":
                model.scalars_per_iteration(),
        },
    })
    if not rec["comm_audit"]["match"]:
        raise RuntimeError(
            f"saddle-dsvc {shape_name} x {mesh_name}: measured "
            f"collectives {rec['comm_audit']['measured_per_iteration']} "
            f"!= CommModel {rec['comm_audit']['predicted_per_iteration']}")
    if verbose:
        ca = rec["comm_audit"]
        print(f"[dryrun] {specs_mod.SOLVER_ARCH} x {shape_name} x "
              f"{mesh_name}: OK  k={ca['k']}  "
              f"collectives/iter {ca['per_iteration_count']} "
              f"(model {model.collectives_per_iteration(meta['block_size'])})"
              f"  bytes/iter {ca['per_iteration_bytes']}  "
              f"Theorem8 scalars/iter {ca['model_scalars_per_iteration']:.0f}"
              f"  (lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    return rec


def run_one_saddle_serve(shape_name: str, multi_pod: bool,
                         verbose: bool = True) -> dict:
    """Lower + compile the mesh-sharded SERVING slot chunk on the
    dry-run mesh and pin its collectives exactly: the lanes placement
    must compile collective-FREE, the points placement must match
    ``ServeCommModel`` on BOTH the per-iteration and per-chunk
    multisets.  Any mismatch raises."""
    from repro.utils import comm_audit

    shape = specs_mod.SADDLE_SERVE_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": specs_mod.SERVE_ARCH, "shape": shape_name,
           "mesh": mesh_name, "applicable": True, "reason": "ok"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        fn, args, meta = specs_mod.build_saddle_serve_lowerable(mesh,
                                                                shape)
        lowered = jax.jit(fn, donate_argnums=(0,)).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        roof = rl.analyze(compiled)

    model = meta["model"]
    counts = comm_audit.audit_hlo(compiled.as_text(),
                                  has_step_loop=shape.sharded)
    if model is not None:
        predicted = model.collective_multiset(meta["block_size"])
        predicted_chunk = model.per_chunk_multiset(meta["d"])
    else:
        predicted, predicted_chunk = {}, {}
    rec.update({
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": _mem_dict(mem),
        "hlo_flops_per_device": roof.flops,
        "hlo_bytes_per_device": roof.hbm_bytes,
        "collective_bytes_per_device": roof.collective_bytes,
        "comm_audit": {
            "slot_axes": list(meta["slot_axes"]),
            "point_axes": list(meta["point_axes"]),
            "k_slots": meta["k_slots"], "k_points": meta["k_points"],
            "nu": meta["nu"], "num_slots": meta["num_slots"],
            "n_pad": meta["n_pad"],
            "block_size": meta["block_size"],
            "chunk_steps": meta["chunk_steps"],
            "measured_per_iteration":
                comm_audit.multiset_to_json(counts.per_iteration),
            "predicted_per_iteration":
                comm_audit.multiset_to_json(predicted),
            "measured_per_chunk":
                comm_audit.multiset_to_json(counts.per_chunk),
            "predicted_per_chunk":
                comm_audit.multiset_to_json(predicted_chunk),
            "match": (counts.per_iteration == predicted
                      and counts.per_chunk == predicted_chunk),
            "per_iteration_count": counts.per_iteration_count,
            "per_iteration_bytes": counts.per_iteration_bytes,
        },
    })
    if not rec["comm_audit"]["match"]:
        raise RuntimeError(
            f"saddle-serve {shape_name} x {mesh_name}: measured "
            f"collectives iter="
            f"{rec['comm_audit']['measured_per_iteration']} chunk="
            f"{rec['comm_audit']['measured_per_chunk']} != model iter="
            f"{rec['comm_audit']['predicted_per_iteration']} chunk="
            f"{rec['comm_audit']['predicted_per_chunk']}")
    if verbose:
        ca = rec["comm_audit"]
        placement = (f"slots/{'x'.join(ca['slot_axes']) or '-'} "
                     f"points/{'x'.join(ca['point_axes']) or '-'}")
        print(f"[dryrun] {specs_mod.SERVE_ARCH} x {shape_name} x "
              f"{mesh_name}: OK  {placement}  "
              f"S={ca['num_slots']} n_pad={ca['n_pad']}  "
              f"collectives/iter {ca['per_iteration_count']}  "
              f"bytes/iter {ca['per_iteration_bytes']}  "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None,
                    help=f"one of {list_configs()} + "
                         f"'{specs_mod.SOLVER_ARCH}' "
                         f"(default: all assigned)")
    ap.add_argument("--shape", default=None,
                    help=f"one of {sorted(SHAPES)} (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="emit layers unrolled (accurate cost_analysis)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.shape and args.shape not in SHAPES \
            and args.shape not in specs_mod.SADDLE_DSVC_SHAPES \
            and args.shape not in specs_mod.SADDLE_SERVE_SHAPES:
        raise SystemExit(
            f"unknown --shape {args.shape!r}: LM shapes {sorted(SHAPES)}, "
            f"solver shapes {sorted(specs_mod.SADDLE_DSVC_SHAPES)}, "
            f"serve shapes {sorted(specs_mod.SADDLE_SERVE_SHAPES)}")
    solver_only = args.arch == specs_mod.SOLVER_ARCH
    serve_only = args.arch == specs_mod.SERVE_ARCH
    archs = [] if (solver_only or serve_only) \
        else ([args.arch] if args.arch else ASSIGNED)
    # the solver entry has its own shape namespace (point counts, not
    # token shapes), so a --shape pick routes to exactly one of the two
    lm_shapes = ([args.shape] if args.shape in SHAPES
                 else [] if args.shape else list(SHAPES))
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    combos = [(a, s) for a in archs for s in lm_shapes]
    if not args.arch and not args.shape:
        # the dense->SWA variant that licenses long_500k for gemma
        combos.append(("gemma-7b-swa", "long_500k"))

    # saddle-dsvc / saddle-serve join the sweep by default and via --arch
    if solver_only or args.arch is None:
        solver_shapes = (
            [args.shape] if args.shape in specs_mod.SADDLE_DSVC_SHAPES
            else ([] if args.shape else
                  list(specs_mod.SADDLE_DSVC_SHAPES)))
        combos += [(specs_mod.SOLVER_ARCH, s) for s in solver_shapes]
    if serve_only or args.arch is None:
        serve_shapes = (
            [args.shape] if args.shape in specs_mod.SADDLE_SERVE_SHAPES
            else ([] if args.shape else
                  list(specs_mod.SADDLE_SERVE_SHAPES)))
        combos += [(specs_mod.SERVE_ARCH, s) for s in serve_shapes]
    if not combos:
        raise SystemExit(
            f"no (arch, shape) combinations: --arch {args.arch!r} does "
            f"not take --shape {args.shape!r}")

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in combos:
        for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                if args.unroll:
                    tag += "_unrolled"
                try:
                    if arch == specs_mod.SOLVER_ARCH:
                        rec = run_one_saddle(shape, mp)
                    elif arch == specs_mod.SERVE_ARCH:
                        rec = run_one_saddle_serve(shape, mp)
                    else:
                        rec = run_one(arch, shape, mp,
                                      unroll=args.unroll)
                except Exception as e:      # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": str(e)}
                    failures.append(tag)
                with open(os.path.join(args.out, tag + ".json"),
                          "w") as f:
                    json.dump(rec, f, indent=1)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete: all combinations lowered + compiled")


if __name__ == "__main__":
    main()
