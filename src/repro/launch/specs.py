"""ShapeDtypeStruct input specs + step builders + sharding trees for the
dry-run and the real launchers.

``build_lowerable(cfg, shape, mesh)`` returns (fn, args) such that
``jax.jit(fn).lower(*args).compile()`` exercises the full
(architecture x input-shape x mesh) combination with zero device
allocation: every arg is a ShapeDtypeStruct carrying a NamedSharding.
"""

from __future__ import annotations

import functools
import math
from typing import Any, NamedTuple as _NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.shapes import SHAPES, InputShape
from repro.models import sharding as shd
from repro.models import transformer as tf
from repro.serve import engine
from repro.train import optimizer as opt
from repro.train import steps as train_steps

CACHE_DTYPE = jnp.bfloat16


# ----------------------------------------------------------- sharding trees
def _leaf_logical(path: str, shape) -> list:
    """Logical axes for a serve-state / batch leaf, by name + rank."""
    leaf = path.split("/")[-1]
    rank = len(shape)
    if leaf in ("k", "v") and rank == 4:
        # heads over 'model' when divisible (local DUS on decode); the
        # seq dim only takes data/pod leftovers.  MLA caches (below)
        # have no heads dim and keep seq-over-model (memory forces it).
        return ["batch", "kv_seq_bp", "kv_heads", None]
    if leaf == "c_kv" and rank == 3:
        return ["batch", "kv_seq", None]
    if leaf == "k_rope" and rank == 3:
        return ["batch", "kv_seq", None]
    if leaf == "C" and rank == 4:
        return ["batch", "heads", None, None]
    if leaf in ("n", "m", "c", "h") and rank == 3:
        return ["batch", "heads", None]
    if leaf == "h" and rank == 2:
        return ["batch", "mlp"]
    if leaf == "conv" and rank == 3:
        return ["batch", None, "mlp"]
    if leaf == "last_logits" and rank == 2:
        return ["batch", "vocab"]
    if leaf in ("tokens", "targets", "vision_mask") and rank == 2:
        return ["batch", None]
    if leaf in ("vision_embeds", "enc_frames") and rank == 3:
        return ["batch", None, None]
    if leaf == "positions":
        return [None] * (rank - 2) + ["batch", None]
    return [None] * rank


def _tree_paths(tree):
    def keyname(p):
        return str(getattr(p, "key", getattr(p, "idx", getattr(p, "name",
                                                               p))))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: ("/".join(keyname(p) for p in path), leaf),
        tree, is_leaf=lambda x: hasattr(x, "shape"))


def state_sharding(tree, mesh):
    """NamedSharding tree for serve states / batches.  Leaves under a
    ``blocks`` list are scanned (leading layer axis, never sharded)."""
    def visit(path, leaf):
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        shape = leaf.shape
        if "/blocks/" in f"/{keys}/" and len(shape) >= 1:
            spec = shd.spec_for(_leaf_logical(keys, shape[1:]), shape[1:])
            spec = P(None, *spec)
        else:
            spec = shd.spec_for(_leaf_logical(keys, shape), shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, tree)


def train_state_sharding(state_shapes, mesh, cfg=None):
    fsdp = cfg.fsdp_params if cfg is not None else True
    embed_fsdp = cfg.embed_fsdp if cfg is not None else True
    params_sh = shd.param_sharding_tree(state_shapes.params, mesh,
                                        fsdp=fsdp,
                                        embed_fsdp=embed_fsdp)

    def like_params(tree):
        # optimizer states ALWAYS keep full FSDP sharding (ZeRO-2 when
        # the compute params don't)
        return shd.param_sharding_tree(tree, mesh)

    os = state_shapes.opt_state
    opt_sh = opt.OptState(master=like_params(os.master),
                          m=like_params(os.m), v=like_params(os.v),
                          step=NamedSharding(mesh, P()))
    return train_steps.TrainState(params=params_sh, opt_state=opt_sh)


def _with_sharding(shapes_tree, shardings_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, shardings_tree)


# -------------------------------------------------------------- input specs
def batch_specs(cfg, shape: InputShape) -> dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one step of the given shape (train/prefill)."""
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    d = cfg.d_model
    out: dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.kind == "train":
        out["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.vision_embeds and shape.kind != "decode":
        out["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, s, d), jnp.dtype(cfg.compute_dtype))
        out["vision_mask"] = jax.ShapeDtypeStruct((b, s), jnp.bool_)
        out["positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    if cfg.is_encoder_decoder and shape.kind != "decode":
        out["enc_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_frames, d), jnp.dtype(cfg.compute_dtype))
    return out


def input_specs(arch_or_cfg, shape_name: str = "train_4k"):
    """Public entry: ShapeDtypeStruct stand-ins for every model input."""
    from repro.configs import get_config
    cfg = arch_or_cfg if hasattr(arch_or_cfg, "d_model") \
        else get_config(arch_or_cfg)
    return batch_specs(cfg, SHAPES[shape_name])


# -------------------------------------------------- saddle-dsvc (the solver)
SOLVER_ARCH = "saddle-dsvc"


class SaddleDsvcShape(_NamedTuple):
    """Input shape for the distributed Saddle-DSVC dry-run entry:
    clients are mapped over ALL mesh axes (a 16x16 pod is k=256
    clients, the 2x16x16 multi-pod k=512), each holding a round-robin
    shard of the packed +- point set."""
    name: str
    n1: int
    n2: int
    d: int
    nu_frac: float        # 0 => HM-Saddle; else nu = 1 / (nu_frac * n1)
    block_size: int
    chunk_steps: int


SADDLE_DSVC_SHAPES: dict[str, SaddleDsvcShape] = {
    # paper-scale-and-beyond: 1M points, d=256, nu-Saddle block mode
    "svm_1m_nu": SaddleDsvcShape("svm_1m_nu", 1 << 19, 1 << 19, 256,
                                 0.8, 128, 50),
    # hard-margin single-coordinate mode (Algorithm 2 exactly)
    "svm_1m_hm": SaddleDsvcShape("svm_1m_hm", 1 << 19, 1 << 19, 256,
                                 0.0, 1, 50),
}


def saddle_dsvc_client_shape(shape: SaddleDsvcShape, k: int) -> dict:
    """Per-client packed shard shape for ``shape`` round-robined over
    ``k`` clients: the lane-padded point count each client's kernels
    see (``n_pad``), the feature dim ``d`` and the coordinate block
    size ``b``.  This is what the static kernel auditor
    (repro.analysis.pallas_audit) sweeps for the dry-run meshes."""
    from repro.core.preprocess import packed_length

    m = math.ceil(shape.n1 / k) + math.ceil(shape.n2 / k)
    return {"n_pad": packed_length(m), "d": shape.d,
            "b": shape.block_size}


def build_saddle_dsvc_lowerable(mesh, shape: SaddleDsvcShape,
                                backend: str = "jnp"):
    """Returns (fn, args, meta) ready for ``jit(fn).lower(*args)``: the
    PRODUCTION Saddle-DSVC chunk (``distributed.sharded_run_fn``) with
    clients over every mesh axis, all args ShapeDtypeStructs.

    ``meta`` carries (k, params, CommModel) so the dry-run can compare
    the lowered module's measured collectives against the analytic
    model (see repro.utils.comm_audit)."""
    from repro.core import distributed, projections
    from repro.utils import comm_audit

    axis = tuple(mesh.axis_names)
    k = int(math.prod(mesh.devices.shape))
    nu = 1.0 / (shape.nu_frac * shape.n1) if shape.nu_frac else 0.0
    fn, args = comm_audit.runner_lowerable(
        mesh, axis, n1=shape.n1, n2=shape.n2, d=shape.d, nu=nu,
        block_size=shape.block_size, chunk_steps=shape.chunk_steps,
        backend=backend)
    rounds = float(projections.BISECT_ROUNDS_SOLVER) if nu > 0 else 0.0
    model = distributed.CommModel(k=k, nu_rounds_per_iter=rounds)
    meta = {"k": k, "nu": nu, "model": model,
            "block_size": shape.block_size,
            "chunk_steps": shape.chunk_steps}
    return fn, args, meta


# ---------------------------------------------- saddle-serve (mesh serving)
SERVE_ARCH = "saddle-serve"


class SaddleServeShape(_NamedTuple):
    """Input shape for the mesh-sharded serving dry-run entry (the
    ``engine.run_chunk_slots_sharded`` slot chunk).

    ``sharded=False`` is the heavy-traffic LANES placement: the slot
    dim spans every mesh axis, each device owns whole lanes and the
    chunk must lower collective-free.  ``sharded=True`` is the big-fit
    POINTS placement, hybrid over the production meshes: slots span
    the ``model`` axis (independent lane columns) while each lane's
    point dim spans the remaining axes (``data`` / ``pod x data``) and
    runs the Theorem-8 rounds.  ``n1``/``n2`` are PER-SLOT point
    counts; ``num_slots`` is the GLOBAL lane count."""
    name: str
    num_slots: int
    n1: int
    n2: int
    d: int
    nu_frac: float        # 0 => HM; else nu = 1 / (nu_frac * n1)
    block_size: int
    chunk_steps: int
    sharded: bool


SADDLE_SERVE_SHAPES: dict[str, SaddleServeShape] = {
    # heavy traffic: 512 concurrent mid-size nu-SVM fits, 2 (single
    # pod) or 1 (multi-pod) lanes per device, zero collectives
    "serve_lanes_512": SaddleServeShape(
        "serve_lanes_512", 512, 1500, 1400, 64, 0.8, 1, 50, False),
    # big fits: 32 lanes of 1M points each; slots over 'model', points
    # over the data axes -- one serving executable at paper scale
    "serve_points_1m": SaddleServeShape(
        "serve_points_1m", 32, 1 << 19, 1 << 19, 256, 0.8, 128, 50,
        True),
}


def saddle_serve_placement(mesh, shape: SaddleServeShape):
    """(slot_axes, point_axes) of ``shape`` on ``mesh`` -- the single
    source of the production placement rule described on
    :class:`SaddleServeShape`."""
    axes = tuple(mesh.axis_names)
    if not shape.sharded:
        return axes, ()
    if "model" not in axes:
        raise ValueError(
            f"points placement needs a 'model' axis, mesh has {axes}")
    return ("model",), tuple(a for a in axes if a != "model")


def build_saddle_serve_lowerable(mesh, shape: SaddleServeShape,
                                 backend: str = "jnp"):
    """Returns (fn, args, meta) ready for
    ``jit(fn, donate_argnums=(0,)).lower(*args)``: the mesh-sharded
    serving slot chunk with the production placement, all args
    ShapeDtypeStructs.  ``meta`` carries the placement extents and the
    :class:`repro.core.distributed.ServeCommModel` (None for the
    collective-free lanes placement) so the dry-run can pin the
    lowered module's collectives exactly."""
    from repro.core import distributed, projections
    from repro.core.preprocess import bucket_length
    from repro.utils import comm_audit

    slot_axes, point_axes = saddle_serve_placement(mesh, shape)
    ks = int(math.prod(mesh.shape[a] for a in slot_axes)) \
        if slot_axes else 1
    kp = int(math.prod(mesh.shape[a] for a in point_axes)) \
        if point_axes else 1
    if shape.num_slots % ks:
        raise ValueError(
            f"{shape.name}: num_slots={shape.num_slots} not divisible "
            f"by the slot-axes extent {ks}")
    n = shape.n1 + shape.n2
    # the service bucket rule: per-shard lane-aligned power-of-2 rung
    n_pad = kp * bucket_length(-(-n // kp)) if point_axes \
        else bucket_length(n)
    nu = 1.0 / (shape.nu_frac * shape.n1) if shape.nu_frac else 0.0
    fn, args = comm_audit.serve_runner_lowerable(
        mesh, num_slots=shape.num_slots, n_pad=n_pad, d=shape.d, nu=nu,
        block_size=shape.block_size, chunk_steps=shape.chunk_steps,
        backend=backend, slot_axes=slot_axes, point_axes=point_axes)
    model = None
    if point_axes:
        rounds = float(projections.BISECT_ROUNDS_SOLVER) if nu > 0 \
            else 0.0
        model = distributed.ServeCommModel(
            k=kp, num_slots=shape.num_slots // ks,
            nu_rounds_per_iter=rounds)
    meta = {"slot_axes": slot_axes, "point_axes": point_axes,
            "k_slots": ks, "k_points": kp, "nu": nu, "model": model,
            "num_slots": shape.num_slots, "n_pad": n_pad, "d": shape.d,
            "block_size": shape.block_size,
            "chunk_steps": shape.chunk_steps}
    return fn, args, meta


# ------------------------------------------------------------ step builders
def opt_config(cfg) -> opt.AdamWConfig:
    return opt.AdamWConfig(state_dtype=cfg.optimizer_state_dtype)


def build_lowerable(cfg, shape: InputShape, mesh):
    """Returns (fn, args_pytree) ready for jit(fn).lower(*args)."""
    shd.set_mesh_axes(mesh)
    if shape.kind == "train":
        ocfg = opt_config(cfg)
        state_shapes = jax.eval_shape(
            lambda: train_steps.init_train_state(jax.random.key(0), cfg,
                                                 ocfg))
        state_sh = train_state_sharding(state_shapes, mesh, cfg)
        state_in = _with_sharding(state_shapes, state_sh)
        batch = batch_specs(cfg, shape)
        batch_in = _with_sharding(batch, state_sharding(batch, mesh))
        step = train_steps.make_train_step(cfg, ocfg)
        return step, (state_in, batch_in)

    if shape.kind == "prefill":
        params_shapes = jax.eval_shape(
            lambda: tf.init_lm(jax.random.key(0), cfg))
        params_in = _with_sharding(
            params_shapes,
            shd.param_sharding_tree(params_shapes, mesh,
                                    fsdp=cfg.fsdp_params,
                                    embed_fsdp=cfg.embed_fsdp))
        batch = batch_specs(cfg, shape)
        batch_in = _with_sharding(batch, state_sharding(batch, mesh))

        def prefill_step(params, batch):
            extras = {k: v for k, v in batch.items() if k != "tokens"}
            if "positions" in extras:
                extras.pop("positions")
            return engine.prefill(params, cfg, batch["tokens"],
                                  max_len=shape.seq_len,
                                  cache_dtype=CACHE_DTYPE, **extras)

        return prefill_step, (params_in, batch_in)

    # decode: one token against a seq_len cache
    params_shapes = jax.eval_shape(
        lambda: tf.init_lm(jax.random.key(0), cfg))
    params_in = _with_sharding(
        params_shapes,
        shd.param_sharding_tree(params_shapes, mesh,
                                fsdp=cfg.fsdp_params,
                                embed_fsdp=cfg.embed_fsdp))
    b = shape.global_batch

    def make_state():
        cache = engine.init_cache(cfg, b, shape.seq_len,
                                  cache_dtype=CACHE_DTYPE)
        return engine.ServeState(
            cache=cache,
            last_logits=jnp.zeros((b, cfg.padded_vocab),
                                  jnp.dtype(cfg.compute_dtype)),
            pos=jnp.full((), shape.seq_len - 1, jnp.int32))

    state_shapes = jax.eval_shape(make_state)
    state_in = _with_sharding(state_shapes,
                              state_sharding(state_shapes, mesh))
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32,
                                  sharding=NamedSharding(
                                      mesh, shd.spec_for(
                                          ["batch", None], (b, 1))))

    def serve_step(params, tokens, state):
        return engine.decode_step(params, cfg, tokens, state)

    return serve_step, (params_in, tokens, state_in)
