"""Production meshes.

Functions (not module constants) so importing never touches jax device
state -- the dry-run forces 512 host devices before calling these."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: 16x16 (data, model).  Multi-pod: 2x16x16
    (pod, data, model) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int | None = None) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (unit tests)."""
    n = devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
