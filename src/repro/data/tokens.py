"""Synthetic LM token pipeline for the model zoo (deterministic,
shardable, no external corpora -- this container is offline).

Produces an infinite stream of (tokens, targets) batches from a mixture
of Zipf-distributed unigrams and short Markov motifs, so losses fall
smoothly during the example training runs (unlike uniform noise).
"""

from __future__ import annotations

from typing import Iterator, NamedTuple

import numpy as np


class Batch(NamedTuple):
    tokens: np.ndarray    # (B, S) int32
    targets: np.ndarray   # (B, S) int32  (tokens shifted left)


class TokenPipeline:
    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 *, seed: int = 0, motif_len: int = 8,
                 num_motifs: int = 512):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        v = min(vocab_size, 50_000)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.vocab_used = v
        self.motifs = self.rng.integers(
            0, v, size=(num_motifs, motif_len)).astype(np.int32)

    def __iter__(self) -> Iterator[Batch]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> Batch:
        b, s = self.batch_size, self.seq_len
        toks = self.rng.choice(self.vocab_used, size=(b, s + 1),
                               p=self.probs).astype(np.int32)
        # splice motifs (so there is learnable local structure)
        n_splice = max(1, s // (4 * self.motifs.shape[1]))
        for i in range(b):
            for _ in range(n_splice):
                m = self.motifs[self.rng.integers(len(self.motifs))]
                pos = self.rng.integers(0, s + 1 - len(m))
                toks[i, pos:pos + len(m)] = m
        return Batch(tokens=toks[:, :-1], targets=toks[:, 1:])
