"""Synthetic data sets exactly as described in paper Appendix D.

Three generators:
  * separable        -- random hyperplane H through the unit ball; n
                        points sampled so the max/min distance ratio to
                        H is controlled by beta1 (default 0.1); labels
                        by side of H.
  * non_separable    -- same, but points with |dist to H| < beta2 get a
                        uniformly random label (the noisy band).
  * sparse           -- non-separable with exactly nnz non-zero
                        coordinates per point.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class Dataset(NamedTuple):
    x: np.ndarray      # (n, d) float32
    y: np.ndarray      # (n,) in {+1, -1}

    def split(self, test_frac: float = 0.1, seed: int = 0):
        rng = np.random.default_rng(seed)
        n = len(self.y)
        perm = rng.permutation(n)
        k = int(n * (1.0 - test_frac))
        tr, te = perm[:k], perm[k:]
        return (Dataset(self.x[tr], self.y[tr]),
                Dataset(self.x[te], self.y[te]))


def _hyperplane(rng, d):
    w = rng.normal(size=d)
    return w / np.linalg.norm(w)


def separable(n: int, d: int, *, beta1: float = 0.1,
              seed: int = 0) -> Dataset:
    """Linearly separable set with margin/diameter ratio ~= beta1."""
    rng = np.random.default_rng(seed)
    w = _hyperplane(rng, d)
    # sample directions in the ball, then push each point away from H so
    # that distances lie in [beta1 * R, R] with R chosen to fit the ball
    x = rng.normal(size=(n, d))
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    x *= rng.uniform(0.0, 1.0, size=(n, 1)) ** (1.0 / d)
    side = np.where(rng.random(n) < 0.5, 1.0, -1.0)
    r_max = 0.5
    dist = rng.uniform(beta1 * r_max, r_max, size=n)
    proj = x - np.outer(x @ w, w)                # component parallel to H
    proj *= 0.5                                  # keep inside the ball
    x = proj + np.outer(side * dist, w)
    y = side.astype(np.int64)
    return Dataset(x.astype(np.float32), y)


def non_separable(n: int, d: int, *, beta2: float = 0.1,
                  seed: int = 0) -> Dataset:
    """Separable construction + random labels inside the beta2 band."""
    rng = np.random.default_rng(seed)
    w = _hyperplane(rng, d)
    x = rng.normal(size=(n, d))
    x /= np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)
    x *= rng.uniform(0.0, 1.0, size=(n, 1)) ** (1.0 / d)
    signed = x @ w
    y = np.where(signed > 0, 1, -1)
    band = np.abs(signed) < beta2 * 0.5
    flips = rng.random(n) < 0.5
    y = np.where(band & flips, -y, y).astype(np.int64)
    return Dataset(x.astype(np.float32), y)


def sparse_non_separable(n: int, d: int, *, nnz: int, beta2: float = 0.1,
                         seed: int = 0) -> Dataset:
    """Each point has exactly ``nnz`` non-zero coordinates."""
    rng = np.random.default_rng(seed)
    ds = non_separable(n, d, beta2=beta2, seed=seed)
    x = ds.x.copy()
    for i in range(n):
        keep = rng.choice(d, size=nnz, replace=False)
        mask = np.zeros(d, bool)
        mask[keep] = True
        x[i, ~mask] = 0.0
    return Dataset(x, ds.y)


def blobs(n1: int, n2: int, d: int, *, gap: float = 1.0,
          spread: float = 0.3, seed: int = 0) -> Dataset:
    """Two Gaussian blobs (quick fixtures for tests)."""
    rng = np.random.default_rng(seed)
    c = np.zeros(d)
    c[0] = gap / 2
    xp = rng.normal(size=(n1, d)) * spread + c
    xm = rng.normal(size=(n2, d)) * spread - c
    x = np.vstack([xp, xm]).astype(np.float32)
    y = np.concatenate([np.ones(n1), -np.ones(n2)]).astype(np.int64)
    return Dataset(x, y)
