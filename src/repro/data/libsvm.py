"""Reader for the libsvm text format used by the paper's real data sets
("a9a", "ijcnn1", "phishing", ... from the LIBSVM site [8]):

    <label> <index>:<value> <index>:<value> ...

Labels are mapped to {+1, -1}; indices are 1-based.
"""

from __future__ import annotations

import numpy as np

from repro.data.synthetic import Dataset


def load_libsvm(path: str, *, n_features: int | None = None) -> Dataset:
    labels: list[float] = []
    rows: list[list[tuple[int, float]]] = []
    max_idx = 0
    with open(path) as f:
        for line in f:
            parts = line.split()
            if not parts:
                continue
            labels.append(float(parts[0]))
            feats = []
            for tok in parts[1:]:
                if tok.startswith("#"):
                    break
                idx, val = tok.split(":")
                j = int(idx) - 1
                feats.append((j, float(val)))
                max_idx = max(max_idx, j + 1)
            rows.append(feats)
    d = n_features or max_idx
    x = np.zeros((len(rows), d), np.float32)
    for i, feats in enumerate(rows):
        for j, v in feats:
            if j < d:
                x[i, j] = v
    y_raw = np.asarray(labels)
    uniq = np.unique(y_raw)
    if set(uniq.tolist()) <= {-1.0, 1.0}:
        y = y_raw.astype(np.int64)
    else:
        # map the two most common labels to {+1, -1}
        pos = uniq[-1]
        y = np.where(y_raw == pos, 1, -1).astype(np.int64)
    return Dataset(x, y)


def save_libsvm(path: str, ds: Dataset) -> None:
    with open(path, "w") as f:
        for xi, yi in zip(ds.x, ds.y):
            nz = np.nonzero(xi)[0]
            feats = " ".join(f"{j + 1}:{xi[j]:.6g}" for j in nz)
            f.write(f"{int(yi)} {feats}\n")
