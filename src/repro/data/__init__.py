"""Data pipeline: synthetic SVM generators (paper Appendix D), a libsvm
text-format reader, and a synthetic LM token pipeline for the model zoo."""
