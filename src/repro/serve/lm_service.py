"""Continuous-batching LM generation service with MID-DECODE admission.

``generate`` (:mod:`repro.serve.engine`) batches sequences that all
start together; a freed row stays idle until the whole batch drains.
This service removes that restriction with the same architecture as
the SVM fit endpoint -- and the SAME scheduler core
(:class:`repro.serve.scheduler.Scheduler`):

  * S decode LANES share one compiled slot-granular decode chunk
    (:func:`repro.serve.engine.decode_chunk_slots`): each lane has its
    own KV-cache lane, position, PRNG chain, token budget and active
    flag, so sequences at DIFFERENT depths coexist in one executable
    and a finished sequence freezes (active mask) without halting the
    batch -- mirroring ``repro.core.engine.run_chunk_slots``.
  * Between decode chunks the host admits queued prompts into freed
    lanes: one bucketed jitted prefill per pow-2 prompt bucket
    (``_prefill_bucketed``, the PR 4 executable at the service's
    ``max_len``) fills a fresh lane cache with the index rewound to
    the true prompt length, and :func:`repro.serve.engine.admit_lane`
    overwrites every per-lane field.
  * Queue order (arrival / priority / deadline), admission into freed
    slots, idle eviction, queue-to-result latency stamps and
    compile-cache accounting are the scheduler's -- shared verbatim
    with :class:`repro.serve.solver_service.SolverService`.

Parity contract: a sequence admitted mid-decode into a freed lane
reproduces the solo ``generate(..., seed=s)`` output TOKEN-FOR-TOKEN
at the same seed and prompt bucket -- the lane replays the solo
sampling chain (one key split per token) against the same bucketed-
prefill cache, and decode masking is independent of the cache capacity
``max_len``.  Exact for full-attention caches (GQA, MLA) only:
ring-buffer, recurrent and encoder-decoder caches absorb prompts
order-dependently (the ``_can_bucket`` gate), so those configs take
the FALLBACK path -- requests still flow through the scheduler's
queue, but each runs a solo ``generate`` to completion on its own
(exact by construction, no mid-decode admission).

Compile discipline: one decode-chunk executable per service
(keyed by (model, S, max_len, chunk_steps, temperature)) plus one
prefill executable per pow-2 prompt bucket -- after those are warm,
every dispatch must be a compile-cache hit (asserted in
``benchmarks/lm_serve_bench.py``).

Status contract & fault handling
--------------------------------

Same contract as the solver service: requests walk the scheduler's
:class:`~repro.serve.scheduler.Status` lifecycle, readable via
``status(rid)``.  ``submit`` fails fast (``ValueError`` naming the
field) on non-1-D / non-integer prompts, out-of-vocab token ids,
non-positive step counts and over-capacity shapes.  The decode chunk
returns a per-lane finite-health flag
(:func:`repro.serve.engine.decode_chunk_slots`, accumulated over the
chunk's logits); an unhealthy lane is quarantined at the boundary --
freed for re-admission, batch-mates token-for-token unaffected -- and
retried within ``GenRequest.max_retries`` or failed with a structured
:class:`~repro.serve.scheduler.RequestFailure`.  With a ``clock``,
expired queued tickets are shed (DEADLINE_EXCEEDED) before each step;
``cancel(rid)`` frees queued or running lanes between chunks.
``result(rid)`` returns the ``GenResult`` or the ``RequestFailure``,
raising :class:`~repro.serve.scheduler.ResultNotReady` (a ``KeyError``
subclass) on known-but-unfinished rids.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import engine
from repro.serve import faults as faults_mod
from repro.serve.scheduler import (RequestFailure, ResultNotReady,
                                   Scheduler, Status)

# All lanes share one decode executable regardless of prompt bucket
# (prefill is per-bucket; decode is depth-agnostic), so the LM side is
# a single scheduler group -- the solver side's many-bucket case and
# this degenerate case run the identical admission core.
_GROUP = "decode"


@dataclass
class GenRequest:
    """One generation request: a 1-D prompt token array plus the
    sampling configuration a solo ``generate`` call would take.
    (``temperature`` is service-level: it keys the decode executable.)
    ``max_retries`` bounds re-admissions after a quarantine."""
    prompt: np.ndarray
    steps: int
    seed: int = 0
    max_retries: int = 0


class GenResult(NamedTuple):
    """Generated tokens plus the serving metadata of the request's
    ride through the decode batch."""
    request_id: int
    tokens: np.ndarray       # (steps,) generated token ids
    prompt_len: int
    bucket: int              # pow-2 prompt bucket the prefill used
    admitted_chunk: int      # service decode-chunk count at admission
                             # (> 0 == admitted MID-decode)


class _LaneLog:
    """Host-side token accumulator for one RUNNING lane (attached to
    the scheduler ticket as ``ticket.note``)."""

    __slots__ = ("req", "tokens", "t_seen", "admitted_chunk")

    def __init__(self, req: GenRequest, admitted_chunk: int):
        self.req = req
        self.tokens: list[np.ndarray] = []
        self.t_seen = 0
        self.admitted_chunk = admitted_chunk


class LMService:
    """Continuous-batching generation endpoint over the slot-granular
    decode driver.

    ``submit`` enqueues a prompt (assigning a ticket id); ``step``
    runs ONE decode chunk -- admitting queued prompts into freed lanes
    first (bucketed prefill + lane write), harvesting finished
    sequences after -- and returns completed :class:`GenResult`s;
    ``run`` drains everything; ``generate`` is the one-shot wrapper.

    ``max_len`` is the per-lane cache capacity: every admitted request
    must satisfy ``prompt_bucket + steps <= max_len`` (the decode
    executable is keyed by it, so it is fixed per service).
    ``temperature`` is static per service for the same reason.
    """

    def __init__(self, params, cfg, *, num_slots: int = 4,
                 chunk_steps: int = 8, max_len: int = 128,
                 temperature: float = 0.0, policy: str = "oldest",
                 cache_dtype=jnp.bfloat16, clock=None,
                 fault_injector=None):
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.chunk_steps = chunk_steps
        self.max_len = max_len
        self.temperature = temperature
        self.cache_dtype = cache_dtype
        # opt-in wall-clock deadline shedding (see solver service)
        self._clock = clock
        self._injector = fault_injector     # faults.FaultInjector | None
        # full-attention caches only; other families -> fallback path
        self.slot_mode = engine._can_bucket(cfg)
        self._sched = Scheduler(
            num_slots=num_slots if self.slot_mode else 1, policy=policy)
        self._state: engine.LMSlotState | None = None
        self._results: dict[int, GenResult | RequestFailure] = {}
        self._tickets: dict[int, object] = {}   # rid -> live ticket
        self._next_id = 0
        self._chunks = 0         # decode chunks dispatched (lifetime)

    # ------------------------------------------------------------ intake
    def submit(self, prompt, steps: int, *, seed: int = 0,
               priority: int = 0, deadline: float | None = None,
               max_retries: int = 0) -> int:
        """Enqueue one prompt; returns its ticket id.
        ``priority``/``deadline`` feed the scheduler's urgency order.

        Fails fast (``ValueError`` naming the offending field) on
        malformed prompts -- wrong rank/dtype, out-of-vocab token ids,
        non-positive ``steps``, over-capacity shapes."""
        prompt = np.asarray(prompt)
        if prompt.ndim != 1:
            raise ValueError(f"prompt must be 1-D, got {prompt.shape}")
        if not np.issubdtype(prompt.dtype, np.integer):
            raise ValueError(
                f"prompt must hold integer token ids, got dtype "
                f"{prompt.dtype}")
        if prompt.size and (prompt.min() < 0
                            or prompt.max() >= self.cfg.vocab_size):
            raise ValueError(
                f"prompt token ids must lie in [0, "
                f"{self.cfg.vocab_size}); got range "
                f"[{prompt.min()}, {prompt.max()}]")
        if steps < 1:
            raise ValueError(f"steps must be >= 1, got {steps}")
        s_b = engine.prompt_bucket(len(prompt))
        if self.slot_mode and s_b + steps > self.max_len:
            raise ValueError(
                f"prompt bucket {s_b} + steps {steps} exceeds the "
                f"service cache capacity max_len={self.max_len}")
        rid = self._next_id
        self._next_id += 1
        ticket = self._sched.submit(
            _GROUP, rid,
            GenRequest(prompt=prompt, steps=steps, seed=seed,
                       max_retries=max_retries),
            priority=priority, deadline=deadline)
        self._tickets[rid] = ticket
        return rid

    # --------------------------------------------------------- admission
    def _admit(self, group) -> None:
        """Prefill queued prompts into freed lanes (between chunks):
        one bucketed jitted prefill per request, then the donated
        ``admit_lane`` write.  The lane table itself is stamped from
        the first prefill (its cache pytree structure is
        config-dependent)."""
        for lane, ticket in self._sched.admit(group):
            req = ticket.payload
            s = len(req.prompt)
            s_b = engine.prompt_bucket(s)
            toks = jnp.pad(jnp.asarray(req.prompt, jnp.int32)[None],
                           ((0, 0), (0, s_b - s)))
            pkey = (self.cfg.name, s_b, self.max_len)
            with self._sched.stats.chunk(pkey, engine.trace_counts):
                pre = engine._prefill_bucketed(
                    self.params, self.cfg, toks,
                    jnp.asarray(s, jnp.int32), max_len=self.max_len,
                    cache_dtype=self.cache_dtype)
            if self._state is None:
                self._state = engine.init_lm_slot_state(
                    pre, self.num_slots)
            self._state = engine.admit_lane(
                self._state, lane, pre, jax.random.key(req.seed),
                req.steps)
            ticket.note = _LaneLog(req, self._chunks)

    # ----------------------------------------------------------- failure
    def _record_failure(self, ticket, status: Status, reason: str) -> None:
        """Terminal non-result: structured record claimable via
        ``result(rid)``, live bookkeeping dropped."""
        self._results[ticket.rid] = RequestFailure(
            request_id=ticket.rid, status=status, reason=reason,
            attempts=ticket.attempts)
        self._tickets.pop(ticket.rid, None)

    # ----------------------------------------------------------- harvest
    def _harvest(self, group, toks, healthy) -> list[GenResult]:
        """QUARANTINE unhealthy lanes (retry or structured FAILED --
        batch-mates are untouched), append each healthy running lane's
        new tokens (its prefix of the chunk's (S, chunk) token block),
        finish lanes whose budget is exhausted, and free them."""
        # ONE blocking transfer per chunk: lifecycle vectors + tokens
        active, t, toks, healthy = map(np.asarray, jax.device_get(
            (self._state.active, self._state.t, toks, healthy)))
        out = []
        for lane, ticket in list(group.slots.items()):
            log = ticket.note
            if not healthy[lane]:
                # Engine already deactivated the lane on device; free
                # it host-side.  Retries re-queue behind waiting
                # tickets (fresh arrival = backoff ordering).
                if ticket.attempts <= ticket.payload.max_retries:
                    self._sched.resubmit(group, lane, ticket)
                else:
                    self._record_failure(
                        ticket, Status.FAILED,
                        f"non-finite logits detected after "
                        f"{log.t_seen} tokens (quarantined; "
                        f"attempts={ticket.attempts})")
                    self._sched.release(group, lane, Status.FAILED)
                continue
            gen = int(t[lane]) - log.t_seen
            if gen:
                log.tokens.append(toks[lane, :gen])
                log.t_seen = int(t[lane])
            if active[lane]:
                continue
            tokens = (np.concatenate(log.tokens) if log.tokens
                      else np.zeros((0,), toks.dtype))
            res = GenResult(request_id=ticket.rid, tokens=tokens,
                            prompt_len=len(log.req.prompt),
                            bucket=engine.prompt_bucket(
                                len(log.req.prompt)),
                            admitted_chunk=log.admitted_chunk)
            self._results[ticket.rid] = res
            self._tickets.pop(ticket.rid, None)
            out.append(res)
            self._sched.release(group, lane)
        return out

    # -------------------------------------------------------------- run
    def step(self) -> list[GenResult]:
        """One scheduling round: shed expired deadlines -> policy pick
        -> admit into freed lanes -> one decode chunk -> harvest
        (quarantining unhealthy lanes) -> evict-if-drained.  Returns
        the requests that finished this round."""
        if self._clock is not None:
            for g, ticket in self._sched.shed_expired(self._clock()):
                self._record_failure(
                    ticket, Status.DEADLINE_EXCEEDED,
                    f"deadline {ticket.deadline} passed before "
                    f"admission")
                self._sched.evict_idle(g)
        group = self._sched.next_group()
        if group is None:
            return []
        if not self.slot_mode:
            return self._step_fallback(group)
        self._admit(group)
        if not group.slots:
            return []
        # Deterministic fault injection (tests/bench only): poison a
        # targeted lane's logits BEFORE its chunk.  A request's chunk
        # index is how many decode chunks it has lived through.
        if self._injector is not None:
            for lane, ticket in group.slots.items():
                if self._injector.poison_due(
                        ticket.rid,
                        self._chunks - ticket.note.admitted_chunk):
                    self._state = faults_mod.poison_lane_logits(
                        self._state, lane)
        dkey = engine.lm_slot_trace_key(
            self.cfg.name, self.num_slots, self.max_len,
            self.chunk_steps, self.temperature)
        with self._sched.stats.chunk(dkey, engine.trace_counts):
            self._state, toks, healthy = engine.decode_chunk_slots(
                self.params, self._state, cfg=self.cfg,
                chunk_steps=self.chunk_steps,
                temperature=self.temperature, max_len=self.max_len)
        self._chunks += 1
        out = self._harvest(group, toks, healthy)
        # Idle eviction: a drained service drops its lane table (the
        # stacked caches are the big device allocation); re-creating
        # it later costs one allocation, never a trace.
        if self._sched.evict_idle(group):
            self._state = None
        return out

    def _step_fallback(self, group) -> list[GenResult]:
        """Non-bucketable cache families (ring / recurrent / enc-dec):
        run each request solo via ``generate`` -- exact by
        construction, scheduler-ordered, occupancy 1."""
        out = []
        for _lane, ticket in self._sched.admit(group):
            req = ticket.payload
            toks = engine.generate(
                self.params, self.cfg,
                jnp.asarray(req.prompt, jnp.int32)[None],
                steps=req.steps, temperature=self.temperature,
                seed=req.seed)
            res = GenResult(request_id=ticket.rid,
                            tokens=np.asarray(toks)[0],
                            prompt_len=len(req.prompt),
                            bucket=engine.prompt_bucket(len(req.prompt)),
                            admitted_chunk=self._chunks)
            self._results[ticket.rid] = res
            self._tickets.pop(ticket.rid, None)
            out.append(res)
            self._sched.release(group, _lane)
        self._sched.evict_idle(group)
        return out

    def run(self) -> dict[int, GenResult]:
        """Drain every queue; returns (and RELEASES) every result
        completed since the last drain."""
        while self._sched.has_work():
            self.step()
        out, self._results = self._results, {}
        return out

    # ------------------------------------------------------------ status
    def status(self, rid: int) -> Status:
        """The request's lifecycle state (see the module docstring).
        KeyError on unknown/claimed rids."""
        res = self._results.get(rid)
        if res is not None:
            return (res.status if isinstance(res, RequestFailure)
                    else Status.DONE)
        return self._tickets[rid].status

    def result(self, rid: int) -> GenResult | RequestFailure:
        """Pop one terminal outcome: the :class:`GenResult`, or the
        structured :class:`RequestFailure`.  A KNOWN rid still in
        flight raises :class:`ResultNotReady`; an unknown (or already
        claimed) rid keeps the historical bare ``KeyError``."""
        if rid in self._results:
            return self._results.pop(rid)
        if rid in self._tickets:
            raise ResultNotReady(
                f"request {rid} is {self._tickets[rid].status.value}")
        raise KeyError(rid)

    def cancel(self, rid: int) -> bool:
        """Cancel a live request: queued tickets are removed eagerly, a
        running lane is deactivated and freed (between chunks -- the
        service is host-driven).  Returns True if cancelled; False for
        unknown/terminal rids."""
        ticket = self._tickets.get(rid)
        if ticket is None:
            return False
        hit = self._sched.cancel_queued(rid)
        if hit is not None:
            g, t = hit
            self._record_failure(t, Status.CANCELLED,
                                 "cancelled while queued")
            if self._sched.evict_idle(g):
                self._state = None
            return True
        for g in self._sched.groups:
            for lane, t in list(g.slots.items()):
                if t.rid == rid:
                    if self._state is not None:
                        self._state = engine.deactivate_lane(
                            self._state, lane)
                    self._record_failure(t, Status.CANCELLED,
                                         "cancelled while running")
                    self._sched.release(g, lane, Status.CANCELLED)
                    if self._sched.evict_idle(g):
                        self._state = None
                    return True
        return False

    def generate(self, prompt, steps: int, **kw) -> GenResult:
        """One-shot convenience: submit + drain (still exercises the
        full lane path, occupancy 1).  Other requests completed by the
        drain stay claimable via ``result()``.  Raises ``RuntimeError``
        if the request was quarantined past its retry budget."""
        rid = self.submit(prompt, steps, **kw)
        out = self.run()
        res = out.pop(rid)
        self._results.update(out)
        if isinstance(res, RequestFailure):
            raise RuntimeError(
                f"generate request {rid} failed: {res.status.value} "
                f"({res.reason})")
        return res

    # ------------------------------------------------------------- stats
    @property
    def stats(self) -> dict:
        """Compile-cache accounting (scheduler-tracked) over BOTH
        dispatch kinds: per-bucket prefills and the decode chunk."""
        return self._sched.stats.as_dict()

    @property
    def latencies(self):
        """(request_id, queue-to-result seconds) per completed request
        (bounded sliding window)."""
        return self._sched.latencies

    def latency_percentiles(self, *pcts: float) -> dict[float, float]:
        """Queue-to-result latency percentiles (seconds), e.g.
        ``svc.latency_percentiles(50.0, 95.0)``."""
        return self._sched.latency_percentiles(*pcts)
