"""Deterministic fault injection for the serving stack.

Robust serving needs repeatable chaos: "slot 3 diverges at chunk 2
while slots 0-7 keep their deadlines" must be a REPLAYABLE scenario,
not a flaky race.  This module is the single source of injected
faults for the chaos tests (``tests/test_faults.py``) and the
``serve_bench`` chaos mode:

* :class:`Fault` -- one injected event.  Kinds:

  ``poison``       overwrite a running request's device lane with NaN
                   at a given service chunk index (models a tenant
                   whose numerics diverge mid-run; exercises the
                   engine's finite-health flag and the service's
                   quarantine + re-admission path).
  ``delay``        hold a request back for N scheduler steps before
                   submitting it (models bursty arrival; exercised by
                   the bench/test DRIVER, not the service -- a service
                   never sees a delayed request until it is
                   submitted).
  ``drop_client``  remove one client from the k-client vmap
                   simulation at a given outer iteration (models a
                   worker loss in the distributed MWU solve; consumed
                   by ``core.distributed.solve_distributed``).

* :class:`FaultPlan` -- a seed-keyed, immutable set of faults.
  :meth:`FaultPlan.generate` derives the whole plan from one integer
  seed via ``numpy.random.default_rng`` -- same seed, same faults,
  every run, on every backend.

* :class:`FaultInjector` -- the per-service adapter.  Each fault
  fires AT MOST ONCE (one-shot), so a retried request is NOT
  re-poisoned: the retry models a transient failure recovering, which
  is exactly what the bounded-retry path needs to exercise.

* :func:`poison_slot_state` / :func:`poison_lane_logits` -- jitted,
  donated device helpers that overwrite one lane with NaN.  The lane
  index is traced, so each helper compiles once regardless of which
  lane is poisoned; neither touches the chunk executables'
  ``trace_counts`` keys, preserving the zero-recompiles-after-warm-up
  invariant under chaos.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected event.  ``rid`` targets a request (poison/delay);
    ``client`` targets a vmap-sim client (drop_client).  ``at_chunk``
    is the service chunk index (poison) or outer iteration
    (drop_client) at which the event fires; ``delay_steps`` is how
    many scheduler steps a delayed request is held back."""

    kind: str                     # "poison" | "delay" | "drop_client"
    rid: int | None = None
    at_chunk: int = 0
    delay_steps: int = 0
    client: int | None = None

    def __post_init__(self):
        if self.kind not in ("poison", "delay", "drop_client"):
            raise ValueError(f"unknown fault kind: {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, seed-keyed set of faults (see module docstring)."""

    seed: int
    faults: tuple[Fault, ...]

    @classmethod
    def generate(cls, seed: int, rids: list[int], *,
                 poison_frac: float = 0.25, delay_frac: float = 0.25,
                 max_chunk: int = 3, max_delay: int = 3) -> "FaultPlan":
        """Derive a plan from one seed: each rid is independently
        poisoned with probability ``poison_frac`` (at a uniform chunk
        in [0, max_chunk]) and delayed with probability ``delay_frac``
        (by a uniform 1..max_delay scheduler steps).  Poison and delay
        can coincide on one rid."""
        rng = np.random.default_rng(seed)
        faults: list[Fault] = []
        for rid in rids:
            if rng.random() < poison_frac:
                faults.append(Fault(
                    "poison", rid=rid,
                    at_chunk=int(rng.integers(0, max_chunk + 1))))
            if rng.random() < delay_frac:
                faults.append(Fault(
                    "delay", rid=rid,
                    delay_steps=int(rng.integers(1, max_delay + 1))))
        return cls(seed=seed, faults=tuple(faults))

    def poisoned_rids(self) -> set[int]:
        return {f.rid for f in self.faults if f.kind == "poison"}

    def delays(self) -> dict[int, int]:
        return {f.rid: f.delay_steps for f in self.faults
                if f.kind == "delay"}


class FaultInjector:
    """Per-service adapter over a :class:`FaultPlan`.

    The service consults :meth:`poison_due` between chunks for every
    occupied lane; a poison fault fires exactly once, the first time
    the request's chunk index reaches ``at_chunk``.  ``fired`` is the
    audit trail the chaos tests assert against."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.fired: list[Fault] = []
        self._pending: dict[int, Fault] = {
            f.rid: f for f in plan.faults if f.kind == "poison"}

    def poison_due(self, rid: int, chunk_idx: int) -> bool:
        """True exactly once: the first query at/after the fault's
        ``at_chunk`` for a rid with a pending poison fault."""
        f = self._pending.get(rid)
        if f is None or chunk_idx < f.at_chunk:
            return False
        del self._pending[rid]
        self.fired.append(f)
        return True


@functools.partial(jax.jit, donate_argnums=(0,))
def poison_slot_state(state, slot):
    """Overwrite one solver lane's primal iterate with NaN (traced
    ``slot`` index: one compile total).  The next chunk boundary's
    finite-health flag trips on it."""
    return state._replace(
        w=state.w.at[slot].set(jnp.nan),
        u=state.u.at[slot].set(jnp.nan))


@functools.partial(jax.jit, donate_argnums=(0,))
def poison_lane_logits(state, lane):
    """Overwrite one LM lane's next-token logits with NaN (traced
    ``lane`` index: one compile total)."""
    bad = jnp.full(state.last_logits.shape[-1:], jnp.nan,
                   state.last_logits.dtype)
    return state._replace(
        last_logits=state.last_logits.at[lane].set(bad))
