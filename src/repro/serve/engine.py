"""Serving engine.

``prefill``      run the prompt through the model, filling the cache.
``decode_step``  one token for the whole batch against the cache
                 (this is what the decode_32k / long_500k shapes lower).
``generate``     greedy/temperature sampling loop (examples + tests).

Cache layout comes from transformer.init_stack_cache; recurrent archs
(xlstm, recurrentgemma) keep O(1) state instead of KV, sliding-window
attention keeps a ring buffer of ``window`` entries -- these are what
make long_500k sub-quadratic (DESIGN.md shape applicability).

Prefill length bucketing
------------------------

``generate`` pads prompts RIGHT to a pow-2 length bucket and runs ONE
jitted prefill per (bucket, max_len) instead of retracing per distinct
prompt length (``trace_counts`` pins one-trace-after-warmup).  This is
exact for full-attention caches (GQA, MLA):

  * inside the prefill, causal masking means no real query ever
    attends a pad key (pads sit at positions >= the true length);
  * after the prefill, every cache ``index`` is REWOUND to the true
    length, so decode masks the pad entries out (``k_pos < index + 1``)
    and each decode write overwrites the next pad entry exactly when
    its position would first become attendable.

Ring-buffer (sliding-window), recurrent and encoder-decoder caches
absorb prompt tokens order-dependently, so those configs fall back to
the exact-length eager prefill (``_can_bucket``).
"""

from __future__ import annotations

import collections
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.preprocess import next_pow2
from repro.models import transformer as tf

# Incremented at TRACE time inside the jitted bucketed prefill, keyed
# (model, bucket length, max_len) -- counts XLA traces, not calls, so
# tests can pin "two prompt lengths, one bucket, one compile".
trace_counts: collections.Counter = collections.Counter()


class ServeState(NamedTuple):
    cache: Any
    last_logits: jax.Array
    pos: jax.Array             # next position index


def init_cache(cfg, batch: int, max_len: int, cache_dtype=jnp.bfloat16):
    cross = cfg.enc_frames if cfg.is_encoder_decoder else 0
    return tf.init_stack_cache(cfg, batch, max_len, cross_len=cross,
                               cache_dtype=cache_dtype)


def prefill(params, cfg, tokens, *, max_len: int, enc_frames=None,
            vision_embeds=None, vision_mask=None,
            cache_dtype=jnp.bfloat16) -> ServeState:
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len, cache_dtype)
    logits, cache, _ = tf.forward(
        params, cfg, tokens, cache=cache, enc_frames=enc_frames,
        vision_embeds=vision_embeds, vision_mask=vision_mask,
        pos_offset=jnp.zeros((), jnp.int32))
    return ServeState(cache=cache, last_logits=logits[:, -1],
                      pos=jnp.full((), s, jnp.int32))


def decode_step(params, cfg, tokens, state: ServeState) -> ServeState:
    """tokens: (B, 1) next input token per sequence."""
    logits, cache, _ = tf.forward(params, cfg, tokens, cache=state.cache,
                                  pos_offset=state.pos)
    return ServeState(cache=cache, last_logits=logits[:, -1],
                      pos=state.pos + 1)


def prompt_bucket(s: int, min_bucket: int = 8) -> int:
    """The pow-2 prompt-length ladder (8, 16, 32, ...): at most 2x pad,
    O(log s) distinct prefill executables."""
    return next_pow2(max(s, min_bucket))


def _can_bucket(cfg) -> bool:
    """Bucketed prefill is exact only for order-independent caches:
    full-attention blocks with no sliding window, no recurrent state,
    no encoder-decoder cross cache."""
    return (all(kind == "attn" for kind in cfg.block_pattern)
            and cfg.window == 0 and not cfg.is_encoder_decoder)


def _rewind_cache_index(cache, true_len):
    """Set every ``index`` leaf of the (nested dict/list) cache to the
    TRUE prompt length, undoing the pad tokens' advance: decode then
    writes at the true position and masks the pad entries out."""
    if isinstance(cache, dict):
        return {k: (jnp.full_like(v, true_len) if k == "index"
                    else _rewind_cache_index(v, true_len))
                for k, v in cache.items()}
    if isinstance(cache, (list, tuple)):
        return type(cache)(_rewind_cache_index(v, true_len)
                           for v in cache)
    return cache


@functools.partial(jax.jit, static_argnames=("cfg", "max_len",
                                             "cache_dtype"))
def _prefill_bucketed(params, cfg, tokens, true_len, *, max_len: int,
                      cache_dtype=jnp.bfloat16) -> ServeState:
    """Jitted bucket-shaped prefill: ``tokens`` is (B, s_bucket) with
    pad ids right of ``true_len`` (a traced scalar, so one executable
    serves every true length in the bucket)."""
    trace_counts[(cfg.name, tokens.shape[1], max_len)] += 1  # trace time
    b, s_b = tokens.shape
    cache = init_cache(cfg, b, max_len, cache_dtype)
    logits, cache, _ = tf.forward(params, cfg, tokens, cache=cache,
                                  pos_offset=jnp.zeros((), jnp.int32))
    cache = _rewind_cache_index(cache, true_len)
    last = jax.lax.dynamic_index_in_dim(logits, true_len - 1, axis=1,
                                        keepdims=False)
    return ServeState(cache=cache, last_logits=last,
                      pos=jnp.asarray(true_len, jnp.int32))


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


@functools.partial(jax.jit, static_argnames=("cfg", "steps",
                                             "temperature"),
                   donate_argnums=(2,))
def _decode_loop(params, cfg, state: ServeState, key, steps: int,
                 temperature: float):
    # the prefill cache is donated: the decode scan updates the KV
    # buffers in place instead of copying the whole cache on entry
    def body(carry, _):
        st, k = carry
        k, sub = jax.random.split(k)
        tok = sample(st.last_logits, sub, temperature)
        st = decode_step(params, cfg, tok[:, None], st)
        return (st, k), tok

    (state, _), toks = jax.lax.scan(body, (state, key), None,
                                    length=steps)
    return state, jnp.moveaxis(toks, 0, 1)       # (B, steps)


def generate(params, cfg, prompt_tokens, *, steps: int,
             temperature: float = 0.0, seed: int = 0,
             enc_frames=None, vision_embeds=None, vision_mask=None,
             max_len: int | None = None, bucket_prompts: bool = True):
    """Batched generation; returns (B, steps) generated token ids.

    Prompts are padded to the pow-2 length bucket and prefilled through
    ONE jitted executable per bucket (exact -- see the module
    docstring) whenever the cache family allows it; ring-buffer,
    recurrent, encoder-decoder and vision-conditioned calls fall back
    to the exact-length prefill.  The default ``max_len`` becomes
    s_bucket + steps -- stable across all prompt lengths in a bucket,
    so the decode executable is shared too."""
    b, s = prompt_tokens.shape
    s_b = prompt_bucket(s)
    if (bucket_prompts and _can_bucket(cfg) and enc_frames is None
            and vision_embeds is None
            # an explicit max_len smaller than the bucket cannot hold
            # the padded prompt -- honor it via the exact-length path
            and (max_len is None or max_len >= s_b)):
        # s_b + steps is already stable across every prompt length in
        # the bucket (both are executable keys), so no further pow-2
        # rounding: the cache stays as tight as bucketing allows
        max_len = max_len or (s_b + steps)
        toks = jnp.pad(prompt_tokens, ((0, 0), (0, s_b - s)))
        state = _prefill_bucketed(params, cfg, toks,
                                  jnp.asarray(s, jnp.int32),
                                  max_len=max_len)
    else:
        max_len = max_len or (s + steps)
        state = prefill(params, cfg, prompt_tokens, max_len=max_len,
                        enc_frames=enc_frames,
                        vision_embeds=vision_embeds,
                        vision_mask=vision_mask)
    _, toks = _decode_loop(params, cfg, state, jax.random.key(seed),
                           steps, temperature)
    return toks
