"""Serving engine.

``prefill``      run the prompt through the model, filling the cache.
``decode_step``  one token for the whole batch against the cache
                 (this is what the decode_32k / long_500k shapes lower).
``generate``     greedy/temperature sampling loop (examples + tests).

Cache layout comes from transformer.init_stack_cache; recurrent archs
(xlstm, recurrentgemma) keep O(1) state instead of KV, sliding-window
attention keeps a ring buffer of ``window`` entries -- these are what
make long_500k sub-quadratic (DESIGN.md shape applicability).

Prefill length bucketing
------------------------

``generate`` pads prompts RIGHT to a pow-2 length bucket and runs ONE
jitted prefill per (bucket, max_len) instead of retracing per distinct
prompt length (``trace_counts`` pins one-trace-after-warmup).  This is
exact for full-attention caches (GQA, MLA):

  * inside the prefill, causal masking means no real query ever
    attends a pad key (pads sit at positions >= the true length);
  * after the prefill, every cache ``index`` is REWOUND to the true
    length, so decode masks the pad entries out (``k_pos < index + 1``)
    and each decode write overwrites the next pad entry exactly when
    its position would first become attendable.

Ring-buffer (sliding-window), recurrent and encoder-decoder caches
absorb prompt tokens order-dependently, so those configs fall back to
the exact-length eager prefill (``_can_bucket``).

Slot-granular decode (continuous batching)
------------------------------------------

The batched ``decode_step`` assumes every sequence sits at the SAME
position (one scalar cache ``index``, ``q_pos = positions[0]``), so a
new sequence can only join between full ``generate`` calls.  The
slot-granular driver at the bottom of this module
(:class:`LMSlotState`, :func:`admit_lane`, :func:`decode_chunk_slots`)
lifts that restriction for the LM service
(:mod:`repro.serve.lm_service`): each of S lanes carries its OWN
per-lane cache (a solo batch=1 cache stacked on a leading lane axis --
per-lane ``index`` included), position, PRNG chain, token count/budget
and active flag, and one decode step is the solo single-token forward
``vmap``-ped over lanes.  Sequences at different depths therefore
coexist in one executable, a finished lane freezes via the active mask
without halting the batch (mirroring
``repro.core.engine.run_chunk_slots``), and between decode chunks the
host admits a queued prompt into a freed lane: the bucketed jitted
prefill above fills a fresh lane cache (index rewound to the true
length per slot) and :func:`admit_lane` overwrites EVERY per-lane
field, so a reused lane cannot leak its previous occupant's KV state.
Exact for full-attention caches only -- the same ``_can_bucket`` gate
as prefill bucketing; other cache families take the service's
fallback path.
"""

from __future__ import annotations

import collections
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.preprocess import next_pow2
from repro.models import transformer as tf

# Incremented at TRACE time inside the jitted bucketed prefill, keyed
# (model, bucket length, max_len) -- counts XLA traces, not calls, so
# tests can pin "two prompt lengths, one bucket, one compile".
trace_counts: collections.Counter = collections.Counter()


class ServeState(NamedTuple):
    cache: Any
    last_logits: jax.Array
    pos: jax.Array             # next position index


def init_cache(cfg, batch: int, max_len: int, cache_dtype=jnp.bfloat16):
    cross = cfg.enc_frames if cfg.is_encoder_decoder else 0
    return tf.init_stack_cache(cfg, batch, max_len, cross_len=cross,
                               cache_dtype=cache_dtype)


def prefill(params, cfg, tokens, *, max_len: int, enc_frames=None,
            vision_embeds=None, vision_mask=None,
            cache_dtype=jnp.bfloat16) -> ServeState:
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len, cache_dtype)
    logits, cache, _ = tf.forward(
        params, cfg, tokens, cache=cache, enc_frames=enc_frames,
        vision_embeds=vision_embeds, vision_mask=vision_mask,
        pos_offset=jnp.zeros((), jnp.int32))
    return ServeState(cache=cache, last_logits=logits[:, -1],
                      pos=jnp.full((), s, jnp.int32))


def decode_step(params, cfg, tokens, state: ServeState) -> ServeState:
    """tokens: (B, 1) next input token per sequence."""
    logits, cache, _ = tf.forward(params, cfg, tokens, cache=state.cache,
                                  pos_offset=state.pos)
    return ServeState(cache=cache, last_logits=logits[:, -1],
                      pos=state.pos + 1)


def prompt_bucket(s: int, min_bucket: int = 8) -> int:
    """The pow-2 prompt-length ladder (8, 16, 32, ...): at most 2x pad,
    O(log s) distinct prefill executables."""
    return next_pow2(max(s, min_bucket))


def _can_bucket(cfg) -> bool:
    """Bucketed prefill is exact only for order-independent caches:
    full-attention blocks with no sliding window, no recurrent state,
    no encoder-decoder cross cache."""
    return (all(kind == "attn" for kind in cfg.block_pattern)
            and cfg.window == 0 and not cfg.is_encoder_decoder)


def _rewind_cache_index(cache, true_len):
    """Set every ``index`` leaf of the (nested dict/list) cache to the
    TRUE prompt length, undoing the pad tokens' advance: decode then
    writes at the true position and masks the pad entries out."""
    if isinstance(cache, dict):
        return {k: (jnp.full_like(v, true_len) if k == "index"
                    else _rewind_cache_index(v, true_len))
                for k, v in cache.items()}
    if isinstance(cache, (list, tuple)):
        return type(cache)(_rewind_cache_index(v, true_len)
                           for v in cache)
    return cache


@functools.partial(jax.jit, static_argnames=("cfg", "max_len",
                                             "cache_dtype"))
def _prefill_bucketed(params, cfg, tokens, true_len, *, max_len: int,
                      cache_dtype=jnp.bfloat16) -> ServeState:
    """Jitted bucket-shaped prefill: ``tokens`` is (B, s_bucket) with
    pad ids right of ``true_len`` (a traced scalar, so one executable
    serves every true length in the bucket)."""
    trace_counts[(cfg.name, tokens.shape[1], max_len)] += 1  # trace time
    b, s_b = tokens.shape
    cache = init_cache(cfg, b, max_len, cache_dtype)
    logits, cache, _ = tf.forward(params, cfg, tokens, cache=cache,
                                  pos_offset=jnp.zeros((), jnp.int32))
    cache = _rewind_cache_index(cache, true_len)
    last = jax.lax.dynamic_index_in_dim(logits, true_len - 1, axis=1,
                                        keepdims=False)
    return ServeState(cache=cache, last_logits=last,
                      pos=jnp.asarray(true_len, jnp.int32))


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


@functools.partial(jax.jit, static_argnames=("cfg", "steps",
                                             "temperature"),
                   donate_argnums=(2,))
def _decode_loop(params, cfg, state: ServeState, key, steps: int,
                 temperature: float):
    # the prefill cache is donated: the decode scan updates the KV
    # buffers in place instead of copying the whole cache on entry
    def body(carry, _):
        st, k = carry
        k, sub = jax.random.split(k)
        tok = sample(st.last_logits, sub, temperature)
        st = decode_step(params, cfg, tok[:, None], st)
        return (st, k), tok

    (state, _), toks = jax.lax.scan(body, (state, key), None,
                                    length=steps)
    return state, jnp.moveaxis(toks, 0, 1)       # (B, steps)


# ==========================================================================
# Slot-granular decode: S independent sequences, each with its own cache
# lane / position / PRNG chain, through ONE vmapped decode executable.
# ==========================================================================


class LMSlotState(NamedTuple):
    """S decode lanes for the continuous-batching LM service.

    ``cache`` leaves are the SOLO (batch=1) cache leaves stacked on a
    leading lane axis -- e.g. a GQA k-buffer is (S, L_periods, 1,
    T_max, KV, Dh) and every cache ``index`` is (S, ...)-shaped -- so
    ``vmap`` over axis 0 hands each lane EXACTLY the pytree a solo
    ``decode_step`` consumes, index included.  That per-lane index is
    what lets sequences at different depths share one executable.

    Lifecycle mirrors :class:`repro.core.engine.SlotState`: a FREE lane
    (``active=False``) still flows through every decode step (shape-
    static executable) but only ``t`` is guarded by the mask -- a
    frozen lane's cache/logits keep advancing harmlessly because its
    tokens are already harvested and admission overwrites every field.
    ``key`` is the per-lane PRNG chain, split once per decode step
    exactly like ``generate``'s sampling chain, so a lane admitted at
    seed s replays a solo ``generate(seed=s)`` token-for-token.
    """
    cache: Any               # per-lane caches, lane axis leading
    last_logits: jax.Array   # (S, V) logits the next token samples from
    pos: jax.Array           # (S,) next position index per lane
    t: jax.Array             # (S,) tokens generated so far
    max_t: jax.Array         # (S,) per-lane token budget
    key: jax.Array           # (S,) per-lane sampling PRNG chains
    active: jax.Array        # (S,) bool lifecycle mask

    @property
    def num_slots(self) -> int:
        return self.last_logits.shape[0]


def init_lm_slot_state(prefill: ServeState,
                       num_slots: int) -> LMSlotState:
    """An all-FREE lane table stamped from one prefilled lane's
    batch=1 :class:`ServeState`.  The cache PYTREE STRUCTURE is what
    matters: ``forward`` omits empty head/tail sections from its
    output cache, so the table must mirror a real prefill's structure
    (not ``init_cache``'s) for ``admit_lane``'s tree zip to line up."""
    return LMSlotState(
        cache=jax.tree.map(
            lambda l: jnp.zeros((num_slots,) + l.shape, l.dtype),
            prefill.cache),
        last_logits=jnp.zeros((num_slots,)
                              + prefill.last_logits.shape[-1:],
                              prefill.last_logits.dtype),
        pos=jnp.zeros((num_slots,), jnp.int32),
        t=jnp.zeros((num_slots,), jnp.int32),
        max_t=jnp.zeros((num_slots,), jnp.int32),
        key=jax.random.split(jax.random.key(0), num_slots),
        active=jnp.zeros((num_slots,), bool),
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def admit_lane(state: LMSlotState, lane, prefill: ServeState,
               key: jax.Array, max_t) -> LMSlotState:
    """Admit a freshly prefilled sequence into ``lane`` (a traced
    index: one compile serves every lane).  ``prefill`` is the batch=1
    :class:`ServeState` of the bucketed jitted prefill -- its cache
    (index already rewound to the true prompt length) becomes the
    lane's cache.  Every per-lane field is overwritten -- cache, last
    logits, position, token count, budget, PRNG chain, active flag --
    so a reused lane cannot leak its previous occupant's KV state."""
    return LMSlotState(
        cache=jax.tree.map(lambda b, l: b.at[lane].set(l),
                           state.cache, prefill.cache),
        last_logits=state.last_logits.at[lane].set(
            prefill.last_logits[0].astype(state.last_logits.dtype)),
        pos=state.pos.at[lane].set(prefill.pos),
        t=state.t.at[lane].set(0),
        max_t=state.max_t.at[lane].set(jnp.asarray(max_t, jnp.int32)),
        key=state.key.at[lane].set(key),
        active=state.active.at[lane].set(True),
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def deactivate_lane(state: LMSlotState, lane) -> LMSlotState:
    """Freeze one decode lane (traced ``lane`` index: one compile
    total) -- the LM service's cancellation path.  The lane's cache is
    left as-is; :func:`admit_lane` overwrites every field anyway."""
    return state._replace(active=state.active.at[lane].set(False))


def lm_slot_trace_key(name: str, num_slots: int, max_len: int,
                      chunk_steps: int, temperature: float) -> tuple:
    """The ``trace_counts`` key of one slot-decode chunk executable --
    the compile-cache key the LM service warms once."""
    return ("lm_slots", name, num_slots, max_len, chunk_steps,
            temperature)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "chunk_steps", "temperature",
                                    "max_len"),
                   donate_argnums=(1,))
def decode_chunk_slots(params, state: LMSlotState, *, cfg,
                       chunk_steps: int, temperature: float,
                       max_len: int):
    """One slot-granular decode chunk: ``chunk_steps`` tokens for every
    lane, the solo single-token forward vmapped over the lane axis.

    Per step each lane samples its next token from its own
    ``last_logits`` with its own PRNG chain (bit-identical to
    ``generate``'s ``k, sub = split(k); sample(logits, sub)``
    schedule), then runs one decode forward against its own cache at
    its own position.  The active mask guards only the token counter
    ``t`` -- a frozen lane's cache keeps advancing harmlessly (tokens
    past ``max_t`` are never read; admission overwrites the lane) --
    so the executable stays shape-static and branch-free.  ``max_len``
    is implied by the cache shapes; it is threaded only to key
    ``trace_counts``.

    Lane health: a per-lane finite-health flag is accumulated across
    the chunk -- the entry logits AND every step's fresh logits must
    be free of NaN/Inf (checking only the boundary would miss a NaN
    that one sampling step consumes before a finite forward overwrites
    it).  Unhealthy lanes are deactivated on device; lanes are vmapped
    independently, so a poisoned lane's batch-mates decode bit-for-bit
    as if it were healthy.  Free lanes hold zero logits and always
    pass.  The LM service reads the flag from the chunk's single host
    transfer and quarantines the lane.

    Returns (new_state, toks (S, chunk_steps), healthy (S,) bool); per
    lane only the first ``t_after - t_before`` token columns are
    meaningful (a lane freezes mid-chunk at exactly ``max_t``, and
    admission happens only between chunks, so a lane's valid tokens
    are always a prefix).
    """
    trace_counts[lm_slot_trace_key(
        cfg.name, state.num_slots, max_len, chunk_steps,
        temperature)] += 1                               # trace time

    def lane_decode(tok, cache, pos):
        logits, new_cache, _ = tf.forward(params, cfg, tok[None, None],
                                          cache=cache, pos_offset=pos)
        return logits[0, -1], new_cache

    def lane_ok(logits):
        return jnp.isfinite(logits.astype(jnp.float32)).all(axis=-1)

    def body(carry, _):
        st, ok = carry
        splits = jax.vmap(jax.random.split)(st.key)      # (S, 2)
        chain, sub = splits[:, 0], splits[:, 1]
        tok = jax.vmap(
            lambda lg, k: sample(lg[None], k, temperature)[0])(
                st.last_logits, sub)
        last, cache = jax.vmap(lane_decode)(tok, st.cache, st.pos)
        do = st.active & (st.t < st.max_t)
        st = LMSlotState(cache=cache, last_logits=last, pos=st.pos + 1,
                         t=jnp.where(do, st.t + 1, st.t),
                         max_t=st.max_t, key=chain, active=st.active)
        return (st, ok & lane_ok(last)), tok

    (state, healthy), toks = jax.lax.scan(
        body, (state, lane_ok(state.last_logits)), None,
        length=chunk_steps)
    state = state._replace(
        active=state.active & (state.t < state.max_t) & healthy)
    return state, jnp.moveaxis(toks, 0, 1), healthy      # (S, chunk)


def generate(params, cfg, prompt_tokens, *, steps: int,
             temperature: float = 0.0, seed: int = 0,
             enc_frames=None, vision_embeds=None, vision_mask=None,
             max_len: int | None = None, bucket_prompts: bool = True):
    """Batched generation; returns (B, steps) generated token ids.

    Prompts are padded to the pow-2 length bucket and prefilled through
    ONE jitted executable per bucket (exact -- see the module
    docstring) whenever the cache family allows it; ring-buffer,
    recurrent, encoder-decoder and vision-conditioned calls fall back
    to the exact-length prefill.  The default ``max_len`` becomes
    s_bucket + steps -- stable across all prompt lengths in a bucket,
    so the decode executable is shared too."""
    b, s = prompt_tokens.shape
    s_b = prompt_bucket(s)
    if (bucket_prompts and _can_bucket(cfg) and enc_frames is None
            and vision_embeds is None
            # an explicit max_len smaller than the bucket cannot hold
            # the padded prompt -- honor it via the exact-length path
            and (max_len is None or max_len >= s_b)):
        # s_b + steps is already stable across every prompt length in
        # the bucket (both are executable keys), so no further pow-2
        # rounding: the cache stays as tight as bucketing allows
        max_len = max_len or (s_b + steps)
        toks = jnp.pad(prompt_tokens, ((0, 0), (0, s_b - s)))
        state = _prefill_bucketed(params, cfg, toks,
                                  jnp.asarray(s, jnp.int32),
                                  max_len=max_len)
    else:
        max_len = max_len or (s + steps)
        state = prefill(params, cfg, prompt_tokens, max_len=max_len,
                        enc_frames=enc_frames,
                        vision_embeds=vision_embeds,
                        vision_mask=vision_mask)
    _, toks = _decode_loop(params, cfg, state, jax.random.key(seed),
                           steps, temperature)
    return toks
