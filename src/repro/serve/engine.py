"""Serving engine.

``prefill``      run the prompt through the model, filling the cache.
``decode_step``  one token for the whole batch against the cache
                 (this is what the decode_32k / long_500k shapes lower).
``generate``     greedy/temperature sampling loop (examples + tests).

Cache layout comes from transformer.init_stack_cache; recurrent archs
(xlstm, recurrentgemma) keep O(1) state instead of KV, sliding-window
attention keeps a ring buffer of ``window`` entries -- these are what
make long_500k sub-quadratic (DESIGN.md shape applicability).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tf


class ServeState(NamedTuple):
    cache: Any
    last_logits: jax.Array
    pos: jax.Array             # next position index


def init_cache(cfg, batch: int, max_len: int, cache_dtype=jnp.bfloat16):
    cross = cfg.enc_frames if cfg.is_encoder_decoder else 0
    return tf.init_stack_cache(cfg, batch, max_len, cross_len=cross,
                               cache_dtype=cache_dtype)


def prefill(params, cfg, tokens, *, max_len: int, enc_frames=None,
            vision_embeds=None, vision_mask=None,
            cache_dtype=jnp.bfloat16) -> ServeState:
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len, cache_dtype)
    logits, cache, _ = tf.forward(
        params, cfg, tokens, cache=cache, enc_frames=enc_frames,
        vision_embeds=vision_embeds, vision_mask=vision_mask,
        pos_offset=jnp.zeros((), jnp.int32))
    return ServeState(cache=cache, last_logits=logits[:, -1],
                      pos=jnp.full((), s, jnp.int32))


def decode_step(params, cfg, tokens, state: ServeState) -> ServeState:
    """tokens: (B, 1) next input token per sequence."""
    logits, cache, _ = tf.forward(params, cfg, tokens, cache=state.cache,
                                  pos_offset=state.pos)
    return ServeState(cache=cache, last_logits=logits[:, -1],
                      pos=state.pos + 1)


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


@functools.partial(jax.jit, static_argnames=("cfg", "steps",
                                             "temperature"),
                   donate_argnums=(2,))
def _decode_loop(params, cfg, state: ServeState, key, steps: int,
                 temperature: float):
    # the prefill cache is donated: the decode scan updates the KV
    # buffers in place instead of copying the whole cache on entry
    def body(carry, _):
        st, k = carry
        k, sub = jax.random.split(k)
        tok = sample(st.last_logits, sub, temperature)
        st = decode_step(params, cfg, tok[:, None], st)
        return (st, k), tok

    (state, _), toks = jax.lax.scan(body, (state, key), None,
                                    length=steps)
    return state, jnp.moveaxis(toks, 0, 1)       # (B, steps)


def generate(params, cfg, prompt_tokens, *, steps: int,
             temperature: float = 0.0, seed: int = 0,
             enc_frames=None, vision_embeds=None, vision_mask=None,
             max_len: int | None = None):
    """Batched generation; returns (B, steps) generated token ids."""
    b, s = prompt_tokens.shape
    max_len = max_len or (s + steps)
    state = prefill(params, cfg, prompt_tokens, max_len=max_len,
                    enc_frames=enc_frames, vision_embeds=vision_embeds,
                    vision_mask=vision_mask)
    _, toks = _decode_loop(params, cfg, state, jax.random.key(seed),
                           steps, temperature)
    return toks
