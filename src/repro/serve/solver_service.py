"""Multi-tenant SVM fit serving: continuous batching over the
slot-batched saddle engine.

The paper's per-iteration work is tiny -- O(B + n) after preprocessing
(Theorem 6) -- so a single fit request cannot saturate the hardware.
At serving scale the unit of work is therefore MANY independent small
problems, not one large one: this service packs S concurrent fit
requests into ONE compiled slot-batched step
(:func:`repro.core.engine.run_chunk_slots`, a ``vmap`` over the
leading slot axis) and keeps that executable busy by admitting queued
requests into lanes as they free up mid-run.

Scheduling is delegated to the shared latency-aware core
(:class:`repro.serve.scheduler.Scheduler`): the service is a thin
WORKLOAD ADAPTER that owns only the device side -- per-bucket slot
buffers (:class:`_Batch`), engine chunk dispatch, and harvest through
the svm.py recovery path.  Queue ordering (arrival / priority /
deadline urgency), cross-bucket policy (``oldest`` default,
``round_robin`` retained for bit-compat), admission-into-freed-slots,
idle-batch eviction, queue-to-result latency stamps and compile-cache
accounting all live in the scheduler and are shared verbatim with the
LM service (:mod:`repro.serve.lm_service`).

Shape buckets
-------------

One executable serves exactly one (n_bucket, d_bucket) shape.  To keep
the number of distinct executables logarithmic in problem size,
requests are packed onto a POW-2 BUCKET LADDER
(:func:`repro.core.preprocess.bucket_shape`):

  * point axis: ``LANE * 2^k``  (128, 256, 512, ...) -- at most 2x
    padding, each rung lane-aligned for the Pallas kernels;
  * coordinate axis: ``2^k`` -- already satisfied by the WD transform
    of Algorithm 1, so requests of different dimensionality simply
    land on different d rungs (cross-d sharing via inert coordinate
    padding is what ``saddle.solve(..., d_pad)`` /
    ``preprocess.pack_points_to`` provide for callers that want it).

Padding points carry sign 0 / log-weight NEG_INF (inert in every
reduction); padding coordinates are all-zero rows of the column-major
mirror, so ``w`` stays pinned at 0 there.  Because the solver samples
coordinate blocks over the FULL bucket axis, a bucketed solve is
reproducible slot-for-slot against ``saddle.solve(..., n_pad, d_pad)``
at the same bucket -- that is the service's parity contract (tested in
``tests/test_solver_service.py``).  Scheduling policy can never change
a request's numbers: a slot's trajectory depends only on its own seed,
budget and bucket, and every chunk is a FULL chunk, so policies differ
in WHEN a request runs, never in WHAT it computes.

Slot lifecycle (see also :class:`repro.core.engine.SlotState`)
--------------------------------------------------------------

  queue -> ADMIT -> RUNNING -> FINISHED -> harvest -> (lane FREE)

  * ADMIT (between chunks only): the scheduler assigns urgency-ordered
    tickets to free lanes; :func:`engine.admit_into_slot` then
    overwrites EVERY per-slot field -- state, PRNG chain, budget,
    active flag -- so a reused lane cannot leak its previous
    occupant's duals; the request's packed operand is written into the
    batch buffers by a donated updater (in-place, no reallocation).
  * RUNNING: the slot steps while ``t < max_t`` and (if the request
    set ``gap_tol``) its relative duality gap is above threshold.
    The per-slot active mask freezes finished slots WITHOUT halting
    the batch.
  * FINISHED -> harvest: the host reads the (S,) active/t vectors
    after each chunk, extracts finished slots, and recovers each
    request's input-space (w, b) via the exact ``svm.py`` path
    (:func:`repro.core.svm.recover_hyperplane`).

Compile discipline
------------------

The chunk executable is keyed by (S, bucket shape, block size,
chunk_steps, project, check_gap, backend) -- all admission patterns,
chunk lengths and per-request parameter VALUES share it.  The
scheduler tracks trace counts per key (``engine.trace_counts``); after
a bucket is warm, every chunk must be a compile-cache hit
(``SolverService.stats`` is asserted in ``benchmarks/serve_bench.py``).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core import preprocess as pp
from repro.core import saddle
from repro.core import svm as svm_mod
from repro.serve.scheduler import Scheduler


@dataclass
class FitRequest:
    """One SVM fit: raw (x, y) plus the solver configuration a
    ``SaddleSVC``/``SaddleNuSVC`` would take.  ``nu=0`` is hard margin.
    ``gap_tol > 0`` enables the per-slot duality-gap early stop (the
    request may then finish before ``num_iters``)."""
    x: np.ndarray
    y: np.ndarray
    eps: float = 1e-3
    beta: float = 0.1
    nu: float = 0.0
    num_iters: int | None = None
    block_size: int = 1
    seed: int = 0
    gap_tol: float = 0.0


class FitResult(NamedTuple):
    """Input-space hyperplane (the ``svm.py`` recovery path) plus the
    serving metadata of the request's ride through the batch."""
    request_id: int
    w: np.ndarray
    b: float
    objective: float
    margin: float
    iterations: int          # iterations actually run (gap stop <= budget)
    bucket: tuple            # (n_bucket, d_bucket) the request shared
    history: list            # [(iteration, objective)] at chunk marks


class _Slot(NamedTuple):
    """Host-side bookkeeping for one RUNNING lane (attached to the
    scheduler ticket as ``ticket.note``)."""
    request_id: int
    req: FitRequest
    pre: Any                 # Preprocessed (transform to undo at harvest)
    xp_t: jax.Array          # transformed + bucket-padded class matrices
    xm_t: jax.Array
    history: list


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _write_slot_data(x_t_b, sign_b, slot, x_t, sign):
    """Write one request's packed operand into lane ``slot`` of the
    batch buffers.  Donated: the (S, d, n) buffer is updated in place,
    and ``slot`` is traced so one compile serves every lane."""
    return x_t_b.at[slot].set(x_t), sign_b.at[slot].set(sign)


class _Batch:
    """One bucket's DEVICE buffers: slot-batched engine state, the
    (S, d, n) packed operands and the per-slot SlotParams mirror.  The
    host-side queue and lane occupancy live in the scheduler's Group
    (this object is that group's ``payload``).

    ``project``/``check_gap`` are FIXED at batch creation (hard-margin
    and nu-SVM requests live in separate batches): a request's
    executable -- and therefore its numeric trajectory -- is fully
    determined by the request itself, never by which co-tenants happen
    to share its bucket at admission time."""

    def __init__(self, bucket: tuple[int, int], num_slots: int,
                 project: bool, check_gap: bool):
        n_pad, d_pad = bucket
        self.bucket = bucket
        self.project = project
        self.check_gap = check_gap
        self.state = engine.init_slot_state(num_slots, n_pad, d_pad)
        self.x_t = jnp.zeros((num_slots, d_pad, n_pad), jnp.float32)
        self.sign = jnp.zeros((num_slots, n_pad), jnp.float32)
        self.sp = jax.tree.map(
            lambda v: np.repeat(np.asarray(v, np.float32), num_slots),
            engine.SlotParams(theta=0.0, sigma=0.0, inv_sig1=1.0,
                              gamma=1.0, tau=1.0, mwu_c=1.0, mwu_dot=1.0,
                              nu=1.0, gap_tol=0.0))
        self.sp_dev = None                      # device mirror of sp


class SolverService:
    """Continuous-batching fit endpoint over the slot-batched engine.

    ``submit`` enqueues a request (assigning it a ticket id); ``step``
    runs ONE chunk of one bucket's batch -- admitting queued requests
    into free lanes first, harvesting finished slots after -- and
    returns any completed :class:`FitResult`s; ``run`` drains
    everything.  ``fit`` is the one-shot convenience wrapper.

    ``policy`` selects the cross-bucket scheduler: ``"oldest"``
    (default, latency-aware oldest-request-first, fill-rate tie-break)
    or ``"round_robin"`` (PR 4's cursor).  Results are policy-invariant
    (see the module docstring); only queue latency changes.

    The service is deliberately host-driven between chunks (admission
    and harvest are O(S) scalar decisions); all per-iteration work
    stays inside the one compiled chunk per bucket.
    """

    def __init__(self, num_slots: int = 8, chunk_steps: int = 64,
                 backend: str = "jnp", policy: str = "oldest"):
        self.num_slots = num_slots
        self.chunk_steps = chunk_steps
        self.backend = backend
        self._sched = Scheduler(num_slots=num_slots, policy=policy)
        self._results: dict[int, FitResult] = {}
        self._pre_cache: dict[int, Any] = {}
        self._next_id = 0

    @property
    def _batches(self) -> dict:
        """Legacy view: bucket key -> device-buffer payload (kept for
        tests/introspection; the scheduler owns the group table)."""
        return {g.key: g.payload for g in self._sched.groups}

    # ------------------------------------------------------------ intake
    def submit(self, req: FitRequest, *, priority: int = 0,
               deadline: float | None = None) -> int:
        """Validate, preprocess and enqueue a fit request; returns its
        ticket id.  The heavy per-request work here (split, WD
        transform, bucket packing) is exactly Algorithm 1 --
        preprocessing is NOT the serving bottleneck the slot engine
        addresses, so it runs at intake.  ``priority``/``deadline``
        feed the scheduler's urgency order (see
        :mod:`repro.serve.scheduler`)."""
        rid = self._next_id
        self._next_id += 1
        xp, xm = svm_mod.split_classes(req.x, req.y)   # raises on 1 class
        n1, n2 = len(xp), len(xm)
        saddle.validate_nu(req.nu, n1, n2)
        k_pre, _ = jax.random.split(jax.random.key(req.seed))
        pre = pp.preprocess(xp, xm, k_pre)
        d_pre = pre.xp.shape[1]
        bucket = pp.bucket_shape(n1 + n2, d_pre)
        # everything that keys the compiled chunk also keys the batch:
        # block_size (shape), project (nu>0) and check_gap (gap_tol>0)
        # statics -- so co-tenancy can never change a request's
        # executable and the warm-up set is exactly the batch set
        project = req.nu > 0.0
        check_gap = req.gap_tol > 0.0
        batch_key = bucket + (req.block_size, project, check_gap)
        self._sched.submit(
            batch_key, rid, req, priority=priority, deadline=deadline,
            payload_factory=lambda: _Batch(bucket, self.num_slots,
                                           project, check_gap))
        self._pre_cache[rid] = pre
        return rid

    # --------------------------------------------------------- admission
    def _admit(self, group) -> None:
        """Realize the scheduler's urgency-ordered lane assignments in
        device state (between chunks)."""
        batch = group.payload
        n_pad, d_pad = batch.bucket
        for lane, ticket in self._sched.admit(group):
            req = ticket.payload
            pre = self._pre_cache.pop(ticket.rid)
            xp_t, xm_t = pre.xp, pre.xm
            # preprocess() already padded d to a power of two, so the
            # request's dimensionality IS the batch's d rung
            assert xp_t.shape[1] == d_pad, (xp_t.shape, batch.bucket)
            n1, n2 = xp_t.shape[0], xm_t.shape[0]
            pts = pp.pack_points(xp_t, xm_t, pad_to=n_pad)
            params = saddle.make_params(
                n1 + n2, d_pad, req.eps, req.beta, nu=req.nu,
                block_size=req.block_size)
            # the SAME budget derivation as saddle.solve (shared
            # helper), so a request's schedule equals its solo solve's
            num_iters = saddle.resolve_num_iters(
                req.num_iters, d_pad, req.eps, req.beta, n1 + n2,
                req.block_size)

            batch.x_t, batch.sign = _write_slot_data(
                batch.x_t, batch.sign, lane, pts.x_t, pts.sign)
            batch.state = engine.admit_into_slot(
                batch.state, lane,
                engine.init_packed_state(pts.sign, n1, n2, d_pad),
                jax.random.key(req.seed), num_iters)
            row = engine.slot_params_row(params, req.gap_tol)
            for f in engine.SlotParams._fields:
                getattr(batch.sp, f)[lane] = getattr(row, f)
            batch.sp_dev = None                 # refresh device mirror
            ticket.note = _Slot(request_id=ticket.rid, req=req, pre=pre,
                                xp_t=xp_t, xm_t=xm_t, history=[])

    # ----------------------------------------------------------- harvest
    def _harvest(self, group, obj) -> list[FitResult]:
        """Record per-slot history, extract every FINISHED slot through
        the svm.py recovery path, and free its lane."""
        batch = group.payload
        # ONE blocking transfer per chunk for all (S,)-sized lifecycle
        # vectors; the big per-slot state only moves for finished slots
        active, t, obj = map(np.asarray, jax.device_get(
            (batch.state.active, batch.state.t, obj)))
        out = []
        for lane, ticket in list(group.slots.items()):
            slot = ticket.note
            slot.history.append((int(t[lane]), float(obj[lane])))
            if active[lane]:
                continue
            lam = np.asarray(jax.device_get(batch.state.log_lam[lane]))
            n1 = slot.xp_t.shape[0]
            n2 = slot.xm_t.shape[0]
            eta = jnp.exp(jnp.asarray(lam[:n1]))
            xi = jnp.exp(jnp.asarray(lam[n1:n1 + n2]))
            w, b, objective, margin, _ = svm_mod.recover_hyperplane(
                slot.pre, eta, xi, slot.xp_t, slot.xm_t)
            res = FitResult(request_id=slot.request_id, w=w, b=b,
                            objective=objective, margin=margin,
                            iterations=int(t[lane]), bucket=batch.bucket,
                            history=slot.history)
            self._results[slot.request_id] = res
            out.append(res)
            self._sched.release(group, lane)
        return out

    # -------------------------------------------------------------- run
    def step(self) -> list[FitResult]:
        """One scheduling round: policy pick -> admit -> one chunk ->
        harvest -> evict-if-drained.  Returns the requests that
        finished this round."""
        group = self._sched.next_group()
        if group is None:
            return []
        self._admit(group)
        if not group.slots:
            return []
        batch = group.payload
        n_pad, d_pad = batch.bucket
        project, check_gap = batch.project, batch.check_gap
        block_size = next(iter(group.slots.values())).payload.block_size
        key = engine.slot_trace_key(self.num_slots, n_pad, d_pad,
                                    block_size, self.chunk_steps,
                                    project, check_gap, self.backend)
        # Always run FULL chunks: a slot near its budget is frozen by
        # the per-slot mask at exactly max_t, which keeps every slot's
        # chunk/key schedule identical to a solo solve with
        # record_every == chunk_steps (the parity contract).  A
        # shortened trip count here would give a mid-run-admitted slot
        # a partial FIRST chunk no solo schedule ever takes.
        if batch.sp_dev is None:
            batch.sp_dev = jax.tree.map(jnp.asarray, batch.sp)
        with self._sched.stats.chunk(key, engine.trace_counts):
            batch.state, obj = engine.run_chunk_slots(
                batch.state, batch.x_t, batch.sign, batch.sp_dev,
                self.chunk_steps,
                chunk_steps=self.chunk_steps, d=d_pad,
                block_size=block_size, project=project,
                check_gap=check_gap, backend=self.backend)
        out = self._harvest(group, obj)
        # Idle-batch eviction: a drained batch's device buffers (slot
        # state + the (S, d, n) operand) would otherwise leak device
        # memory across varied request shapes.  The COMPILED executable
        # survives in the jit cache regardless.
        self._sched.evict_idle(group)
        return out

    def run(self) -> dict[int, FitResult]:
        """Drain every queue; returns (and RELEASES) every result
        completed since the last drain -- results are not retained
        service-side, so a long-running service stays O(active slots),
        not O(requests served)."""
        while self._sched.has_work():
            self.step()
        out, self._results = self._results, {}
        return out

    def result(self, rid: int) -> FitResult:
        """Pop one completed result (KeyError if not finished yet)."""
        return self._results.pop(rid)

    def fit(self, x, y, **kw) -> FitResult:
        """One-shot convenience: submit + drain (still exercises the
        full slot path, S=1 occupancy).  Other requests completed by
        the drain stay claimable via ``result()``."""
        rid = self.submit(FitRequest(x=x, y=y, **kw))
        out = self.run()
        res = out.pop(rid)
        self._results.update(out)      # keep co-drained results claimable
        return res

    # ------------------------------------------------------------- stats
    @property
    def stats(self) -> dict:
        """Compile-cache accounting (scheduler-tracked): ``compiles``
        counts the traces observed during THIS service's chunk
        dispatches (trace-count delta around each call -- other
        services or solo solves sharing an executable key are never
        misattributed), ``cache_hits`` the chunk calls served without
        tracing.  After warm-up every call must be a hit (asserted by
        the serve bench)."""
        return self._sched.stats.as_dict()

    @property
    def latencies(self):
        """(request_id, queue-to-result seconds) per completed request
        -- stamped by the scheduler at submit and release (bounded
        sliding window)."""
        return self._sched.latencies

    def latency_percentiles(self, *pcts: float) -> dict[float, float]:
        """Queue-to-result latency percentiles (seconds), e.g.
        ``svc.latency_percentiles(50.0, 95.0)``."""
        return self._sched.latency_percentiles(*pcts)
