"""Multi-tenant SVM fit serving: continuous batching over the
slot-batched saddle engine.

The paper's per-iteration work is tiny -- O(B + n) after preprocessing
(Theorem 6) -- so a single fit request cannot saturate the hardware.
At serving scale the unit of work is therefore MANY independent small
problems, not one large one: this service packs S concurrent fit
requests into ONE compiled slot-batched step
(:func:`repro.core.engine.run_chunk_slots`, a ``vmap`` over the
leading slot axis) and keeps that executable busy by admitting queued
requests into lanes as they free up mid-run.

Scheduling is delegated to the shared latency-aware core
(:class:`repro.serve.scheduler.Scheduler`): the service is a thin
WORKLOAD ADAPTER that owns only the device side -- per-bucket slot
buffers (:class:`_Batch`), engine chunk dispatch, and harvest through
the svm.py recovery path.  Queue ordering (arrival / priority /
deadline urgency), cross-bucket policy (``oldest`` default,
``round_robin`` retained for bit-compat), admission-into-freed-slots,
idle-batch eviction, queue-to-result latency stamps and compile-cache
accounting all live in the scheduler and are shared verbatim with the
LM service (:mod:`repro.serve.lm_service`).

Mesh-sharded serving
--------------------

Constructed with a ``mesh`` the service runs every chunk through
``engine.run_chunk_slots_sharded`` and composes the paper's two scale
axes under ONE scheduler and one executable family: ordinary requests
land in LANE-PARALLEL groups (the slot axis shards over every mesh
axis; each device steps its own lanes with zero cross-device traffic
-- admission, quarantine and cancel all stay lane-local), while
requests above ``shard_points_above`` points land in POINT-SHARDED
groups whose slots span the mesh and pay exactly the solo distributed
step's Theorem-8 collective rounds per iteration (vmap batches each
round across the group's lanes into one launch; see
``distributed.ServeCommModel``).  The shard placement is part of the
scheduler group key -- see :meth:`repro.serve.scheduler.Scheduler.
group` -- and a 1-device mesh reproduces the meshless service
bit-for-bit (tested in ``tests/test_mesh_service.py``).

Streaming updates (warm starts)
-------------------------------

A fit submitted with ``stream=True`` declares a LIVE TENANT whose data
keeps changing.  :meth:`SolverService.submit_update` takes an
:class:`UpdateRequest` -- append points, replace the set, or pure
re-fit -- applies the tenant's FIXED preprocessing transform to the
new points (``preprocess.transform_like``), supersedes the tenant's
in-flight request (``Status.SUPERSEDED``), and enqueues a re-fit that
WARM-STARTS from the tenant's last completed saddle state instead of
the uniform init: ``w`` and the dual momentum carry over, carried
points keep their dual mass re-placed at the new class offsets, new
points are seeded at the uniform level and the next MWU normalizer
round renormalizes each class (``preprocess.repack_warm_duals`` --
normalization IS the repair, no host-side fix-up pass), and ``u`` is
recomputed from the carried w on device
(``engine.warm_packed_state``).  When the updated point count still
fits the tenant's pow-2 rung, the update re-packs in place and reuses
the SAME hot chunk executable (the warm helpers are jitted outside the
chunk trace keys, so the zero-recompile contract holds); an overflow
jumps one rung (one new bucket, compiled once).  Warm-vs-cold
iterations-to-gap is gated in ``benchmarks/serve_bench.py``
(``serve/stream/warm_iters_ratio``).

Shape buckets
-------------

One executable serves exactly one (n_bucket, d_bucket) shape.  To keep
the number of distinct executables logarithmic in problem size,
requests are packed onto a POW-2 BUCKET LADDER
(:func:`repro.core.preprocess.bucket_shape`):

  * point axis: ``LANE * 2^k``  (128, 256, 512, ...) -- at most 2x
    padding, each rung lane-aligned for the Pallas kernels;
  * coordinate axis: ``2^k`` -- already satisfied by the WD transform
    of Algorithm 1, so requests of different dimensionality simply
    land on different d rungs (cross-d sharing via inert coordinate
    padding is what ``saddle.solve(..., d_pad)`` /
    ``preprocess.pack_points_to`` provide for callers that want it).

Padding points carry sign 0 / log-weight NEG_INF (inert in every
reduction); padding coordinates are all-zero rows of the column-major
mirror, so ``w`` stays pinned at 0 there.  Because the solver samples
coordinate blocks over the FULL bucket axis, a bucketed solve is
reproducible slot-for-slot against ``saddle.solve(..., n_pad, d_pad)``
at the same bucket -- that is the service's parity contract (tested in
``tests/test_solver_service.py``).  Scheduling policy can never change
a request's numbers: a slot's trajectory depends only on its own seed,
budget and bucket, and every chunk is a FULL chunk, so policies differ
in WHEN a request runs, never in WHAT it computes.

Slot lifecycle (see also :class:`repro.core.engine.SlotState`)
--------------------------------------------------------------

  queue -> ADMIT -> RUNNING -> FINISHED -> harvest -> (lane FREE)

  * ADMIT (between chunks only): the scheduler assigns urgency-ordered
    tickets to free lanes; :func:`engine.admit_into_slot` then
    overwrites EVERY per-slot field -- state, PRNG chain, budget,
    active flag -- so a reused lane cannot leak its previous
    occupant's duals; the request's packed operand is written into the
    batch buffers by a donated updater (in-place, no reallocation).
  * RUNNING: the slot steps while ``t < max_t`` and (if the request
    set ``gap_tol``) its relative duality gap is above threshold.
    The per-slot active mask freezes finished slots WITHOUT halting
    the batch.
  * FINISHED -> harvest: the host reads the (S,) active/t vectors
    after each chunk, extracts finished slots, and recovers each
    request's input-space (w, b) via the exact ``svm.py`` path
    (:func:`repro.core.svm.recover_hyperplane`).

Compile discipline
------------------

The chunk executable is keyed by (S, bucket shape, block size,
chunk_steps, project, check_gap, backend) -- all admission patterns,
chunk lengths and per-request parameter VALUES share it.  The
scheduler tracks trace counts per key (``engine.trace_counts``); after
a bucket is warm, every chunk must be a compile-cache hit
(``SolverService.stats`` is asserted in ``benchmarks/serve_bench.py``).

Status contract & fault handling
--------------------------------

Every request walks the scheduler's :class:`~repro.serve.scheduler.
Status` lifecycle (PENDING -> RUNNING -> DONE / FAILED / CANCELLED /
DEADLINE_EXCEEDED), readable any time via ``status(rid)``:

  * INTAKE: ``submit`` fails fast with ``ValueError`` on non-finite
    ``x``/``y``, shape mismatches, single-class ``y``, infeasible
    ``nu`` and over-ladder shapes -- a malformed request never reaches
    a device lane.
  * QUARANTINE: the chunk executable returns a per-slot finite-health
    flag (:func:`repro.core.engine.run_chunk_slots`); a slot whose
    state diverged to NaN/Inf is quarantined at the chunk boundary --
    lane freed for re-admission, batch-mates bit-for-bit unaffected
    (lanes are vmapped independently) -- and either retried
    (``FitRequest.max_retries``, re-enqueued BEHIND waiting tickets:
    backoff ordering) or failed with a structured
    :class:`~repro.serve.scheduler.RequestFailure`.
  * DEADLINES: constructed with a ``clock``, the service sheds every
    queued ticket whose deadline has passed at the top of each step
    (DEADLINE_EXCEEDED) so hopeless requests never occupy a lane.
    Without a clock, deadlines remain pure urgency ordering.
  * CANCEL: ``cancel(rid)`` removes a queued ticket eagerly or frees a
    running lane between chunks (the device slot is deactivated; the
    executable shape never changes).

``result(rid)`` returns the ``FitResult`` OR the ``RequestFailure``;
on a known-but-unfinished rid it raises
:class:`~repro.serve.scheduler.ResultNotReady` (a ``KeyError``
subclass -- unknown rids keep the historical bare ``KeyError``).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, replace as dc_replace
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import engine
from repro.core import preprocess as pp
from repro.core import saddle
from repro.core import svm as svm_mod
from repro.serve import faults as faults_mod
from repro.serve.scheduler import (RequestFailure, ResultNotReady,
                                   Scheduler, Status)


@dataclass
class FitRequest:
    """One SVM fit: raw (x, y) plus the solver configuration a
    ``SaddleSVC``/``SaddleNuSVC`` would take.  ``nu=0`` is hard margin.
    ``gap_tol > 0`` enables the per-slot duality-gap early stop (the
    request may then finish before ``num_iters``).  ``max_retries``
    bounds how many times a quarantined (non-finite) run is re-admitted
    before the request fails for good.  ``stream=True`` declares a LIVE
    TENANT: the service retains the request's preprocessing transform
    and, at harvest, its final saddle state, so later
    :class:`UpdateRequest`\\ s can edit the data and warm-start the
    re-fit (see ``submit_update``)."""
    x: np.ndarray
    y: np.ndarray
    eps: float = 1e-3
    beta: float = 0.1
    nu: float = 0.0
    num_iters: int | None = None
    block_size: int = 1
    seed: int = 0
    gap_tol: float = 0.0
    max_retries: int = 0
    stream: bool = False


@dataclass
class UpdateRequest:
    """One STREAMING UPDATE of a live tenant's problem: edit the data
    (append new labelled points, replace the whole set, or neither for
    a pure re-fit) and re-solve -- warm-started from the tenant's last
    completed saddle state unless ``warm=False`` (the cold-reference
    knob the benchmarks and parity tests use).

    ``tenant`` is the rid of the original ``stream=True`` fit.  ``x``/
    ``y`` are new raw points in the tenant's ORIGINAL input space (the
    tenant's fixed WD transform+scale is applied at intake,
    ``preprocess.transform_like``); ``mode="append"`` may carry a
    single class (the tenant already has both), ``mode="replace"``
    must carry both.  ``nu``/``num_iters``/``gap_tol``/``max_retries``
    default to the tenant's original configuration when None.

    An accepted update SUPERSEDES the tenant's in-flight request, if
    any (its ticket terminates with ``Status.SUPERSEDED``); already
    completed results stay claimable.  The dataset edit is applied at
    intake and survives even if this update's solve later fails."""
    tenant: int
    x: np.ndarray | None = None
    y: np.ndarray | None = None
    mode: str = "append"
    warm: bool = True
    nu: float | None = None
    num_iters: int | None = None
    gap_tol: float | None = None
    max_retries: int | None = None


class FitResult(NamedTuple):
    """Input-space hyperplane (the ``svm.py`` recovery path) plus the
    serving metadata of the request's ride through the batch."""
    request_id: int
    w: np.ndarray
    b: float
    objective: float
    margin: float
    iterations: int          # iterations actually run (gap stop <= budget)
    bucket: tuple            # (n_bucket, d_bucket) the request shared
    history: list            # [(iteration, objective)] at chunk marks


class _WarmState(NamedTuple):
    """A tenant's last COMPLETED saddle state, host-retained at harvest
    (idle-group eviction frees the device lane, so warm state cannot
    stay slot-resident).  ``log_lam``/``log_lam_prev`` are in the
    packed layout of the bucket the state was harvested at; only the
    first ``n1 + n2`` entries are meaningful
    (``preprocess.repack_warm_duals`` re-places them at admission)."""
    w: np.ndarray            # (d_bucket,) transformed-space direction
    log_lam: np.ndarray      # (n_pad_old,) packed log duals
    log_lam_prev: np.ndarray
    n1: int                  # class sizes the state was fit at
    n2: int


class _Tenant:
    """Host-side record of one live streaming tenant: the FIXED
    preprocessing transform, the CURRENT transformed class matrices
    (updates edit these at intake), the original request as the config
    template for derived update fits, and the warm-start state."""

    __slots__ = ("pre", "xp_t", "xm_t", "req", "warm", "live_rid",
                 "version")

    def __init__(self, pre: Any, xp_t: jax.Array, xm_t: jax.Array,
                 req: FitRequest):
        self.pre = pre
        self.xp_t = xp_t
        self.xm_t = xm_t
        self.req = req
        self.warm: _WarmState | None = None
        self.live_rid: int | None = None   # in-flight fit/update rid
        self.version = 0                   # bumped per accepted update


class _Admission(NamedTuple):
    """Everything the admission path needs to (re-)stage one request
    into a device lane: the transform, the class matrices, the warm
    state to start from (None = cold uniform init) and the owning
    streaming tenant (None = plain fit).  Stored per queued rid; a
    quarantine retry re-stashes the SAME record, so the retry re-enters
    from the last good warm state."""
    pre: Any
    xp_t: jax.Array
    xm_t: jax.Array
    warm: _WarmState | None
    tenant: int | None


class _Slot(NamedTuple):
    """Host-side bookkeeping for one RUNNING lane (attached to the
    scheduler ticket as ``ticket.note``)."""
    request_id: int
    req: FitRequest
    pre: Any                 # Preprocessed (transform to undo at harvest)
    xp_t: jax.Array          # transformed + bucket-padded class matrices
    xm_t: jax.Array
    warm: Any                # _WarmState | None (admission's init state)
    tenant: int | None       # owning streaming tenant, if any
    history: list


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _write_slot_data(x_t_b, sign_b, slot, x_t, sign):
    """Write one request's packed operand into lane ``slot`` of the
    batch buffers.  Donated: the (S, d, n) buffer is updated in place,
    and ``slot`` is traced so one compile serves every lane."""
    return x_t_b.at[slot].set(x_t), sign_b.at[slot].set(sign)


class _Batch:
    """One bucket's DEVICE buffers: slot-batched engine state, the
    (S, d, n) packed operands and the per-slot SlotParams mirror.  The
    host-side queue and lane occupancy live in the scheduler's Group
    (this object is that group's ``payload``).

    ``project``/``check_gap`` are FIXED at batch creation (hard-margin
    and nu-SVM requests live in separate batches): a request's
    executable -- and therefore its numeric trajectory -- is fully
    determined by the request itself, never by which co-tenants happen
    to share its bucket at admission time.

    On a device ``mesh`` the batch also owns its SHARD PLACEMENT (the
    second component of the scheduler group key):

      * lane-parallel (``point_sharded=False``): the slot axis shards
        over every mesh axis -- each device owns ``S / mesh.size``
        whole lanes and the chunk exchanges ZERO collectives;
      * point-sharded (``point_sharded=True``): every slot's POINT axis
        spans the mesh and the chunk runs the Theorem-8 collective
        rounds (large-n fits; see ``engine.run_chunk_slots_sharded``).

    The buffers are created under :class:`~jax.sharding.NamedSharding`
    so the first chunk already lowers at the placement the whole group
    lifetime keeps."""

    def __init__(self, bucket: tuple[int, int], num_slots: int,
                 project: bool, check_gap: bool,
                 mesh: jax.sharding.Mesh | None = None,
                 point_sharded: bool = False):
        n_pad, d_pad = bucket
        self.bucket = bucket
        self.project = project
        self.check_gap = check_gap
        self.mesh = mesh
        self.point_sharded = point_sharded
        self.state = engine.init_slot_state(num_slots, n_pad, d_pad)
        self.x_t = jnp.zeros((num_slots, d_pad, n_pad), jnp.float32)
        self.sign = jnp.zeros((num_slots, n_pad), jnp.float32)
        self.sp = jax.tree.map(
            lambda v: np.repeat(np.asarray(v, np.float32), num_slots),
            engine.SlotParams(theta=0.0, sigma=0.0, inv_sig1=1.0,
                              gamma=1.0, tau=1.0, mwu_c=1.0, mwu_dot=1.0,
                              nu=1.0, gap_tol=0.0))
        self.sp_dev = None                      # device mirror of sp
        if mesh is None:
            self.slot_axes: tuple = ()
            self.point_axes: tuple = ()
            self.shardings = None
            self.sp_sharding = None
        else:
            axes = tuple(mesh.axis_names)
            self.slot_axes, self.point_axes = (
                ((), axes) if point_sharded else (axes, ()))
            s = self.slot_axes or None
            p = self.point_axes or None
            mk = lambda spec: NamedSharding(mesh, spec)   # noqa: E731
            state_sh = engine.SlotState(
                w=mk(PartitionSpec(s)),
                log_lam=mk(PartitionSpec(s, p)),
                log_lam_prev=mk(PartitionSpec(s, p)),
                u=mk(PartitionSpec(s, p)),
                t=mk(PartitionSpec(s)), max_t=mk(PartitionSpec(s)),
                key=mk(PartitionSpec(s)), active=mk(PartitionSpec(s)))
            self.shardings = (state_sh,
                              mk(PartitionSpec(s, None, p)),
                              mk(PartitionSpec(s, p)))
            self.sp_sharding = engine.SlotParams(
                *(mk(PartitionSpec(s))
                  for _ in engine.SlotParams._fields))
            self.state = jax.device_put(self.state, state_sh)
            self.x_t = jax.device_put(self.x_t, self.shardings[1])
            self.sign = jax.device_put(self.sign, self.shardings[2])

    def ensure_placement(self) -> None:
        """Re-pin any buffer whose sharding drifted off the batch's
        placement (admission writers are sharding-preserving in
        practice; this is the cheap invariant guard that keeps the
        chunk executable's jit cache keyed at ONE sharding)."""
        if self.shardings is None:
            return
        fix = lambda a, sh: (a if a.sharding == sh          # noqa: E731
                             else jax.device_put(a, sh))
        self.state = jax.tree.map(fix, self.state, self.shardings[0])
        self.x_t = fix(self.x_t, self.shardings[1])
        self.sign = fix(self.sign, self.shardings[2])


class SolverService:
    """Continuous-batching fit endpoint over the slot-batched engine.

    ``submit`` enqueues a request (assigning it a ticket id); ``step``
    runs ONE chunk of one bucket's batch -- admitting queued requests
    into free lanes first, harvesting finished slots after -- and
    returns any completed :class:`FitResult`s; ``run`` drains
    everything.  ``fit`` is the one-shot convenience wrapper.

    ``policy`` selects the cross-bucket scheduler: ``"oldest"``
    (default, latency-aware oldest-request-first, fill-rate tie-break)
    or ``"round_robin"`` (PR 4's cursor).  Results are policy-invariant
    (see the module docstring); only queue latency changes.

    The service is deliberately host-driven between chunks (admission
    and harvest are O(S) scalar decisions); all per-iteration work
    stays inside the one compiled chunk per bucket.
    """

    def __init__(self, num_slots: int = 8, chunk_steps: int = 64,
                 backend: str = "jnp", policy: str = "oldest",
                 clock=None, fault_injector=None,
                 max_points: int = 1 << 20, max_dim: int = 1 << 14,
                 mesh: jax.sharding.Mesh | None = None,
                 shard_points_above: int | None = None,
                 shard_num_slots: int = 2):
        self.num_slots = num_slots
        self.chunk_steps = chunk_steps
        self.backend = backend
        # Mesh-sharded serving (opt-in): with a ``mesh`` every batch
        # runs under shard_map.  Ordinary requests land in
        # lane-parallel groups (slots sharded over every mesh axis,
        # zero collectives -- ``num_slots`` must divide into
        # ``mesh.size`` whole lanes per device).  Requests with more
        # than ``shard_points_above`` points land in POINT-SHARDED
        # groups of ``shard_num_slots`` lanes whose points span the
        # mesh (Theorem-8 collectives); None disables point sharding.
        # A 1-device mesh reproduces the meshless service bit-for-bit:
        # shard_map over one device partitions nothing and the chunk
        # body is the identical computation.
        self.mesh = mesh
        self._mesh_k = 1 if mesh is None else int(mesh.size)
        if mesh is not None and num_slots % self._mesh_k:
            raise ValueError(
                f"num_slots={num_slots} must be divisible by the mesh "
                f"device count {self._mesh_k} (whole lanes per device)")
        self.shard_points_above = shard_points_above
        self.shard_num_slots = shard_num_slots
        # Deadline semantics are OPT-IN: without a clock, deadlines are
        # pure urgency ordering (any orderable float, the historical
        # contract); with ``clock`` (e.g. ``time.monotonic``) queued
        # tickets whose deadline is past clock() are shed each step.
        self._clock = clock
        self._injector = fault_injector     # faults.FaultInjector | None
        self.max_points = max_points        # over-ladder intake bounds:
        self.max_dim = max_dim              # largest admissible bucket
        self._sched = Scheduler(num_slots=num_slots, policy=policy)
        self._results: dict[int, FitResult | RequestFailure] = {}
        self._pre_cache: dict[int, _Admission] = {}
        self._tickets: dict[int, Any] = {}  # rid -> live (non-terminal)
        self._tenants: dict[int, _Tenant] = {}   # streaming tenants
        self._rid_tenant: dict[int, int] = {}    # live rid -> tenant id
        self._next_id = 0

    @property
    def _batches(self) -> dict:
        """Legacy view: bucket key -> device-buffer payload (kept for
        tests/introspection; the scheduler owns the group table)."""
        return {g.key: g.payload for g in self._sched.groups}

    # ------------------------------------------------------------ intake
    def submit(self, req: FitRequest, *, priority: int = 0,
               deadline: float | None = None) -> int:
        """Validate, preprocess and enqueue a fit request; returns its
        ticket id.  The heavy per-request work here (split, WD
        transform, bucket packing) is exactly Algorithm 1 --
        preprocessing is NOT the serving bottleneck the slot engine
        addresses, so it runs at intake.  ``priority``/``deadline``
        feed the scheduler's urgency order (see
        :mod:`repro.serve.scheduler`).

        Fails fast (``ValueError`` naming the offending field) on
        malformed requests -- non-finite ``x``/``y``, shape
        mismatches, single-class ``y``, infeasible ``nu``, over-ladder
        shapes -- so one bad tenant is rejected at intake instead of
        poisoning a device lane."""
        x = np.asarray(req.x)
        y = np.asarray(req.y)
        if x.ndim != 2:
            raise ValueError(
                f"FitRequest.x must be 2-D (n, d); got shape {x.shape}")
        if y.shape != (x.shape[0],):
            raise ValueError(
                f"FitRequest.y must be shape ({x.shape[0]},) to match "
                f"x; got {y.shape}")
        if not np.isfinite(x).all():
            raise ValueError(
                "FitRequest.x contains non-finite values (NaN/Inf)")
        if not np.isfinite(y.astype(np.float64, copy=False)).all():
            raise ValueError(
                "FitRequest.y contains non-finite values (NaN/Inf)")
        if x.shape[0] > self.max_points or x.shape[1] > self.max_dim:
            raise ValueError(
                f"FitRequest.x shape {x.shape} exceeds the service's "
                f"bucket ladder (max_points={self.max_points}, "
                f"max_dim={self.max_dim})")
        rid = self._next_id
        self._next_id += 1
        xp, xm = svm_mod.split_classes(req.x, req.y)   # raises on 1 class
        n1, n2 = len(xp), len(xm)
        saddle.validate_nu(req.nu, n1, n2)
        k_pre, _ = jax.random.split(jax.random.key(req.seed))
        pre = pp.preprocess(xp, xm, k_pre)
        self._enqueue(rid, req, n1, n2, pre.xp.shape[1],
                      priority=priority, deadline=deadline)
        self._pre_cache[rid] = _Admission(
            pre=pre, xp_t=pre.xp, xm_t=pre.xm, warm=None,
            tenant=rid if req.stream else None)
        if req.stream:
            self._tenants[rid] = _Tenant(pre, pre.xp, pre.xm, req)
            self._tenants[rid].live_rid = rid
            self._rid_tenant[rid] = rid
        return rid

    def _enqueue(self, rid: int, req: FitRequest, n1: int, n2: int,
                 d_pre: int, *, priority: int,
                 deadline: float | None):
        """Shared tail of ``submit``/``submit_update``: derive the
        bucket + placement group key and enqueue the ticket.  ONE
        derivation for both intakes, so an update can never land beside
        a plain fit under a different key discipline."""
        bucket = pp.bucket_shape(n1 + n2, d_pre)
        # everything that keys the compiled chunk also keys the batch:
        # block_size (shape), project (nu>0) and check_gap (gap_tol>0)
        # statics -- so co-tenancy can never change a request's
        # executable and the warm-up set is exactly the batch set
        project = req.nu > 0.0
        check_gap = req.gap_tol > 0.0
        point_sharded = (self.mesh is not None
                         and self.shard_points_above is not None
                         and n1 + n2 > self.shard_points_above)
        if point_sharded and check_gap:
            raise ValueError(
                "FitRequest.gap_tol > 0 is not supported for "
                "point-sharded fits (the duality gap's water-filling "
                "sorts the full point axis and does not distribute); "
                "submit with gap_tol=0 or below the shard threshold")
        if point_sharded:
            # the point axis must split into whole lane-aligned shards:
            # per-shard pow-2 rung times the mesh extent (>= the plain
            # rung whenever mesh.size is a power of two)
            k = self._mesh_k
            bucket = (k * pp.bucket_length(-(-(n1 + n2) // k)), bucket[1])
        # on a mesh, placement is part of the group key (see
        # Scheduler.group): same bucket, different shard_map program
        if self.mesh is None:
            placement: tuple = ()
            group_slots = self.num_slots
        elif point_sharded:
            placement = ("points", self._mesh_k)
            group_slots = self.shard_num_slots
        else:
            placement = ("lanes", self._mesh_k)
            group_slots = self.num_slots
        batch_key = bucket + (req.block_size, project, check_gap) \
            + placement
        ticket = self._sched.submit(
            batch_key, rid, req, priority=priority, deadline=deadline,
            payload_factory=lambda: _Batch(bucket, group_slots,
                                           project, check_gap,
                                           mesh=self.mesh,
                                           point_sharded=point_sharded),
            num_slots=group_slots)
        self._tickets[rid] = ticket
        return ticket

    # ---------------------------------------------------------- updates
    def submit_update(self, ureq: UpdateRequest, *, priority: int = 0,
                      deadline: float | None = None) -> int:
        """Edit a live tenant's problem and enqueue its re-fit;
        returns the new ticket id.

        Validation-first, then commit: shape/finiteness/label checks,
        nu RE-validation at the post-edit class sizes, and the bucket
        ladder bound (an update that would overflow ``max_points``
        fails fast HERE with a ValueError -- it never reaches a device
        lane, so it cannot masquerade as a quarantine).  Only once the
        update is accepted does it mutate the tenant: the dataset edit
        is applied (and survives even if the re-fit later fails), the
        tenant's in-flight request -- if any -- is SUPERSEDED, and the
        re-fit is enqueued exactly like any admission.  When the new
        point count still fits the tenant's current pow-2 rung the
        update re-packs in place (same bucket, same hot executable);
        when it does not, the re-fit simply lands on the next rung
        (whose executable compiles once and is then shared like any
        bucket's).

        The re-fit WARM-STARTS from the tenant's last completed state
        (``warm=False`` forces the cold uniform init -- the reference
        the warm ratio is measured against): append mode carries the
        old points' dual mass and seeds only the new points at the
        uniform level; replace mode carries ``w`` (and momentum zero)
        but resets all dual mass, since the old points no longer exist.
        A tenant with no completed fit yet falls back to cold."""
        ten = self._tenants.get(ureq.tenant)
        if ten is None:
            raise KeyError(
                f"unknown streaming tenant {ureq.tenant} (submit the "
                f"original fit with stream=True)")
        if ureq.mode not in ("append", "replace"):
            raise ValueError(
                f"UpdateRequest.mode must be 'append' or 'replace'; "
                f"got {ureq.mode!r}")
        if (ureq.x is None) != (ureq.y is None):
            raise ValueError(
                "UpdateRequest.x and .y must be given together "
                "(both None = pure re-fit of the current data)")
        xp_t, xm_t = ten.xp_t, ten.xm_t
        if ureq.x is not None:
            x = np.asarray(ureq.x)
            y = np.asarray(ureq.y)
            if x.ndim != 2:
                raise ValueError(
                    f"UpdateRequest.x must be 2-D (m, d); got shape "
                    f"{x.shape}")
            if y.shape != (x.shape[0],):
                raise ValueError(
                    f"UpdateRequest.y must be shape ({x.shape[0]},) to "
                    f"match x; got {y.shape}")
            if not np.isfinite(x).all():
                raise ValueError(
                    "UpdateRequest.x contains non-finite values "
                    "(NaN/Inf)")
            if not np.isfinite(y.astype(np.float64, copy=False)).all():
                raise ValueError(
                    "UpdateRequest.y contains non-finite values "
                    "(NaN/Inf)")
            xp_new = x[y > 0]
            xm_new = x[y < 0]
            if len(xp_new) + len(xm_new) != len(x):
                raise ValueError(
                    "UpdateRequest.y must be +-1 labels; got "
                    f"{np.unique(y).tolist()}")
            # the tenant's FIXED transform (raises on a d mismatch)
            txp = pp.transform_like(ten.pre, xp_new) if len(xp_new) \
                else ten.xp_t[:0]
            txm = pp.transform_like(ten.pre, xm_new) if len(xm_new) \
                else ten.xm_t[:0]
            if ureq.mode == "append":
                xp_t = jnp.concatenate([ten.xp_t, txp]) if len(xp_new) \
                    else ten.xp_t
                xm_t = jnp.concatenate([ten.xm_t, txm]) if len(xm_new) \
                    else ten.xm_t
            else:
                xp_t, xm_t = txp, txm
        n1, n2 = int(xp_t.shape[0]), int(xm_t.shape[0])
        if n1 == 0 or n2 == 0:
            raise ValueError(
                "UpdateRequest(mode='replace') must carry both classes "
                f"(+1 and -1); got {n1} positive and {n2} negative "
                f"points")
        nu_eff = ten.req.nu if ureq.nu is None else ureq.nu
        saddle.validate_nu(nu_eff, n1, n2)   # nu RE-validation post-edit
        if n1 + n2 > self.max_points:
            raise ValueError(
                f"update for tenant {ureq.tenant} grows the problem to "
                f"{n1 + n2} points, exceeding the service's bucket "
                f"ladder (max_points={self.max_points})")

        # -- validated: commit the edit and enqueue the re-fit --------
        rid = self._next_id
        self._next_id += 1
        replaced = ureq.mode == "replace" and ureq.x is not None
        if ten.live_rid is not None:
            self._supersede(ten.live_rid, rid)
        ten.xp_t, ten.xm_t = xp_t, xm_t
        ten.version += 1
        if replaced and ten.warm is not None:
            # old points no longer exist: dual mass cannot transfer.
            # Keep w (same transformed space) but reset the dual
            # segments to uniform -- n1=n2=0 makes repack_warm_duals
            # ignore the stale arrays entirely.
            ten.warm = ten.warm._replace(n1=0, n2=0)
        req = dc_replace(
            ten.req,
            # raw x/y are never read for updates (the transformed
            # matrices above are authoritative); drop the stale arrays
            x=None, y=None,
            nu=nu_eff,
            num_iters=(ten.req.num_iters if ureq.num_iters is None
                       else ureq.num_iters),
            gap_tol=(ten.req.gap_tol if ureq.gap_tol is None
                     else ureq.gap_tol),
            max_retries=(ten.req.max_retries if ureq.max_retries is None
                         else ureq.max_retries),
            # deterministic per-revision schedule: warm and cold
            # re-fits of the same revision share it, revisions differ
            seed=ten.req.seed + 1000003 * ten.version,
            stream=True)
        self._enqueue(rid, req, n1, n2, int(xp_t.shape[1]),
                      priority=priority, deadline=deadline)
        warm = ten.warm if ureq.warm else None
        self._pre_cache[rid] = _Admission(
            pre=ten.pre, xp_t=xp_t, xm_t=xm_t, warm=warm,
            tenant=ureq.tenant)
        ten.live_rid = rid
        self._rid_tenant[rid] = ureq.tenant
        return rid

    def _supersede(self, rid_old: int, rid_new: int) -> None:
        """Terminate the tenant's stale in-flight request with
        SUPERSEDED: a queued ticket is removed eagerly, a running one
        has its lane deactivated and freed (between chunks -- the
        service is host-driven).  The stale outcome is a claimable
        :class:`RequestFailure` naming the superseding rid."""
        ticket = self._tickets.get(rid_old)
        if ticket is None:
            return
        reason = f"superseded by update request {rid_new}"
        hit = self._sched.cancel_queued(rid_old, Status.SUPERSEDED)
        if hit is not None:
            g, t = hit
            self._record_failure(t, Status.SUPERSEDED, reason)
            self._sched.evict_idle(g)
            return
        for g in self._sched.groups:
            for lane, t in list(g.slots.items()):
                if t.rid == rid_old:
                    g.payload.state = engine.deactivate_slot(
                        g.payload.state, lane)
                    self._record_failure(t, Status.SUPERSEDED, reason)
                    self._sched.release(g, lane, Status.SUPERSEDED)
                    self._sched.evict_idle(g)
                    return

    def close_stream(self, tenant: int) -> bool:
        """Drop a streaming tenant's host-side record (transform,
        transformed matrices, warm state).  An in-flight re-fit keeps
        running and its result stays claimable; it just no longer
        updates warm state at harvest.  Returns False on unknown
        tenants."""
        return self._tenants.pop(tenant, None) is not None

    # --------------------------------------------------------- admission
    def _admit(self, group) -> None:
        """Realize the scheduler's urgency-ordered lane assignments in
        device state (between chunks)."""
        batch = group.payload
        n_pad, d_pad = batch.bucket
        for lane, ticket in self._sched.admit(group):
            req = ticket.payload
            adm = self._pre_cache.pop(ticket.rid)
            xp_t, xm_t = adm.xp_t, adm.xm_t
            # preprocess() already padded d to a power of two, so the
            # request's dimensionality IS the batch's d rung
            assert xp_t.shape[1] == d_pad, (xp_t.shape, batch.bucket)
            n1, n2 = xp_t.shape[0], xm_t.shape[0]
            pts = pp.pack_points(xp_t, xm_t, pad_to=n_pad)
            params = saddle.make_params(
                n1 + n2, d_pad, req.eps, req.beta, nu=req.nu,
                block_size=req.block_size)
            # the SAME budget derivation as saddle.solve (shared
            # helper), so a request's schedule equals its solo solve's
            num_iters = saddle.resolve_num_iters(
                req.num_iters, d_pad, req.eps, req.beta, n1 + n2,
                req.block_size)

            batch.x_t, batch.sign = _write_slot_data(
                batch.x_t, batch.sign, lane, pts.x_t, pts.sign)
            if adm.warm is not None:
                # WARM admission: re-place the carried dual segments at
                # the new class offsets (appended points seeded at the
                # uniform level; the next MWU normalizer round
                # renormalizes each class -- no host-side repair), and
                # recompute u from the carried w on device.  Both
                # helpers are jitted OUTSIDE the chunk trace keys, so
                # the hot executables stay zero-recompile.
                lam = pp.repack_warm_duals(
                    adm.warm.log_lam, adm.warm.n1, adm.warm.n2,
                    n1, n2, n_pad)
                prev = pp.repack_warm_duals(
                    adm.warm.log_lam_prev, adm.warm.n1, adm.warm.n2,
                    n1, n2, n_pad)
                pstate = engine.warm_packed_state(
                    pts.x_t, jnp.asarray(adm.warm.w),
                    jnp.asarray(lam), jnp.asarray(prev))
            else:
                pstate = engine.init_packed_state(pts.sign, n1, n2,
                                                  d_pad)
            batch.state = engine.admit_into_slot(
                batch.state, lane, pstate,
                jax.random.key(req.seed), num_iters)
            row = engine.slot_params_row(params, req.gap_tol)
            for f in engine.SlotParams._fields:
                getattr(batch.sp, f)[lane] = getattr(row, f)
            batch.sp_dev = None                 # refresh device mirror
            ticket.note = _Slot(request_id=ticket.rid, req=req,
                                pre=adm.pre, xp_t=xp_t, xm_t=xm_t,
                                warm=adm.warm, tenant=adm.tenant,
                                history=[])

    # ----------------------------------------------------------- failure
    def _record_failure(self, ticket, status: Status, reason: str) -> None:
        """Terminal non-result: structured record claimable via
        ``result(rid)``, live bookkeeping dropped.  A streaming
        tenant's failed/superseded re-fit clears the tenant's live-rid
        (the tenant itself, its dataset and its last good warm state
        all survive -- the next update retries from there)."""
        self._results[ticket.rid] = RequestFailure(
            request_id=ticket.rid, status=status, reason=reason,
            attempts=ticket.attempts)
        self._pre_cache.pop(ticket.rid, None)
        self._tickets.pop(ticket.rid, None)
        ten_id = self._rid_tenant.pop(ticket.rid, None)
        if ten_id is not None:
            ten = self._tenants.get(ten_id)
            if ten is not None and ten.live_rid == ticket.rid:
                ten.live_rid = None

    # ----------------------------------------------------------- harvest
    def _harvest(self, group, obj, healthy) -> list[FitResult]:
        """Record per-slot history, QUARANTINE unhealthy slots (retry
        or structured FAILED -- batch-mates are untouched), extract
        every FINISHED healthy slot through the svm.py recovery path,
        and free its lane."""
        batch = group.payload
        # ONE blocking transfer per chunk for all (S,)-sized lifecycle
        # vectors; the big per-slot state only moves for finished slots
        active, t, obj, healthy = map(np.asarray, jax.device_get(
            (batch.state.active, batch.state.t, obj, healthy)))
        out = []
        for lane, ticket in list(group.slots.items()):
            slot = ticket.note
            if not healthy[lane]:
                # Quarantine: the engine already deactivated the lane
                # on device; free it host-side.  Within the retry
                # budget the ticket re-queues BEHIND waiting tickets
                # (fresh arrival = backoff ordering); past it, the
                # request fails with a structured record.
                if ticket.attempts <= ticket.payload.max_retries:
                    # re-stash the FULL admission record: the retry
                    # re-enters from the same (last good) warm state
                    # the poisoned attempt started from, so a clean
                    # retry is bit-for-bit a clean first run
                    self._pre_cache[ticket.rid] = _Admission(
                        pre=slot.pre, xp_t=slot.xp_t, xm_t=slot.xm_t,
                        warm=slot.warm, tenant=slot.tenant)
                    self._sched.resubmit(group, lane, ticket)
                else:
                    self._record_failure(
                        ticket, Status.FAILED,
                        f"non-finite solver state detected at "
                        f"iteration {int(t[lane])} (quarantined; "
                        f"attempts={ticket.attempts})")
                    self._sched.release(group, lane, Status.FAILED)
                continue
            slot.history.append((int(t[lane]), float(obj[lane])))
            if active[lane]:
                continue
            lam = np.asarray(jax.device_get(batch.state.log_lam[lane]))
            n1 = slot.xp_t.shape[0]
            n2 = slot.xm_t.shape[0]
            if slot.tenant is not None:
                # STREAMING harvest: host-retain the final saddle state
                # (w + dual momentum; lam is already here) BEFORE the
                # lane is freed -- idle-group eviction drops the device
                # buffers, so warm state cannot stay slot-resident.
                ten = self._tenants.get(slot.tenant)
                if ten is not None and ten.live_rid == slot.request_id:
                    w_h, prev_h = map(np.asarray, jax.device_get(
                        (batch.state.w[lane],
                         batch.state.log_lam_prev[lane])))
                    ten.warm = _WarmState(
                        w=w_h, log_lam=lam, log_lam_prev=prev_h,
                        n1=n1, n2=n2)
                    ten.live_rid = None
                self._rid_tenant.pop(slot.request_id, None)
            eta = jnp.exp(jnp.asarray(lam[:n1]))
            xi = jnp.exp(jnp.asarray(lam[n1:n1 + n2]))
            w, b, objective, margin, _ = svm_mod.recover_hyperplane(
                slot.pre, eta, xi, slot.xp_t, slot.xm_t)
            res = FitResult(request_id=slot.request_id, w=w, b=b,
                            objective=objective, margin=margin,
                            iterations=int(t[lane]), bucket=batch.bucket,
                            history=slot.history)
            self._results[slot.request_id] = res
            self._tickets.pop(slot.request_id, None)
            out.append(res)
            self._sched.release(group, lane)
        return out

    # -------------------------------------------------------------- run
    def step(self) -> list[FitResult]:
        """One scheduling round: shed expired deadlines -> policy pick
        -> admit -> one chunk -> harvest (quarantining unhealthy
        slots) -> evict-if-drained.  Returns the requests that
        finished this round."""
        # Deadline shedding FIRST (opt-in via clock): expired queued
        # tickets must neither drive the policy pick nor occupy a lane.
        if self._clock is not None:
            for g, ticket in self._sched.shed_expired(self._clock()):
                self._record_failure(
                    ticket, Status.DEADLINE_EXCEEDED,
                    f"deadline {ticket.deadline} passed before "
                    f"admission")
                self._sched.evict_idle(g)
        group = self._sched.next_group()
        if group is None:
            return []
        self._admit(group)
        if not group.slots:
            return []
        batch = group.payload
        n_pad, d_pad = batch.bucket
        project, check_gap = batch.project, batch.check_gap
        block_size = next(iter(group.slots.values())).payload.block_size
        if batch.mesh is None:
            key = engine.slot_trace_key(group.num_slots, n_pad, d_pad,
                                        block_size, self.chunk_steps,
                                        project, check_gap, self.backend)
        else:
            key = engine.sharded_slot_trace_key(
                group.num_slots, n_pad, d_pad, block_size,
                self.chunk_steps, project, check_gap, self.backend,
                batch.mesh, batch.slot_axes, batch.point_axes)
        # Always run FULL chunks: a slot near its budget is frozen by
        # the per-slot mask at exactly max_t, which keeps every slot's
        # chunk/key schedule identical to a solo solve with
        # record_every == chunk_steps (the parity contract).  A
        # shortened trip count here would give a mid-run-admitted slot
        # a partial FIRST chunk no solo schedule ever takes.
        if batch.sp_dev is None:
            batch.sp_dev = jax.tree.map(jnp.asarray, batch.sp)
            if batch.sp_sharding is not None:
                batch.sp_dev = jax.device_put(batch.sp_dev,
                                              batch.sp_sharding)
        # Deterministic fault injection (tests/bench only): poison a
        # targeted lane BEFORE its chunk; the jitted helper is keyed
        # outside the chunk executables, so zero-recompile accounting
        # is untouched.  A request's chunk index is the length of its
        # recorded history.
        if self._injector is not None:
            for lane, ticket in group.slots.items():
                if self._injector.poison_due(ticket.rid,
                                             len(ticket.note.history)):
                    batch.state = faults_mod.poison_slot_state(
                        batch.state, lane)
        batch.ensure_placement()
        with self._sched.stats.chunk(key, engine.trace_counts):
            if batch.mesh is None:
                batch.state, obj, healthy = engine.run_chunk_slots(
                    batch.state, batch.x_t, batch.sign, batch.sp_dev,
                    self.chunk_steps,
                    chunk_steps=self.chunk_steps, d=d_pad,
                    block_size=block_size, project=project,
                    check_gap=check_gap, backend=self.backend)
            else:
                batch.state, obj, healthy = \
                    engine.run_chunk_slots_sharded(
                        batch.state, batch.x_t, batch.sign,
                        batch.sp_dev, self.chunk_steps,
                        mesh=batch.mesh, slot_axes=batch.slot_axes,
                        point_axes=batch.point_axes,
                        chunk_steps=self.chunk_steps, d=d_pad,
                        block_size=block_size, project=project,
                        check_gap=check_gap, backend=self.backend)
        out = self._harvest(group, obj, healthy)
        # Idle-batch eviction: a drained batch's device buffers (slot
        # state + the (S, d, n) operand) would otherwise leak device
        # memory across varied request shapes.  The COMPILED executable
        # survives in the jit cache regardless.
        self._sched.evict_idle(group)
        return out

    def run(self) -> dict[int, FitResult]:
        """Drain every queue; returns (and RELEASES) every result
        completed since the last drain -- results are not retained
        service-side, so a long-running service stays O(active slots),
        not O(requests served)."""
        while self._sched.has_work():
            self.step()
        out, self._results = self._results, {}
        return out

    # ------------------------------------------------------------ status
    def status(self, rid: int) -> Status:
        """The request's lifecycle state: DONE/FAILED/CANCELLED/
        DEADLINE_EXCEEDED once terminal (until its result is claimed),
        PENDING/RUNNING while live.  KeyError on unknown/claimed
        rids."""
        res = self._results.get(rid)
        if res is not None:
            return (res.status if isinstance(res, RequestFailure)
                    else Status.DONE)
        return self._tickets[rid].status

    def result(self, rid: int) -> FitResult | RequestFailure:
        """Pop one terminal outcome: the :class:`FitResult`, or the
        structured :class:`RequestFailure` (quarantined / cancelled /
        deadline-shed).  A KNOWN rid still in flight raises
        :class:`ResultNotReady`; an unknown (or already claimed) rid
        keeps the historical bare ``KeyError``."""
        if rid in self._results:
            return self._results.pop(rid)
        if rid in self._tickets:
            raise ResultNotReady(
                f"request {rid} is {self._tickets[rid].status.value}")
        raise KeyError(rid)

    def cancel(self, rid: int) -> bool:
        """Cancel a live request: a QUEUED ticket is removed eagerly, a
        RUNNING one has its device lane deactivated and freed (the
        service is host-driven, so this is always between chunks).
        Returns True if cancelled; False for unknown/terminal rids.
        The outcome is a claimable CANCELLED :class:`RequestFailure`."""
        ticket = self._tickets.get(rid)
        if ticket is None:
            return False
        hit = self._sched.cancel_queued(rid)
        if hit is not None:
            g, t = hit
            self._record_failure(t, Status.CANCELLED,
                                 "cancelled while queued")
            self._sched.evict_idle(g)
            return True
        for g in self._sched.groups:
            for lane, t in list(g.slots.items()):
                if t.rid == rid:
                    g.payload.state = engine.deactivate_slot(
                        g.payload.state, lane)
                    self._record_failure(t, Status.CANCELLED,
                                         "cancelled while running")
                    self._sched.release(g, lane, Status.CANCELLED)
                    self._sched.evict_idle(g)
                    return True
        return False

    def fit(self, x, y, **kw) -> FitResult:
        """One-shot convenience: submit + drain (still exercises the
        full slot path, S=1 occupancy).  Other requests completed by
        the drain stay claimable via ``result()``.  Raises
        ``RuntimeError`` if the request was quarantined past its retry
        budget."""
        rid = self.submit(FitRequest(x=x, y=y, **kw))
        out = self.run()
        res = out.pop(rid)
        self._results.update(out)      # keep co-drained results claimable
        if isinstance(res, RequestFailure):
            raise RuntimeError(
                f"fit request {rid} failed: {res.status.value} "
                f"({res.reason})")
        return res

    # ------------------------------------------------------------- stats
    @property
    def stats(self) -> dict:
        """Compile-cache accounting (scheduler-tracked): ``compiles``
        counts the traces observed during THIS service's chunk
        dispatches (trace-count delta around each call -- other
        services or solo solves sharing an executable key are never
        misattributed), ``cache_hits`` the chunk calls served without
        tracing.  After warm-up every call must be a hit (asserted by
        the serve bench)."""
        return self._sched.stats.as_dict()

    @property
    def latencies(self):
        """(request_id, queue-to-result seconds) per completed request
        -- stamped by the scheduler at submit and release (bounded
        sliding window)."""
        return self._sched.latencies

    def latency_percentiles(self, *pcts: float) -> dict[float, float]:
        """Queue-to-result latency percentiles (seconds), e.g.
        ``svc.latency_percentiles(50.0, 95.0)``."""
        return self._sched.latency_percentiles(*pcts)
