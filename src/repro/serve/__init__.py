"""Serving runtime: prefill + batched single-token decode with
per-family caches (KV / compressed-KV / ring / recurrent state)."""
