"""Serving runtime.

One scheduler, two workloads:

* :mod:`repro.serve.scheduler` -- the workload-agnostic
  continuous-batching core both services share: urgency-ordered
  request queues (arrival / priority / deadline), per-group slot
  tables, pluggable cross-group policy (latency-aware ``oldest``
  default, ``round_robin`` bit-compat), admission into freed lanes,
  idle eviction, queue-to-result latency stamps and compile-cache
  accounting.
* :mod:`repro.serve.solver_service` -- the SVM fit endpoint:
  continuous batching of independent fit requests through the
  slot-batched saddle engine (pow-2 shape buckets + mid-run
  admission).
* :mod:`repro.serve.lm_service` -- the LM generation endpoint:
  slot-granular decode (per-lane KV cache / position / PRNG chain)
  with MID-DECODE admission of queued prompts into freed lanes;
  token-for-token equal to solo ``generate``.
* :mod:`repro.serve.engine` -- the LM primitives: prefill + batched
  single-token decode with per-family caches (KV / compressed-KV /
  ring / recurrent state), pow-2 prompt-length bucketing, and the
  slot-granular lane helpers the LM service drives.
"""
