"""Serving runtime.

Two serving surfaces share this package:

* :mod:`repro.serve.engine` -- the LM path: prefill + batched
  single-token decode with per-family caches (KV / compressed-KV /
  ring / recurrent state), with pow-2 prompt-length bucketing so
  varying prompt lengths do not retrace.
* :mod:`repro.serve.solver_service` -- the SVM fit endpoint:
  continuous batching of independent fit requests through the
  slot-batched saddle engine (shape buckets + mid-run admission).
"""
