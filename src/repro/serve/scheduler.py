"""Workload-agnostic continuous-batching scheduler core.

Both serving surfaces -- the SVM fit endpoint
(:mod:`repro.serve.solver_service`) and the LM decode loop
(:mod:`repro.serve.lm_service`) -- face the same scheduling problem:
requests of varying shapes arrive over time, each compiled executable
serves exactly one shape GROUP (a solver bucket, one decode batch), a
group owns a fixed table of reusable slot LANES, and the host must
decide, between device chunks, (a) which group runs its next chunk and
(b) which queued requests are admitted into the group's freed lanes.
This module is that decision core, with no knowledge of what a lane's
device state looks like -- workloads attach their per-group device
buffers as ``Group.payload`` and their per-lane bookkeeping as
``Ticket.note``.

Tickets and urgency
-------------------

Every request is wrapped in a :class:`Ticket` carrying its arrival
sequence number (a global monotonic counter), wall-clock submit time
(for queue-to-result latency accounting), an integer ``priority``
(higher first) and an optional ``deadline`` (any orderable float;
earlier first).  Tickets order by the URGENCY key

    (deadline is None, deadline, -priority, arrival)

so deadline-tagged requests always precede slack ones, higher priority
precedes lower within each of those classes, and arrival order (FIFO)
breaks the remaining ties.  The same key drives both decisions:
admission pops a group's queue in urgency order, and the default
policy runs the group holding the globally most urgent live ticket.

Policies
--------

``oldest``       :class:`OldestFirstPolicy` (default): run the group
                 whose most urgent ticket (queued or running) is
                 globally most urgent -- with pure FIFO traffic that is
                 oldest-request-first across buckets.  Bucket-fill-rate
                 aware: among equally urgent groups the FULLER one runs
                 first, so a chunk's fixed cost is amortized over more
                 tenants.  Starvation-free WITHIN an urgency class
                 under sustained backlog: a waiting ticket's urgency is
                 fixed while same-class tickets elsewhere complete and
                 are replaced by later-arrival (less urgent) ones, so
                 its group's turn always comes.  Deadline tags and
                 priorities are deliberately STRICT classes -- a
                 sustained stream of higher-class traffic CAN starve
                 lower classes (that is what "deadline-tagged never
                 scheduled after slack" means); callers wanting
                 fairness across classes should simply not tag
                 bulk traffic.
``round_robin``  :class:`RoundRobinPolicy`: PR 4's ``_pick_batch``
                 cursor, retained bit-for-bit for compatibility tests
                 -- the cursor advances past the chosen group and no
                 group with work is skipped twice.

Policies only pick among groups WITH WORK; they never admit or evict.
Admission into freed lanes (:meth:`Scheduler.admit`) and idle-group
eviction (:meth:`Scheduler.evict_idle`) are explicit scheduler calls
the workload's step loop makes around its chunk dispatch.

Compile-cache accounting
------------------------

:class:`CompileStats` wraps every chunk dispatch
(``with sched.stats.chunk(key, trace_counter): ...``) and attributes
trace-count deltas to THIS scheduler's calls only -- other services or
solo solves sharing an executable key are never misattributed.  After
warm-up every dispatch must be a cache hit; the serve benchmarks
assert exactly that.

Request lifecycle (status contract)
-----------------------------------

Every ticket carries a :class:`Status`::

    PENDING -----------------> RUNNING ----------------> DONE
       |  \\                      |  \\
       |   `-> CANCELLED          |   `-> CANCELLED   (cancel(rid))
       |-----> DEADLINE_EXCEEDED  |-----> FAILED      (quarantine)
       |        (shed_expired)    |   \\
       `-----> SUPERSEDED         |    `-> PENDING    (resubmit, bounded
                                  |                    retry budget)
                                  `-----> SUPERSEDED  (streaming update)

``submit`` creates PENDING tickets; ``admit`` marks them RUNNING;
``release`` stamps the terminal status (DONE / FAILED / CANCELLED) and
the queue-to-result latency.  ``shed_expired`` sweeps queued tickets
whose deadline has passed (opt-in: services only shed when constructed
with a ``clock``), ``cancel_queued`` removes a queued ticket eagerly,
and ``resubmit`` re-enqueues a quarantined ticket with a FRESH arrival
counter -- the retry queues behind everything already waiting, which
is the backoff ordering.  SUPERSEDED is the streaming-update outcome:
a newer revision of the same tenant's problem arrived, so the stale
fit's answer is no longer wanted -- the solver service cancels the old
request (queued or running) with this status when it accepts an
``UpdateRequest`` for the tenant.  Terminal statuses never transition
again.
"""

from __future__ import annotations

import collections
import contextlib
import enum
import heapq
import itertools
import time
from typing import Any, Callable, Iterator, NamedTuple


class Status(enum.Enum):
    """Request lifecycle states carried on the scheduler ticket.

    Values are the wire strings services expose from ``status(rid)``.
    """

    PENDING = "PENDING"                      # queued, not yet in a lane
    RUNNING = "RUNNING"                      # occupying a device lane
    DONE = "DONE"                            # finished, result available
    FAILED = "FAILED"                        # quarantined / rejected
    CANCELLED = "CANCELLED"                  # cancel(rid) honored
    DEADLINE_EXCEEDED = "DEADLINE_EXCEEDED"  # shed before admission
    SUPERSEDED = "SUPERSEDED"                # replaced by a newer
                                             # revision of its tenant's
                                             # streaming problem

    @property
    def terminal(self) -> bool:
        return self not in (Status.PENDING, Status.RUNNING)


class RequestFailure(NamedTuple):
    """Structured terminal record for a request that did NOT produce a
    normal result: quarantined (FAILED), cancelled, or shed past its
    deadline.  Services store these in their results map so callers get
    a typed object from ``result(rid)`` instead of an exception."""

    request_id: int
    status: Status
    reason: str
    attempts: int = 0   # device admissions consumed (0 = never ran:
                        # shed or cancelled while still queued)


class ResultNotReady(KeyError):
    """``result(rid)`` on a KNOWN request that has not reached a
    terminal status yet.  Subclasses ``KeyError`` so pre-status-API
    callers that caught the bare ``KeyError`` keep working; unknown
    rids still raise the plain ``KeyError``."""


class Ticket:
    """One scheduled request: identity + urgency + latency stamps.

    ``payload`` is the workload's request object (opaque here);
    ``note`` is free per-lane bookkeeping the workload attaches at
    admission (solver: harvest metadata; LM: the token accumulator).
    """

    __slots__ = ("rid", "payload", "priority", "deadline", "arrival",
                 "submitted", "note", "status", "attempts")

    def __init__(self, rid: int, payload: Any, priority: int,
                 deadline: float | None, arrival: int, submitted: float):
        self.rid = rid
        self.payload = payload
        self.priority = priority
        self.deadline = deadline
        self.arrival = arrival
        self.submitted = submitted
        self.note: Any = None
        self.status: Status = Status.PENDING
        self.attempts: int = 0   # admissions so far (retry accounting)

    @property
    def urgency(self) -> tuple:
        """Total order: deadline-tagged first (earliest deadline), then
        priority (higher first), then FIFO.  Unique per ticket (the
        arrival counter is global and monotonic)."""
        return (self.deadline is None,
                self.deadline if self.deadline is not None else 0.0,
                -self.priority, self.arrival)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Ticket(rid={self.rid}, prio={self.priority}, "
                f"deadline={self.deadline}, arrival={self.arrival})")


class Group:
    """One executable's slot table: a sorted request queue plus the
    lane -> ticket map of currently running requests.  ``payload``
    holds the workload's per-group device buffers (opaque)."""

    def __init__(self, key: Any, num_slots: int, payload: Any = None):
        self.key = key
        self.num_slots = num_slots
        self.payload = payload
        self._heap: list[tuple[tuple, Ticket]] = []
        self.slots: dict[int, Ticket] = {}

    # ----------------------------------------------------------- queue
    def enqueue(self, ticket: Ticket) -> None:
        heapq.heappush(self._heap, (ticket.urgency, ticket))

    def pop_most_urgent(self) -> Ticket:
        return heapq.heappop(self._heap)[1]

    def remove_queued(self, rid: int) -> Ticket | None:
        """Eagerly remove one queued ticket by rid (O(queue) rebuild);
        None if the rid is not queued here."""
        hit = None
        kept = []
        for entry in self._heap:
            if hit is None and entry[1].rid == rid:
                hit = entry[1]
            else:
                kept.append(entry)
        if hit is not None:
            self._heap = kept
            heapq.heapify(self._heap)
        return hit

    def drain_expired(self, now: float) -> list[Ticket]:
        """Remove every queued ticket whose deadline is <= ``now``
        (deadline-less tickets never expire).  Returns the shed
        tickets; the survivors keep their heap order."""
        shed = [t for _, t in self._heap
                if t.deadline is not None and t.deadline <= now]
        if shed:
            self._heap = [e for e in self._heap
                          if not (e[1].deadline is not None
                                  and e[1].deadline <= now)]
            heapq.heapify(self._heap)
        return shed

    @property
    def queued(self) -> int:
        return len(self._heap)

    # ----------------------------------------------------------- lanes
    def free_lanes(self) -> list[int]:
        return [i for i in range(self.num_slots) if i not in self.slots]

    @property
    def fill(self) -> int:
        return len(self.slots)

    def has_work(self) -> bool:
        return bool(self.slots or self._heap)

    def most_urgent(self) -> tuple | None:
        """Min urgency over queued AND running tickets (None if the
        group is drained) -- the group's claim on the next chunk."""
        best = self._heap[0][0] if self._heap else None
        for t in self.slots.values():
            if best is None or t.urgency < best:
                best = t.urgency
        return best


class OldestFirstPolicy:
    """Latency-aware default: the group holding the globally most
    urgent live ticket runs next; ties (possible only between equal
    urgency keys, i.e. never for distinct tickets) break toward the
    fuller group, then insertion order.  Starvation-free within an
    urgency class; deadline/priority classes are strict (see the
    module docstring)."""

    def select(self, groups: list[Group]) -> Group | None:
        best, best_key = None, None
        for i, g in enumerate(groups):
            u = g.most_urgent()
            if u is None:
                continue
            key = (u, g.num_slots - g.fill, i)
            if best_key is None or key < best_key:
                best, best_key = g, key
        return best


class RoundRobinPolicy:
    """PR 4's ``SolverService._pick_batch`` verbatim: a cursor over the
    insertion-ordered group list, advanced past the chosen group so a
    continuously-fed group cannot starve the others.  Retained as a
    policy so the bit-compat tests keep a reference scheduler."""

    def __init__(self) -> None:
        self._rr = 0

    def select(self, groups: list[Group]) -> Group | None:
        for i in range(len(groups)):
            j = (self._rr + i) % len(groups)
            if groups[j].has_work():
                self._rr = j + 1
                return groups[j]
        return None


POLICIES: dict[str, Callable[[], Any]] = {
    "oldest": OldestFirstPolicy,
    "round_robin": RoundRobinPolicy,
}


class CompileStats:
    """Per-scheduler compile-cache accounting: ``chunk`` wraps one
    dispatch and records the trace-count delta it caused, so traces by
    other services / solo solves sharing an executable key are never
    attributed here."""

    def __init__(self) -> None:
        self.chunk_calls: collections.Counter = collections.Counter()
        self.compiles = 0

    @contextlib.contextmanager
    def chunk(self, key: Any, trace_counter: collections.Counter
              ) -> Iterator[None]:
        self.chunk_calls[key] += 1
        before = trace_counter.get(key, 0)
        try:
            yield
        finally:
            self.compiles += trace_counter.get(key, 0) - before

    def as_dict(self) -> dict:
        calls = sum(self.chunk_calls.values())
        return {"chunk_calls": calls, "compiles": self.compiles,
                "cache_hits": calls - self.compiles}


class Scheduler:
    """The latency-aware admission core shared by both services.

    Workload step loop shape::

        group = sched.next_group()             # policy pick
        for lane, ticket in sched.admit(group):
            ...write the request into device lane state...
        ...dispatch one chunk under sched.stats.chunk(key, counter)...
        for finished lane: sched.release(group, lane)
        sched.evict_idle(group)

    The scheduler owns everything host-side and O(requests): queues,
    lane occupancy, urgency ordering, queue-to-result latency stamps,
    compile-cache stats.  Device state stays with the workload.
    """

    def __init__(self, num_slots: int, policy: str | Any = "oldest",
                 latency_window: int = 4096):
        self.num_slots = num_slots
        self.policy = (POLICIES[policy]() if isinstance(policy, str)
                       else policy)
        self._groups: dict[Any, Group] = {}     # insertion-ordered
        self._arrival = itertools.count()
        self.stats = CompileStats()
        # (rid, queue-to-result seconds), appended at release; a
        # BOUNDED sliding window so a long-running service stays
        # O(active slots + window), never O(requests served)
        self.latencies: collections.deque[tuple[int, float]] = \
            collections.deque(maxlen=latency_window)

    # ---------------------------------------------------------- groups
    @property
    def groups(self) -> list[Group]:
        return list(self._groups.values())

    def group(self, key: Any,
              payload_factory: Callable[[], Any] | None = None,
              num_slots: int | None = None) -> Group:
        """Get-or-create the slot group for ``key`` (insertion order is
        the round-robin policy's rotation order).

        ``key`` is opaque to the scheduler; the workload picks it so
        that requests sharing a key share one compiled executable.  The
        solver service keys by (bucket, shard-placement): the bucket
        tuple -- padded shapes plus the step statics -- PLUS, on a
        device mesh, the slot's placement kind (lane-parallel unsharded
        slots vs point-sharded large-n slots).  Two fits with identical
        buckets but different placements lower to different
        ``shard_map`` programs with different collective budgets, so
        they must never share a lane table; everything the scheduler
        does (queueing, admission, eviction, stats) is per-key and
        therefore placement-local for free.

        ``num_slots`` overrides the scheduler-wide lane count for THIS
        group at creation (point-sharded groups run few large-n lanes
        where lane-parallel groups run many); ignored if the group
        already exists.
        """
        g = self._groups.get(key)
        if g is None:
            payload = payload_factory() if payload_factory else None
            g = self._groups[key] = Group(
                key, num_slots or self.num_slots, payload)
        return g

    def has_work(self) -> bool:
        return any(g.has_work() for g in self._groups.values())

    # ---------------------------------------------------------- intake
    def submit(self, key: Any, rid: int, payload: Any = None, *,
               priority: int = 0, deadline: float | None = None,
               payload_factory: Callable[[], Any] | None = None,
               num_slots: int | None = None) -> Ticket:
        """Enqueue a request on its group's queue; stamps arrival order
        and wall-clock submit time (queue-to-result latency starts
        here).  ``num_slots`` sizes the group if this submit creates
        it (see :meth:`group`)."""
        g = self.group(key, payload_factory, num_slots)
        t = Ticket(rid, payload, priority, deadline,
                   next(self._arrival), time.perf_counter())
        g.enqueue(t)
        return t

    # -------------------------------------------------------- schedule
    def next_group(self) -> Group | None:
        """Policy pick among groups with work (queued or running)."""
        return self.policy.select(self.groups)

    def admit(self, group: Group) -> list[tuple[int, Ticket]]:
        """Fill the group's free lanes from its queue in urgency order;
        returns the (lane, ticket) assignments for the workload to
        realize in device state.  Between chunks only -- admission
        never interrupts a running chunk."""
        out = []
        for lane in group.free_lanes():
            if not group.queued:
                break
            t = group.pop_most_urgent()
            t.status = Status.RUNNING
            t.attempts += 1
            group.slots[lane] = t
            out.append((lane, t))
        return out

    def release(self, group: Group, lane: int,
                status: Status = Status.DONE) -> Ticket:
        """Free a finished lane, stamp the terminal ``status`` and the
        ticket's queue-to-result latency.  The lane is immediately
        admissible again."""
        t = group.slots.pop(lane)
        t.status = status
        self.latencies.append((t.rid, time.perf_counter() - t.submitted))
        return t

    # ------------------------------------------------ faults/deadlines
    def shed_expired(self, now: float) -> list[tuple[Group, Ticket]]:
        """Sweep every group's queue for tickets whose deadline is
        already past (``deadline <= now``) and shed them with status
        DEADLINE_EXCEEDED -- a hopeless request never occupies a lane.
        Only QUEUED tickets are shed; running ones finish their budget
        (cancel them explicitly if needed).  Returns (group, ticket)
        pairs so the workload can record structured failures."""
        shed = []
        for g in self.groups:
            for t in g.drain_expired(now):
                t.status = Status.DEADLINE_EXCEEDED
                shed.append((g, t))
        return shed

    def cancel_queued(self, rid: int,
                      status: Status = Status.CANCELLED
                      ) -> tuple[Group, Ticket] | None:
        """Remove a still-queued ticket from whichever group holds it,
        stamping ``status`` (CANCELLED by default; the solver service
        passes SUPERSEDED when a streaming update replaces a queued
        fit).  None if no group has it queued (it may be running -- the
        workload cancels those between chunks via :meth:`release`)."""
        for g in self.groups:
            t = g.remove_queued(rid)
            if t is not None:
                t.status = status
                return g, t
        return None

    def resubmit(self, group: Group, lane: int, ticket: Ticket) -> Ticket:
        """Retry path: free the quarantined lane WITHOUT a terminal
        status and re-enqueue the same ticket with a fresh arrival
        counter.  The fresh counter is the backoff ordering -- the
        retry queues behind every ticket already waiting in its
        urgency class, so one flaky tenant cannot hog a lane.  No
        latency stamp (the request is still in flight)."""
        assert group.slots.get(lane) is ticket
        del group.slots[lane]
        ticket.arrival = next(self._arrival)
        ticket.status = Status.PENDING
        ticket.note = None
        group.enqueue(ticket)
        return ticket

    def evict_idle(self, group: Group) -> bool:
        """Drop a drained group so workload device buffers held by its
        payload can be freed -- compiled executables survive in the jit
        cache regardless, so re-creating the group later costs one
        allocation, not a trace.  Returns True if evicted."""
        if group.has_work():
            return False
        if self._groups.get(group.key) is group:
            del self._groups[group.key]
        return True

    # ----------------------------------------------------------- stats
    def latency_percentiles(self, *pcts: float) -> dict[float, float]:
        """Queue-to-result latency percentiles (seconds) over the
        sliding window of released tickets; empty dict if nothing
        completed yet."""
        if not self.latencies:
            return {}
        import numpy as np
        lats = np.asarray([s for _, s in self.latencies])
        return {p: float(np.percentile(lats, p)) for p in pcts}
