"""Pre-processing for Saddle-SVC (Algorithm 1 of the paper).

Steps:
  1. scale all points by 1/max_i ||x_i||  (footnote 3),
  2. apply the randomized Walsh--Hadamard transform ``WD`` so that with
     high probability every coordinate of every point is
     O(sqrt(log n / d))  -- this makes uniform coordinate sampling in
     Algorithm 2 effective.

``W`` is the (normalized) d x d Walsh--Hadamard matrix and ``D`` a random
+-1 diagonal.  We use the *normalized* transform (W W^T = I) so the map
is orthonormal: optima are preserved exactly and ``w`` can be mapped back
by the inverse transform.  Dimensions that are not a power of two are
zero-padded (see DESIGN.md assumption log #3).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def next_pow2(d: int) -> int:
    p = 1
    while p < d:
        p *= 2
    return p


def fwht(x: jax.Array, *, normalize: bool = True) -> jax.Array:
    """Fast Walsh--Hadamard transform along the LAST axis (pure jnp).

    The last axis length must be a power of two.  O(d log d) butterflies
    implemented with reshapes; used as the reference implementation (the
    Pallas kernel in ``repro.kernels.fwht`` is benchmarked against it).
    """
    d = x.shape[-1]
    if d & (d - 1):
        raise ValueError(f"fwht needs a power-of-two axis, got {d}")
    orig_shape = x.shape
    x = x.reshape(-1, d)
    h = 1
    while h < d:
        x = x.reshape(-1, d // (2 * h), 2, h)
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.stack([a + b, a - b], axis=-2)
        x = x.reshape(-1, d)
        h *= 2
    if normalize:
        x = x / jnp.sqrt(jnp.asarray(d, x.dtype))
    return x.reshape(orig_shape)


LANE = 128  # TPU lane width; packed point counts are padded to this


def packed_length(n: int, lane: int = LANE) -> int:
    """Smallest multiple of ``lane`` >= n (>= lane, so the Pallas tile
    grid always divides evenly)."""
    return max(-(-n // lane), 1) * lane


def bucket_length(n: int, lane: int = LANE) -> int:
    """The pow-2 BUCKET ladder for the point axis: ``lane * 2^k``
    (128, 256, 512, 1024, ...), the smallest rung >= n.

    Where :func:`packed_length` pads to the next lane multiple (tight,
    one executable per distinct multiple), the bucket ladder trades at
    most 2x padding for O(log n) distinct shapes -- the multi-tenant
    serving layer compiles ONE slot-batched executable per rung and
    every request whose n lands in the rung shares it."""
    return lane * next_pow2(max(-(-n // lane), 1))


def bucket_shape(n: int, d: int) -> tuple[int, int]:
    """(n_bucket, d_bucket) for a problem with n points in d dims: the
    pow-2 point-axis rung and the pow-2 coordinate count (d is already
    a power of two after :func:`preprocess`, so the d rung is the
    identity on preprocessed problems; :func:`pack_points_to` can pad
    d further for callers sharing one executable across
    dimensionalities)."""
    return bucket_length(n), next_pow2(d)


class PackedPoints(NamedTuple):
    """Both classes packed into ONE lane-padded operand (the single-sweep
    engine's view of the data; see :mod:`repro.core.engine`).

    Slots ``[0, n1)`` hold the +1 class, ``[n1, n1+n2)`` the -1 class,
    and the lane-padding tail is all-zero points.  ``sign`` doubles as
    the validity mask: +1 / -1 for real points, 0 for padding (padding
    additionally carries log-weight NEG_INF in the solver state, so it
    contributes exactly 0 to every sum).
    """

    x_t: jax.Array       # (d, n_pad) COLUMN-major mirror: x_t[c] is
                         #   coordinate c of every packed point, so a
                         #   sampled block is b contiguous rows
    sign: jax.Array      # (n_pad,) +1 class P, -1 class Q, 0 padding
    n1: int
    n2: int

    @property
    def n_pad(self) -> int:
        return self.x_t.shape[-1]


@functools.partial(jax.jit, static_argnames=("n_pad",))
def _pack(xp, xm, n_pad):
    n1, d = xp.shape
    n2 = xm.shape[0]
    x_t = jnp.zeros((d, n_pad), jnp.float32)
    x_t = x_t.at[:, :n1].set(xp.T).at[:, n1:n1 + n2].set(xm.T)
    sign = jnp.zeros((n_pad,), jnp.float32)
    sign = sign.at[:n1].set(1.0).at[n1:n1 + n2].set(-1.0)
    return x_t, sign


def pack_points(xp: jax.Array, xm: jax.Array,
                pad_to: int | None = None) -> PackedPoints:
    """Pack the two (row-major) class matrices into the single-sweep
    layout: one (d, n_pad) column-major mirror plus the +-1 sign vector.

    The mirror is materialized ONCE here so the per-iteration coordinate
    block gather ``x_t[idx]`` reads b contiguous rows instead of b
    strided columns of a row-major (n, d) matrix.
    """
    xp = jnp.asarray(xp, jnp.float32)
    xm = jnp.asarray(xm, jnp.float32)
    n1, d = xp.shape
    n2 = xm.shape[0]
    assert xm.shape[1] == d, "class matrices must share dimensionality"
    n_pad = packed_length(n1 + n2) if pad_to is None else pad_to
    if n_pad < n1 + n2:
        raise ValueError(f"pad_to={pad_to} < n1+n2={n1 + n2}")
    if n_pad % LANE:
        raise ValueError(f"pad_to={pad_to} must be a multiple of the "
                         f"lane width {LANE}")
    x_t, sign = _pack(xp, xm, n_pad)
    return PackedPoints(x_t=x_t, sign=sign, n1=n1, n2=n2)


def pack_points_to(xp: jax.Array, xm: jax.Array, n_pad: int,
                   d_pad: int) -> PackedPoints:
    """BUCKETED packing: pack into an exact (d_pad, n_pad) target shape
    so every problem assigned to the same bucket shares one compiled
    executable (see :func:`bucket_shape`).

    Beyond :func:`pack_points`' lane padding of the point axis, the
    COORDINATE axis is zero-padded to ``d_pad``: padding coordinates
    are all-zero rows of ``x_t``, so a sampled block touching them
    contributes exactly 0 to every dot product and the corresponding
    ``w`` entries stay pinned at 0 (the update is w <- w / (sigma+1)
    from w = 0).  The solver must be configured with d = d_pad so its
    uniform coordinate sampling covers the padded axis -- that is what
    makes a bucketed solve reproducible slot-for-slot against a solo
    solve at the same bucket.
    """
    xp = jnp.asarray(xp, jnp.float32)
    xm = jnp.asarray(xm, jnp.float32)
    d = xp.shape[1]
    if d_pad < d:
        raise ValueError(f"d_pad={d_pad} < d={d}")
    if d_pad > d:
        xp = jnp.pad(xp, ((0, 0), (0, d_pad - d)))
        xm = jnp.pad(xm, ((0, 0), (0, d_pad - d)))
    return pack_points(xp, xm, pad_to=n_pad)


class Preprocessed(NamedTuple):
    """Output of :func:`preprocess` -- the transformed problem."""

    xp: jax.Array        # (n1, d_pad) transformed +1 points (rows)
    xm: jax.Array        # (n2, d_pad) transformed -1 points (rows)
    signs: jax.Array     # (d_pad,) the +-1 diagonal of D
    scale: jax.Array     # scalar: 1 / max ||x_i||
    d_orig: int          # original dimensionality before padding


def hadamard_transform(x: jax.Array, signs: jax.Array) -> jax.Array:
    """Apply ``W D`` to rows of ``x`` (already padded to len(signs))."""
    return fwht(x * signs[None, :])


def inverse_hadamard_transform(v: jax.Array, signs: jax.Array) -> jax.Array:
    """Apply ``(W D)^-1 = D W^T`` to a vector in transformed space."""
    return fwht(v) * signs


@functools.partial(jax.jit, static_argnames=("d_pad",))
def _transform(xp, xm, signs, d_pad):
    def pad(x):
        return jnp.pad(x, ((0, 0), (0, d_pad - x.shape[1])))

    xp, xm = pad(xp), pad(xm)
    norms = jnp.concatenate(
        [jnp.linalg.norm(xp, axis=1), jnp.linalg.norm(xm, axis=1)]
    )
    scale = 1.0 / jnp.maximum(jnp.max(norms), 1e-30)
    return (
        hadamard_transform(xp * scale, signs),
        hadamard_transform(xm * scale, signs),
        scale,
    )


def preprocess(xp: np.ndarray | jax.Array, xm: np.ndarray | jax.Array,
               key: jax.Array) -> Preprocessed:
    """Algorithm 1: scale to the unit ball and apply the WD transform."""
    xp = jnp.asarray(xp, jnp.float32)
    xm = jnp.asarray(xm, jnp.float32)
    d = xp.shape[1]
    assert xm.shape[1] == d, "class matrices must share dimensionality"
    d_pad = next_pow2(d)
    signs = jax.random.rademacher(key, (d_pad,), dtype=jnp.float32)
    txp, txm, scale = _transform(xp, xm, signs, d_pad)
    return Preprocessed(xp=txp, xm=txm, signs=signs, scale=scale, d_orig=d)


def transform_like(pre: Preprocessed, x: np.ndarray | jax.Array) -> jax.Array:
    """Apply a tenant's FIXED preprocessing transform to NEW raw points.

    Streaming updates must keep the transform (the +-1 diagonal ``D``
    and the unit-ball scale) of the tenant's ORIGINAL :func:`preprocess`
    call: carried saddle state lives in the transformed space, so
    re-deriving either one would silently re-base the warm start.  The
    scale therefore stays pinned even if an arriving point's norm
    exceeds the original max -- the unit-ball guarantee (footnote 3)
    degrades gracefully for such points while optima are still exact
    (the map stays a fixed orthonormal transform times a constant).
    """
    x = jnp.asarray(x, jnp.float32)
    if x.ndim != 2 or x.shape[1] != pre.d_orig:
        raise ValueError(
            f"transform_like expects (m, d_orig={pre.d_orig}) points; "
            f"got shape {tuple(x.shape)}")
    d_pad = pre.signs.shape[0]
    x = jnp.pad(x, ((0, 0), (0, d_pad - x.shape[1])))
    return hadamard_transform(x * pre.scale, pre.signs)


def repack_warm_duals(log_lam: np.ndarray, n1_old: int, n2_old: int,
                      n1_new: int, n2_new: int,
                      n_pad_new: int) -> np.ndarray:
    """Transfer packed per-class log dual mass across bucket shapes.

    The packed layout is ``[eta (n1) | xi (n2) | NEG_INF pad]``, so
    appending points to either class SHIFTS the other class's block:
    a warm start cannot just zero-pad the old vector, it must re-place
    each class segment at its new offset.  Carried entries keep their
    old log weights; new points are seeded at the NEW uniform level
    (``-log(n_class_new)``).  The carried segment still sums to the OLD
    class's total mass, so the class sum is temporarily != 1 -- by
    design: the next MWU round's per-class logsumexp renormalizes each
    class to exactly 1 (normalization IS the repair, the same rule the
    sharded paths use for dropped shards), so no host-side repair pass
    and no extra executable is needed.

    ``n1_old = n2_old = 0`` ignores ``log_lam`` entirely and yields the
    pure uniform init on the new shape (the replace-mode dual reset).
    """
    from repro.core.engine import NEG_INF  # engine never imports us back
    if not (0 <= n1_old <= n1_new and 0 <= n2_old <= n2_new):
        raise ValueError(
            f"warm dual transfer needs old class sizes within new ones; "
            f"got ({n1_old}, {n2_old}) -> ({n1_new}, {n2_new})")
    if n1_new + n2_new > n_pad_new:
        raise ValueError(
            f"n1_new+n2_new={n1_new + n2_new} > n_pad_new={n_pad_new}")
    lam = np.asarray(log_lam, np.float32)
    out = np.full((n_pad_new,), NEG_INF, np.float32)
    out[:n1_old] = lam[:n1_old]
    out[n1_old:n1_new] = -math.log(n1_new)
    out[n1_new:n1_new + n2_old] = lam[n1_old:n1_old + n2_old]
    out[n1_new + n2_old:n1_new + n2_new] = -math.log(n2_new)
    return out


def recover_direction(w: jax.Array, pre: Preprocessed) -> jax.Array:
    """Map a direction from transformed space back to the input space.

    Predictions on raw points x use sign(w_orig . x - b_orig); the
    orthonormal transform gives w_orig = scale * (WD)^T w.
    """
    w_orig = inverse_hadamard_transform(w, pre.signs)[: pre.d_orig]
    return w_orig * pre.scale
