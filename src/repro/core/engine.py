"""Fused solver core shared by every Saddle-SVC execution mode.

The paper's Algorithm 2 (serial) and Algorithm 4 (distributed) are the
same iteration: the serial solver is the k=1 degenerate client, where
every all-reduce is the identity.  This module implements that single
step ONCE, parameterized along two orthogonal axes:

  ``axis_name``   None          -> serial (all psum/pmax collapse away)
                  "clients"     -> distributed, under ``jax.vmap``
                                   (bit-exact k-client simulation) or
                                   ``shard_map`` (real device mesh)

  ``backend``     "jnp"         -> pure jax.numpy step
                  "pallas"      -> the Pallas kernels in
                                   ``repro.kernels.ops`` for the two
                                   O(n) passes over the points

On top of the step sits a fixed-shape chunk driver:

  * ``chunk_body`` pre-splits the per-step keys at a static
    ``chunk_steps`` shape but runs the step under a ``fori_loop`` with
    a DYNAMIC trip count, so one executable serves every chunk length
    and the padded tail of a partial final chunk is never executed --
    the seed driver re-jitted its scan for each distinct ``num_steps``
    (e.g. the partial final chunk of a ``record_every``-chunked solve).
  * ``run_chunk`` (the serial jit wrapper) donates the state buffers
    (``donate_argnums``) so the solver state is updated in place.
  * The objective is computed on device at the end of each chunk and
    returned as a device scalar; drivers accumulate those and do ONE
    host transfer at the end of the solve instead of a blocking
    ``float(...)`` sync per chunk.

Coordinate blocks are sampled WITHOUT replacement.  With replacement
(the seed behavior), a duplicated index made ``w.at[idx].set(w_new)``
last-write-wins while ``cols @ dw`` double-counted that column in the
incremental inner products ``u_p``/``u_m``, silently corrupting the
invariant ``u == X w``.
"""

from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp

from repro.core import projections

CLIENT_AXIS = "clients"
NEG_INF = -1e30     # log-weight of padding points (exp() == 0 exactly)

# Incremented at TRACE time inside chunk_body, keyed by the static
# configuration -- i.e. it counts XLA compilations, not calls.  Tests
# use this to assert that chunked solves with a partial final chunk
# compile the chunk exactly once.
trace_counts: collections.Counter = collections.Counter()


def sample_block(key: jax.Array, d: int, b: int) -> jax.Array:
    """b distinct coordinates, uniform without replacement (b=1 keeps
    the cheap single-draw path; the distributions coincide)."""
    if b == 1:
        return jax.random.randint(key, (1,), 0, d)
    return jax.random.permutation(key, d)[:b]


def _all_sum(x, axis_name):
    return x if axis_name is None else jax.lax.psum(x, axis_name)


def _all_max(x, axis_name):
    return x if axis_name is None else jax.lax.pmax(x, axis_name)


def _dual_update(cols, log_lam, u, dw, sign, p, axis_name, backend):
    """Lines 5-6 of Algorithm 2 + incremental u maintenance, normalized
    with a (possibly distributed) logsumexp.  Returns (log_new, u_new).

    Both backends produce the UNNORMALIZED log weights plus local
    normalizer partials (m, s) with lse = m + log(s); the partials are
    then combined across clients (rounds 2-3 of Algorithm 4) or used
    directly in serial mode.
    """
    d_eff = p.d / p.block_size
    if backend == "pallas":
        from repro.kernels import ops as kops
        log_new, u_new, m_local, s_local = kops.mwu_update(
            cols, log_lam, u, dw, sign=sign, gamma=p.gamma, tau=p.tau,
            d_eff=d_eff, normalize=False)
    else:
        dv = cols @ dw
        v = sign * (u + d_eff * dv)
        c = 1.0 / (p.gamma + d_eff / p.tau)
        log_new = c * ((d_eff / p.tau) * log_lam - v)
        u_new = u + dv
        m_local = jnp.max(log_new)
        s_local = jnp.sum(jnp.exp(log_new - m_local))
    m = _all_max(m_local, axis_name)
    s = _all_sum(s_local * jnp.exp(m_local - m), axis_name)
    return log_new - (m + jnp.log(s)), u_new


def _capped_project(log_lam, nu, axis_name):
    """Rule 2 (serial: one sort) or the distributed Rule-3 loop (round 4
    of Algorithm 4: psum'd (varsigma, Omega) until varsigma == 0)."""
    if axis_name is None:
        eta = projections.capped_simplex_project_sorted(
            jnp.exp(log_lam), nu)
        return jnp.log(jnp.maximum(eta, 1e-38))

    max_rounds = int(1.0 / nu) + 2

    def cond(state):
        eta, it = state
        varsig = jax.lax.psum(
            jnp.sum(jnp.where(eta > nu, eta - nu, 0.0)), axis_name)
        return (varsig > 1e-12) & (it < max_rounds)

    def body(state):
        eta, it = state
        varsig = jax.lax.psum(
            jnp.sum(jnp.where(eta > nu, eta - nu, 0.0)), axis_name)
        omega = jax.lax.psum(
            jnp.sum(jnp.where(eta < nu, eta, 0.0)), axis_name)
        eta = jnp.where(eta >= nu, nu,
                        eta * (1.0 + varsig / jnp.maximum(omega, 1e-30)))
        return eta, it + 1

    eta = jnp.exp(log_lam)
    eta, _ = jax.lax.while_loop(cond, body, (eta, jnp.array(0, jnp.int32)))
    return jnp.where(eta > 0, jnp.log(jnp.maximum(eta, 1e-38)), NEG_INF)


def step(state, key: jax.Array, xp: jax.Array, xm: jax.Array, p, *,
         axis_name: str | None = None, backend: str = "jnp"):
    """One Algorithm-2/4 iteration from a single client's viewpoint.

    ``state`` is any NamedTuple with the canonical eight fields
    (SaddleState / ShardedState); the same type is returned.  ``xp`` and
    ``xm`` are the client's local (m1, d)/(m2, d) slices -- the full
    matrices in serial mode.  Under an axis, the key is identical across
    clients (the server broadcasts i*).
    """
    d, b = p.d, p.block_size
    d_eff = d / b
    idx = sample_block(key, d, b)
    cols_p = xp[:, idx]                              # (n1, B) rows X_{i*,.}
    cols_m = xm[:, idx]                              # (n2, B)

    # Lines 2-3 (round 1): momentum-extrapolated dual dot products,
    # all-reduced over clients.
    if backend == "pallas":
        from repro.kernels import ops as kops
        delta_p = kops.momentum_dot(cols_p, state.log_eta,
                                    state.log_eta_prev, p.theta)
        delta_m = kops.momentum_dot(cols_m, state.log_xi,
                                    state.log_xi_prev, p.theta)
    else:
        eta = jnp.exp(state.log_eta)
        eta_prev = jnp.exp(state.log_eta_prev)
        xi = jnp.exp(state.log_xi)
        xi_prev = jnp.exp(state.log_xi_prev)
        delta_p = cols_p.T @ (eta + p.theta * (eta - eta_prev))
        delta_m = cols_m.T @ (xi + p.theta * (xi - xi_prev))
    delta_p = _all_sum(delta_p, axis_name)
    delta_m = _all_sum(delta_m, axis_name)

    # Line 4 (round 2): every client performs the identical w update.
    w_old = state.w[idx]
    w_new = (w_old + p.sigma * (delta_p - delta_m)) / (p.sigma + 1.0)
    dw = w_new - w_old

    # Lines 5-6 (rounds 2-3): MWU dual updates.
    log_eta_new, u_p_new = _dual_update(
        cols_p, state.log_eta, state.u_p, dw, 1.0, p, axis_name, backend)
    log_xi_new, u_m_new = _dual_update(
        cols_m, state.log_xi, state.u_m, dw, -1.0, p, axis_name, backend)

    # Rule 2 / round 4: nu-Saddle capped-simplex projection.
    if p.nu > 0.0:
        log_eta_new = _capped_project(log_eta_new, p.nu, axis_name)
        log_xi_new = _capped_project(log_xi_new, p.nu, axis_name)

    return type(state)(
        w=state.w.at[idx].set(w_new),
        log_eta=log_eta_new, log_eta_prev=state.log_eta,
        log_xi=log_xi_new, log_xi_prev=state.log_xi,
        u_p=u_p_new, u_m=u_m_new,
        t=state.t + 1,
    )


def objective_from_state(state, xp, xm, axis_name=None) -> jax.Array:
    """C-Hull / RC-Hull objective 0.5 * ||A eta - B xi||^2, all-reduced
    over clients when run under an axis."""
    diff = jnp.exp(state.log_eta) @ xp - jnp.exp(state.log_xi) @ xm
    diff = _all_sum(diff, axis_name)
    return 0.5 * jnp.sum(diff * diff)


def chunk_body(state, key, xp, xm, params, num_steps, *,
               chunk_steps: int, axis_name: str | None = None,
               backend: str = "jnp"):
    """Run ``num_steps`` (dynamic) of at most ``chunk_steps`` (static)
    iterations and record the objective on device.

    The per-step keys are pre-split at the FIXED shape ``chunk_steps``
    while the trip count stays dynamic, so one executable serves every
    chunk length (the seed driver re-jitted its scan per distinct
    length) and a partial final chunk both reuses the executable AND
    skips the padded tail entirely (``fori_loop``, not a masked scan).
    Returns (new_state, objective_scalar)."""
    trace_counts[(axis_name, backend, chunk_steps)] += 1  # trace-time only

    keys = jax.random.split(key, chunk_steps)

    def body(i, st):
        return step(st, keys[i], xp, xm, params,
                    axis_name=axis_name, backend=backend)

    state = jax.lax.fori_loop(0, num_steps, body, state)
    return state, objective_from_state(state, xp, xm, axis_name)


@functools.partial(jax.jit,
                   static_argnames=("params", "chunk_steps", "backend"),
                   donate_argnums=(0,))
def run_chunk(state, key, xp, xm, num_steps, *, params, chunk_steps: int,
              backend: str = "jnp"):
    """Serial chunk: state buffers donated, objective returned as a
    device scalar (no host sync), one compile for all chunk lengths up
    to ``chunk_steps``."""
    return chunk_body(state, key, xp, xm, params, num_steps,
                      chunk_steps=chunk_steps, axis_name=None,
                      backend=backend)


def drive(state, key, num_iters: int, chunk: int, run) -> tuple:
    """Shared host loop: split one key per chunk, dispatch fixed-shape
    chunks, accumulate device scalars, transfer history ONCE at the end.

    ``run(state, subkey, steps_remaining) -> (state, obj)`` is the
    mode-specific jitted chunk.  Returns (state, [(done, obj), ...]).
    """
    import numpy as np

    objs, marks = [], []
    done = 0
    while done < num_iters:
        key, sub = jax.random.split(key)
        ns = min(chunk, num_iters - done)
        state, obj = run(state, sub, ns)
        done += ns
        objs.append(obj)
        marks.append(done)
    # per-client objectives (k,) are identical across clients; take [0]
    objs = [float(np.asarray(o).reshape(-1)[0]) for o in jax.device_get(objs)]
    return state, list(zip(marks, objs))
