"""Fused solver core shared by every Saddle-SVC execution mode.

The paper's Algorithm 2 (serial) and Algorithm 4 (distributed) are the
same iteration: the serial solver is the k=1 degenerate client, where
every all-reduce is the identity.  This module implements that single
step ONCE, parameterized along two orthogonal axes:

  ``axis_name``   None          -> serial (all psum/pmax collapse away)
                  "clients"     -> distributed, under ``jax.vmap``
                                   (bit-exact k-client simulation) or
                                   ``shard_map`` (real device mesh)

  ``backend``     "jnp"         -> pure jax.numpy step
                  "pallas"      -> the Pallas kernels in
                                   ``repro.kernels.ops``

Packed single-sweep step
------------------------

The PRIMARY step (:func:`step_packed`, what ``saddle.solve`` and
``distributed.solve_distributed`` run) works on the packed +- layout of
:func:`repro.core.preprocess.pack_points`: both classes live in ONE
lane-padded point set with a +-1 ``sign`` vector (0 marks lane-padding,
which also carries log-weight NEG_INF so it contributes exactly 0 to
every reduction).  The packed state holds THREE point-length vectors
(``log_lam``, ``log_lam_prev``, ``u``) plus ``w`` where the unpacked
state needs six, and every per-point pass runs ONCE per step instead of
once per class:

  pass 1  signed momentum dot: delta = sum_i sign_i mom_i x_t[idx, i]
          (the sign folds delta+ - delta- into a single sweep)
  pass 2  MWU update + incremental u + BOTH per-class logsumexp
          normalizer partials, masked by sign in the same sweep

so the Pallas backend launches 2 kernels per step (vs 4 for the
unpacked reference).  Coordinate blocks are gathered from the
column-major mirror ``x_t`` (d, n_pad): a sampled block is b CONTIGUOUS
rows (``jnp.take(x_t, idx, axis=0)``), not b strided columns of a
row-major (n, d) matrix; the Pallas kernels go further and gather
tile-by-tile inside the kernel from scalar-prefetched indices, never
materializing a cols intermediate.

The nu-Saddle capped-simplex projection is SORT-FREE: a fixed-round
bisection on the cap scale (the shared core
:func:`repro.core.projections.capped_bisect_masked`) whose every round
is one masked O(n) reduction -- both classes share the sweep, and
under an axis each round all-reduces a single (2,) vector, so the
round-4 budget is a DETERMINISTIC O(k) scalars per iteration
(BISECT_ROUNDS_SOLVER two-scalar all-reduces; Theorem 8).  The
reference path pays an O(n log n) argsort + scatter per class per
iteration serially, and a data-dependent loop -- worst case O(1/nu)
rounds -- distributed.

The unpacked :func:`step` is retained as the reference oracle the
packed path is parity-tested against (serial/distributed x jnp/pallas x
nu=0/nu>0) and as the baseline ``benchmarks/engine_bench.py`` measures
the packed speedup over.

On top of either step sits the fixed-shape chunk driver:

  * ``chunk_body*`` pre-splits the per-step keys at a static
    ``chunk_steps`` shape but runs the step under a ``fori_loop`` with
    a DYNAMIC trip count, so one executable serves every chunk length
    and the padded tail of a partial final chunk is never executed.
  * ``run_chunk*`` (the serial jit wrappers) donate the state buffers
    (``donate_argnums``) so the solver state is updated in place.
  * The objective is computed on device at the end of each chunk and
    returned as a device scalar; drivers accumulate those and do ONE
    host transfer at the end of the solve.

Coordinate blocks are sampled WITHOUT replacement (a duplicated index
would corrupt the incremental invariant ``u == X w``) by a partial
Fisher--Yates shuffle: b swap rounds on an iota array, O(d + b) work
per draw instead of the O(d log d) full ``jax.random.permutation``.
"""

from __future__ import annotations

import collections
import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import projections

CLIENT_AXIS = "clients"
NEG_INF = -1e30     # log-weight of padding points (exp() == 0 exactly)

# Incremented at TRACE time inside the chunk bodies, keyed by the static
# configuration -- i.e. it counts XLA compilations, not calls.  Tests
# use this to assert that chunked solves with a partial final chunk
# compile the chunk exactly once.
trace_counts: collections.Counter = collections.Counter()


def sample_block(key: jax.Array, d: int, b: int) -> jax.Array:
    """b distinct coordinates, uniform without replacement, via a
    partial Fisher--Yates shuffle: swap slot i with a uniform slot in
    [i, d) for i < b, then read the b-prefix.  O(d + b) work -- the
    full ``jax.random.permutation`` sort is O(d log d) for b << d --
    and exactly the uniform without-replacement distribution (each
    prefix outcome has probability 1 / (d (d-1) ... (d-b+1))).
    b=1 keeps the cheap single-draw path; the distributions coincide.
    """
    if b == 1:
        return jax.random.randint(key, (1,), 0, d)
    offs = jnp.arange(b)
    swap = offs + jax.random.randint(key, (b,), 0, d - offs)  # j_i ~ U[i, d)

    def body(i, a):
        ai, aj = a[i], a[swap[i]]
        return a.at[i].set(aj).at[swap[i]].set(ai)

    arr = jax.lax.fori_loop(0, b, body, jnp.arange(d))
    return arr[:b]


def _all_sum(x, axis_name):
    return x if axis_name is None else jax.lax.psum(x, axis_name)


def _all_max(x, axis_name):
    return x if axis_name is None else jax.lax.pmax(x, axis_name)


# ==========================================================================
# Reference (unpacked) step: two passes per class, retained as the
# parity oracle and the engine_bench baseline.
# ==========================================================================

def _dual_update(cols, log_lam, u, dw, sign, p, axis_name, backend):
    """Lines 5-6 of Algorithm 2 + incremental u maintenance, normalized
    with a (possibly distributed) logsumexp.  Returns (log_new, u_new).

    Both backends produce the UNNORMALIZED log weights plus local
    normalizer partials (m, s) with lse = m + log(s); the partials are
    then combined across clients (rounds 2-3 of Algorithm 4) or used
    directly in serial mode.
    """
    d_eff = p.d / p.block_size
    if backend == "pallas":
        from repro.kernels import ops as kops
        log_new, u_new, m_local, s_local = kops.mwu_update(
            cols, log_lam, u, dw, sign=sign, gamma=p.gamma, tau=p.tau,
            d_eff=d_eff, normalize=False)
    else:
        dv = cols @ dw
        v = sign * (u + d_eff * dv)
        c = 1.0 / (p.gamma + d_eff / p.tau)
        log_new = c * ((d_eff / p.tau) * log_lam - v)
        u_new = u + dv
        m_local = jnp.max(log_new)
        s_local = jnp.sum(jnp.exp(log_new - m_local))
    m = _all_max(m_local, axis_name)
    s = _all_sum(s_local * jnp.exp(m_local - m), axis_name)
    return log_new - (m + jnp.log(s)), u_new


def _capped_project(log_lam, nu, axis_name):
    """Reference nu-projection: Rule 2 (serial: one sort per iteration)
    or the distributed Rule-3 loop (round 4 of Algorithm 4).  The packed
    step replaces both with the sort-free fixed-round bisection."""
    if axis_name is None:
        eta = projections.capped_simplex_project_sorted(
            jnp.exp(log_lam), nu)
        return jnp.log(jnp.maximum(eta, 1e-38))

    max_rounds = int(1.0 / nu) + 2

    def cond(state):
        eta, it = state
        varsig = jax.lax.psum(
            jnp.sum(jnp.where(eta > nu, eta - nu, 0.0)), axis_name)
        return (varsig > 1e-12) & (it < max_rounds)

    def body(state):
        eta, it = state
        varsig = jax.lax.psum(
            jnp.sum(jnp.where(eta > nu, eta - nu, 0.0)), axis_name)
        omega = jax.lax.psum(
            jnp.sum(jnp.where(eta < nu, eta, 0.0)), axis_name)
        eta = jnp.where(eta >= nu, nu,
                        eta * (1.0 + varsig / jnp.maximum(omega, 1e-30)))
        return eta, it + 1

    eta = jnp.exp(log_lam)
    eta, _ = jax.lax.while_loop(cond, body, (eta, jnp.array(0, jnp.int32)))
    return jnp.where(eta > 0, jnp.log(jnp.maximum(eta, 1e-38)), NEG_INF)


def step(state, key: jax.Array, xp: jax.Array, xm: jax.Array, p, *,
         axis_name: str | None = None, backend: str = "jnp"):
    """One REFERENCE Algorithm-2/4 iteration from a single client's
    viewpoint (two passes per class; the production path is
    :func:`step_packed`).

    ``state`` is any NamedTuple with the canonical eight fields
    (SaddleState / ShardedState); the same type is returned.  ``xp`` and
    ``xm`` are the client's local (m1, d)/(m2, d) slices -- the full
    matrices in serial mode.  Under an axis, the key is identical across
    clients (the server broadcasts i*).
    """
    d, b = p.d, p.block_size
    d_eff = d / b
    idx = sample_block(key, d, b)
    cols_p = xp[:, idx]                              # (n1, B) rows X_{i*,.}
    cols_m = xm[:, idx]                              # (n2, B)

    # Lines 2-3 (round 1): momentum-extrapolated dual dot products,
    # all-reduced over clients.
    if backend == "pallas":
        from repro.kernels import ops as kops
        delta_p = kops.momentum_dot(cols_p, state.log_eta,
                                    state.log_eta_prev, p.theta)
        delta_m = kops.momentum_dot(cols_m, state.log_xi,
                                    state.log_xi_prev, p.theta)
    else:
        eta = jnp.exp(state.log_eta)
        eta_prev = jnp.exp(state.log_eta_prev)
        xi = jnp.exp(state.log_xi)
        xi_prev = jnp.exp(state.log_xi_prev)
        delta_p = cols_p.T @ (eta + p.theta * (eta - eta_prev))
        delta_m = cols_m.T @ (xi + p.theta * (xi - xi_prev))
    delta_p = _all_sum(delta_p, axis_name)
    delta_m = _all_sum(delta_m, axis_name)

    # Line 4 (round 2): every client performs the identical w update.
    # Multiply by the precomputed reciprocal instead of dividing by
    # (sigma + 1): bit-identical to what XLA's divide-by-constant
    # rewrite produced, and -- crucially -- ALSO bit-identical when the
    # scalar is a traced per-slot value (a runtime divide rounds
    # differently), keeping every engine mode in lockstep.
    w_old = state.w[idx]
    w_new = (w_old + p.sigma * (delta_p - delta_m)) * (1.0 / (p.sigma + 1.0))
    dw = w_new - w_old

    # Lines 5-6 (rounds 2-3): MWU dual updates.
    log_eta_new, u_p_new = _dual_update(
        cols_p, state.log_eta, state.u_p, dw, 1.0, p, axis_name, backend)
    log_xi_new, u_m_new = _dual_update(
        cols_m, state.log_xi, state.u_m, dw, -1.0, p, axis_name, backend)

    # Rule 2 / round 4: nu-Saddle capped-simplex projection.
    if p.nu > 0.0:
        log_eta_new = _capped_project(log_eta_new, p.nu, axis_name)
        log_xi_new = _capped_project(log_xi_new, p.nu, axis_name)

    return type(state)(
        w=state.w.at[idx].set(w_new),
        log_eta=log_eta_new, log_eta_prev=state.log_eta,
        log_xi=log_xi_new, log_xi_prev=state.log_xi,
        u_p=u_p_new, u_m=u_m_new,
        t=state.t + 1,
    )


def objective_from_state(state, xp, xm, axis_name=None) -> jax.Array:
    """C-Hull / RC-Hull objective 0.5 * ||A eta - B xi||^2, all-reduced
    over clients when run under an axis."""
    diff = jnp.exp(state.log_eta) @ xp - jnp.exp(state.log_xi) @ xm
    diff = _all_sum(diff, axis_name)
    return 0.5 * jnp.sum(diff * diff)


def chunk_body(state, key, xp, xm, params, num_steps, *,
               chunk_steps: int, axis_name: str | None = None,
               backend: str = "jnp"):
    """Reference chunk: run ``num_steps`` (dynamic) of at most
    ``chunk_steps`` (static) unpacked iterations and record the
    objective on device.  Returns (new_state, objective_scalar)."""
    trace_counts[(axis_name, backend, chunk_steps)] += 1  # trace-time only

    keys = jax.random.split(key, chunk_steps)

    def body(i, st):
        return step(st, keys[i], xp, xm, params,
                    axis_name=axis_name, backend=backend)

    state = jax.lax.fori_loop(0, num_steps, body, state)
    return state, objective_from_state(state, xp, xm, axis_name)


@functools.partial(jax.jit,
                   static_argnames=("params", "chunk_steps", "backend"),
                   donate_argnums=(0,))
def run_chunk(state, key, xp, xm, num_steps, *, params, chunk_steps: int,
              backend: str = "jnp"):
    """Serial reference chunk: state buffers donated, objective returned
    as a device scalar (no host sync), one compile for all chunk lengths
    up to ``chunk_steps``."""
    return chunk_body(state, key, xp, xm, params, num_steps,
                      chunk_steps=chunk_steps, axis_name=None,
                      backend=backend)


# ==========================================================================
# Packed single-sweep step (the production path)
# ==========================================================================


class PackedState(NamedTuple):
    """Solver state over the packed +- layout: one point-length vector
    per role instead of one per class per role.  Slot i belongs to the
    class given by ``sign[i]`` of the accompanying
    :class:`repro.core.preprocess.PackedPoints`; padding slots carry
    log-weight NEG_INF forever."""
    w: jax.Array             # (d,)
    log_lam: jax.Array       # (n_pad,)  [log eta | log xi | NEG_INF pad]
    log_lam_prev: jax.Array  # (n_pad,)
    u: jax.Array             # (n_pad,)  <w, x_i> maintained incrementally
    t: jax.Array             # iteration counter


def init_packed_state(sign: jax.Array, n1: int, n2: int,
                      d: int) -> PackedState:
    """Line 5 of Algorithm 1 on the packed layout: w=0, eta=1/n1,
    xi=1/n2 (global counts -- under sharding each client passes its own
    sign slice but the same n1/n2)."""
    log_lam = jnp.where(
        sign > 0, -math.log(n1),
        jnp.where(sign < 0, -math.log(n2), NEG_INF)).astype(jnp.float32)
    zeros_w = jnp.zeros(sign.shape[:-1] + (d,), jnp.float32)
    # distinct buffers for the "prev" copy: the chunk drivers donate the
    # state, and XLA rejects donating the same buffer twice
    return PackedState(
        w=zeros_w,
        log_lam=log_lam, log_lam_prev=jnp.copy(log_lam),
        u=jnp.zeros_like(log_lam),
        t=jnp.zeros(sign.shape[:-1], jnp.int32),
    )


@functools.partial(jax.jit, donate_argnums=(1, 2, 3))
def warm_packed_state(x_t: jax.Array, w: jax.Array, log_lam: jax.Array,
                      log_lam_prev: jax.Array) -> PackedState:
    """WARM-START packed state from a previous solution: carry ``w``
    and the (re-placed, see ``preprocess.repack_warm_duals``) log duals
    plus their momentum copy, and recompute ``u = x_t^T w`` ON DEVICE
    so the incremental invariant ``u_i == <w, x_i>`` holds EXACTLY for
    every point -- carried, appended and padding alike (recomputing IS
    carrying u: it is the unique value consistent with the carried w
    over the new operand, with zero accumulated drift).

    ``t`` resets to 0: the warm run's iteration counter counts the
    UPDATE round's own work, which is what iterations-to-gap accounting
    (``serve/stream/warm_iters_ratio``) compares against a cold solve.

    The state leaves are donated (the caller hands over freshly staged
    buffers); ``x_t`` is not -- it is the batch operand the chunk
    executable keeps reading.  This helper is jitted OUTSIDE the
    ``trace_counts`` accounting, like ``admit_into_slot``: warm
    admission must not perturb the zero-recompile contract of the hot
    chunk executables.
    """
    return PackedState(
        w=w, log_lam=log_lam, log_lam_prev=log_lam_prev,
        u=w @ x_t, t=jnp.zeros((), jnp.int32))


def unpack_state(pstate: PackedState, n1: int, n2: int, cls):
    """Slice a packed state back into the per-class 8-field view
    (``cls`` is SaddleState or ShardedState -- same field names; the
    ``...`` slicing serves both the flat and the stacked-client
    layouts).  Slots [0, n1) are eta, [n1, n1+n2) are xi; the
    lane-padding tail is dropped."""
    lam, prev, u = pstate.log_lam, pstate.log_lam_prev, pstate.u
    return cls(
        w=pstate.w,
        log_eta=lam[..., :n1], log_eta_prev=prev[..., :n1],
        log_xi=lam[..., n1:n1 + n2], log_xi_prev=prev[..., n1:n1 + n2],
        u_p=u[..., :n1], u_m=u[..., n1:n1 + n2],
        t=pstate.t,
    )


def _dual_update_packed(x_t, idx, cols_t, log_lam, u, dw, sign, sc,
                        d_eff, axis_name, backend):
    """Packed lines 5-6 + incremental u for BOTH classes in one pass,
    with per-class logsumexp normalizers computed in the same sweep
    (masked partials) and combined across clients as (2,)-vector
    all-reduces.  ``sc`` carries the per-problem scalars (python floats
    on the static SaddleParams path, traced per-slot f32 scalars under
    the slot-batched driver).  Returns (log_new_normalized, u_new)."""
    if backend == "pallas":
        from repro.kernels import ops as kops
        log_new, u_new, m_p, s_p, m_m, s_m = kops.mwu_update_packed(
            x_t, idx, log_lam, u, dw, sign,
            gamma=sc.gamma, tau=sc.tau, d_eff=d_eff)
    else:
        dv = dw @ cols_t                       # (n_pad,) rank-B update
        v = sign * (u + d_eff * dv)
        log_new = sc.mwu_c * (sc.mwu_dot * log_lam - v)
        u_new = u + dv
        is_p = sign > 0
        is_m = sign < 0
        m_p = jnp.max(jnp.where(is_p, log_new, NEG_INF))
        m_m = jnp.max(jnp.where(is_m, log_new, NEG_INF))
        s_p = jnp.sum(jnp.where(is_p, jnp.exp(log_new - m_p), 0.0))
        s_m = jnp.sum(jnp.where(is_m, jnp.exp(log_new - m_m), 0.0))
    # combine the per-class partials across clients (rounds 2-3): one
    # (2,) pmax + one (2,) psum
    m_loc = jnp.stack([m_p, m_m])
    s_loc = jnp.stack([s_p, s_m])
    m = _all_max(m_loc, axis_name)
    s = _all_sum(s_loc * jnp.exp(m_loc - m), axis_name)
    lse = m + jnp.log(s)
    return log_new - jnp.where(sign > 0, lse[0], lse[1]), u_new


def _capped_project_packed(log_lam, sign, nu, axis_name):
    """Sort-free round 4: the shared masked bisection core
    (projections.capped_bisect_masked) over BOTH classes in the same
    sweep.  Each round reduces one (2,) vector -- under an axis that is
    one psum of 2 scalars -- for a FIXED BISECT_ROUNDS_SOLVER rounds,
    so the round-4 scalar budget is deterministic and O(k) (Theorem 8);
    the reference Rule-3 loop's worst case is O(1/nu) data-dependent
    rounds.  Padding (sign 0) belongs to neither mask, projects to 0,
    and so keeps its NEG_INF marker."""
    masks = jnp.stack([sign > 0, sign < 0])
    eta = projections.capped_bisect_masked(
        jnp.exp(log_lam), nu, masks,
        rounds=projections.BISECT_ROUNDS_SOLVER,
        all_sum=lambda x: _all_sum(x, axis_name),
        all_max=lambda x: _all_max(x, axis_name))
    return jnp.where(eta > 0, jnp.log(jnp.maximum(eta, 1e-38)), NEG_INF)


class SlotParams(NamedTuple):
    """Per-problem step scalars, decoupled from the shape-static fields
    of ``SaddleParams`` (d, block_size) so ONE compiled executable can
    serve problems that differ only in their parameter values.

    On the classic ``step_packed(p: SaddleParams)`` path the fields are
    python floats derived at trace time (:func:`scalarize_params`) --
    the arithmetic is done in f64 on the host and baked as f32
    constants, exactly as the inline expressions used to be, so the op
    graph is unchanged.  Under the slot-batched driver each field is a
    traced per-slot f32 scalar holding the SAME f32 value (the host
    derivation also runs in f64 before the cast), which keeps the slot
    path numerically aligned with the static path.

    ``nu`` is the EFFECTIVE capped-simplex cap: for hard-margin
    problems it is 1.0, which makes the projection an exact identity
    (each class simplex already satisfies max eta_i <= 1), so
    hard-margin and nu-SVM slots can share a projecting executable.
    Whether the projection runs at all stays a STATIC choice
    (``project``).  ``gap_tol`` is the relative duality-gap early-stop
    threshold (0 disables; only read by the slot chunk driver).
    """
    theta: float | jax.Array
    sigma: float | jax.Array
    inv_sig1: float | jax.Array  # 1 / (sigma + 1), the w-update scale
    gamma: float | jax.Array
    tau: float | jax.Array
    mwu_c: float | jax.Array     # 1 / (gamma + d_eff / tau)
    mwu_dot: float | jax.Array   # d_eff / tau
    nu: float | jax.Array        # effective cap (1.0 == identity)
    gap_tol: float | jax.Array


def scalarize_params(p, gap_tol: float = 0.0) -> SlotParams:
    """Derive the per-problem step scalars from a SaddleParams in host
    (f64) arithmetic -- identical to the constants the static step has
    always baked."""
    d_eff = p.d / p.block_size
    return SlotParams(
        theta=p.theta, sigma=p.sigma, inv_sig1=1.0 / (p.sigma + 1.0),
        gamma=p.gamma, tau=p.tau,
        mwu_c=1.0 / (p.gamma + d_eff / p.tau),
        mwu_dot=d_eff / p.tau,
        nu=p.nu if p.nu > 0.0 else 1.0,
        gap_tol=gap_tol)


def slot_params_row(p, gap_tol: float = 0.0) -> SlotParams:
    """:func:`scalarize_params` as a row of f32 arrays, ready to be
    stacked into the (S,)-shaped SlotParams of a slot batch."""
    import numpy as np
    sc = scalarize_params(p, gap_tol)
    return SlotParams(*(np.float32(v) for v in sc))


def _step_packed_core(state: PackedState, key: jax.Array, x_t: jax.Array,
                      sign: jax.Array, sc: SlotParams, *, d: int,
                      block_size: int, project: bool,
                      axis_name: str | None = None,
                      backend: str = "jnp") -> PackedState:
    """The packed iteration parameterized by step SCALARS (see
    :class:`SlotParams`): shared verbatim by the classic per-problem
    step (python-float scalars) and the slot-batched driver (traced
    per-slot scalars under ``vmap``)."""
    d_eff = d / block_size
    idx = sample_block(key, d, block_size)
    if backend == "pallas":
        from repro.kernels import ops as kops
        cols_t = None                    # gathered inside the kernels
        delta = kops.momentum_dot_packed(
            x_t, idx, state.log_lam, state.log_lam_prev, sign, sc.theta)
    else:
        cols_t = jnp.take(x_t, idx, axis=0)          # (B, n_pad) CONTIGUOUS
        lam = jnp.exp(state.log_lam)
        lam_prev = jnp.exp(state.log_lam_prev)
        delta = cols_t @ (sign * (lam + sc.theta * (lam - lam_prev)))
    delta = _all_sum(delta, axis_name)               # round 1

    # Line 4 (round 2): every client performs the identical w update
    # (delta already IS delta+ - delta-, folded by the sign).
    w_old = state.w[idx]
    w_new = (w_old + sc.sigma * delta) * sc.inv_sig1
    dw = w_new - w_old

    # Lines 5-6 (rounds 2-3): ONE packed MWU pass for both classes.
    log_new, u_new = _dual_update_packed(
        x_t, idx, cols_t, state.log_lam, state.u, dw, sign, sc, d_eff,
        axis_name, backend)

    # Round 4: sort-free nu-Saddle capped-simplex projection.
    if project:
        log_new = _capped_project_packed(log_new, sign, sc.nu, axis_name)

    return PackedState(
        w=state.w.at[idx].set(w_new),
        log_lam=log_new, log_lam_prev=state.log_lam,
        u=u_new, t=state.t + 1,
    )


def step_packed(state: PackedState, key: jax.Array, x_t: jax.Array,
                sign: jax.Array, p, *, axis_name: str | None = None,
                backend: str = "jnp") -> PackedState:
    """One PACKED Algorithm-2/4 iteration: both classes in every sweep.

    ``x_t`` is the client's (d, n_pad) column-major mirror and ``sign``
    its +-1/0 slot vector (see preprocess.pack_points).  Under an axis,
    the key is identical across clients (the server broadcasts i*).
    """
    return _step_packed_core(state, key, x_t, sign, scalarize_params(p),
                             d=p.d, block_size=p.block_size,
                             project=p.nu > 0.0, axis_name=axis_name,
                             backend=backend)


def objective_from_duals(log_lam: jax.Array, x_t: jax.Array,
                         sign: jax.Array, axis_name=None) -> jax.Array:
    """0.5 * ||A eta - B xi||^2 from packed log duals: the signed dual
    combination x_t @ (sign * lam) IS A eta - B xi.  (Single source of
    truth -- the per-problem and per-slot objectives both call this.)"""
    diff = x_t @ (sign * jnp.exp(log_lam))
    diff = _all_sum(diff, axis_name)
    return 0.5 * jnp.sum(diff * diff)


def objective_packed(state: PackedState, x_t: jax.Array, sign: jax.Array,
                     axis_name=None) -> jax.Array:
    return objective_from_duals(state.log_lam, x_t, sign, axis_name)


def chunk_body_packed(state, key, x_t, sign, params, num_steps, *,
                      chunk_steps: int, axis_name: str | None = None,
                      backend: str = "jnp"):
    """Packed chunk: identical driver discipline to :func:`chunk_body`
    (static key shape, dynamic trip count, on-device objective)."""
    trace_counts[("packed", axis_name, backend, chunk_steps)] += 1

    keys = jax.random.split(key, chunk_steps)

    def body(i, st):
        return step_packed(st, keys[i], x_t, sign, params,
                           axis_name=axis_name, backend=backend)

    state = jax.lax.fori_loop(0, num_steps, body, state)
    return state, objective_packed(state, x_t, sign, axis_name)


@functools.partial(jax.jit,
                   static_argnames=("params", "chunk_steps", "backend"),
                   donate_argnums=(0,))
def run_chunk_packed(state, key, x_t, sign, num_steps, *, params,
                     chunk_steps: int, backend: str = "jnp"):
    """Serial packed chunk: state buffers donated, objective returned as
    a device scalar, one compile for all chunk lengths."""
    return chunk_body_packed(state, key, x_t, sign, params, num_steps,
                             chunk_steps=chunk_steps, axis_name=None,
                             backend=backend)


# ==========================================================================
# Slot-batched driver (multi-tenant serving): S independent problems
# through ONE compiled step via vmap over a leading slot axis.
# ==========================================================================


class SlotState(NamedTuple):
    """S independent packed solver states stacked on a leading SLOT
    axis, plus the per-slot serving lifecycle fields.

    A slot is a reusable execution lane of the multi-tenant driver:

      FREE      ``active == False`` and no request assigned.  The lane
                still flows through the vmapped step every iteration
                (that is what keeps the executable shape-static), but
                every result is discarded by the active mask.
      RUNNING   ``active == True``: the slot steps while
                ``t < max_t`` and its duality gap is above the slot's
                ``gap_tol``.
      FINISHED  the chunk driver flipped ``active`` off (budget
                exhausted or gap converged).  The state stays intact
                until the host harvests it and either re-admits a new
                request into the lane (:func:`admit_into_slot`
                overwrites EVERY field -- no state can leak from the
                previous occupant) or leaves it FREE.

    ``key`` is the per-slot PRNG chain: each chunk splits it exactly
    like the serial driver splits its solve key, so a slot admitted at
    seed s replays the SAME block-coordinate schedule as a solo
    ``saddle.solve(seed=s)`` at the same bucket shape.
    """
    w: jax.Array             # (S, d)
    log_lam: jax.Array       # (S, n_pad)
    log_lam_prev: jax.Array  # (S, n_pad)
    u: jax.Array             # (S, n_pad)
    t: jax.Array             # (S,) per-slot iteration counter
    max_t: jax.Array         # (S,) per-slot iteration budget
    key: jax.Array           # (S,) per-slot PRNG chains
    active: jax.Array        # (S,) bool lifecycle mask

    @property
    def num_slots(self) -> int:
        return self.w.shape[0]


def init_slot_state(num_slots: int, n_pad: int, d: int) -> SlotState:
    """An all-FREE slot table for one (n_pad, d) bucket."""
    s = num_slots
    neg = jnp.full((s, n_pad), NEG_INF, jnp.float32)
    return SlotState(
        w=jnp.zeros((s, d), jnp.float32),
        log_lam=neg, log_lam_prev=jnp.copy(neg),
        u=jnp.zeros((s, n_pad), jnp.float32),
        t=jnp.zeros((s,), jnp.int32),
        max_t=jnp.zeros((s,), jnp.int32),
        key=jax.random.split(jax.random.key(0), s),
        active=jnp.zeros((s,), bool),
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def admit_into_slot(state: SlotState, slot: jax.Array,
                    pstate: PackedState, key: jax.Array,
                    max_t: jax.Array) -> SlotState:
    """Admit a freshly initialized problem into lane ``slot`` (a traced
    index: one compile serves every lane).  Every per-slot field is
    overwritten -- w, duals, u, t, budget, PRNG chain, active flag --
    so a reused lane cannot leak its previous occupant's state."""
    return SlotState(
        w=state.w.at[slot].set(pstate.w),
        log_lam=state.log_lam.at[slot].set(pstate.log_lam),
        log_lam_prev=state.log_lam_prev.at[slot].set(pstate.log_lam_prev),
        u=state.u.at[slot].set(pstate.u),
        t=state.t.at[slot].set(pstate.t),
        max_t=state.max_t.at[slot].set(jnp.asarray(max_t, jnp.int32)),
        key=state.key.at[slot].set(key),
        active=state.active.at[slot].set(True),
    )


def _capped_min_masked(scores: jax.Array, mask: jax.Array,
                       nu: jax.Array) -> jax.Array:
    """min_{eta in D(nu)} <scores, eta> restricted to ``mask`` with a
    TRACED cap: greedy water-filling puts weight min(nu, max(0, 1-i*nu))
    on the i-th smallest masked score.  nu=1 degenerates to the plain
    min (the hard-margin inner problem), so one formula serves both
    slot kinds."""
    big = jnp.float32(1e30)
    s = jnp.sort(jnp.where(mask, scores, big))
    w = jnp.clip(1.0 - jnp.arange(s.shape[0]) * nu, 0.0, nu)
    return jnp.sum(jnp.where(w > 0, s * w, 0.0))


def saddle_gap_packed(w: jax.Array, x_t: jax.Array, sign: jax.Array,
                      nu: jax.Array) -> jax.Array:
    """g(w) = min_{eta,xi} w^T A eta - w^T B xi - ||w||^2/2 on the
    packed layout (the per-slot early-stop diagnostic; nu here is the
    EFFECTIVE cap, 1.0 for hard margin)."""
    s = w @ x_t                                      # (n_pad,) <w, x_i>
    inner_p = _capped_min_masked(s, sign > 0, nu)
    inner_m = -_capped_min_masked(-s, sign < 0, nu)
    return inner_p - inner_m - 0.5 * jnp.sum(w * w)


@functools.partial(jax.jit, donate_argnums=(0,))
def deactivate_slot(state: SlotState, slot) -> SlotState:
    """Freeze one lane (traced ``slot`` index: one compile total) --
    the serving layer's cancellation path.  The lane's buffers are
    left as-is; admission overwrites every field anyway."""
    return state._replace(active=state.active.at[slot].set(False))


def slot_trace_key(num_slots: int, n_pad: int, d: int, block_size: int,
                   chunk_steps: int, project: bool, check_gap: bool,
                   backend: str, axis_name=None) -> tuple:
    """The ``trace_counts`` key of one slot-chunk executable -- i.e.
    the compile-cache key a serving layer warms per bucket.  Shapes are
    the PER-DEVICE shapes the chunk body is traced at (``shard_map``
    hands the body its local shard); ``axis_name`` is the point-axis
    tuple of a sharded-slot chunk, None for the collective-free kinds.
    """
    key = ("slots", num_slots, n_pad, d, block_size, chunk_steps,
           project, check_gap, backend)
    if axis_name is not None:
        key += ("axis", axis_name)
    return key


def chunk_body_slots(state: SlotState, x_t: jax.Array, sign: jax.Array,
                     sp: SlotParams, num_steps, *, chunk_steps: int,
                     d: int, block_size: int, project: bool,
                     check_gap: bool, backend: str = "jnp",
                     axis_name=None):
    """One slot-batched chunk: ``num_steps`` (dynamic, <= static
    ``chunk_steps``) vmapped packed iterations over every lane.

    Per iteration each slot advances iff ``active & (t < max_t)`` --
    the step is computed for every lane (shape-static) and discarded
    by the mask, so a lane that exhausts its budget mid-chunk freezes
    at exactly ``max_t`` iterations (same schedule as a solo solve)
    without halting the batch.  Each slot draws its block coordinates
    from its OWN key chain: the chain is split once per chunk (exactly
    the serial driver's ``key, sub = split(key)`` discipline) and the
    per-step keys are pre-split at the static ``chunk_steps`` shape.

    At the chunk boundary every slot's objective is computed on device
    and -- when ``check_gap`` -- its duality gap (:func:
    `saddle_gap_packed`); a slot whose relative gap falls below its
    ``gap_tol`` or whose budget is exhausted goes inactive, freeing
    its lane for mid-run admission.

    Slot health: the same boundary computes a per-slot finite-health
    flag -- ``w``/``u`` all finite, ``log_lam`` free of NaN/+inf (the
    ``NEG_INF`` padding sentinel is finite and passes), objective
    finite.  An unhealthy slot is deactivated ON DEVICE in the same
    masked style as convergence, so a diverged/poisoned lane freezes
    immediately instead of burning its remaining budget -- and because
    lanes are vmapped independently, batch-mates' trajectories are
    bit-for-bit unaffected.  The serving layer reads the flag from the
    chunk's single host transfer and quarantines the lane.

    Under ``axis_name`` (the sharded-slot serving path) every slot's
    POINT axis is a shard: the vmapped step runs the same Theorem-8
    collective rounds as the solo distributed step -- vmap batches each
    round into ONE launch whose payload scales by S -- and the chunk
    boundary adds exactly two more: the objective's psum and a health
    agreement reduce that keeps ``active`` replica-consistent (``u`` /
    ``log_lam`` are shard-local, so one shard's overflow must
    quarantine the slot on EVERY shard).  ``check_gap`` is rejected:
    the gap's water-filling sorts the full point axis and does not
    distribute.

    Returns (new_state, obj (S,), healthy (S,) bool).
    """
    if check_gap and axis_name is not None:
        raise ValueError(
            "check_gap is not supported for point-sharded slot chunks "
            "(saddle_gap_packed sorts the full point axis); submit "
            "sharded fits with gap_tol=0")
    trace_counts[slot_trace_key(
        state.num_slots, x_t.shape[-1], d, block_size, chunk_steps,
        project, check_gap, backend, axis_name)] += 1  # trace-time only

    splits = jax.vmap(jax.random.split)(state.key)   # (S, 2)
    chain, chunk_key = splits[:, 0], splits[:, 1]
    keys = jax.vmap(lambda k: jax.random.split(k, chunk_steps))(chunk_key)

    def step_slot(ps, key_i, x_t_i, sign_i, row):
        return _step_packed_core(ps, key_i, x_t_i, sign_i, row, d=d,
                                 block_size=block_size, project=project,
                                 axis_name=axis_name, backend=backend)

    def body(i, st):
        ps = PackedState(w=st.w, log_lam=st.log_lam,
                         log_lam_prev=st.log_lam_prev, u=st.u, t=st.t)
        new = jax.vmap(step_slot)(ps, keys[:, i], x_t, sign, sp)
        do = st.active & (st.t < st.max_t)           # (S,)
        sel = lambda n, o: jnp.where(
            do.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)
        return st._replace(
            w=sel(new.w, st.w), log_lam=sel(new.log_lam, st.log_lam),
            log_lam_prev=sel(new.log_lam_prev, st.log_lam_prev),
            u=sel(new.u, st.u), t=sel(new.t, st.t))

    state = jax.lax.fori_loop(0, num_steps, body, state)
    state = state._replace(key=chain)

    obj = jax.vmap(
        lambda ll, xt, sg: objective_from_duals(ll, xt, sg, axis_name)
    )(state.log_lam, x_t, sign)

    healthy = (jnp.isfinite(state.w).all(axis=-1)
               & jnp.isfinite(state.u).all(axis=-1)
               & ~jnp.isnan(state.log_lam).any(axis=-1)
               & ~jnp.isposinf(state.log_lam).any(axis=-1)
               & jnp.isfinite(obj))
    if axis_name is not None:
        # u / log_lam health is shard-local: agree across point shards
        # so the replicated ``active`` mask stays replica-consistent.
        healthy = _all_sum(
            jnp.where(healthy, 0.0, 1.0), axis_name) == 0.0

    done = (state.t >= state.max_t) | ~healthy
    if check_gap:
        gap = jax.vmap(saddle_gap_packed)(state.w, x_t, sign, sp.nu)
        converged = (sp.gap_tol > 0) & (
            obj - gap <= sp.gap_tol * jnp.maximum(obj, 1e-12))
        done = done | converged
    return state._replace(active=state.active & ~done), obj, healthy


@functools.partial(jax.jit,
                   static_argnames=("chunk_steps", "d", "block_size",
                                    "project", "check_gap", "backend"),
                   donate_argnums=(0,))
def run_chunk_slots(state: SlotState, x_t: jax.Array, sign: jax.Array,
                    sp: SlotParams, num_steps, *, chunk_steps: int,
                    d: int, block_size: int, project: bool,
                    check_gap: bool = False, backend: str = "jnp"):
    """Jitted slot-batched chunk: slot-state buffers donated (updated in
    place), per-slot objectives AND finite-health flags returned as
    device vectors (see :func:`chunk_body_slots`).  One compile serves
    every chunk length up to ``chunk_steps`` and every admission
    pattern -- the data buffers (``x_t``, ``sign``) and the per-slot
    SlotParams are plain dynamic arguments."""
    return chunk_body_slots(state, x_t, sign, sp, num_steps,
                            chunk_steps=chunk_steps, d=d,
                            block_size=block_size, project=project,
                            check_gap=check_gap, backend=backend)


# --------------------------------------------------------------------------
# Mesh-sharded slot chunk: the SAME chunk body under shard_map, with two
# orthogonal placements a serving layer composes per slot group:
#
#   slot_axes    the SLOT axis is data-parallel over these mesh axes --
#                each device owns its own lanes, steps them with
#                axis_name=None, and exchanges ZERO loop collectives
#                (the unsharded slot-group placement).
#   point_axes   every slot's POINT axis spans these mesh axes and the
#                step runs the Theorem-8 collective rounds over them
#                (the sharded-slot placement for large-n fits).
# --------------------------------------------------------------------------


def _normalize_axes(point_axes) -> tuple | None:
    """The in-step ``axis_name`` for a point-axis tuple (None == serial)."""
    return tuple(point_axes) or None


def sharded_slot_run_fn(mesh: jax.sharding.Mesh, *, slot_axes=(),
                        point_axes=(), chunk_steps: int, d: int,
                        block_size: int, project: bool,
                        check_gap: bool = False, backend: str = "jnp"):
    """UN-jitted ``shard_map``-wrapped slot chunk over ``mesh`` (AOT
    lowering / audit entry; :func:`run_chunk_slots_sharded` is the
    dispatch path).  Placement per the module-level table: the slot
    axis shards over ``slot_axes``, the point axis over ``point_axes``
    (disjoint; either may be empty).  Per-slot lifecycle rows (``t``,
    ``max_t``, ``key``, ``active``) and ``w`` are replicated across
    ``point_axes``; ``check_rep=False`` because psum-produced outputs
    defeat shard_map's static replication check.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    slot_axes, point_axes = tuple(slot_axes), tuple(point_axes)
    overlap = set(slot_axes) & set(point_axes)
    if overlap:
        raise ValueError(f"slot_axes and point_axes overlap: {overlap}")
    for a in slot_axes + point_axes:
        if a not in mesh.axis_names:
            raise ValueError(f"axis {a!r} not in mesh {mesh.axis_names}")
    axis_name = _normalize_axes(point_axes)

    s = slot_axes or None           # slot-dim placement
    p = point_axes or None          # point-dim placement
    state_spec = SlotState(
        w=P(s), log_lam=P(s, p), log_lam_prev=P(s, p), u=P(s, p),
        t=P(s), max_t=P(s), key=P(s), active=P(s))
    sp_spec = SlotParams(*(P(s) for _ in SlotParams._fields))

    def local_fn(st, x_t, sign, sp, num_steps):
        return chunk_body_slots(
            st, x_t, sign, sp, num_steps, chunk_steps=chunk_steps, d=d,
            block_size=block_size, project=project, check_gap=check_gap,
            backend=backend, axis_name=axis_name)

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(state_spec, P(s, None, p), P(s, p), sp_spec, P()),
        out_specs=(state_spec, P(s), P(s)),
        check_rep=False)


@functools.lru_cache(maxsize=None)
def _sharded_slot_runner(mesh, slot_axes, point_axes, chunk_steps, d,
                         block_size, project, check_gap, backend):
    return jax.jit(
        sharded_slot_run_fn(mesh, slot_axes=slot_axes,
                            point_axes=point_axes, chunk_steps=chunk_steps,
                            d=d, block_size=block_size, project=project,
                            check_gap=check_gap, backend=backend),
        donate_argnums=(0,))


def run_chunk_slots_sharded(state: SlotState, x_t: jax.Array,
                            sign: jax.Array, sp: SlotParams, num_steps, *,
                            mesh: jax.sharding.Mesh, slot_axes=(),
                            point_axes=(), chunk_steps: int, d: int,
                            block_size: int, project: bool,
                            check_gap: bool = False,
                            backend: str = "jnp"):
    """Mesh-sharded :func:`run_chunk_slots`: same signature and return
    contract plus the (mesh, slot_axes, point_axes) placement, slot
    state donated.  The jitted runner is cached per placement+statics
    (``Mesh`` hashes by device assignment), so the serving layer pays
    one trace per warmed bucket exactly as on a single device."""
    run = _sharded_slot_runner(mesh, tuple(slot_axes), tuple(point_axes),
                               chunk_steps, d, block_size, project,
                               check_gap, backend)
    return run(state, x_t, sign, sp, jnp.asarray(num_steps, jnp.int32))


def sharded_slot_trace_key(num_slots: int, n_pad: int, d: int,
                           block_size: int, chunk_steps: int,
                           project: bool, check_gap: bool, backend: str,
                           mesh: jax.sharding.Mesh, slot_axes=(),
                           point_axes=()) -> tuple:
    """:func:`slot_trace_key` of one mesh-sharded chunk executable, from
    GLOBAL shapes: shard_map traces the body at the per-device shard, so
    the slot dim divides by the slot-axes extent and the point dim by
    the point-axes extent."""
    ks = math.prod(mesh.shape[a] for a in slot_axes) if slot_axes else 1
    kp = math.prod(mesh.shape[a] for a in point_axes) if point_axes else 1
    return slot_trace_key(num_slots // ks, n_pad // kp, d, block_size,
                          chunk_steps, project, check_gap, backend,
                          _normalize_axes(tuple(point_axes)))


@functools.partial(jax.jit,
                   static_argnames=("chunk_steps", "num_chunks", "d",
                                    "block_size", "project", "check_gap",
                                    "backend"),
                   donate_argnums=(0,))
def run_solve_slots(state: SlotState, x_t: jax.Array, sign: jax.Array,
                    sp: SlotParams, num_iters, *, chunk_steps: int,
                    num_chunks: int, d: int, block_size: int,
                    project: bool, check_gap: bool = False,
                    backend: str = "jnp"):
    """DEVICE-RESIDENT multi-chunk solve driver: the whole chunked solve
    in ONE executable, so a full solve is a single dispatch and a single
    end-of-solve host transfer.

    The host chunk loop this replaces re-dispatched
    :func:`run_chunk_slots` once per chunk and -- whenever the duality
    gap was enabled -- blocked on a ``device_get`` of the active mask at
    every chunk boundary, serializing host<->device round-trips into the
    hot path.  Here the outer loop is a ``lax.while_loop`` keyed on the
    slot-active flag: it runs the SAME :func:`chunk_body_slots` the
    per-chunk driver jits (bit-for-bit identical state trajectory, key
    schedule and gap/health semantics), writes each boundary's per-slot
    objective and iteration mark into preallocated device history
    buffers, and exits as soon as every lane is inactive (budget
    exhausted, gap converged, or health-frozen) or ``num_iters`` is
    dispatched.  The gap-enabled path therefore needs ZERO per-chunk
    host polls -- convergence is consumed by the loop condition on
    device.

    ``num_chunks`` (static) is the history capacity,
    ``ceil(num_iters / chunk_steps)`` for a full-budget run; a gap stop
    leaves the tail unwritten.  Returns ``(state, objs (num_chunks, S),
    marks (num_chunks, S), chunks_done)`` -- callers slice the history
    to ``chunks_done`` rows after ONE transfer.  ``marks`` records each
    slot's iteration counter at the boundary, which equals the
    cumulative dispatched iterations while the slot is live (and the
    exact stop iteration on a gap stop).

    The per-chunk :func:`run_chunk_slots` stays the serving entry point:
    ``SolverService`` needs the host back between chunks to harvest
    finished lanes and admit queued requests; a solo solve does not.
    """
    S = state.num_slots
    objs = jnp.zeros((num_chunks, S), jnp.float32)
    marks = jnp.zeros((num_chunks, S), jnp.int32)
    num_iters = jnp.asarray(num_iters, jnp.int32)

    def cond(carry):
        st, done, i, _objs, _marks = carry
        return (done < num_iters) & st.active.any()

    def body(carry):
        st, done, i, objs, marks = carry
        ns = jnp.minimum(chunk_steps, num_iters - done)
        st, obj, _healthy = chunk_body_slots(
            st, x_t, sign, sp, ns, chunk_steps=chunk_steps, d=d,
            block_size=block_size, project=project, check_gap=check_gap,
            backend=backend)
        return (st, done + ns, i + 1,
                objs.at[i].set(obj), marks.at[i].set(st.t))

    state, _done, i, objs, marks = jax.lax.while_loop(
        cond, body, (state, jnp.asarray(0, jnp.int32),
                     jnp.asarray(0, jnp.int32), objs, marks))
    return state, objs, marks, i


def drive(state, key, num_iters: int, chunk: int, run) -> tuple:
    """Shared host loop: split one key per chunk, dispatch fixed-shape
    chunks, accumulate device scalars, transfer history ONCE at the end.

    ``run(state, subkey, steps_remaining) -> (state, obj)`` is the
    mode-specific jitted chunk.  Returns (state, [(done, obj), ...]).
    """
    import numpy as np

    objs, marks = [], []
    done = 0
    while done < num_iters:
        key, sub = jax.random.split(key)
        ns = min(chunk, num_iters - done)
        state, obj = run(state, sub, ns)
        done += ns
        objs.append(obj)
        marks.append(done)
    # per-client objectives (k,) are identical across clients; take [0]
    objs = [float(np.asarray(o).reshape(-1)[0]) for o in jax.device_get(objs)]
    return state, list(zip(marks, objs))
