"""Fused solver core shared by every Saddle-SVC execution mode.

The paper's Algorithm 2 (serial) and Algorithm 4 (distributed) are the
same iteration: the serial solver is the k=1 degenerate client, where
every all-reduce is the identity.  This module implements that single
step ONCE, parameterized along two orthogonal axes:

  ``axis_name``   None          -> serial (all psum/pmax collapse away)
                  "clients"     -> distributed, under ``jax.vmap``
                                   (bit-exact k-client simulation) or
                                   ``shard_map`` (real device mesh)

  ``backend``     "jnp"         -> pure jax.numpy step
                  "pallas"      -> the Pallas kernels in
                                   ``repro.kernels.ops``

Packed single-sweep step
------------------------

The PRIMARY step (:func:`step_packed`, what ``saddle.solve`` and
``distributed.solve_distributed`` run) works on the packed +- layout of
:func:`repro.core.preprocess.pack_points`: both classes live in ONE
lane-padded point set with a +-1 ``sign`` vector (0 marks lane-padding,
which also carries log-weight NEG_INF so it contributes exactly 0 to
every reduction).  The packed state holds THREE point-length vectors
(``log_lam``, ``log_lam_prev``, ``u``) plus ``w`` where the unpacked
state needs six, and every per-point pass runs ONCE per step instead of
once per class:

  pass 1  signed momentum dot: delta = sum_i sign_i mom_i x_t[idx, i]
          (the sign folds delta+ - delta- into a single sweep)
  pass 2  MWU update + incremental u + BOTH per-class logsumexp
          normalizer partials, masked by sign in the same sweep

so the Pallas backend launches 2 kernels per step (vs 4 for the
unpacked reference).  Coordinate blocks are gathered from the
column-major mirror ``x_t`` (d, n_pad): a sampled block is b CONTIGUOUS
rows (``jnp.take(x_t, idx, axis=0)``), not b strided columns of a
row-major (n, d) matrix; the Pallas kernels go further and gather
tile-by-tile inside the kernel from scalar-prefetched indices, never
materializing a cols intermediate.

The nu-Saddle capped-simplex projection is SORT-FREE: a fixed-round
bisection on the cap scale (the shared core
:func:`repro.core.projections.capped_bisect_masked`) whose every round
is one masked O(n) reduction -- both classes share the sweep, and
under an axis each round all-reduces a single (2,) vector, so the
round-4 budget is a DETERMINISTIC O(k) scalars per iteration
(BISECT_ROUNDS_SOLVER two-scalar all-reduces; Theorem 8).  The
reference path pays an O(n log n) argsort + scatter per class per
iteration serially, and a data-dependent loop -- worst case O(1/nu)
rounds -- distributed.

The unpacked :func:`step` is retained as the reference oracle the
packed path is parity-tested against (serial/distributed x jnp/pallas x
nu=0/nu>0) and as the baseline ``benchmarks/engine_bench.py`` measures
the packed speedup over.

On top of either step sits the fixed-shape chunk driver:

  * ``chunk_body*`` pre-splits the per-step keys at a static
    ``chunk_steps`` shape but runs the step under a ``fori_loop`` with
    a DYNAMIC trip count, so one executable serves every chunk length
    and the padded tail of a partial final chunk is never executed.
  * ``run_chunk*`` (the serial jit wrappers) donate the state buffers
    (``donate_argnums``) so the solver state is updated in place.
  * The objective is computed on device at the end of each chunk and
    returned as a device scalar; drivers accumulate those and do ONE
    host transfer at the end of the solve.

Coordinate blocks are sampled WITHOUT replacement (a duplicated index
would corrupt the incremental invariant ``u == X w``) by a partial
Fisher--Yates shuffle: b swap rounds on an iota array, O(d + b) work
per draw instead of the O(d log d) full ``jax.random.permutation``.
"""

from __future__ import annotations

import collections
import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import projections

CLIENT_AXIS = "clients"
NEG_INF = -1e30     # log-weight of padding points (exp() == 0 exactly)

# Incremented at TRACE time inside the chunk bodies, keyed by the static
# configuration -- i.e. it counts XLA compilations, not calls.  Tests
# use this to assert that chunked solves with a partial final chunk
# compile the chunk exactly once.
trace_counts: collections.Counter = collections.Counter()


def sample_block(key: jax.Array, d: int, b: int) -> jax.Array:
    """b distinct coordinates, uniform without replacement, via a
    partial Fisher--Yates shuffle: swap slot i with a uniform slot in
    [i, d) for i < b, then read the b-prefix.  O(d + b) work -- the
    full ``jax.random.permutation`` sort is O(d log d) for b << d --
    and exactly the uniform without-replacement distribution (each
    prefix outcome has probability 1 / (d (d-1) ... (d-b+1))).
    b=1 keeps the cheap single-draw path; the distributions coincide.
    """
    if b == 1:
        return jax.random.randint(key, (1,), 0, d)
    offs = jnp.arange(b)
    swap = offs + jax.random.randint(key, (b,), 0, d - offs)  # j_i ~ U[i, d)

    def body(i, a):
        ai, aj = a[i], a[swap[i]]
        return a.at[i].set(aj).at[swap[i]].set(ai)

    arr = jax.lax.fori_loop(0, b, body, jnp.arange(d))
    return arr[:b]


def _all_sum(x, axis_name):
    return x if axis_name is None else jax.lax.psum(x, axis_name)


def _all_max(x, axis_name):
    return x if axis_name is None else jax.lax.pmax(x, axis_name)


# ==========================================================================
# Reference (unpacked) step: two passes per class, retained as the
# parity oracle and the engine_bench baseline.
# ==========================================================================

def _dual_update(cols, log_lam, u, dw, sign, p, axis_name, backend):
    """Lines 5-6 of Algorithm 2 + incremental u maintenance, normalized
    with a (possibly distributed) logsumexp.  Returns (log_new, u_new).

    Both backends produce the UNNORMALIZED log weights plus local
    normalizer partials (m, s) with lse = m + log(s); the partials are
    then combined across clients (rounds 2-3 of Algorithm 4) or used
    directly in serial mode.
    """
    d_eff = p.d / p.block_size
    if backend == "pallas":
        from repro.kernels import ops as kops
        log_new, u_new, m_local, s_local = kops.mwu_update(
            cols, log_lam, u, dw, sign=sign, gamma=p.gamma, tau=p.tau,
            d_eff=d_eff, normalize=False)
    else:
        dv = cols @ dw
        v = sign * (u + d_eff * dv)
        c = 1.0 / (p.gamma + d_eff / p.tau)
        log_new = c * ((d_eff / p.tau) * log_lam - v)
        u_new = u + dv
        m_local = jnp.max(log_new)
        s_local = jnp.sum(jnp.exp(log_new - m_local))
    m = _all_max(m_local, axis_name)
    s = _all_sum(s_local * jnp.exp(m_local - m), axis_name)
    return log_new - (m + jnp.log(s)), u_new


def _capped_project(log_lam, nu, axis_name):
    """Reference nu-projection: Rule 2 (serial: one sort per iteration)
    or the distributed Rule-3 loop (round 4 of Algorithm 4).  The packed
    step replaces both with the sort-free fixed-round bisection."""
    if axis_name is None:
        eta = projections.capped_simplex_project_sorted(
            jnp.exp(log_lam), nu)
        return jnp.log(jnp.maximum(eta, 1e-38))

    max_rounds = int(1.0 / nu) + 2

    def cond(state):
        eta, it = state
        varsig = jax.lax.psum(
            jnp.sum(jnp.where(eta > nu, eta - nu, 0.0)), axis_name)
        return (varsig > 1e-12) & (it < max_rounds)

    def body(state):
        eta, it = state
        varsig = jax.lax.psum(
            jnp.sum(jnp.where(eta > nu, eta - nu, 0.0)), axis_name)
        omega = jax.lax.psum(
            jnp.sum(jnp.where(eta < nu, eta, 0.0)), axis_name)
        eta = jnp.where(eta >= nu, nu,
                        eta * (1.0 + varsig / jnp.maximum(omega, 1e-30)))
        return eta, it + 1

    eta = jnp.exp(log_lam)
    eta, _ = jax.lax.while_loop(cond, body, (eta, jnp.array(0, jnp.int32)))
    return jnp.where(eta > 0, jnp.log(jnp.maximum(eta, 1e-38)), NEG_INF)


def step(state, key: jax.Array, xp: jax.Array, xm: jax.Array, p, *,
         axis_name: str | None = None, backend: str = "jnp"):
    """One REFERENCE Algorithm-2/4 iteration from a single client's
    viewpoint (two passes per class; the production path is
    :func:`step_packed`).

    ``state`` is any NamedTuple with the canonical eight fields
    (SaddleState / ShardedState); the same type is returned.  ``xp`` and
    ``xm`` are the client's local (m1, d)/(m2, d) slices -- the full
    matrices in serial mode.  Under an axis, the key is identical across
    clients (the server broadcasts i*).
    """
    d, b = p.d, p.block_size
    d_eff = d / b
    idx = sample_block(key, d, b)
    cols_p = xp[:, idx]                              # (n1, B) rows X_{i*,.}
    cols_m = xm[:, idx]                              # (n2, B)

    # Lines 2-3 (round 1): momentum-extrapolated dual dot products,
    # all-reduced over clients.
    if backend == "pallas":
        from repro.kernels import ops as kops
        delta_p = kops.momentum_dot(cols_p, state.log_eta,
                                    state.log_eta_prev, p.theta)
        delta_m = kops.momentum_dot(cols_m, state.log_xi,
                                    state.log_xi_prev, p.theta)
    else:
        eta = jnp.exp(state.log_eta)
        eta_prev = jnp.exp(state.log_eta_prev)
        xi = jnp.exp(state.log_xi)
        xi_prev = jnp.exp(state.log_xi_prev)
        delta_p = cols_p.T @ (eta + p.theta * (eta - eta_prev))
        delta_m = cols_m.T @ (xi + p.theta * (xi - xi_prev))
    delta_p = _all_sum(delta_p, axis_name)
    delta_m = _all_sum(delta_m, axis_name)

    # Line 4 (round 2): every client performs the identical w update.
    w_old = state.w[idx]
    w_new = (w_old + p.sigma * (delta_p - delta_m)) / (p.sigma + 1.0)
    dw = w_new - w_old

    # Lines 5-6 (rounds 2-3): MWU dual updates.
    log_eta_new, u_p_new = _dual_update(
        cols_p, state.log_eta, state.u_p, dw, 1.0, p, axis_name, backend)
    log_xi_new, u_m_new = _dual_update(
        cols_m, state.log_xi, state.u_m, dw, -1.0, p, axis_name, backend)

    # Rule 2 / round 4: nu-Saddle capped-simplex projection.
    if p.nu > 0.0:
        log_eta_new = _capped_project(log_eta_new, p.nu, axis_name)
        log_xi_new = _capped_project(log_xi_new, p.nu, axis_name)

    return type(state)(
        w=state.w.at[idx].set(w_new),
        log_eta=log_eta_new, log_eta_prev=state.log_eta,
        log_xi=log_xi_new, log_xi_prev=state.log_xi,
        u_p=u_p_new, u_m=u_m_new,
        t=state.t + 1,
    )


def objective_from_state(state, xp, xm, axis_name=None) -> jax.Array:
    """C-Hull / RC-Hull objective 0.5 * ||A eta - B xi||^2, all-reduced
    over clients when run under an axis."""
    diff = jnp.exp(state.log_eta) @ xp - jnp.exp(state.log_xi) @ xm
    diff = _all_sum(diff, axis_name)
    return 0.5 * jnp.sum(diff * diff)


def chunk_body(state, key, xp, xm, params, num_steps, *,
               chunk_steps: int, axis_name: str | None = None,
               backend: str = "jnp"):
    """Reference chunk: run ``num_steps`` (dynamic) of at most
    ``chunk_steps`` (static) unpacked iterations and record the
    objective on device.  Returns (new_state, objective_scalar)."""
    trace_counts[(axis_name, backend, chunk_steps)] += 1  # trace-time only

    keys = jax.random.split(key, chunk_steps)

    def body(i, st):
        return step(st, keys[i], xp, xm, params,
                    axis_name=axis_name, backend=backend)

    state = jax.lax.fori_loop(0, num_steps, body, state)
    return state, objective_from_state(state, xp, xm, axis_name)


@functools.partial(jax.jit,
                   static_argnames=("params", "chunk_steps", "backend"),
                   donate_argnums=(0,))
def run_chunk(state, key, xp, xm, num_steps, *, params, chunk_steps: int,
              backend: str = "jnp"):
    """Serial reference chunk: state buffers donated, objective returned
    as a device scalar (no host sync), one compile for all chunk lengths
    up to ``chunk_steps``."""
    return chunk_body(state, key, xp, xm, params, num_steps,
                      chunk_steps=chunk_steps, axis_name=None,
                      backend=backend)


# ==========================================================================
# Packed single-sweep step (the production path)
# ==========================================================================


class PackedState(NamedTuple):
    """Solver state over the packed +- layout: one point-length vector
    per role instead of one per class per role.  Slot i belongs to the
    class given by ``sign[i]`` of the accompanying
    :class:`repro.core.preprocess.PackedPoints`; padding slots carry
    log-weight NEG_INF forever."""
    w: jax.Array             # (d,)
    log_lam: jax.Array       # (n_pad,)  [log eta | log xi | NEG_INF pad]
    log_lam_prev: jax.Array  # (n_pad,)
    u: jax.Array             # (n_pad,)  <w, x_i> maintained incrementally
    t: jax.Array             # iteration counter


def init_packed_state(sign: jax.Array, n1: int, n2: int,
                      d: int) -> PackedState:
    """Line 5 of Algorithm 1 on the packed layout: w=0, eta=1/n1,
    xi=1/n2 (global counts -- under sharding each client passes its own
    sign slice but the same n1/n2)."""
    log_lam = jnp.where(
        sign > 0, -math.log(n1),
        jnp.where(sign < 0, -math.log(n2), NEG_INF)).astype(jnp.float32)
    zeros_w = jnp.zeros(sign.shape[:-1] + (d,), jnp.float32)
    # distinct buffers for the "prev" copy: the chunk drivers donate the
    # state, and XLA rejects donating the same buffer twice
    return PackedState(
        w=zeros_w,
        log_lam=log_lam, log_lam_prev=jnp.copy(log_lam),
        u=jnp.zeros_like(log_lam),
        t=jnp.zeros(sign.shape[:-1], jnp.int32),
    )


def unpack_state(pstate: PackedState, n1: int, n2: int, cls):
    """Slice a packed state back into the per-class 8-field view
    (``cls`` is SaddleState or ShardedState -- same field names; the
    ``...`` slicing serves both the flat and the stacked-client
    layouts).  Slots [0, n1) are eta, [n1, n1+n2) are xi; the
    lane-padding tail is dropped."""
    lam, prev, u = pstate.log_lam, pstate.log_lam_prev, pstate.u
    return cls(
        w=pstate.w,
        log_eta=lam[..., :n1], log_eta_prev=prev[..., :n1],
        log_xi=lam[..., n1:n1 + n2], log_xi_prev=prev[..., n1:n1 + n2],
        u_p=u[..., :n1], u_m=u[..., n1:n1 + n2],
        t=pstate.t,
    )


def _dual_update_packed(x_t, idx, cols_t, log_lam, u, dw, sign, p,
                        axis_name, backend):
    """Packed lines 5-6 + incremental u for BOTH classes in one pass,
    with per-class logsumexp normalizers computed in the same sweep
    (masked partials) and combined across clients as (2,)-vector
    all-reduces.  Returns (log_new_normalized, u_new)."""
    d_eff = p.d / p.block_size
    if backend == "pallas":
        from repro.kernels import ops as kops
        log_new, u_new, m_p, s_p, m_m, s_m = kops.mwu_update_packed(
            x_t, idx, log_lam, u, dw, sign,
            gamma=p.gamma, tau=p.tau, d_eff=d_eff)
    else:
        dv = dw @ cols_t                       # (n_pad,) rank-B update
        v = sign * (u + d_eff * dv)
        c = 1.0 / (p.gamma + d_eff / p.tau)
        log_new = c * ((d_eff / p.tau) * log_lam - v)
        u_new = u + dv
        is_p = sign > 0
        is_m = sign < 0
        m_p = jnp.max(jnp.where(is_p, log_new, NEG_INF))
        m_m = jnp.max(jnp.where(is_m, log_new, NEG_INF))
        s_p = jnp.sum(jnp.where(is_p, jnp.exp(log_new - m_p), 0.0))
        s_m = jnp.sum(jnp.where(is_m, jnp.exp(log_new - m_m), 0.0))
    # combine the per-class partials across clients (rounds 2-3): one
    # (2,) pmax + one (2,) psum
    m_loc = jnp.stack([m_p, m_m])
    s_loc = jnp.stack([s_p, s_m])
    m = _all_max(m_loc, axis_name)
    s = _all_sum(s_loc * jnp.exp(m_loc - m), axis_name)
    lse = m + jnp.log(s)
    return log_new - jnp.where(sign > 0, lse[0], lse[1]), u_new


def _capped_project_packed(log_lam, sign, nu, axis_name):
    """Sort-free round 4: the shared masked bisection core
    (projections.capped_bisect_masked) over BOTH classes in the same
    sweep.  Each round reduces one (2,) vector -- under an axis that is
    one psum of 2 scalars -- for a FIXED BISECT_ROUNDS_SOLVER rounds,
    so the round-4 scalar budget is deterministic and O(k) (Theorem 8);
    the reference Rule-3 loop's worst case is O(1/nu) data-dependent
    rounds.  Padding (sign 0) belongs to neither mask, projects to 0,
    and so keeps its NEG_INF marker."""
    masks = jnp.stack([sign > 0, sign < 0])
    eta = projections.capped_bisect_masked(
        jnp.exp(log_lam), nu, masks,
        rounds=projections.BISECT_ROUNDS_SOLVER,
        all_sum=lambda x: _all_sum(x, axis_name),
        all_max=lambda x: _all_max(x, axis_name))
    return jnp.where(eta > 0, jnp.log(jnp.maximum(eta, 1e-38)), NEG_INF)


def step_packed(state: PackedState, key: jax.Array, x_t: jax.Array,
                sign: jax.Array, p, *, axis_name: str | None = None,
                backend: str = "jnp") -> PackedState:
    """One PACKED Algorithm-2/4 iteration: both classes in every sweep.

    ``x_t`` is the client's (d, n_pad) column-major mirror and ``sign``
    its +-1/0 slot vector (see preprocess.pack_points).  Under an axis,
    the key is identical across clients (the server broadcasts i*).
    """
    d, b = p.d, p.block_size
    idx = sample_block(key, d, b)
    if backend == "pallas":
        from repro.kernels import ops as kops
        cols_t = None                    # gathered inside the kernels
        delta = kops.momentum_dot_packed(
            x_t, idx, state.log_lam, state.log_lam_prev, sign, p.theta)
    else:
        cols_t = jnp.take(x_t, idx, axis=0)          # (B, n_pad) CONTIGUOUS
        lam = jnp.exp(state.log_lam)
        lam_prev = jnp.exp(state.log_lam_prev)
        delta = cols_t @ (sign * (lam + p.theta * (lam - lam_prev)))
    delta = _all_sum(delta, axis_name)               # round 1

    # Line 4 (round 2): every client performs the identical w update
    # (delta already IS delta+ - delta-, folded by the sign).
    w_old = state.w[idx]
    w_new = (w_old + p.sigma * delta) / (p.sigma + 1.0)
    dw = w_new - w_old

    # Lines 5-6 (rounds 2-3): ONE packed MWU pass for both classes.
    log_new, u_new = _dual_update_packed(
        x_t, idx, cols_t, state.log_lam, state.u, dw, sign, p,
        axis_name, backend)

    # Round 4: sort-free nu-Saddle capped-simplex projection.
    if p.nu > 0.0:
        log_new = _capped_project_packed(log_new, sign, p.nu, axis_name)

    return PackedState(
        w=state.w.at[idx].set(w_new),
        log_lam=log_new, log_lam_prev=state.log_lam,
        u=u_new, t=state.t + 1,
    )


def objective_packed(state: PackedState, x_t: jax.Array, sign: jax.Array,
                     axis_name=None) -> jax.Array:
    """0.5 * ||A eta - B xi||^2 from the packed state: the signed dual
    combination x_t @ (sign * lam) IS A eta - B xi."""
    diff = x_t @ (sign * jnp.exp(state.log_lam))
    diff = _all_sum(diff, axis_name)
    return 0.5 * jnp.sum(diff * diff)


def chunk_body_packed(state, key, x_t, sign, params, num_steps, *,
                      chunk_steps: int, axis_name: str | None = None,
                      backend: str = "jnp"):
    """Packed chunk: identical driver discipline to :func:`chunk_body`
    (static key shape, dynamic trip count, on-device objective)."""
    trace_counts[("packed", axis_name, backend, chunk_steps)] += 1

    keys = jax.random.split(key, chunk_steps)

    def body(i, st):
        return step_packed(st, keys[i], x_t, sign, params,
                           axis_name=axis_name, backend=backend)

    state = jax.lax.fori_loop(0, num_steps, body, state)
    return state, objective_packed(state, x_t, sign, axis_name)


@functools.partial(jax.jit,
                   static_argnames=("params", "chunk_steps", "backend"),
                   donate_argnums=(0,))
def run_chunk_packed(state, key, x_t, sign, num_steps, *, params,
                     chunk_steps: int, backend: str = "jnp"):
    """Serial packed chunk: state buffers donated, objective returned as
    a device scalar, one compile for all chunk lengths."""
    return chunk_body_packed(state, key, x_t, sign, params, num_steps,
                             chunk_steps=chunk_steps, axis_name=None,
                             backend=backend)


def drive(state, key, num_iters: int, chunk: int, run) -> tuple:
    """Shared host loop: split one key per chunk, dispatch fixed-shape
    chunks, accumulate device scalars, transfer history ONCE at the end.

    ``run(state, subkey, steps_remaining) -> (state, obj)`` is the
    mode-specific jitted chunk.  Returns (state, [(done, obj), ...]).
    """
    import numpy as np

    objs, marks = [], []
    done = 0
    while done < num_iters:
        key, sub = jax.random.split(key)
        ns = min(chunk, num_iters - done)
        state, obj = run(state, sub, ns)
        done += ns
        objs.append(obj)
        marks.append(done)
    # per-client objectives (k,) are identical across clients; take [0]
    objs = [float(np.asarray(o).reshape(-1)[0]) for o in jax.device_get(objs)]
    return state, list(zip(marks, objs))
