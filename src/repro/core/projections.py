"""Projection / proximal steps for HM-Saddle and nu-Saddle.

Implements the paper's explicit update rules:

* :func:`entropy_prox` -- the closed form of Lemma 10: the entropy-prox
  (multiplicative-weights) step on the simplex,
      eta_i  propto  exp{ (gamma + d/tau)^-1 ( (d/tau) log eta_i[t] - v_i ) }
  where v_i = <w[t] + d(w[t+1]-w[t]), X_{.i}>.  Computed in log space.

* :func:`capped_simplex_project_sorted` -- Rule 2 of Lemma 11: the
  O(n log n) sort-based projection onto D = {eta : ||eta||_1 = 1,
  0 <= eta_i <= nu} that preserves the entropy-prox KKT structure
  (clamp the top block to nu, scale the rest by 1 + sigma/Omega).

* :func:`capped_simplex_project_loop` -- Rule 3: the O(n/nu) iterative
  water-filling loop (used as an oracle and for tiny 1/nu).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def entropy_prox(log_lam: jax.Array, v: jax.Array, gamma: float | jax.Array,
                 tau: float | jax.Array, d: int | jax.Array) -> jax.Array:
    """One MWU step; returns *normalized* log-weights on the simplex."""
    c = 1.0 / (gamma + d / tau)
    log_new = c * ((d / tau) * log_lam - v)
    return log_new - jax.scipy.special.logsumexp(log_new)


def capped_simplex_project_sorted(eta: jax.Array, nu: float) -> jax.Array:
    """Rule 2 (Lemma 11): sorted projection onto the capped simplex.

    Finds the largest index i* (in ascending sorted order) such that
      varsigma_{i*} = sum_{j >= i*} (eta_j - nu) >= 0   and
      eta_{i*-1} (1 + varsigma_{i*}/Omega_{i*}) < nu,  Omega_{i*} = sum_{j<i*} eta_j,
    then clamps entries >= i* to nu and scales the rest.
    Fully vectorized: one sort + prefix sums + argmax.
    """
    n = eta.shape[0]
    order = jnp.argsort(eta)
    s = eta[order]                                    # ascending
    total = jnp.sum(s)
    prefix = jnp.cumsum(s)                            # prefix[i] = sum_{j<=i}
    omega = prefix - s                                # Omega_i = sum_{j<i}
    suffix = total - omega                            # sum_{j>=i}
    idx = jnp.arange(n)
    varsig = suffix - nu * (n - idx)                  # sum_{j>=i}(s_j - nu)
    prev = jnp.concatenate([jnp.zeros((1,), s.dtype), s[:-1]])
    scale = 1.0 + varsig / jnp.maximum(omega, 1e-30)
    ok = (varsig >= 0) & (prev * scale < nu)
    # largest index satisfying both conditions
    i_star = jnp.max(jnp.where(ok, idx, -1))
    no_violation = jnp.max(eta) <= nu
    sc = jnp.where(no_violation, 1.0, scale[jnp.maximum(i_star, 0)])
    proj_sorted = jnp.where(
        no_violation | (idx < i_star), s * sc, jnp.full_like(s, nu)
    )
    out = jnp.zeros_like(eta).at[order].set(proj_sorted)
    return out


def capped_simplex_project_loop(eta: jax.Array, nu: float,
                                max_iters: int | None = None) -> jax.Array:
    """Rule 3 (eq. 12): iterative projection. Terminates in <= ceil(1/nu)
    rounds (each round fixes at least one new entry at nu)."""
    if max_iters is None:
        max_iters = int(1.0 / nu) + 2

    def cond(state):
        eta, it = state
        varsig = jnp.sum(jnp.where(eta > nu, eta - nu, 0.0))
        return (varsig > 1e-12) & (it < max_iters)

    def body(state):
        eta, it = state
        over = eta >= nu
        varsig = jnp.sum(jnp.where(eta > nu, eta - nu, 0.0))
        omega = jnp.sum(jnp.where(eta < nu, eta, 0.0))
        eta = jnp.where(
            over, nu, eta * (1.0 + varsig / jnp.maximum(omega, 1e-30))
        )
        return eta, it + 1

    out, _ = jax.lax.while_loop(cond, body, (eta, jnp.array(0, jnp.int32)))
    return out


def capped_entropy_prox(log_lam: jax.Array, v: jax.Array,
                        gamma: float | jax.Array, tau: float | jax.Array,
                        d: int | jax.Array, nu: float) -> jax.Array:
    """nu-Saddle update: entropy-prox followed by the Rule-2 projection.

    Returns normalized log-weights on the *capped* simplex D_n."""
    log_eta = entropy_prox(log_lam, v, gamma, tau, d)
    eta = capped_simplex_project_sorted(jnp.exp(log_eta), nu)
    return jnp.log(jnp.maximum(eta, 1e-38))
