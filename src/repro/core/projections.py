"""Projection / proximal steps for HM-Saddle and nu-Saddle.

Implements the paper's explicit update rules:

* :func:`entropy_prox` -- the closed form of Lemma 10: the entropy-prox
  (multiplicative-weights) step on the simplex,
      eta_i  propto  exp{ (gamma + d/tau)^-1 ( (d/tau) log eta_i[t] - v_i ) }
  where v_i = <w[t] + d(w[t+1]-w[t]), X_{.i}>.  Computed in log space.

* :func:`capped_simplex_project_sorted` -- Rule 2 of Lemma 11: the
  O(n log n) sort-based projection onto D = {eta : ||eta||_1 = 1,
  0 <= eta_i <= nu} that preserves the entropy-prox KKT structure
  (clamp the top block to nu, scale the rest by 1 + sigma/Omega).

* :func:`capped_simplex_project_loop` -- Rule 3: the O(n/nu) iterative
  water-filling loop (used as an oracle and for tiny 1/nu).

* :func:`capped_bisect_masked` -- the sort-free O(n) projection the
  solver hot loop runs (single source of truth, shared with the
  standalone :func:`capped_simplex_project_bisect`): the KKT solution
  of the KL projection onto D is ``min(c * eta, nu)`` for a scalar
  ``c >= 1`` fixing the sum to 1, so a fixed-round geometric bisection
  on ``c`` (each round ONE masked O(n) reduction over however many
  disjoint classes share the sweep, no sort, no scatter) locates the
  cap set, and one exact closed-form rescale of the below-cap block
  removes the residual bisection error.  The sorted rule is kept as
  the reference oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Geometric bisection rounds.  The scale c lives in [1, e^BISECT_LOG_HI];
# after R rounds the bracket has log-width BISECT_LOG_HI * 2^-R.  Only
# the CAP SET is read off the bracket (the below-cap block is rescaled
# by the exact closed form), so the output error is at most
# nu * (cap-set ambiguity band) = nu * BISECT_LOG_HI * 2^-R:
#   * BISECT_ROUNDS = 32 (oracle grade): band ~2e-8, below f32 eps --
#     used by the standalone projection the property tests pin at
#     atol 2e-5 for nu up to O(1).
#   * BISECT_ROUNDS_SOLVER = 24: band ~5e-6, error <= 5e-6 * nu < 1e-5
#     for ANY feasible nu <= 1 -- used by the engine hot loop, where
#     each round is one blocking (2,) all-reduce under an axis, so
#     rounds are the round-4 communication budget.
BISECT_ROUNDS = 32
BISECT_ROUNDS_SOLVER = 24
BISECT_LOG_HI = 80.0


def entropy_prox(log_lam: jax.Array, v: jax.Array, gamma: float | jax.Array,
                 tau: float | jax.Array, d: int | jax.Array) -> jax.Array:
    """One MWU step; returns *normalized* log-weights on the simplex."""
    c = 1.0 / (gamma + d / tau)
    log_new = c * ((d / tau) * log_lam - v)
    return log_new - jax.scipy.special.logsumexp(log_new)


def capped_simplex_project_sorted(eta: jax.Array, nu: float) -> jax.Array:
    """Rule 2 (Lemma 11): sorted projection onto the capped simplex.

    Finds the largest index i* (in ascending sorted order) such that
      varsigma_{i*} = sum_{j >= i*} (eta_j - nu) >= 0   and
      eta_{i*-1} (1 + varsigma_{i*}/Omega_{i*}) < nu,  Omega_{i*} = sum_{j<i*} eta_j,
    then clamps entries >= i* to nu and scales the rest.
    Fully vectorized: one sort + prefix sums + argmax.
    """
    n = eta.shape[0]
    order = jnp.argsort(eta)
    s = eta[order]                                    # ascending
    total = jnp.sum(s)
    prefix = jnp.cumsum(s)                            # prefix[i] = sum_{j<=i}
    omega = prefix - s                                # Omega_i = sum_{j<i}
    suffix = total - omega                            # sum_{j>=i}
    idx = jnp.arange(n)
    varsig = suffix - nu * (n - idx)                  # sum_{j>=i}(s_j - nu)
    prev = jnp.concatenate([jnp.zeros((1,), s.dtype), s[:-1]])
    scale = 1.0 + varsig / jnp.maximum(omega, 1e-30)
    ok = (varsig >= 0) & (prev * scale < nu)
    # largest index satisfying both conditions
    i_star = jnp.max(jnp.where(ok, idx, -1))
    no_violation = jnp.max(eta) <= nu
    sc = jnp.where(no_violation, 1.0, scale[jnp.maximum(i_star, 0)])
    proj_sorted = jnp.where(
        no_violation | (idx < i_star), s * sc, jnp.full_like(s, nu)
    )
    out = jnp.zeros_like(eta).at[order].set(proj_sorted)
    return out


def capped_simplex_project_loop(eta: jax.Array, nu: float,
                                max_iters: int | None = None) -> jax.Array:
    """Rule 3 (eq. 12): iterative projection. Terminates in <= ceil(1/nu)
    rounds (each round fixes at least one new entry at nu)."""
    if max_iters is None:
        max_iters = int(1.0 / nu) + 2

    def cond(state):
        eta, it = state
        varsig = jnp.sum(jnp.where(eta > nu, eta - nu, 0.0))
        return (varsig > 1e-12) & (it < max_iters)

    def body(state):
        eta, it = state
        over = eta >= nu
        varsig = jnp.sum(jnp.where(eta > nu, eta - nu, 0.0))
        omega = jnp.sum(jnp.where(eta < nu, eta, 0.0))
        eta = jnp.where(
            over, nu, eta * (1.0 + varsig / jnp.maximum(omega, 1e-30))
        )
        return eta, it + 1

    out, _ = jax.lax.while_loop(cond, body, (eta, jnp.array(0, jnp.int32)))
    return out


def capped_bisect_masked(lam: jax.Array, nu: float, masks: jax.Array, *,
                         rounds: int,
                         all_sum=lambda x: x,
                         all_max=lambda x: x) -> jax.Array:
    """THE sort-free capped-simplex projection core (single source of
    truth -- both the standalone single-class projection and the
    engine's packed two-class hot-loop variant call this).

    Projects ``lam`` restricted to each row of ``masks`` (C, n) -- C
    disjoint index sets, each a separate capped simplex -- in ONE
    shared sweep per bisection round.  ``all_sum``/``all_max`` are the
    shape-agnostic cross-client reduction hooks (identity in serial;
    under an axis: one (C,) pmax for feasibility, one (C,) psum per
    bisection round, and one (2C,) psum for the cap-set stats -- the
    whole round-4 collective budget of Algorithm 4).  Entries outside
    every mask come back 0.

    Per class: bisect ``log c`` until ``g(c) = sum min(c lam, nu)``
    brackets 1, read off the cap set ``{i : c lam_i >= nu}``, then
    rescale the below-cap block by the exact
    ``alpha = (1 - nu |cap|) / Omega``.  Feasible classes
    (``max lam <= nu``) are returned unchanged (identity on the
    feasible set, which also makes the projection idempotent).
    """
    mx = all_max(jnp.max(jnp.where(masks, lam, 0.0), axis=1))   # (C,)
    feasible = mx <= nu

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)                                    # (C,)
        capped = jnp.minimum(jnp.exp(mid)[:, None] * lam, nu)
        s = all_sum(jnp.sum(jnp.where(masks, capped, 0.0), axis=1))
        under = s < 1.0
        return jnp.where(under, mid, lo), jnp.where(under, hi, mid)

    c_shape = (masks.shape[0],)
    _, hi = jax.lax.fori_loop(
        0, rounds, body,
        (jnp.zeros(c_shape, lam.dtype),
         jnp.full(c_shape, BISECT_LOG_HI, lam.dtype)))
    # per-entry class scale (masks are disjoint; off-mask entries get 0,
    # so they are never clamped and scale to 0)
    c_i = jnp.sum(masks * jnp.exp(hi)[:, None], axis=0)
    clamped = c_i * lam >= nu
    # cap-set stats for the exact rescale, combined into ONE (2C,)
    # all-reduce (|cap| per class, then Omega per class) -- the single
    # "(4,) cap-set stats psum" of the CommModel's round-4 accounting
    n_cl_loc = jnp.sum(jnp.where(masks & clamped[None, :], 1.0, 0.0),
                       axis=1)
    omega_loc = jnp.sum(jnp.where(masks & ~clamped[None, :], lam, 0.0),
                        axis=1)
    stats = all_sum(jnp.concatenate([n_cl_loc, omega_loc]))
    n_cl, omega = stats[:masks.shape[0]], stats[masks.shape[0]:]
    alpha = (1.0 - nu * n_cl) / jnp.maximum(omega, 1e-30)
    alpha_i = jnp.sum(masks * alpha[:, None], axis=0)
    proj = jnp.where(clamped, nu, lam * alpha_i)
    feas_i = jnp.any(masks & feasible[:, None], axis=0)
    return jnp.where(feas_i, lam, proj)


def capped_simplex_project_bisect(eta: jax.Array, nu: float, *,
                                  rounds: int = BISECT_ROUNDS) -> jax.Array:
    """Sort-free projection onto D = {0 <= x <= nu, sum x = 1}:
    the single-class view of :func:`capped_bisect_masked` (equivalent
    to Rule 2, tested property-wise, with every round one masked O(n)
    reduction instead of a sort)."""
    masks = jnp.ones((1,) + eta.shape, bool)
    return capped_bisect_masked(eta, nu, masks, rounds=rounds)


def capped_entropy_prox(log_lam: jax.Array, v: jax.Array,
                        gamma: float | jax.Array, tau: float | jax.Array,
                        d: int | jax.Array, nu: float) -> jax.Array:
    """nu-Saddle update: entropy-prox followed by the Rule-2 projection.

    Returns normalized log-weights on the *capped* simplex D_n."""
    log_eta = entropy_prox(log_lam, v, gamma, tau, d)
    eta = capped_simplex_project_sorted(jnp.exp(log_eta), nu)
    return jnp.log(jnp.maximum(eta, 1e-38))
