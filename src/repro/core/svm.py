"""Scikit-learn-style front end for Saddle-SVC.

``SaddleSVC``    -- hard-margin SVM (HM-Saddle).
``SaddleNuSVC``  -- nu-SVM (nu-Saddle).

Both run Algorithm 1 (pre-processing) + Algorithm 2 (the saddle solver)
and expose ``w_``, ``b_`` in the ORIGINAL input space.  The offset uses
the paper's footnote 2: b* = w*^T (A eta* + B xi*) / 2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import preprocess as pp
from repro.core import saddle


def split_classes(x: np.ndarray, y: np.ndarray):
    """Split (x, y in {+-1}) into the P (+1) and Q (-1) point matrices.

    Fails fast on a single-class ``y``: the saddle problem is defined
    between TWO convex hulls, and an empty class would otherwise
    surface as an opaque shape error deep inside ``pack_points``.
    """
    x = np.asarray(x, np.float32)
    y = np.asarray(y)
    xp, xm = x[y > 0], x[y < 0]
    if len(xp) == 0 or len(xm) == 0:
        raise ValueError(
            "y must contain both classes (+1 and -1): got "
            f"{len(xp)} positive and {len(xm)} negative points "
            f"(labels seen: {np.unique(y).tolist()})")
    return xp, xm


def recover_hyperplane(pre: pp.Preprocessed, eta: jax.Array,
                       xi: jax.Array, xp_t: jax.Array, xm_t: jax.Array):
    """Map final dual weights to the input-space hyperplane.

    The shared recovery path of ``SaddleSVC.fit`` and the multi-tenant
    ``serve.solver_service``: the optimal direction is w = A eta - B xi
    in TRANSFORMED space, the offset is footnote 2's
    b = w.(A eta + B xi)/2, and the direction is mapped back through
    the orthonormal WD transform.  ``xp_t``/``xm_t`` may carry inert
    zero-padding columns beyond ``pre``'s dimensionality (bucketed
    solves); those coordinates of w are exactly 0 and are sliced off.

    Returns (w_orig, b, objective, margin, w_t).
    """
    a_eta = eta @ xp_t
    b_xi = xi @ xm_t
    w_t = a_eta - b_xi                     # optimal w = A eta - B xi
    b_t = jnp.dot(w_t, a_eta + b_xi) / 2.0
    w = np.asarray(pp.recover_direction(w_t[: pre.signs.shape[0]], pre))
    return (w, float(b_t), float(0.5 * jnp.sum(w_t * w_t)),
            float(jnp.linalg.norm(w_t)), w_t)


class SaddleSVC:
    """Hard-margin SVM via HM-Saddle (paper Sections 2-3)."""

    nu = 0.0

    def __init__(self, eps: float = 1e-3, beta: float = 0.1,
                 num_iters: int | None = None, block_size: int = 1,
                 seed: int = 0, record_every: int | None = None,
                 use_kernels: bool = False):
        self.eps = eps
        self.beta = beta
        self.num_iters = num_iters
        self.block_size = block_size
        self.seed = seed
        self.record_every = record_every
        self.use_kernels = use_kernels

    def _nu_for(self, n1: int, n2: int) -> float:
        return 0.0

    def fit(self, x: np.ndarray, y: np.ndarray) -> "SaddleSVC":
        xp, xm = split_classes(x, y)
        n1, n2 = len(xp), len(xm)
        key = jax.random.key(self.seed)
        k_pre, _ = jax.random.split(key)
        pre = pp.preprocess(xp, xm, k_pre)
        nu = self._nu_for(n1, n2)
        res = saddle.solve(
            pre.xp, pre.xm, eps=self.eps, beta=self.beta, nu=nu,
            num_iters=self.num_iters, block_size=self.block_size,
            seed=self.seed, record_every=self.record_every,
            use_kernels=self.use_kernels)
        st = res.state
        self.history_ = res.history
        # direction & offset in TRANSFORMED space, mapped back to input
        # space (recover_hyperplane folds the transform AND the scale,
        # so w_ . x == w_t . x_t pointwise and the threshold carries
        # over as-is)
        eta = jnp.exp(st.log_eta)
        xi = jnp.exp(st.log_xi)
        (self.w_, self.b_, self.objective_, self.margin_,
         w_t) = recover_hyperplane(pre, eta, xi, pre.xp, pre.xm)
        self.eta_ = np.asarray(eta)
        self.xi_ = np.asarray(xi)
        self.state_ = st
        return self

    def decision_function(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(x, np.float32) @ self.w_ - self.b_

    def predict(self, x: np.ndarray) -> np.ndarray:
        return np.where(self.decision_function(x) >= 0, 1, -1)

    def score(self, x: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(x) == np.asarray(y)))


class SaddleNuSVC(SaddleSVC):
    """nu-SVM via nu-Saddle.  ``alpha`` parameterizes the paper's
    experiment convention nu = 1 / (alpha * min(n1, n2))."""

    def __init__(self, nu: float | None = None, alpha: float = 0.85,
                 **kw):
        super().__init__(**kw)
        self._nu = nu
        self.alpha = alpha

    def _nu_for(self, n1: int, n2: int) -> float:
        if self._nu is not None:
            return self._nu
        return 1.0 / (self.alpha * min(n1, n2))
