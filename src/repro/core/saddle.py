"""Saddle-SVC (Algorithm 2): stochastic primal--dual coordinate solver for
HM-Saddle (hard-margin SVM) and nu-Saddle (nu-SVM).

Layout convention: the USER-facing point matrices are row-major,
``xp[i] = x_i^+`` (shape (n1, d)) -- the paper's column ``X_{.i}``
(point i) is ``xp[i]``.  The SOLVER, however, runs on the packed +-
layout of :func:`repro.core.preprocess.pack_points`: both classes in
one lane-padded point set with a +-1 ``sign`` vector, stored as the
COLUMN-major mirror ``x_t`` of shape (d, n_pad) so the sampled
coordinate row ``X_{i*,.}`` is the CONTIGUOUS row ``x_t[i*]`` rather
than a strided column of a row-major matrix.  ``solve`` packs on entry
and unpacks the final state back into this module's per-class
:class:`SaddleState`, so the packed layout never leaks to callers.

The actual iteration lives in :mod:`repro.core.engine` -- ONE fused
single-sweep step (``engine.step_packed``) shared by this serial front
end, the distributed solver (:mod:`repro.core.distributed`), and the
Pallas-kernel backend (``backend="pallas"`` / ``use_kernels=True``).
This module keeps the paper-facing API: parameter formulas (Algorithm 1
line 4), state init, the objective/saddle-gap diagnostics, and
:func:`solve`.

Faithfulness notes:
  * With ``block_size=1`` this is exactly Algorithm 2: one uniformly
    random coordinate i* per iteration, momentum theta on the duals,
    momentum d*(w[t+1]-w[t]) on the primal, entropy-prox (MWU) dual
    updates, and the nu-Saddle capped-simplex projection (Rule 2).
  * The per-point inner products u_i = <w, x_i> are maintained
    incrementally (rank-1 update) so one iteration costs O(n), matching
    Theorem 6.
  * ``block_size=B>1`` is the beyond-paper TPU block-coordinate mode
    (DESIGN.md section 2): B lane-aligned coordinates per iteration,
    sampled WITHOUT replacement so the rank-B update of u stays exact.
    B=1 recovers the paper exactly.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core import preprocess as pp


class SaddleParams(NamedTuple):
    gamma: float
    q: float
    tau: float
    sigma: float
    theta: float
    d: int
    block_size: int
    nu: float          # 0.0 => HM-Saddle (no cap)


class SaddleState(NamedTuple):
    w: jax.Array            # (d,)
    log_eta: jax.Array      # (n1,)
    log_eta_prev: jax.Array
    log_xi: jax.Array       # (n2,)
    log_xi_prev: jax.Array
    u_p: jax.Array          # (n1,)  <w, x_i^+> maintained incrementally
    u_m: jax.Array          # (n2,)
    t: jax.Array            # iteration counter


def make_params(n: int, d: int, eps: float, beta: float,
                nu: float = 0.0, block_size: int = 1,
                block_scaling: str = "lane") -> SaddleParams:
    """Line 4 of Algorithm 1 (with the paper's q = O(sqrt(log n))).

    block_scaling (only matters for block_size > 1; B=1 is identical):
      "lane"   -- keep the PAPER's (tau, sigma, theta) and simply update
                  B coordinates per iteration.  Empirically dominant
                  (EXPERIMENTS.md section Perf: 70x fewer outer
                  iterations at B=128 on d=256), because each block step
                  makes ~B coordinates of primal progress against an
                  unchanged dual step size.
      "scaled" -- rescale with d_eff = d/B (the naive extension treating
                  a block step as B averaged coordinate steps); measured
                  strictly worse -- kept for the ablation.
    """
    if not 1 <= block_size <= d:
        raise ValueError(
            f"block_size={block_size} must be in [1, d={d}] (blocks are "
            "sampled without replacement)")
    gamma = eps * beta / (2.0 * math.log(max(n, 3)))
    q = max(1.0, math.sqrt(math.log(max(n, 3))))
    d_eff = d / block_size if block_scaling == "scaled" else d
    tau = 0.5 / q * math.sqrt(d_eff / gamma)
    sigma = 0.5 / q * math.sqrt(d_eff * gamma)
    theta = 1.0 - 1.0 / (d_eff + q * math.sqrt(d_eff) / math.sqrt(gamma))
    return SaddleParams(gamma=gamma, q=q, tau=tau, sigma=sigma, theta=theta,
                        d=d, block_size=block_size, nu=float(nu))


def default_iterations(d: int, eps: float, beta: float,
                       n: int = 1000) -> int:
    """Theorem 6 iteration count: Õ(d + sqrt(d / (eps * beta)))."""
    logn = math.log(max(n, 3))
    return int(2 * (d + math.sqrt(2.0 * d / (eps * beta)) * logn))


def validate_nu(nu: float, n1: int, n2: int) -> None:
    """The nu-SVM cap is feasible only when each class simplex can
    absorb total mass 1: nu >= 1/min(n1, n2)."""
    if nu > 0.0 and nu * min(n1, n2) < 1.0:
        raise ValueError(
            f"nu={nu} infeasible: need nu >= 1/min(n1,n2) = {1.0/min(n1,n2)}")


def resolve_num_iters(num_iters: int | None, d: int, eps: float,
                      beta: float, n: int, block_size: int) -> int:
    """THE iteration-budget derivation (defaulting + block scaling),
    shared by :func:`solve` and the serving layer so a request's
    schedule cannot drift from a solo solve's."""
    if num_iters is None:
        num_iters = default_iterations(d, eps, beta, n)
    return max(1, num_iters // block_size)


def init_state(n1: int, n2: int, d: int,
               xp: jax.Array, xm: jax.Array) -> SaddleState:
    """Line 5 of Algorithm 1: w=0, eta=1/n1, xi=1/n2 (two copies)."""
    del xp, xm  # u starts at zero because w starts at zero
    log_eta = jnp.full((n1,), -math.log(n1), jnp.float32)
    log_xi = jnp.full((n2,), -math.log(n2), jnp.float32)
    # distinct buffers for the "prev" copies: the engine donates the
    # state, and XLA rejects donating the same buffer twice
    return SaddleState(
        w=jnp.zeros((d,), jnp.float32),
        log_eta=log_eta, log_eta_prev=jnp.copy(log_eta),
        log_xi=log_xi, log_xi_prev=jnp.copy(log_xi),
        u_p=jnp.zeros((n1,), jnp.float32),
        u_m=jnp.zeros((n2,), jnp.float32),
        t=jnp.zeros((), jnp.int32),
    )


def saddle_step(state: SaddleState, key: jax.Array, xp: jax.Array,
                xm: jax.Array, p: SaddleParams) -> SaddleState:
    """One iteration of Algorithm 2 (thin wrapper over the engine)."""
    return engine.step(state, key, xp, xm, p)


def saddle_step_kernels(state: SaddleState, key: jax.Array, xp: jax.Array,
                        xm: jax.Array, p: SaddleParams) -> SaddleState:
    """Algorithm 2 iteration backed by the Pallas kernels (same engine
    step behind ``backend="pallas"``); numerically equivalent to
    :func:`saddle_step` (tested), validated here in interpret mode."""
    return engine.step(state, key, xp, xm, p, backend="pallas")


@functools.partial(jax.jit,
                   static_argnames=("num_steps", "params", "use_kernels"))
def run_chunk(state: SaddleState, key: jax.Array, xp: jax.Array,
              xm: jax.Array, params: SaddleParams, num_steps: int,
              use_kernels: bool = False) -> SaddleState:
    """Run exactly ``num_steps`` REFERENCE (unpacked) iterations under
    jit.

    Compatibility entry point: compiles per distinct ``num_steps`` (it
    is static here) and runs the unpacked oracle step.  Solves should
    use :func:`solve`, which runs the packed single-sweep engine with a
    dynamic trip count (one compile for all chunk lengths).
    """
    backend = "pallas" if use_kernels else "jnp"
    state, _ = engine.chunk_body(state, key, xp, xm, params, num_steps,
                                 chunk_steps=num_steps, backend=backend)
    return state


def objective(log_eta: jax.Array, log_xi: jax.Array, xp: jax.Array,
              xm: jax.Array) -> jax.Array:
    """C-Hull / RC-Hull objective 0.5 * ||A eta - B xi||^2."""
    diff = jnp.exp(log_eta) @ xp - jnp.exp(log_xi) @ xm
    return 0.5 * jnp.sum(diff * diff)


def saddle_gap(state: SaddleState, xp: jax.Array, xm: jax.Array,
               nu: float = 0.0) -> jax.Array:
    """g(w) = min_{eta,xi} w^T A eta - w^T B xi - ||w||^2 / 2.

    For HM-Saddle the inner min over the simplex is attained at a vertex;
    for nu-Saddle at a capped-simplex vertex (greedy water-filling:
    put nu on the 1/nu smallest entries).
    """
    sp = xp @ state.w     # (n1,) <w, x_i^+>
    sm = xm @ state.w
    if nu <= 0.0:
        inner = jnp.min(sp) - jnp.max(sm)
    else:
        inner = _capped_min(sp, nu) - (-_capped_min(-sm, nu))
    return inner - 0.5 * jnp.sum(state.w ** 2)


def _capped_min(scores: jax.Array, nu: float) -> jax.Array:
    """min_{eta in D} <scores, eta>: greedily fill nu on smallest scores."""
    n = scores.shape[0]
    s = jnp.sort(scores)
    k = int(math.floor(1.0 / nu))
    weights = jnp.where(jnp.arange(n) < k, nu, 0.0)
    weights = weights.at[min(k, n - 1)].add(max(1.0 - k * nu, 0.0))
    return jnp.dot(s, weights)


def unpack_state(pstate: engine.PackedState, n1: int,
                 n2: int) -> SaddleState:
    """Slice a packed solver state back into the per-class view (see
    engine.unpack_state for the slot layout)."""
    return engine.unpack_state(pstate, n1, n2, SaddleState)


# Default duality-gap checking cadence when gap_tol > 0 and the caller
# gave no record_every: frequent enough to realize most of the early
# stop's savings, coarse enough that the per-boundary gap evaluation
# (one masked sort + objective, on device -- the device-resident driver
# issues NO host sync at boundaries) stays negligible against the
# chunk's iterations.  Re-derived by the predict-then-verify cadence
# study in benchmarks/engine_bench.py (full mode): the boundary check
# costs ~4-6 iterations, so the sqrt(2 * T * check / step) optimum for
# typical stop horizons (T ~ 3k-30k) lands in the 128-512 band; 256
# stays the default.
GAP_CHECK_EVERY = 256


class SolveResult(NamedTuple):
    state: SaddleState
    history: list            # [(iteration, objective)]


def solve(xp: jax.Array, xm: jax.Array, *, eps: float = 1e-3,
          beta: float = 0.1, nu: float = 0.0, num_iters: int | None = None,
          block_size: int = 1, seed: int = 0,
          record_every: int | None = None,
          use_kernels: bool = False, n_pad: int | None = None,
          d_pad: int | None = None, gap_tol: float = 0.0,
          driver: str = "device",
          warm_start: SaddleState | None = None) -> SolveResult:
    """Run Saddle-SVC on (already preprocessed) data.

    Args:
      xp, xm: (n1, d), (n2, d) transformed point matrices.
      nu: 0 for hard margin; else the nu-SVM cap (must be >= 1/min(n1,n2)).
      n_pad, d_pad: optional BUCKET shape (see preprocess.bucket_shape):
        pad the packed point axis to n_pad and the coordinate axis to
        d_pad so the solve is slot-for-slot reproducible against the
        multi-tenant serving engine running the same bucket.  Padding
        coordinates are inert (w stays 0 there) but DO change the
        block-sampling schedule, which is exactly what sharing a
        bucket's executable requires.
      gap_tol: relative duality-gap early stop -- terminate once
        (objective - saddle_gap) <= gap_tol * objective, checked at
        chunk boundaries.  0 disables (the default: fixed iteration
        budget, reproducible schedule).  With gap_tol > 0 and no
        record_every, the chunk defaults to GAP_CHECK_EVERY iterations
        so the check actually fires before the budget is spent.
      driver: "device" (default) runs the WHOLE chunked solve as one
        executable (``engine.run_solve_slots``: a ``lax.while_loop``
        over the chunk body keyed on the slot-active flag, history in a
        preallocated device buffer, ONE host transfer at the end -- zero
        per-chunk host syncs, gap-enabled or not).  "host" is the
        per-chunk dispatch loop it replaced (one ``run_chunk_slots``
        launch per chunk; with gap_tol > 0, a blocking active-mask
        readback per boundary), retained for the transition as the
        bit-for-bit parity oracle of the device driver.
      warm_start: a previous :class:`SaddleState` (typically a prior
        fit of a PREFIX of this problem: its classes must be leading
        subsets of the new ones, in order).  The solve then starts from
        the carried ``w``, duals and momentum instead of the uniform
        init: new points' dual mass is seeded at the new uniform level
        and the next MWU normalizer round renormalizes each class
        (``preprocess.repack_warm_duals``), ``u`` is recomputed on
        device from the carried w (``engine.warm_packed_state``), and
        ``t`` restarts at 0 so the result's history counts the warm
        run's own iterations.  The trace keys of the hot chunk
        executables are UNCHANGED -- warm and cold solves at the same
        bucket share one compiled chunk.

    The hot loop is the SLOT-BATCHED engine driver at S=1 (one engine
    serves the serial solver and the multi-tenant service; the unpacked
    ``engine.step`` remains the parity oracle).  Both drivers run the
    same ``engine.chunk_body_slots`` chunk with the same key schedule,
    so their histories and final states are bit-for-bit equal; the
    chunk's trip count is dynamic, so the final partial chunk neither
    recompiles nor executes padded steps.
    """
    n1, d = xp.shape
    n2 = xm.shape[0]
    validate_nu(nu, n1, n2)
    if driver not in ("device", "host"):
        raise ValueError(f"driver={driver!r} must be 'device' or 'host'")
    if d_pad is not None:
        d = d_pad
    params = make_params(n1 + n2, d, eps, beta, nu=nu, block_size=block_size)
    num_iters = resolve_num_iters(num_iters, d, eps, beta, n1 + n2,
                                  block_size)
    check_gap = gap_tol > 0.0
    if record_every is None and check_gap:
        record_every = GAP_CHECK_EVERY   # else the gap never fires
    chunk = min(record_every or num_iters, num_iters)
    backend = "pallas" if use_kernels else "jnp"

    pts = pp.pack_points_to(xp, xm, n_pad or pp.packed_length(n1 + n2), d)
    if warm_start is None:
        pstate = engine.init_packed_state(pts.sign, n1, n2, d)
    else:
        n1_w = warm_start.log_eta.shape[0]
        n2_w = warm_start.log_xi.shape[0]
        lam_old = np.concatenate([np.asarray(warm_start.log_eta),
                                  np.asarray(warm_start.log_xi)])
        prev_old = np.concatenate([np.asarray(warm_start.log_eta_prev),
                                   np.asarray(warm_start.log_xi_prev)])
        lam = pp.repack_warm_duals(lam_old, n1_w, n2_w, n1, n2, pts.n_pad)
        prev = pp.repack_warm_duals(prev_old, n1_w, n2_w, n1, n2, pts.n_pad)
        w = np.zeros((d,), np.float32)
        w[: warm_start.w.shape[0]] = np.asarray(warm_start.w)
        pstate = engine.warm_packed_state(
            pts.x_t, jnp.asarray(w), jnp.asarray(lam), jnp.asarray(prev))
    sstate = engine.init_slot_state(1, pts.n_pad, d)
    sstate = engine.admit_into_slot(
        sstate, 0, pstate, jax.random.key(seed), num_iters)
    sp = jax.tree.map(lambda v: jnp.asarray(v)[None],
                      engine.slot_params_row(params, gap_tol))
    x_t_b, sign_b = pts.x_t[None], pts.sign[None]

    if driver == "device":
        sstate, objs_d, marks_d, nc_d = engine.run_solve_slots(
            sstate, x_t_b, sign_b, sp, num_iters, chunk_steps=chunk,
            num_chunks=-(-num_iters // chunk), d=d,
            block_size=block_size, project=nu > 0.0,
            check_gap=check_gap, backend=backend)
        # the solve's ONE host transfer: history + chunk count together
        objs_h, marks_h, nc = jax.device_get((objs_d, marks_d, nc_d))
        objs = [float(o) for o in objs_h[:nc, 0]]
        marks = [int(m) for m in marks_h[:nc, 0]]
    else:
        objs, marks = [], []
        done = 0
        while done < num_iters:
            ns = min(chunk, num_iters - done)
            sstate, obj, _healthy = engine.run_chunk_slots(
                sstate, x_t_b, sign_b, sp, ns, chunk_steps=chunk, d=d,
                block_size=block_size, project=nu > 0.0,
                check_gap=check_gap, backend=backend)
            done += ns
            objs.append(obj)
            marks.append(done)
            if check_gap and not bool(jax.device_get(sstate.active)[0]):
                marks[-1] = int(jax.device_get(sstate.t)[0])  # gap stop
                break
        objs = [float(np.asarray(o)[0]) for o in jax.device_get(objs)]
    pstate = engine.PackedState(
        w=sstate.w[0], log_lam=sstate.log_lam[0],
        log_lam_prev=sstate.log_lam_prev[0], u=sstate.u[0], t=sstate.t[0])
    return SolveResult(state=unpack_state(pstate, n1, n2),
                       history=list(zip(marks, objs)))
