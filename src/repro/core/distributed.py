"""Saddle-DSVC (Section 4 / Algorithm 4): the distributed solver.

The paper's server/clients protocol maps onto JAX collectives:

  round 1  server broadcasts i*; clients send partial delta+-    -> psum
  round 2  server broadcasts summed delta+-; clients update w,
           eta, xi locally and send partial normalizers Z+-      -> psum
  round 3  server broadcasts Z+-; clients normalize               (local)
  round 4  (nu-Saddle only) repeat: clients send partial
           varsigma+-, Omega+-; server broadcasts sums            -> psum
           until varsigma == 0  (at most ceil(1/nu) rounds)

Every "send partials / broadcast sum" pair is exactly one all-reduce of
O(1) scalars over the client axis, so the whole protocol is a handful of
scalar ``lax.psum``s per iteration -- the TPU-native realization of the
O(k) communication bound (Theorem 8).

The step itself is :func:`repro.core.engine.step_packed` with
``axis_name=CLIENT_AXIS`` -- the SAME code the serial solver runs (the
serial path is the k=1 degenerate client).  Each client packs its two
class shards into one +- operand (column-major mirror + sign vector,
see :func:`repro.core.preprocess.pack_points`), so rounds 1-3 are one
signed sweep each and round 4 (nu-Saddle) is the fixed-round bisection
whose per-round traffic is a single (2,) psum.  It executes in two
modes:
  * ``shard_map`` over a real mesh axis (multi-device / dry-run), or
  * ``jax.vmap(..., axis_name=CLIENT_AXIS)`` over a stacked (k, n/k, ...)
    state -- a bit-exact single-device simulation of k clients (psum is
    supported under vmap's axis_name), used for the paper's k=20
    experiments on this host.

Both produce the SAME iterates as serial Saddle-SVC (tested), because
summing per-client partial dot products/normalizers is exact.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core import preprocess
from repro.core import projections
from repro.core import saddle
from repro.core.engine import CLIENT_AXIS, NEG_INF
from repro.core.saddle import SaddleParams


class ShardedState(NamedTuple):
    """Per-client slice of the solver state.  Leading axis (under vmap)
    or shard axis (under shard_map) is the client."""
    w: jax.Array            # (d,) -- every client keeps the same w
    log_eta: jax.Array      # (n1/k,)
    log_eta_prev: jax.Array
    log_xi: jax.Array       # (n2/k,)
    log_xi_prev: jax.Array
    u_p: jax.Array
    u_m: jax.Array
    t: jax.Array


class CommModel(NamedTuple):
    """Analytic communication accounting for Algorithm 4.

    Two views of the same protocol:

    * ``scalars_per_iteration`` -- the PAPER's convention (Theorem 8):
      numbers exchanged per iteration, counting every client's up/down
      traffic, O(k).
    * ``collectives_per_iteration`` / ``collective_multiset`` /
      ``payload_elements_per_iteration`` -- the IMPLEMENTATION's view:
      how many collective launches (and of what reduction/shape) one
      ``engine.step_packed`` must emit per iteration.  This is what
      ``repro.utils.comm_audit`` checks against the post-SPMD HLO XLA
      actually compiles, making the O(k) bound a tested invariant: the
      per-device launch count and payload are independent of n, d and
      k, so total traffic is exactly (payload) x O(k).
    """
    k: int
    nu_rounds_per_iter: float   # 0 for HM-Saddle; else BISECT_ROUNDS

    def scalars_per_iteration(self) -> float:
        k = self.k
        # round 1: broadcast i* (k) + 2 scalars up from each client (2k)
        # round 2: broadcast 2 (2k) + Z's up (2k)
        # round 3: broadcast Z's (2k)
        base = k + 2 * k + 2 * k + 2 * k + 2 * k
        # round 4 (nu-Saddle): the sort-free bisection all-reduces one
        # (2,) vector per round -- 2 scalars up (2k) + 2 down (2k) --
        # for a FIXED round count, independent of n and of the data
        # (the old Rule-3 loop was data-dependent, up to ceil(1/nu)
        # rounds of 8k), plus two fixed out-of-loop all-reduces: the
        # (2,) per-class feasibility pmax (4k) and the (4,) cap-set
        # stats psum for the exact rescale (8k)
        nu_fixed = 12 * k if self.nu_rounds_per_iter else 0
        return base + self.nu_rounds_per_iter * 4 * k + nu_fixed

    def total(self, iters: int) -> float:
        return self.scalars_per_iteration() * iters

    def collective_multiset(self, block_size: int = 1) -> dict:
        """Predicted per-iteration collective launches of the packed
        step, as a multiset keyed (op, reduce_kind, result_elements) --
        directly comparable against the post-SPMD HLO (see
        repro.utils.comm_audit).  Per iteration:

          round 1    momentum psum           add  (B,)
          rounds 2-3 normalizer pmax + psum  max/add  (2,)
          round 4    feasibility pmax        max  (2,)
                     BISECT_ROUNDS psums     add  (2,)  (one per round)
                     cap-set stats psum      add  (4,)
        """
        ms: dict = {}

        def bump(kind, elems, cnt=1):
            key = ("all-reduce", kind, elems)
            ms[key] = ms.get(key, 0) + cnt

        bump("add", block_size)          # momentum delta
        bump("max", 2)                   # normalizer pmax
        bump("add", 2)                   # normalizer psum
        if self.nu_rounds_per_iter:
            bump("max", 2)               # feasibility pmax
            bump("add", 2, int(self.nu_rounds_per_iter))   # bisection
            bump("add", 4)               # cap-set |cap| + Omega stats
        return ms

    def collectives_per_iteration(self, block_size: int = 1) -> int:
        """Predicted collective LAUNCH count per iteration -- constant
        in n, d and k (3 for HM-Saddle; 5 + BISECT_ROUNDS for
        nu-Saddle)."""
        return sum(self.collective_multiset(block_size).values())

    def payload_elements_per_iteration(self, block_size: int = 1) -> int:
        """Predicted per-device all-reduce payload elements per
        iteration: O(B + rounds), independent of n (the O(k*d) bound of
        Theorem 8 with the momentum round's B <= d elements)."""
        return sum(elems * cnt for (_, _, elems), cnt
                   in self.collective_multiset(block_size).items())


class ServeCommModel(NamedTuple):
    """Collective budget of the POINT-SHARDED serving chunk
    (``engine.run_chunk_slots_sharded`` with non-empty ``point_axes``).

    The sharded slot driver vmaps ``engine._step_packed_core`` over the
    S lanes of a slot group with the SAME ``axis_name`` rounds as the
    solo distributed step, and vmap batches each round's collective into
    ONE launch whose payload scales by S.  The per-iteration multiset is
    therefore :class:`CommModel`'s with every payload multiplied by
    ``num_slots`` -- the LAUNCH count stays the Theorem-8 constant (3
    for HM-Saddle, 5 + BISECT_ROUNDS for nu-Saddle), so serving S fits
    across k shards costs exactly one fit's collective rounds.

    ``num_slots`` is the PER-DEVICE slot extent the chunk body is traced
    at (the group's full S for the pure point-sharded placement; S over
    the slot-axes extent when slot- and point-sharding compose).
    Unsharded slot groups need no model: their placement is
    collective-FREE and the audit pins the empty multiset.
    """
    k: int
    num_slots: int
    nu_rounds_per_iter: float   # 0 for HM-Saddle; else BISECT_ROUNDS

    def collective_multiset(self, block_size: int = 1) -> dict:
        """Per-iteration launches inside the chunk's step loop, keyed
        (op, reduce_kind, result_elements).  Identical launch structure
        to :meth:`CommModel.collective_multiset`; payloads are the
        vmap-batched (S, .) shapes.  Keys whose payloads collide (e.g.
        momentum S*B vs cap-set 4S when B == 4) merge, exactly as the
        measured HLO multiset merges them."""
        s = self.num_slots
        ms: dict = {}

        def bump(kind, elems, cnt=1):
            key = ("all-reduce", kind, elems)
            ms[key] = ms.get(key, 0) + cnt

        bump("add", s * block_size)      # momentum delta   (S, B)
        bump("max", 2 * s)               # normalizer pmax  (S, 2)
        bump("add", 2 * s)               # normalizer psum  (S, 2)
        if self.nu_rounds_per_iter:
            bump("max", 2 * s)           # feasibility pmax (S, 2)
            bump("add", 2 * s, int(self.nu_rounds_per_iter))  # bisection
            bump("add", 4 * s)           # cap-set stats    (S, 4)
        return ms

    def per_chunk_multiset(self, d: int) -> dict:
        """Launches at the chunk boundary, OUTSIDE the step loop: the
        per-slot objective psum ((S, d) -- each slot's shard holds only
        its points' dual-weighted sum) and the health agreement psum
        ((S,) -- one shard's overflow must deactivate the slot on every
        shard).  Constant per chunk, amortized over chunk_steps."""
        s = self.num_slots
        return {("all-reduce", "add", s * d): 1,
                ("all-reduce", "add", s): 1}

    def collectives_per_iteration(self, block_size: int = 1) -> int:
        return sum(self.collective_multiset(block_size).values())

    def payload_elements_per_iteration(self, block_size: int = 1) -> int:
        return sum(elems * cnt for (_, _, elems), cnt
                   in self.collective_multiset(block_size).items())


def dsvc_step(state: ShardedState, key: jax.Array, xp: jax.Array,
              xm: jax.Array, p: SaddleParams) -> ShardedState:
    """One Algorithm-4 iteration from a single client's viewpoint
    (engine step under the client axis).  ``xp``/``xm`` are the client's
    local (m1, d)/(m2, d) slices; the key is identical across clients
    (server broadcasts i*)."""
    return engine.step(state, key, xp, xm, p, axis_name=CLIENT_AXIS)


def shard_points(x: np.ndarray, k: int):
    """Round-robin partition of n points into k equal shards (padded with
    zero points whose log-weight is NEG_INF).  Returns (k, m, d) array and
    (k, m) validity mask."""
    n, d = x.shape
    m = -(-n // k)
    pad = k * m - n
    xpad = np.concatenate([x, np.zeros((pad, d), x.dtype)], 0)
    mask = np.concatenate([np.ones(n, bool), np.zeros(pad, bool)])
    order = np.arange(k * m).reshape(m, k).T.reshape(-1)   # round robin
    return xpad[order].reshape(k, m, d), mask[order].reshape(k, m)


def gather_duals(state: ShardedState, n1: int, n2: int, k: int):
    """Undo the round-robin sharding of :func:`shard_points`: shard c,
    slot j holds original point index j*k + c, so stacking slot-major
    (transpose then flatten) restores the original order.  Returns
    (eta, xi) of length n1, n2."""
    def unshard(log_v, n):
        if log_v.shape[0] != k:
            raise ValueError(
                f"state has {log_v.shape[0]} client shards, expected k={k}")
        flat = np.asarray(log_v).T.reshape(-1)   # flat[j*k + c] = v[c, j]
        return np.exp(flat[:n])
    return unshard(state.log_eta, n1), unshard(state.log_xi, n2)


def pack_shards(xp_sh: np.ndarray, mask_p: np.ndarray, xm_sh: np.ndarray,
                mask_m: np.ndarray):
    """Pack each client's two class shards into the single-sweep +-
    layout (see preprocess.pack_points): returns the stacked
    column-major mirrors (k, d, m_pad) and sign vectors (k, m_pad).
    Round-robin padding slots (mask False) get sign 0, like the lane
    padding, so they belong to neither class in any masked reduction."""
    k, m1, d = xp_sh.shape
    m2 = xm_sh.shape[1]
    m_pad = preprocess.packed_length(m1 + m2)
    x = np.zeros((k, m_pad, d), np.float32)
    x[:, :m1] = xp_sh
    x[:, m1:m1 + m2] = xm_sh
    sign = np.zeros((k, m_pad), np.float32)
    sign[:, :m1] = np.where(mask_p, 1.0, 0.0)
    sign[:, m1:m1 + m2] = np.where(mask_m, -1.0, 0.0)
    return np.ascontiguousarray(x.transpose(0, 2, 1)), sign


def unpack_sharded_state(pstate: engine.PackedState, m1: int,
                         m2: int) -> ShardedState:
    """Slice the stacked packed state back into the per-class
    ShardedState view (slot layout [eta | xi | lane pad] per client;
    see engine.unpack_state)."""
    return engine.unpack_state(pstate, m1, m2, ShardedState)


def init_sharded_state(n1: int, n2: int, d: int, mask_p: np.ndarray,
                       mask_m: np.ndarray) -> ShardedState:
    """Stacked (k, ...) client states; padding points get NEG_INF."""
    k, m1 = mask_p.shape
    m2 = mask_m.shape[1]
    log_eta = jnp.where(jnp.asarray(mask_p), -math.log(n1), NEG_INF)
    log_xi = jnp.where(jnp.asarray(mask_m), -math.log(n2), NEG_INF)
    zeros = jnp.zeros((k, d), jnp.float32)
    log_eta = log_eta.astype(jnp.float32)
    log_xi = log_xi.astype(jnp.float32)
    # prev copies are distinct buffers (the state is donated downstream)
    return ShardedState(
        w=zeros,
        log_eta=log_eta, log_eta_prev=jnp.copy(log_eta),
        log_xi=log_xi, log_xi_prev=jnp.copy(log_xi),
        u_p=jnp.zeros((k, m1), jnp.float32),
        u_m=jnp.zeros((k, m2), jnp.float32),
        t=jnp.zeros((k,), jnp.int32),
    )


@functools.partial(jax.jit,
                   static_argnames=("params", "chunk_steps", "backend"),
                   donate_argnums=(0,))
def run_chunk_sim(state: ShardedState, key: jax.Array, xp: jax.Array,
                  xm: jax.Array, num_steps, *, params: SaddleParams,
                  chunk_steps: int, backend: str = "jnp"):
    """Single-device simulation: vmap the engine chunk over the stacked
    client axis (dynamic trip count + donated state, like the serial
    path).  Returns (state, per-client objective (k,))."""

    def one_client(st, xp_c, xm_c):
        return engine.chunk_body(st, key, xp_c, xm_c, params, num_steps,
                                 chunk_steps=chunk_steps,
                                 axis_name=CLIENT_AXIS, backend=backend)

    return jax.vmap(one_client, in_axes=(0, 0, 0),
                    axis_name=CLIENT_AXIS)(state, xp, xm)


@functools.partial(jax.jit,
                   static_argnames=("params", "chunk_steps", "backend"),
                   donate_argnums=(0,))
def run_chunk_sim_packed(state: engine.PackedState, key: jax.Array,
                         x_t: jax.Array, sign: jax.Array, num_steps, *,
                         params: SaddleParams, chunk_steps: int,
                         backend: str = "jnp"):
    """Single-device simulation of the packed step: vmap the packed
    engine chunk over the stacked client axis (dynamic trip count +
    donated state).  Returns (state, per-client objective (k,))."""

    def one_client(st, x_t_c, sign_c):
        return engine.chunk_body_packed(
            st, key, x_t_c, sign_c, params, num_steps,
            chunk_steps=chunk_steps, axis_name=CLIENT_AXIS,
            backend=backend)

    return jax.vmap(one_client, in_axes=(0, 0, 0),
                    axis_name=CLIENT_AXIS)(state, x_t, sign)


def sharded_run_fn(mesh: jax.sharding.Mesh, axis=CLIENT_AXIS,
                   backend: str = "jnp", *, params: SaddleParams,
                   chunk_steps: int):
    """UN-jitted shard_map chunk runner over a real device mesh:
    ``run(state, key, x_t, sign, num_steps) -> (state, obj)``.

    ``axis`` may be a single mesh axis name or a tuple of axis names
    (the dry-run maps clients onto ALL mesh axes, so a 16x16 pod is
    k=256 clients); psum/pmax accept either.  Exposed separately from
    :func:`make_sharded_runner` so the communication audit and the
    launch specs can AOT-lower the exact production chunk from
    ShapeDtypeStructs without allocating anything."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def run(state, key, x_t, sign, num_steps):
        def client_fn(st, x_t_c, sign_c, key_r, ns_r):
            st = jax.tree.map(lambda a: a[0], st)        # drop shard dim
            x_t_c, sign_c = x_t_c[0], sign_c[0]
            st, obj = engine.chunk_body_packed(
                st, key_r, x_t_c, sign_c, params, ns_r,
                chunk_steps=chunk_steps, axis_name=axis, backend=backend)
            return jax.tree.map(lambda a: a[None], st), obj[None]

        spec = P(axis)
        fn = shard_map(client_fn, mesh=mesh,
                       in_specs=(spec, spec, spec, P(), P()),
                       out_specs=(spec, spec), check_rep=False)
        return fn(state, x_t, sign, key, jnp.asarray(num_steps, jnp.int32))

    return run


def make_sharded_runner(mesh: jax.sharding.Mesh, axis=CLIENT_AXIS,
                        backend: str = "jnp"):
    """shard_map runner for a real device mesh: the production path used
    by the multi-pod dry-run (clients = the mesh 'data' axis), running
    the packed single-sweep chunk per shard."""

    @functools.partial(jax.jit,
                       static_argnames=("params", "chunk_steps"),
                       donate_argnums=(0,))
    def run(state, key, x_t, sign, num_steps, *, params, chunk_steps):
        inner = sharded_run_fn(mesh, axis, backend, params=params,
                               chunk_steps=chunk_steps)
        return inner(state, key, x_t, sign, num_steps)

    return run


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _apply_client_drop(state: engine.PackedState, sign: jax.Array,
                       client):
    """Remove one client from the stacked vmap simulation IN SHAPE:
    its sign row goes to 0 (its points leave every masked class
    reduction, including the feasibility pmax rounds) and its dual
    weights to NEG_INF / momentum to 0 (exp(NEG_INF) = 0, so the
    client contributes nothing to any psum).  ``client`` is traced --
    one compile serves every drop target -- and no operand shape
    changes, so the chunk executable is NOT retraced.

    Recovery rule (renormalized mass): the very next iteration's
    normalizer round -- pmax + psum of the survivors' partial Z's --
    rescales each class's total dual mass back to 1 over the k-1
    survivors, exactly as if the protocol had been restarted on the
    survivor shard set with the current iterates.  No host-side repair
    step is needed; the MWU normalization IS the repair."""
    drop = (jnp.arange(sign.shape[0]) == client)[:, None]
    return state._replace(
        log_lam=jnp.where(drop, NEG_INF, state.log_lam),
        log_lam_prev=jnp.where(drop, NEG_INF, state.log_lam_prev),
        u=jnp.where(drop, 0.0, state.u),
    ), jnp.where(drop, 0.0, sign)


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=("num_shards",))
def drop_slot_shard(state: engine.SlotState, sign: jax.Array, slot,
                    shard, *, num_shards: int):
    """:func:`_apply_client_drop` for ONE point-sharded serving slot:
    zero the lost shard's sign range and send its dual weights to
    NEG_INF / momentum to 0, so the shard's points leave every masked
    reduction of that slot while batch-mates' rows are untouched
    bit-for-bit.  ``slot``/``shard`` are traced (one compile per group
    shape serves every drop target).

    The point axis of a sharded slot is split CONTIGUOUSLY by
    ``shard_map`` (unlike :func:`shard_points`' round-robin layout), so
    shard ``s`` owns columns [s*m, (s+1)*m) with m = n_pad/num_shards.
    The same renormalized-mass recovery rule applies: the next
    iteration's normalizer round rescales each class's surviving dual
    mass to 1 -- the MWU normalization IS the repair."""
    n_pad = sign.shape[-1]
    m = n_pad // num_shards
    cols = (jnp.arange(n_pad) // m) == shard
    rows = jnp.arange(sign.shape[0]) == slot
    drop = rows[:, None] & cols[None, :]
    return state._replace(
        log_lam=jnp.where(drop, NEG_INF, state.log_lam),
        log_lam_prev=jnp.where(drop, NEG_INF, state.log_lam_prev),
        u=jnp.where(drop, 0.0, state.u),
    ), jnp.where(drop, 0.0, sign)


class DistSolveResult(NamedTuple):
    state: ShardedState
    history: list
    comm: CommModel
    scalars_sent: float


def solve_distributed(xp: np.ndarray, xm: np.ndarray, *, k: int = 20,
                      eps: float = 1e-3, beta: float = 0.1, nu: float = 0.0,
                      num_iters: int | None = None, block_size: int = 1,
                      seed: int = 0, record_every: int | None = None,
                      mesh: jax.sharding.Mesh | None = None,
                      use_kernels: bool = False,
                      drop_client: tuple[int, int] | None = None
                      ) -> DistSolveResult:
    """Run Saddle-DSVC with k clients (simulation unless a mesh is given).

    Data must already be preprocessed (Algorithm 3 runs WD per client with
    the same shared D -- equivalent to transforming up front).

    ``drop_client=(c, at_iter)`` injects a client loss into the vmap
    SIMULATION path: at outer iteration ``at_iter`` client ``c``
    vanishes (see :func:`_apply_client_drop` -- shape-preserving, no
    retrace) and the solve continues on the k-1 survivors with their
    dual mass renormalized by the next MWU normalizer round.  The
    survivor problem is the round-robin complement of shard ``c``
    (original point index j*k + c belongs to the dropped client), and
    the k-1 solve converges on IT -- the duality-gap tolerance is
    pinned in ``tests/test_distributed.py``."""
    xp = np.asarray(xp, np.float32)
    xm = np.asarray(xm, np.float32)
    n1, d = xp.shape
    n2 = xm.shape[0]
    params = saddle.make_params(n1 + n2, d, eps, beta, nu=nu,
                                block_size=block_size)
    if num_iters is None:
        num_iters = saddle.default_iterations(d, eps, beta, n1 + n2)
    num_iters = max(1, num_iters // block_size)

    xp_sh, mask_p = shard_points(xp, k)
    xm_sh, mask_m = shard_points(xm, k)
    m1, m2 = mask_p.shape[1], mask_m.shape[1]
    x_t, sign = pack_shards(xp_sh, mask_p, xm_sh, mask_m)
    x_t = jnp.asarray(x_t)
    sign = jnp.asarray(sign)
    state = engine.init_packed_state(sign, n1, n2, d)
    chunk = min(record_every or num_iters, num_iters)
    backend = "pallas" if use_kernels else "jnp"

    if drop_client is not None and mesh is not None:
        raise ValueError("drop_client injection is simulation-only "
                         "(mesh=None)")
    if mesh is not None:
        runner = make_sharded_runner(mesh, backend=backend)
        run = lambda st, kk, ns: runner(st, kk, x_t, sign, ns,
                                        params=params, chunk_steps=chunk)
    else:
        # late-bound ``sign`` so the drop injection below takes effect
        # mid-solve without rebuilding the runner (shapes unchanged ->
        # the chunk executable is shared across the drop boundary)
        run = lambda st, kk, ns: run_chunk_sim_packed(st, kk, x_t, sign,
                                                      ns, params=params,
                                                      chunk_steps=chunk,
                                                      backend=backend)

    # nu-projection rounds per iteration: the sort-free bisection runs a
    # FIXED round count (one (2,) psum per round) -- deterministic and
    # worst-case O(k) scalars, where the data-dependent Rule-3 loop was
    # worst-case O(k / nu)
    nu_rounds = float(projections.BISECT_ROUNDS_SOLVER) if nu > 0 else 0.0
    comm = CommModel(k=k, nu_rounds_per_iter=nu_rounds)

    if drop_client is None:
        state, hist = engine.drive(state, jax.random.key(seed),
                                   num_iters, chunk, run)
    else:
        # drive's loop with one extra chunk boundary at the drop
        # iteration (same one-key-split-per-chunk discipline; the trip
        # count is dynamic, so the split chunk costs no retrace)
        drop_c, drop_at = drop_client
        drop_at = max(0, min(int(drop_at), num_iters))
        key = jax.random.key(seed)
        hist, done, dropped = [], 0, False
        while done < num_iters:
            if not dropped and done >= drop_at:
                state, sign = _apply_client_drop(
                    state, sign, jnp.asarray(drop_c, jnp.int32))
                dropped = True
            bound = num_iters if dropped else min(drop_at, num_iters)
            bound = bound if bound > done else num_iters
            key, sub = jax.random.split(key)
            ns = min(chunk, bound - done)
            state, obj = run(state, sub, ns)
            done += ns
            # per-client objectives agree across LIVE clients; read a
            # survivor's row (the dropped client's is stale)
            ridx = ((drop_c + 1) % k) if dropped else 0
            hist.append((done, float(np.asarray(
                jax.device_get(obj)).reshape(-1)[ridx])))
    history = [(done, comm.total(done), obj) for done, obj in hist]
    return DistSolveResult(state=unpack_sharded_state(state, m1, m2),
                           history=history, comm=comm,
                           scalars_sent=comm.total(num_iters))
