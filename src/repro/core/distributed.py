"""Saddle-DSVC (Section 4 / Algorithm 4): the distributed solver.

The paper's server/clients protocol maps onto JAX collectives:

  round 1  server broadcasts i*; clients send partial delta+-    -> psum
  round 2  server broadcasts summed delta+-; clients update w,
           eta, xi locally and send partial normalizers Z+-      -> psum
  round 3  server broadcasts Z+-; clients normalize               (local)
  round 4  (nu-Saddle only) repeat: clients send partial
           varsigma+-, Omega+-; server broadcasts sums            -> psum
           until varsigma == 0  (at most ceil(1/nu) rounds)

Every "send partials / broadcast sum" pair is exactly one all-reduce of
O(1) scalars over the client axis, so the whole protocol is a handful of
scalar ``lax.psum``s per iteration -- the TPU-native realization of the
O(k) communication bound (Theorem 8).

The step itself is :func:`repro.core.engine.step` with
``axis_name=CLIENT_AXIS`` -- the SAME code the serial solver runs (the
serial path is the k=1 degenerate client).  It executes in two modes:
  * ``shard_map`` over a real mesh axis (multi-device / dry-run), or
  * ``jax.vmap(..., axis_name=CLIENT_AXIS)`` over a stacked (k, n/k, ...)
    state -- a bit-exact single-device simulation of k clients (psum is
    supported under vmap's axis_name), used for the paper's k=20
    experiments on this host.

Both produce the SAME iterates as serial Saddle-SVC (tested), because
summing per-client partial dot products/normalizers is exact.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core import saddle
from repro.core.engine import CLIENT_AXIS, NEG_INF
from repro.core.saddle import SaddleParams


class ShardedState(NamedTuple):
    """Per-client slice of the solver state.  Leading axis (under vmap)
    or shard axis (under shard_map) is the client."""
    w: jax.Array            # (d,) -- every client keeps the same w
    log_eta: jax.Array      # (n1/k,)
    log_eta_prev: jax.Array
    log_xi: jax.Array       # (n2/k,)
    log_xi_prev: jax.Array
    u_p: jax.Array
    u_m: jax.Array
    t: jax.Array


class CommModel(NamedTuple):
    """Analytic communication accounting for Algorithm 4 (scalar counts,
    matching the paper's convention of counting numbers exchanged)."""
    k: int
    nu_rounds_per_iter: float   # 0 for HM-Saddle

    def scalars_per_iteration(self) -> float:
        k = self.k
        # round 1: broadcast i* (k) + 2 scalars up from each client (2k)
        # round 2: broadcast 2 (2k) + Z's up (2k)
        # round 3: broadcast Z's (2k)
        base = k + 2 * k + 2 * k + 2 * k + 2 * k
        # each nu projection round: 4 scalars up (4k) + 4 down (4k)
        return base + self.nu_rounds_per_iter * 8 * k

    def total(self, iters: int) -> float:
        return self.scalars_per_iteration() * iters


def dsvc_step(state: ShardedState, key: jax.Array, xp: jax.Array,
              xm: jax.Array, p: SaddleParams) -> ShardedState:
    """One Algorithm-4 iteration from a single client's viewpoint
    (engine step under the client axis).  ``xp``/``xm`` are the client's
    local (m1, d)/(m2, d) slices; the key is identical across clients
    (server broadcasts i*)."""
    return engine.step(state, key, xp, xm, p, axis_name=CLIENT_AXIS)


def shard_points(x: np.ndarray, k: int):
    """Round-robin partition of n points into k equal shards (padded with
    zero points whose log-weight is NEG_INF).  Returns (k, m, d) array and
    (k, m) validity mask."""
    n, d = x.shape
    m = -(-n // k)
    pad = k * m - n
    xpad = np.concatenate([x, np.zeros((pad, d), x.dtype)], 0)
    mask = np.concatenate([np.ones(n, bool), np.zeros(pad, bool)])
    order = np.arange(k * m).reshape(m, k).T.reshape(-1)   # round robin
    return xpad[order].reshape(k, m, d), mask[order].reshape(k, m)


def gather_duals(state: ShardedState, n1: int, n2: int, k: int):
    """Undo the round-robin sharding of :func:`shard_points`: shard c,
    slot j holds original point index j*k + c, so stacking slot-major
    (transpose then flatten) restores the original order.  Returns
    (eta, xi) of length n1, n2."""
    def unshard(log_v, n):
        if log_v.shape[0] != k:
            raise ValueError(
                f"state has {log_v.shape[0]} client shards, expected k={k}")
        flat = np.asarray(log_v).T.reshape(-1)   # flat[j*k + c] = v[c, j]
        return np.exp(flat[:n])
    return unshard(state.log_eta, n1), unshard(state.log_xi, n2)


def init_sharded_state(n1: int, n2: int, d: int, mask_p: np.ndarray,
                       mask_m: np.ndarray) -> ShardedState:
    """Stacked (k, ...) client states; padding points get NEG_INF."""
    k, m1 = mask_p.shape
    m2 = mask_m.shape[1]
    log_eta = jnp.where(jnp.asarray(mask_p), -math.log(n1), NEG_INF)
    log_xi = jnp.where(jnp.asarray(mask_m), -math.log(n2), NEG_INF)
    zeros = jnp.zeros((k, d), jnp.float32)
    log_eta = log_eta.astype(jnp.float32)
    log_xi = log_xi.astype(jnp.float32)
    # prev copies are distinct buffers (the state is donated downstream)
    return ShardedState(
        w=zeros,
        log_eta=log_eta, log_eta_prev=jnp.copy(log_eta),
        log_xi=log_xi, log_xi_prev=jnp.copy(log_xi),
        u_p=jnp.zeros((k, m1), jnp.float32),
        u_m=jnp.zeros((k, m2), jnp.float32),
        t=jnp.zeros((k,), jnp.int32),
    )


@functools.partial(jax.jit,
                   static_argnames=("params", "chunk_steps", "backend"),
                   donate_argnums=(0,))
def run_chunk_sim(state: ShardedState, key: jax.Array, xp: jax.Array,
                  xm: jax.Array, num_steps, *, params: SaddleParams,
                  chunk_steps: int, backend: str = "jnp"):
    """Single-device simulation: vmap the engine chunk over the stacked
    client axis (dynamic trip count + donated state, like the serial
    path).  Returns (state, per-client objective (k,))."""

    def one_client(st, xp_c, xm_c):
        return engine.chunk_body(st, key, xp_c, xm_c, params, num_steps,
                                 chunk_steps=chunk_steps,
                                 axis_name=CLIENT_AXIS, backend=backend)

    return jax.vmap(one_client, in_axes=(0, 0, 0),
                    axis_name=CLIENT_AXIS)(state, xp, xm)


def make_sharded_runner(mesh: jax.sharding.Mesh, axis: str = CLIENT_AXIS,
                        backend: str = "jnp"):
    """shard_map runner for a real device mesh: the production path used
    by the multi-pod dry-run (clients = the mesh 'data' axis)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    @functools.partial(jax.jit,
                       static_argnames=("params", "chunk_steps"),
                       donate_argnums=(0,))
    def run(state, key, xp, xm, num_steps, *, params, chunk_steps):
        def client_fn(st, xp_c, xm_c, key_r, ns_r):
            st = jax.tree.map(lambda a: a[0], st)        # drop shard dim
            xp_c, xm_c = xp_c[0], xm_c[0]
            st, obj = engine.chunk_body(
                st, key_r, xp_c, xm_c, params, ns_r,
                chunk_steps=chunk_steps, axis_name=axis, backend=backend)
            return jax.tree.map(lambda a: a[None], st), obj[None]

        spec = P(axis)
        fn = shard_map(client_fn, mesh=mesh,
                       in_specs=(spec, spec, spec, P(), P()),
                       out_specs=(spec, spec), check_rep=False)
        return fn(state, xp, xm, key, jnp.asarray(num_steps, jnp.int32))

    return run


class DistSolveResult(NamedTuple):
    state: ShardedState
    history: list
    comm: CommModel
    scalars_sent: float


def solve_distributed(xp: np.ndarray, xm: np.ndarray, *, k: int = 20,
                      eps: float = 1e-3, beta: float = 0.1, nu: float = 0.0,
                      num_iters: int | None = None, block_size: int = 1,
                      seed: int = 0, record_every: int | None = None,
                      mesh: jax.sharding.Mesh | None = None,
                      use_kernels: bool = False) -> DistSolveResult:
    """Run Saddle-DSVC with k clients (simulation unless a mesh is given).

    Data must already be preprocessed (Algorithm 3 runs WD per client with
    the same shared D -- equivalent to transforming up front)."""
    xp = np.asarray(xp, np.float32)
    xm = np.asarray(xm, np.float32)
    n1, d = xp.shape
    n2 = xm.shape[0]
    params = saddle.make_params(n1 + n2, d, eps, beta, nu=nu,
                                block_size=block_size)
    if num_iters is None:
        num_iters = saddle.default_iterations(d, eps, beta, n1 + n2)
    num_iters = max(1, num_iters // block_size)

    xp_sh, mask_p = shard_points(xp, k)
    xm_sh, mask_m = shard_points(xm, k)
    state = init_sharded_state(n1, n2, d, mask_p, mask_m)
    xp_sh = jnp.asarray(xp_sh)
    xm_sh = jnp.asarray(xm_sh)
    chunk = min(record_every or num_iters, num_iters)
    backend = "pallas" if use_kernels else "jnp"

    if mesh is not None:
        runner = make_sharded_runner(mesh, backend=backend)
        run = lambda st, kk, ns: runner(st, kk, xp_sh, xm_sh, ns,
                                        params=params, chunk_steps=chunk)
    else:
        run = lambda st, kk, ns: run_chunk_sim(st, kk, xp_sh, xm_sh, ns,
                                               params=params,
                                               chunk_steps=chunk,
                                               backend=backend)

    # expected projection rounds per iteration (<= 1/nu; typically 1-2)
    nu_rounds = 2.0 if nu > 0 else 0.0
    comm = CommModel(k=k, nu_rounds_per_iter=nu_rounds)

    state, hist = engine.drive(state, jax.random.key(seed),
                               num_iters, chunk, run)
    history = [(done, comm.total(done), obj) for done, obj in hist]
    return DistSolveResult(state=state, history=history, comm=comm,
                           scalars_sent=comm.total(num_iters))
