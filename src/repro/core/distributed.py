"""Saddle-DSVC (Section 4 / Algorithm 4): the distributed solver.

The paper's server/clients protocol maps onto JAX collectives:

  round 1  server broadcasts i*; clients send partial delta+-    -> psum
  round 2  server broadcasts summed delta+-; clients update w,
           eta, xi locally and send partial normalizers Z+-      -> psum
  round 3  server broadcasts Z+-; clients normalize               (local)
  round 4  (nu-Saddle only) repeat: clients send partial
           varsigma+-, Omega+-; server broadcasts sums            -> psum
           until varsigma == 0  (at most ceil(1/nu) rounds)

Every "send partials / broadcast sum" pair is exactly one all-reduce of
O(1) scalars over the client axis, so the whole protocol is a handful of
scalar ``lax.psum``s per iteration -- the TPU-native realization of the
O(k) communication bound (Theorem 8).

The SAME step function runs in two modes:
  * ``shard_map`` over a real mesh axis (multi-device / dry-run), or
  * ``jax.vmap(..., axis_name=CLIENT_AXIS)`` over a stacked (k, n/k, ...)
    state -- a bit-exact single-device simulation of k clients (psum is
    supported under vmap's axis_name), used for the paper's k=20
    experiments on this host.

Both produce the SAME iterates as serial Saddle-SVC (tested), because
summing per-client partial dot products/normalizers is exact.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import saddle
from repro.core.saddle import SaddleParams

CLIENT_AXIS = "clients"
NEG_INF = -1e30     # log-weight of padding points (exp() == 0 exactly)


class ShardedState(NamedTuple):
    """Per-client slice of the solver state.  Leading axis (under vmap)
    or shard axis (under shard_map) is the client."""
    w: jax.Array            # (d,) -- every client keeps the same w
    log_eta: jax.Array      # (n1/k,)
    log_eta_prev: jax.Array
    log_xi: jax.Array       # (n2/k,)
    log_xi_prev: jax.Array
    u_p: jax.Array
    u_m: jax.Array
    t: jax.Array


class CommModel(NamedTuple):
    """Analytic communication accounting for Algorithm 4 (scalar counts,
    matching the paper's convention of counting numbers exchanged)."""
    k: int
    nu_rounds_per_iter: float   # 0 for HM-Saddle

    def scalars_per_iteration(self) -> float:
        k = self.k
        # round 1: broadcast i* (k) + 2 scalars up from each client (2k)
        # round 2: broadcast 2 (2k) + Z's up (2k)
        # round 3: broadcast Z's (2k)
        base = k + 2 * k + 2 * k + 2 * k + 2 * k
        # each nu projection round: 4 scalars up (4k) + 4 down (4k)
        return base + self.nu_rounds_per_iter * 8 * k

    def total(self, iters: int) -> float:
        return self.scalars_per_iteration() * iters


def _dist_entropy_prox(log_lam, v, gamma, tau, d_eff):
    """Entropy prox with a DISTRIBUTED normalizer (round 2-3: local sums
    psum'd across clients -- log-space for stability)."""
    c = 1.0 / (gamma + d_eff / tau)
    log_new = c * ((d_eff / tau) * log_lam - v)
    # local logsumexp -> global via psum of exp-shifted sums
    local_max = jnp.max(log_new)
    global_max = jax.lax.pmax(local_max, CLIENT_AXIS)
    local_sum = jnp.sum(jnp.exp(log_new - global_max))
    global_sum = jax.lax.psum(local_sum, CLIENT_AXIS)
    return log_new - (global_max + jnp.log(global_sum))


def _dist_capped_project(log_eta, nu, max_rounds):
    """Round 4 of Algorithm 4: the distributed Rule-3 projection.  All
    clients iterate on psum'd (varsigma, Omega) until varsigma == 0."""
    def cond(state):
        eta, it = state
        varsig = jax.lax.psum(
            jnp.sum(jnp.where(eta > nu, eta - nu, 0.0)), CLIENT_AXIS)
        return (varsig > 1e-12) & (it < max_rounds)

    def body(state):
        eta, it = state
        varsig = jax.lax.psum(
            jnp.sum(jnp.where(eta > nu, eta - nu, 0.0)), CLIENT_AXIS)
        omega = jax.lax.psum(
            jnp.sum(jnp.where(eta < nu, eta, 0.0)), CLIENT_AXIS)
        eta = jnp.where(eta >= nu, nu,
                        eta * (1.0 + varsig / jnp.maximum(omega, 1e-30)))
        return eta, it + 1

    eta = jnp.exp(log_eta)
    eta, _ = jax.lax.while_loop(cond, body, (eta, jnp.array(0, jnp.int32)))
    return jnp.where(eta > 0, jnp.log(jnp.maximum(eta, 1e-38)), NEG_INF)


def dsvc_step(state: ShardedState, key: jax.Array, xp: jax.Array,
              xm: jax.Array, p: SaddleParams) -> ShardedState:
    """One Algorithm-4 iteration from a single client's viewpoint.
    ``xp``/``xm`` are the client's local (m1, d)/(m2, d) slices.  The key
    is identical across clients (server broadcasts i*)."""
    d, b = p.d, p.block_size
    d_eff = d / b
    idx = jax.random.randint(key, (b,), 0, d)
    cols_p = xp[:, idx]
    cols_m = xm[:, idx]

    eta = jnp.exp(state.log_eta)
    eta_prev = jnp.exp(state.log_eta_prev)
    xi = jnp.exp(state.log_xi)
    xi_prev = jnp.exp(state.log_xi_prev)

    # Round 1: partial dot products, all-reduced (C.delta -> S.delta).
    mom_eta = eta + p.theta * (eta - eta_prev)
    mom_xi = xi + p.theta * (xi - xi_prev)
    delta_p = jax.lax.psum(cols_p.T @ mom_eta, CLIENT_AXIS)
    delta_m = jax.lax.psum(cols_m.T @ mom_xi, CLIENT_AXIS)

    # Round 2: every client performs the identical w update.
    w_old = state.w[idx]
    w_new = (w_old + p.sigma * (delta_p - delta_m)) / (p.sigma + 1.0)
    dw = w_new - w_old

    dv_p = cols_p @ dw
    dv_m = cols_m @ dw
    v_p = state.u_p + d_eff * dv_p
    v_m = state.u_m + d_eff * dv_m

    # Rounds 2-3: MWU update with distributed normalizer.
    log_eta_new = _dist_entropy_prox(state.log_eta, v_p, p.gamma, p.tau, d_eff)
    log_xi_new = _dist_entropy_prox(state.log_xi, -v_m, p.gamma, p.tau, d_eff)

    # Round 4 (nu-Saddle): distributed capped-simplex projection.
    if p.nu > 0.0:
        max_rounds = int(1.0 / p.nu) + 2
        log_eta_new = _dist_capped_project(log_eta_new, p.nu, max_rounds)
        log_xi_new = _dist_capped_project(log_xi_new, p.nu, max_rounds)

    return ShardedState(
        w=state.w.at[idx].set(w_new),
        log_eta=log_eta_new, log_eta_prev=state.log_eta,
        log_xi=log_xi_new, log_xi_prev=state.log_xi,
        u_p=state.u_p + dv_p, u_m=state.u_m + dv_m,
        t=state.t + 1,
    )


def shard_points(x: np.ndarray, k: int):
    """Round-robin partition of n points into k equal shards (padded with
    zero points whose log-weight is NEG_INF).  Returns (k, m, d) array and
    (k, m) validity mask."""
    n, d = x.shape
    m = -(-n // k)
    pad = k * m - n
    xpad = np.concatenate([x, np.zeros((pad, d), x.dtype)], 0)
    mask = np.concatenate([np.ones(n, bool), np.zeros(pad, bool)])
    order = np.arange(k * m).reshape(m, k).T.reshape(-1)   # round robin
    return xpad[order].reshape(k, m, d), mask[order].reshape(k, m)


def init_sharded_state(n1: int, n2: int, d: int, mask_p: np.ndarray,
                       mask_m: np.ndarray) -> ShardedState:
    """Stacked (k, ...) client states; padding points get NEG_INF."""
    k, m1 = mask_p.shape
    m2 = mask_m.shape[1]
    log_eta = jnp.where(jnp.asarray(mask_p), -math.log(n1), NEG_INF)
    log_xi = jnp.where(jnp.asarray(mask_m), -math.log(n2), NEG_INF)
    zeros = jnp.zeros((k, d), jnp.float32)
    return ShardedState(
        w=zeros,
        log_eta=log_eta.astype(jnp.float32),
        log_eta_prev=log_eta.astype(jnp.float32),
        log_xi=log_xi.astype(jnp.float32),
        log_xi_prev=log_xi.astype(jnp.float32),
        u_p=jnp.zeros((k, m1), jnp.float32),
        u_m=jnp.zeros((k, m2), jnp.float32),
        t=jnp.zeros((k,), jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("params", "num_steps"))
def run_chunk_sim(state: ShardedState, key: jax.Array, xp: jax.Array,
                  xm: jax.Array, params: SaddleParams,
                  num_steps: int) -> ShardedState:
    """Single-device simulation: vmap over the stacked client axis."""

    def one_client_scan(st, xp_c, xm_c, keys):
        def body(s, kk):
            return dsvc_step(s, kk, xp_c, xm_c, params), None
        out, _ = jax.lax.scan(body, st, keys)
        return out

    keys = jax.random.split(key, num_steps)   # identical for all clients
    return jax.vmap(one_client_scan, in_axes=(0, 0, 0, None),
                    axis_name=CLIENT_AXIS)(state, xp, xm, keys)


def make_sharded_runner(mesh: jax.sharding.Mesh, axis: str = CLIENT_AXIS):
    """shard_map runner for a real device mesh: the production path used
    by the multi-pod dry-run (clients = the mesh 'data' axis)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def run(state, key, xp, xm, params, num_steps):
        def client_fn(st, xp_c, xm_c):
            st = jax.tree.map(lambda a: a[0], st)        # drop shard dim
            xp_c, xm_c = xp_c[0], xm_c[0]
            keys = jax.random.split(key, num_steps)
            def body(s, kk):
                return dsvc_step(s, kk, xp_c, xm_c, params), None
            out, _ = jax.lax.scan(body, st, keys)
            return jax.tree.map(lambda a: a[None], out)

        spec = P(axis)
        fn = shard_map(client_fn, mesh=mesh,
                       in_specs=(spec, spec, spec), out_specs=spec,
                       check_rep=False)
        return fn(state, xp, xm)

    return run


class DistSolveResult(NamedTuple):
    state: ShardedState
    history: list
    comm: CommModel
    scalars_sent: float


def solve_distributed(xp: np.ndarray, xm: np.ndarray, *, k: int = 20,
                      eps: float = 1e-3, beta: float = 0.1, nu: float = 0.0,
                      num_iters: int | None = None, block_size: int = 1,
                      seed: int = 0, record_every: int | None = None,
                      mesh: jax.sharding.Mesh | None = None
                      ) -> DistSolveResult:
    """Run Saddle-DSVC with k clients (simulation unless a mesh is given).

    Data must already be preprocessed (Algorithm 3 runs WD per client with
    the same shared D -- equivalent to transforming up front)."""
    xp = np.asarray(xp, np.float32)
    xm = np.asarray(xm, np.float32)
    n1, d = xp.shape
    n2 = xm.shape[0]
    params = saddle.make_params(n1 + n2, d, eps, beta, nu=nu,
                                block_size=block_size)
    if num_iters is None:
        num_iters = saddle.default_iterations(d, eps, beta, n1 + n2)
    num_iters = max(1, num_iters // block_size)

    xp_sh, mask_p = shard_points(xp, k)
    xm_sh, mask_m = shard_points(xm, k)
    state = init_sharded_state(n1, n2, d, mask_p, mask_m)
    xp_sh = jnp.asarray(xp_sh)
    xm_sh = jnp.asarray(xm_sh)

    if mesh is not None:
        runner = make_sharded_runner(mesh)
        run = lambda st, kk, ns: runner(st, kk, xp_sh, xm_sh, params, ns)
    else:
        run = lambda st, kk, ns: run_chunk_sim(st, kk, xp_sh, xm_sh,
                                               params, ns)

    # expected projection rounds per iteration (<= 1/nu; typically 1-2)
    nu_rounds = 2.0 if nu > 0 else 0.0
    comm = CommModel(k=k, nu_rounds_per_iter=nu_rounds)

    key = jax.random.key(seed)
    chunk = record_every or num_iters
    history = []
    done = 0
    while done < num_iters:
        key, sub = jax.random.split(key)
        ns = min(chunk, num_iters - done)
        state = run(state, sub, ns)
        done += ns
        obj = float(distributed_objective(state, xp_sh, xm_sh))
        history.append((done, comm.total(done), obj))
    return DistSolveResult(state=state, history=history, comm=comm,
                           scalars_sent=comm.total(num_iters))


def distributed_objective(state: ShardedState, xp_sh, xm_sh) -> jax.Array:
    """0.5 || A eta - B xi ||^2 from the stacked client state."""
    eta = jnp.exp(state.log_eta)       # (k, m1)
    xi = jnp.exp(state.log_xi)
    diff = jnp.einsum("km,kmd->d", eta, xp_sh) - \
        jnp.einsum("km,kmd->d", xi, xm_sh)
    return 0.5 * jnp.sum(diff * diff)


def gather_duals(state: ShardedState, n1: int, n2: int, k: int):
    """Undo the round-robin sharding; returns (eta, xi) of length n1, n2."""
    def unshard(log_v, n):
        k_, m = log_v.shape
        flat = np.asarray(log_v).T.reshape(-1)   # inverse of round robin
        return np.exp(flat[:n])
    return unshard(state.log_eta, n1), unshard(state.log_xi, n2)
