"""End-to-end behaviour tests for the whole system."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import gilbert
from repro.core.svm import SaddleNuSVC, SaddleSVC
from repro.data import synthetic
import pytest

# LM-side model/system tests dominate the full-suite runtime; the fast
# CI tier (scripts/ci.sh) deselects them with -m 'not slow'
pytestmark = pytest.mark.slow


def test_saddle_matches_gilbert_end_to_end():
    """Paper Table 1: at matched epsilon, Saddle-SVC reaches the same
    polytope distance as Gilbert."""
    ds = synthetic.separable(300, 32, seed=0)
    xp = ds.x[ds.y > 0]
    xm = ds.x[ds.y < 0]
    clf = SaddleSVC(eps=1e-3, beta=0.1, num_iters=10000).fit(ds.x, ds.y)
    # run Gilbert on the same normalized data (scale by 1/max||x||)
    scale = 1.0 / np.linalg.norm(ds.x, axis=1).max()
    res = gilbert.solve(xp * scale, xm * scale, num_iters=4000)
    d_gilbert = np.sqrt(2 * res.history[-1][1])
    assert abs(clf.margin_ - d_gilbert) / d_gilbert < 0.05


def test_nu_svm_trains_and_predicts():
    ds = synthetic.non_separable(600, 24, beta2=0.1, seed=1)
    tr, te = ds.split(0.2, seed=0)
    clf = SaddleNuSVC(alpha=0.85, eps=1e-3, beta=0.1,
                      num_iters=8000).fit(tr.x, tr.y)
    acc = clf.score(te.x, te.y)
    assert acc >= 0.85, acc


def test_svm_probe_on_lm_features():
    """The integration example: nu-SVM on frozen transformer features."""
    from repro.configs import get_config
    from repro.models import transformer as tf

    cfg = get_config("xlstm-125m").reduced()
    params = tf.init_lm(jax.random.key(0), cfg)

    # two classes of synthetic token sequences (distinct vocab ranges)
    rng = np.random.default_rng(0)
    n = 60
    toks_a = rng.integers(0, cfg.vocab_size // 4, size=(n, 16))
    toks_b = rng.integers(cfg.vocab_size // 2,
                          cfg.vocab_size - 1, size=(n, 16))
    toks = jnp.asarray(np.vstack([toks_a, toks_b]), jnp.int32)

    @jax.jit
    def features(t):
        logits, _, _ = tf.forward(params, cfg, t)
        return logits.mean(axis=1)        # pooled features

    feats = np.asarray(features(toks))[:, :64]
    y = np.r_[np.ones(n), -np.ones(n)]
    clf = SaddleNuSVC(alpha=0.5, num_iters=4000).fit(feats, y)
    assert clf.score(feats, y) >= 0.9


def test_generate_end_to_end():
    from repro.configs import get_config
    from repro.models import transformer as tf
    from repro.serve import engine

    cfg = get_config("recurrentgemma-2b").reduced()
    params = tf.init_lm(jax.random.key(0), cfg)
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    toks = engine.generate(params, cfg, prompt, steps=6, temperature=0.7,
                           seed=1)
    assert toks.shape == (1, 6)
