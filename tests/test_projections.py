"""Unit + property tests for the paper's projection methods (Lemma 10/11)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import projections as proj

jax.config.update("jax_enable_x64", False)


def _rand_simplex(rng, n):
    v = rng.exponential(size=n)
    return v / v.sum()


# ------------------------------------------------------- Rule 2 == Rule 3
@pytest.mark.parametrize("n,nu_scale", [(8, 2.0), (32, 1.5), (100, 5.0),
                                        (257, 1.2)])
def test_rule2_equals_rule3(n, nu_scale):
    rng = np.random.default_rng(n)
    eta = _rand_simplex(rng, n)
    nu = nu_scale / n
    p2 = np.asarray(proj.capped_simplex_project_sorted(
        jnp.asarray(eta, jnp.float32), nu))
    p3 = np.asarray(proj.capped_simplex_project_loop(
        jnp.asarray(eta, jnp.float32), nu))
    np.testing.assert_allclose(p2, p3, atol=2e-5)


@settings(max_examples=60, deadline=None)
@given(st.integers(4, 120), st.floats(1.1, 8.0), st.integers(0, 10_000))
def test_capped_projection_properties(n, nu_scale, seed):
    """Output lies in the capped simplex; no-violation input is fixed."""
    rng = np.random.default_rng(seed)
    eta = _rand_simplex(rng, n)
    nu = nu_scale / n
    out = np.asarray(proj.capped_simplex_project_sorted(
        jnp.asarray(eta, jnp.float32), nu))
    assert abs(out.sum() - 1.0) < 1e-4
    assert out.max() <= nu + 1e-5
    assert out.min() >= -1e-7
    if eta.max() <= nu:                     # already feasible -> identity
        np.testing.assert_allclose(out, eta, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.integers(4, 60), st.integers(0, 10_000))
def test_projection_idempotent(n, seed):
    rng = np.random.default_rng(seed)
    eta = _rand_simplex(rng, n)
    nu = 2.0 / n
    once = proj.capped_simplex_project_sorted(jnp.asarray(eta, jnp.float32),
                                              nu)
    twice = proj.capped_simplex_project_sorted(once, nu)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               atol=2e-5)


def test_projection_preserves_order():
    """The paper's projection is monotone (it clamps the top block and
    scales the rest by a common factor)."""
    rng = np.random.default_rng(3)
    eta = _rand_simplex(rng, 50)
    nu = 1.5 / 50
    out = np.asarray(proj.capped_simplex_project_sorted(
        jnp.asarray(eta, jnp.float32), nu))
    order_in = np.argsort(eta)
    sorted_out = out[order_in]
    assert np.all(np.diff(sorted_out) >= -1e-6)


# ------------------------------------------------ entropy prox vs argmin
def test_entropy_prox_is_argmin():
    """Lemma 10: the closed form solves the prox problem (check by
    comparing against a dense numeric minimization over the simplex)."""
    import scipy.optimize as so
    rng = np.random.default_rng(0)
    n, d, gamma, tau = 12, 16.0, 0.05, 3.0
    lam = _rand_simplex(rng, n)
    v = rng.normal(size=n)

    closed = np.exp(np.asarray(proj.entropy_prox(
        jnp.asarray(np.log(lam), jnp.float32), jnp.asarray(v, jnp.float32),
        gamma, tau, d)))

    def objective(u):
        u = np.maximum(u, 1e-12)
        h = np.sum(u * np.log(u))
        h_lam = np.sum(lam * np.log(lam))
        bregman = np.sum(u * np.log(u / lam)) - (u.sum() - lam.sum())
        return (np.dot(v, u) / d + gamma / d * h + bregman / tau)

    cons = [{"type": "eq", "fun": lambda u: u.sum() - 1}]
    r = so.minimize(objective, lam, bounds=[(1e-9, 1)] * n,
                    constraints=cons, options={"maxiter": 300})
    np.testing.assert_allclose(closed, r.x, atol=1e-3)


@settings(max_examples=40, deadline=None)
@given(st.integers(3, 200), st.integers(0, 10_000))
def test_entropy_prox_normalized(n, seed):
    rng = np.random.default_rng(seed)
    lam = _rand_simplex(rng, n)
    v = rng.normal(size=n) * 3
    out = proj.entropy_prox(jnp.asarray(np.log(lam), jnp.float32),
                            jnp.asarray(v, jnp.float32), 0.01, 10.0, 64.0)
    total = float(jnp.exp(out).sum())
    assert abs(total - 1.0) < 1e-4
