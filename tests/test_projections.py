"""Unit + property tests for the paper's projection methods (Lemma 10/11)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import projections as proj

jax.config.update("jax_enable_x64", False)


def _rand_simplex(rng, n):
    v = rng.exponential(size=n)
    return v / v.sum()


# ------------------------------------------------------- Rule 2 == Rule 3
@pytest.mark.parametrize("n,nu_scale", [(8, 2.0), (32, 1.5), (100, 5.0),
                                        (257, 1.2)])
def test_rule2_equals_rule3(n, nu_scale):
    rng = np.random.default_rng(n)
    eta = _rand_simplex(rng, n)
    nu = nu_scale / n
    p2 = np.asarray(proj.capped_simplex_project_sorted(
        jnp.asarray(eta, jnp.float32), nu))
    p3 = np.asarray(proj.capped_simplex_project_loop(
        jnp.asarray(eta, jnp.float32), nu))
    np.testing.assert_allclose(p2, p3, atol=2e-5)


@settings(max_examples=60, deadline=None)
@given(st.integers(4, 120), st.floats(1.1, 8.0), st.integers(0, 10_000))
def test_capped_projection_properties(n, nu_scale, seed):
    """Output lies in the capped simplex; no-violation input is fixed."""
    rng = np.random.default_rng(seed)
    eta = _rand_simplex(rng, n)
    nu = nu_scale / n
    out = np.asarray(proj.capped_simplex_project_sorted(
        jnp.asarray(eta, jnp.float32), nu))
    assert abs(out.sum() - 1.0) < 1e-4
    assert out.max() <= nu + 1e-5
    assert out.min() >= -1e-7
    if eta.max() <= nu:                     # already feasible -> identity
        np.testing.assert_allclose(out, eta, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.integers(4, 60), st.integers(0, 10_000))
def test_projection_idempotent(n, seed):
    rng = np.random.default_rng(seed)
    eta = _rand_simplex(rng, n)
    nu = 2.0 / n
    once = proj.capped_simplex_project_sorted(jnp.asarray(eta, jnp.float32),
                                              nu)
    twice = proj.capped_simplex_project_sorted(once, nu)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               atol=2e-5)


def test_projection_preserves_order():
    """The paper's projection is monotone (it clamps the top block and
    scales the rest by a common factor)."""
    rng = np.random.default_rng(3)
    eta = _rand_simplex(rng, 50)
    nu = 1.5 / 50
    out = np.asarray(proj.capped_simplex_project_sorted(
        jnp.asarray(eta, jnp.float32), nu))
    order_in = np.argsort(eta)
    sorted_out = out[order_in]
    assert np.all(np.diff(sorted_out) >= -1e-6)


# ------------------------------------- sort-free bisection == Rule 2 == Rule 3
@pytest.mark.parametrize("n,nu_scale", [(8, 2.0), (32, 1.5), (100, 5.0),
                                        (257, 1.2)])
def test_bisect_equals_sorted_and_loop(n, nu_scale):
    rng = np.random.default_rng(n + 1)
    eta = _rand_simplex(rng, n)
    nu = nu_scale / n
    pb = np.asarray(proj.capped_simplex_project_bisect(
        jnp.asarray(eta, jnp.float32), nu))
    p2 = np.asarray(proj.capped_simplex_project_sorted(
        jnp.asarray(eta, jnp.float32), nu))
    p3 = np.asarray(proj.capped_simplex_project_loop(
        jnp.asarray(eta, jnp.float32), nu))
    np.testing.assert_allclose(pb, p2, atol=2e-5)
    np.testing.assert_allclose(pb, p3, atol=2e-5)


@settings(max_examples=60, deadline=None)
@given(st.integers(4, 200), st.floats(1.05, 8.0), st.integers(0, 10_000))
def test_bisect_property_equivalence(n, nu_scale, seed):
    """Property: the sort-free bisection, the sorted Rule 2, and the
    iterative Rule 3 agree on random capped-simplex inputs, and the
    output lies in the capped simplex."""
    rng = np.random.default_rng(seed)
    eta = _rand_simplex(rng, n)
    nu = nu_scale / n
    pb = np.asarray(proj.capped_simplex_project_bisect(
        jnp.asarray(eta, jnp.float32), nu))
    p2 = np.asarray(proj.capped_simplex_project_sorted(
        jnp.asarray(eta, jnp.float32), nu))
    p3 = np.asarray(proj.capped_simplex_project_loop(
        jnp.asarray(eta, jnp.float32), nu))
    np.testing.assert_allclose(pb, p2, atol=2e-5)
    np.testing.assert_allclose(pb, p3, atol=2e-5)
    assert abs(pb.sum() - 1.0) < 1e-4
    assert pb.max() <= nu + 1e-5 and pb.min() >= -1e-7


def test_bisect_all_below_cap_is_identity():
    """Feasible input (max <= nu) must come back unchanged -- exactly,
    not within bisection tolerance."""
    rng = np.random.default_rng(7)
    n = 64
    v = rng.uniform(0.5, 1.0, size=n)
    eta = (v / v.sum()).astype(np.float32)           # max well below 2/n
    out = np.asarray(proj.capped_simplex_project_bisect(
        jnp.asarray(eta), 2.0 / n))
    np.testing.assert_array_equal(out, eta)


@pytest.mark.parametrize("delta", [1e-1, 1e-3, 1e-6, 0.0])
def test_bisect_mass_concentrated(delta):
    """Nearly all mass on one entry: the cap set is a single entry and
    the scale factor is huge (the stress case for the bisection
    bracket).  delta=0 is the degenerate boundary input where even the
    oracles return sum nu < 1 (KL projection cannot move off zeros).

    The loop oracle (Rule 3) is the ground truth here: past
    delta ~ 1e-3 the SORTED rule's Omega = prefix - s suffers f32
    catastrophic cancellation (prefix ~ 1.0, s ~ 1 - delta) and drifts
    by percent while the bisection's directly-summed Omega stays exact,
    so the sorted comparison is gated to the mild cases."""
    n = 50
    eta = np.full(n, delta / (n - 1), np.float32)
    eta[0] = 1.0 - delta
    nu = 2.0 / n
    pb = np.asarray(proj.capped_simplex_project_bisect(
        jnp.asarray(eta), nu))
    p3 = np.asarray(proj.capped_simplex_project_loop(
        jnp.asarray(eta), nu))
    np.testing.assert_allclose(pb, p3, atol=2e-5)
    if delta == 0.0 or delta >= 1e-3:
        p2 = np.asarray(proj.capped_simplex_project_sorted(
            jnp.asarray(eta), nu))
        np.testing.assert_allclose(pb, p2, atol=2e-5)
    assert abs(pb.sum() - (1.0 if delta else nu)) < 1e-5


@settings(max_examples=30, deadline=None)
@given(st.integers(4, 60), st.integers(0, 10_000))
def test_bisect_idempotent(n, seed):
    rng = np.random.default_rng(seed)
    eta = _rand_simplex(rng, n)
    nu = 2.0 / n
    once = proj.capped_simplex_project_bisect(
        jnp.asarray(eta, jnp.float32), nu)
    twice = proj.capped_simplex_project_bisect(once, nu)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               atol=2e-5)


@pytest.mark.parametrize("n1,n2,nu_scale", [(40, 50, 1.5), (100, 70, 3.0)])
def test_engine_packed_projection_matches_oracles(n1, n2, nu_scale):
    """The two-class masked variant the solver hot loop ACTUALLY runs
    (engine._capped_project_packed) must match the per-class oracles,
    with lane padding slots (sign 0, log-weight NEG_INF) present and
    preserved."""
    from repro.core import engine
    rng = np.random.default_rng(n1 * n2)
    n_pad = 256
    sign = np.zeros(n_pad, np.float32)
    sign[:n1] = 1.0
    sign[n1:n1 + n2] = -1.0
    eta = _rand_simplex(rng, n1)
    xi = _rand_simplex(rng, n2)
    log_lam = np.full(n_pad, engine.NEG_INF, np.float32)
    log_lam[:n1] = np.log(eta)
    log_lam[n1:n1 + n2] = np.log(xi)
    nu = nu_scale / min(n1, n2)
    out = np.asarray(engine._capped_project_packed(
        jnp.asarray(log_lam), jnp.asarray(sign), nu, None))
    for sl, v in [(slice(0, n1), eta), (slice(n1, n1 + n2), xi)]:
        want = np.asarray(proj.capped_simplex_project_loop(
            jnp.asarray(v, jnp.float32), nu))
        np.testing.assert_allclose(np.exp(out[sl]), want, atol=2e-5)
        want_b = np.asarray(proj.capped_simplex_project_bisect(
            jnp.asarray(v, jnp.float32), nu))
        np.testing.assert_allclose(np.exp(out[sl]), want_b, atol=2e-5)
    # padding slots keep their NEG_INF marker exactly
    assert (out[n1 + n2:] == engine.NEG_INF).all()


# ----------------------------- bisection degenerate regimes (PR 2 note)
# The PR 2 note flagged that the SORTED rule loses f32 precision under
# extreme mass concentration, so these pins assert the closed-form
# rescale INVARIANTS of capped_bisect_masked (sum preserved, no element
# above cap, identity on feasible input) -- never equality with the
# precision-losing sorted oracle.

def _bisect_invariants(out, nu, total=1.0, atol=2e-5):
    assert np.all(np.isfinite(out))
    assert out.max() <= nu + atol
    assert out.min() >= -1e-7
    assert abs(out.sum() - total) < 1e-4


@settings(max_examples=40, deadline=None)
@given(st.integers(8, 200), st.floats(1.05, 4.0), st.integers(0, 10_000))
def test_bisect_all_mass_on_one_point(n, nu_scale, seed):
    """All mass concentrated on one entry (the rest carries f32 dust):
    the cap set is that single entry and the rescale factor for the
    dust block is enormous -- the bracket stress case."""
    rng = np.random.default_rng(seed)
    nu = nu_scale / n
    eta = rng.uniform(1e-30, 1e-12, size=n).astype(np.float32)
    eta[rng.integers(n)] = 1.0 - eta.sum() + eta[rng.integers(n)]
    eta = (eta / eta.sum()).astype(np.float32)
    out = np.asarray(proj.capped_simplex_project_bisect(
        jnp.asarray(eta), nu))
    _bisect_invariants(out, nu)
    # the concentrated entry must be clamped exactly at the cap
    assert abs(out[np.argmax(eta)] - nu) < 2e-5


@settings(max_examples=40, deadline=None)
@given(st.integers(4, 128), st.integers(0, 10_000))
def test_bisect_everything_at_cap(n, seed):
    """nu -> 1/n (every entry must sit at the cap): the unique feasible
    point is uniform.  Tiny perturbations of uniform input must still
    land on (nearly) uniform output with the sum preserved."""
    rng = np.random.default_rng(seed)
    nu = 1.0 / n
    eta = np.full(n, nu, np.float32)
    eta += rng.uniform(-0.1 * nu, 0.1 * nu, size=n).astype(np.float32)
    eta = (eta / eta.sum()).astype(np.float32)
    out = np.asarray(proj.capped_simplex_project_bisect(
        jnp.asarray(eta), nu))
    _bisect_invariants(out, nu)
    np.testing.assert_allclose(out, nu, atol=2e-5)


def test_bisect_masked_empty_mask():
    """A class whose mask selects NOTHING must come back all-zero (no
    NaNs from the 0/0 rescale) without disturbing the other class."""
    from repro.core.projections import capped_bisect_masked
    rng = np.random.default_rng(11)
    n = 64
    eta = _rand_simplex(rng, n).astype(np.float32)
    nu = 1.5 / n
    masks = np.zeros((2, n), bool)
    masks[0, :] = True                       # class 0: everything
    out2 = np.asarray(capped_bisect_masked(
        jnp.asarray(eta), nu, jnp.asarray(masks),
        rounds=proj.BISECT_ROUNDS))
    _bisect_invariants(out2, nu)
    want = np.asarray(proj.capped_simplex_project_bisect(
        jnp.asarray(eta), nu))
    np.testing.assert_allclose(out2, want, atol=2e-6)
    # both masks empty -> all zeros, still finite
    none = np.asarray(capped_bisect_masked(
        jnp.asarray(eta), nu, jnp.zeros((1, n), bool),
        rounds=proj.BISECT_ROUNDS))
    assert np.all(np.isfinite(none))
    np.testing.assert_array_equal(none, 0.0)


@settings(max_examples=40, deadline=None)
@given(st.integers(16, 128),
       st.floats(1e-7, 1e-1),
       st.integers(0, 10_000))
def test_bisect_f32_mass_concentration(n, delta, seed):
    """Property form of the mass-concentration pin: (1-delta) of the
    mass on one entry, delta spread over the rest, across the f32 range
    where the sorted rule's prefix-sum Omega suffers catastrophic
    cancellation.  Assert the rescale invariants and agreement with the
    loop oracle (ground truth) -- NOT with the sorted rule."""
    rng = np.random.default_rng(seed)
    eta = rng.exponential(size=n).astype(np.float32)
    eta = eta / eta.sum() * delta
    j = rng.integers(n)
    eta[j] = 1.0 - (eta.sum() - eta[j])
    eta = eta.astype(np.float32)
    nu = 2.0 / n
    out = np.asarray(proj.capped_simplex_project_bisect(
        jnp.asarray(eta), nu))
    _bisect_invariants(out, nu)
    want = np.asarray(proj.capped_simplex_project_loop(
        jnp.asarray(eta), nu))
    np.testing.assert_allclose(out, want, atol=2e-5)


# ------------------------------------------------ entropy prox vs argmin
def test_entropy_prox_is_argmin():
    """Lemma 10: the closed form solves the prox problem (check by
    comparing against a dense numeric minimization over the simplex)."""
    import scipy.optimize as so
    rng = np.random.default_rng(0)
    n, d, gamma, tau = 12, 16.0, 0.05, 3.0
    lam = _rand_simplex(rng, n)
    v = rng.normal(size=n)

    closed = np.exp(np.asarray(proj.entropy_prox(
        jnp.asarray(np.log(lam), jnp.float32), jnp.asarray(v, jnp.float32),
        gamma, tau, d)))

    def objective(u):
        u = np.maximum(u, 1e-12)
        h = np.sum(u * np.log(u))
        h_lam = np.sum(lam * np.log(lam))
        bregman = np.sum(u * np.log(u / lam)) - (u.sum() - lam.sum())
        return (np.dot(v, u) / d + gamma / d * h + bregman / tau)

    cons = [{"type": "eq", "fun": lambda u: u.sum() - 1}]
    r = so.minimize(objective, lam, bounds=[(1e-9, 1)] * n,
                    constraints=cons, options={"maxiter": 300})
    np.testing.assert_allclose(closed, r.x, atol=1e-3)


@settings(max_examples=40, deadline=None)
@given(st.integers(3, 200), st.integers(0, 10_000))
def test_entropy_prox_normalized(n, seed):
    rng = np.random.default_rng(seed)
    lam = _rand_simplex(rng, n)
    v = rng.normal(size=n) * 3
    out = proj.entropy_prox(jnp.asarray(np.log(lam), jnp.float32),
                            jnp.asarray(v, jnp.float32), 0.01, 10.0, 64.0)
    total = float(jnp.exp(out).sum())
    assert abs(total - 1.0) < 1e-4
