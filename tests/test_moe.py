"""MoE dispatch properties: combine weights, capacity dropping, load
balance aux, identity-expert check."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import moe
import pytest

# LM-side model/system tests dominate the full-suite runtime; the fast
# CI tier (scripts/ci.sh) deselects them with -m 'not slow'
pytestmark = pytest.mark.slow


def _cfg(**kw):
    base = get_config("deepseek-v2-lite-16b").reduced()
    return dataclasses.replace(base, **kw)


def test_moe_output_shape_and_finite():
    cfg = _cfg()
    params = moe.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.1
    out, aux = moe.moe_block(params, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.99    # E * sum f_e p_e >= 1 by Cauchy-Schwarz


def test_single_expert_equals_dense():
    """With E=1, top-1, generous capacity, routing is the identity and
    the MoE (sans shared experts) equals a plain GLU."""
    cfg = _cfg(moe_num_experts=1, moe_top_k=1, moe_num_shared=0,
               moe_capacity_factor=2.0)
    params = moe.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model)) * 0.1
    out, _ = moe.moe_block(params, x, cfg)
    ref = (jax.nn.silu(x @ params["expert_gate"][0])
           * (x @ params["expert_up"][0])) @ params["expert_down"][0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4)


def test_capacity_drops_overflow():
    """With capacity factor ~0 every routed token drops; only the shared
    experts contribute."""
    cfg = _cfg(moe_capacity_factor=1e-6)
    params = moe.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model)) * 0.1
    out, _ = moe.moe_block(params, x, cfg)
    sp = params["shared"]
    shared_only = (jax.nn.silu(x @ sp["w_gate"])
                   * (x @ sp["w_up"])) @ sp["w_down"]
    # capacity >= 1 is enforced, so at most a couple tokens per expert
    # survive; most of the output is the shared path
    diff = np.abs(np.asarray(out - shared_only))
    base = np.abs(np.asarray(shared_only)).max() + 1e-9
    assert np.median(diff) / base < 0.5


def test_grouping_divides():
    assert moe._num_groups(1_048_576, 32) == 32
    assert moe._num_groups(128, 32) == 32
    assert moe._num_groups(30, 32) == 30
    assert moe._num_groups(31, 32) == 31


def test_moe_gradients_flow():
    cfg = _cfg()
    params = moe.init_moe(jax.random.key(0), cfg)

    def loss(p):
        x = jnp.ones((1, 8, cfg.d_model)) * 0.1
        out, aux = moe.moe_block(p, x, cfg)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    gnorm = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    assert float(jnp.abs(g["router"]).sum()) > 0   # router learns
