"""Reduced-scale dry-run: the full lower+compile+roofline pipeline on an
8-host-device mesh (the 512-device production sweep runs via
src/repro/launch/dryrun.py; its results live in experiments/dryrun)."""

import os
import subprocess
import sys

import pytest

# LM-side model/system tests dominate the full-suite runtime; the fast
# CI tier (scripts/ci.sh) deselects them with -m 'not slow'.  Also
# `dist`: these lower on a forced multi-device host mesh.
pytestmark = [pytest.mark.slow, pytest.mark.dist]

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import dataclasses
import jax
from repro.configs import get_config
from repro.launch import specs as S
from repro.launch.shapes import InputShape
from repro.models import sharding as shd
from repro.utils import roofline as rl

arch = sys.argv[1]
kind = sys.argv[2]
cfg = get_config(arch).reduced()
mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = InputShape("t", kind, 64, 8)
with mesh:
    fn, args = S.build_lowerable(cfg, shape, mesh)
    lowered = jax.jit(fn).lower(*args)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    roof = rl.analyze(compiled)
    assert mem.temp_size_in_bytes >= 0
    assert roof.flops >= 0
    print("DRYRUN_OK", arch, kind, roof.bottleneck)
"""


@pytest.mark.parametrize("arch,kind", [
    ("h2o-danube-1.8b", "train"),
    ("deepseek-v2-lite-16b", "train"),
    ("xlstm-125m", "train"),
    ("recurrentgemma-2b", "decode"),
    ("whisper-medium", "prefill"),
    ("qwen2-vl-7b", "train"),
    ("gemma-7b", "decode"),
])
def test_small_dryrun(arch, kind):
    env = dict(os.environ)
    # pin the subprocess to CPU: with JAX_PLATFORMS unset, a libtpu
    # build probes TPU metadata for minutes before falling back, and
    # --xla_force_host_platform_device_count only applies to cpu anyway
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", CODE, arch, kind],
        capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, timeout=600)
    assert "DRYRUN_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-4000:]
