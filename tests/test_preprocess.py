"""Pre-processing (Algorithm 1): FWHT + scaling properties."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_stub import given, settings, st

from repro.core import preprocess as pp


def test_fwht_matches_hadamard_matrix():
    d = 16
    h = np.array([[1.0]])
    while h.shape[0] < d:
        h = np.block([[h, h], [h, -h]])
    h /= np.sqrt(d)
    x = np.random.default_rng(0).normal(size=(5, d)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(pp.fwht(jnp.asarray(x))),
                               x @ h.T, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([2, 4, 8, 16, 64, 256]), st.integers(1, 20),
       st.integers(0, 9999))
def test_fwht_self_inverse(d, n, seed):
    """Normalized WHT is an involution (orthonormal + symmetric)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    np.testing.assert_allclose(np.asarray(pp.fwht(pp.fwht(x))),
                               np.asarray(x), atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([4, 16, 128]), st.integers(2, 12),
       st.integers(0, 9999))
def test_fwht_preserves_norms(d, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(pp.fwht(x)), axis=1),
        np.linalg.norm(np.asarray(x), axis=1), rtol=1e-4)


def test_preprocess_unit_ball_and_distance_preserved():
    rng = np.random.default_rng(1)
    xp = rng.normal(size=(20, 10)).astype(np.float32) * 3
    xm = rng.normal(size=(30, 10)).astype(np.float32) * 3 - 1
    pre = pp.preprocess(xp, xm, jax.random.key(0))
    norms = np.linalg.norm(np.asarray(pre.xp), axis=1)
    assert norms.max() <= 1.0 + 1e-5
    # orthonormal transform: pairwise distances scale uniformly
    d_orig = np.linalg.norm(xp[0] - xm[0])
    d_tr = np.linalg.norm(np.asarray(pre.xp[0] - pre.xm[0]))
    assert abs(d_tr - d_orig * float(pre.scale)) < 1e-4


def test_recover_direction_roundtrip():
    """w . (WD scale x) == recover_direction(w) . x for all x."""
    rng = np.random.default_rng(2)
    d = 12                      # not a power of two (padding exercised)
    xp = rng.normal(size=(8, d)).astype(np.float32)
    xm = rng.normal(size=(9, d)).astype(np.float32)
    pre = pp.preprocess(xp, xm, jax.random.key(3))
    w_t = jnp.asarray(rng.normal(size=pre.xp.shape[1]), jnp.float32)
    w_orig = np.asarray(pp.recover_direction(w_t, pre))
    lhs = np.asarray(pre.xp) @ np.asarray(w_t)      # transformed space
    rhs = xp @ w_orig                               # original space
    np.testing.assert_allclose(lhs, rhs, atol=1e-4)


def test_bucket_ladder_pow2_rungs():
    """bucket_length walks lane * 2^k; bucket_shape pairs it with the
    pow-2 coordinate rung."""
    assert [pp.bucket_length(n) for n in (1, 128, 129, 256, 300, 1000)] \
        == [128, 128, 256, 256, 512, 1024]
    assert pp.bucket_shape(90, 16) == (128, 16)
    assert pp.bucket_shape(600, 20) == (1024, 32)
    # the ladder never undershoots and pads at most 2x (above one lane)
    for n in (129, 257, 900, 4097):
        b = pp.bucket_length(n)
        assert b >= n and b < 2 * n + pp.LANE


def test_pack_points_to_pads_both_axes():
    rng = np.random.default_rng(0)
    xp = rng.normal(size=(9, 8)).astype(np.float32)
    xm = rng.normal(size=(12, 8)).astype(np.float32)
    pts = pp.pack_points_to(xp, xm, 256, 16)
    assert pts.x_t.shape == (16, 256)
    # real coordinates land unchanged; padding rows/slots are zero
    np.testing.assert_array_equal(np.asarray(pts.x_t[:8, :9]), xp.T)
    np.testing.assert_array_equal(np.asarray(pts.x_t[8:]), 0.0)
    np.testing.assert_array_equal(np.asarray(pts.x_t[:, 21:]), 0.0)
    sign = np.asarray(pts.sign)
    assert (sign[:9] == 1).all() and (sign[9:21] == -1).all()
    assert (sign[21:] == 0).all()
    import pytest
    with pytest.raises(ValueError):
        pp.pack_points_to(xp, xm, 256, 4)          # d_pad < d


def test_bucketed_solve_matches_plain_optimum():
    """Bucket padding (extra points AND extra coordinates) must not
    move the optimum: padding coordinates stay inert (w == 0 there)."""
    from repro.core import saddle
    rng = np.random.default_rng(3)
    xp = rng.normal(size=(20, 8)).astype(np.float32) * 0.2 + 0.3
    xm = rng.normal(size=(25, 8)).astype(np.float32) * 0.2 - 0.3
    plain = saddle.solve(xp, xm, num_iters=3000)
    # double the budget for the bucketed run: half its uniform
    # coordinate draws land on the 8 dead padding coordinates
    buck = saddle.solve(xp, xm, num_iters=6000, n_pad=256, d_pad=16)
    w = np.asarray(buck.state.w)
    np.testing.assert_array_equal(w[8:], 0.0)      # inert padding coords
    assert abs(plain.history[-1][1] - buck.history[-1][1]) < 5e-3


def test_transform_like_matches_original_transform():
    """The streaming-update intake path: transform_like applied to the
    ORIGINAL raw points reproduces the preprocess outputs exactly (same
    sign diagonal, same pinned scale, same coordinate padding)."""
    import pytest
    rng = np.random.default_rng(7)
    xp = rng.normal(size=(7, 10)).astype(np.float32)
    xm = rng.normal(size=(5, 10)).astype(np.float32)
    pre = pp.preprocess(jnp.asarray(xp), jnp.asarray(xm),
                        jax.random.key(3))
    np.testing.assert_allclose(np.asarray(pp.transform_like(pre, xp)),
                               np.asarray(pre.xp), atol=1e-6)
    np.testing.assert_allclose(np.asarray(pp.transform_like(pre, xm)),
                               np.asarray(pre.xm), atol=1e-6)
    with pytest.raises(ValueError, match="d_orig"):
        pp.transform_like(pre, xp[:, :4])          # wrong input dim
    with pytest.raises(ValueError, match="d_orig"):
        pp.transform_like(pre, xp[0])              # not 2-D


def test_repack_warm_duals_layout_and_uniform_seed():
    """Class segments are RE-PLACED at their new offsets (appending to
    eta shifts the whole xi block), carried entries keep their old log
    weights, new entries sit at the new uniform level, padding at
    NEG_INF."""
    import math

    import pytest

    from repro.core.engine import NEG_INF
    lam = np.array([-1.0, -2.0, -3.0, -4.0, -5.0], np.float32)
    out = pp.repack_warm_duals(lam, 2, 3, 4, 3, 16)
    np.testing.assert_array_equal(out[:2], lam[:2])          # carried eta
    np.testing.assert_allclose(out[2:4], -math.log(4))       # new eta
    np.testing.assert_array_equal(out[4:7], lam[2:5])        # shifted xi
    np.testing.assert_array_equal(out[7:], np.float32(NEG_INF))  # pad
    # n_old == 0 ignores the old vector: the replace-mode uniform reset
    uni = pp.repack_warm_duals(lam, 0, 0, 4, 3, 8)
    np.testing.assert_allclose(uni[:4], -math.log(4))
    np.testing.assert_allclose(uni[4:7], -math.log(3))
    with pytest.raises(ValueError, match="within new"):
        pp.repack_warm_duals(lam, 2, 3, 1, 3, 16)  # class shrank
    with pytest.raises(ValueError, match="n_pad_new"):
        pp.repack_warm_duals(lam, 2, 3, 9, 8, 16)  # overflows the pad
