"""Pre-processing (Algorithm 1): FWHT + scaling properties."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_stub import given, settings, st

from repro.core import preprocess as pp


def test_fwht_matches_hadamard_matrix():
    d = 16
    h = np.array([[1.0]])
    while h.shape[0] < d:
        h = np.block([[h, h], [h, -h]])
    h /= np.sqrt(d)
    x = np.random.default_rng(0).normal(size=(5, d)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(pp.fwht(jnp.asarray(x))),
                               x @ h.T, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([2, 4, 8, 16, 64, 256]), st.integers(1, 20),
       st.integers(0, 9999))
def test_fwht_self_inverse(d, n, seed):
    """Normalized WHT is an involution (orthonormal + symmetric)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    np.testing.assert_allclose(np.asarray(pp.fwht(pp.fwht(x))),
                               np.asarray(x), atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([4, 16, 128]), st.integers(2, 12),
       st.integers(0, 9999))
def test_fwht_preserves_norms(d, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(pp.fwht(x)), axis=1),
        np.linalg.norm(np.asarray(x), axis=1), rtol=1e-4)


def test_preprocess_unit_ball_and_distance_preserved():
    rng = np.random.default_rng(1)
    xp = rng.normal(size=(20, 10)).astype(np.float32) * 3
    xm = rng.normal(size=(30, 10)).astype(np.float32) * 3 - 1
    pre = pp.preprocess(xp, xm, jax.random.key(0))
    norms = np.linalg.norm(np.asarray(pre.xp), axis=1)
    assert norms.max() <= 1.0 + 1e-5
    # orthonormal transform: pairwise distances scale uniformly
    d_orig = np.linalg.norm(xp[0] - xm[0])
    d_tr = np.linalg.norm(np.asarray(pre.xp[0] - pre.xm[0]))
    assert abs(d_tr - d_orig * float(pre.scale)) < 1e-4


def test_recover_direction_roundtrip():
    """w . (WD scale x) == recover_direction(w) . x for all x."""
    rng = np.random.default_rng(2)
    d = 12                      # not a power of two (padding exercised)
    xp = rng.normal(size=(8, d)).astype(np.float32)
    xm = rng.normal(size=(9, d)).astype(np.float32)
    pre = pp.preprocess(xp, xm, jax.random.key(3))
    w_t = jnp.asarray(rng.normal(size=pre.xp.shape[1]), jnp.float32)
    w_orig = np.asarray(pp.recover_direction(w_t, pre))
    lhs = np.asarray(pre.xp) @ np.asarray(w_t)      # transformed space
    rhs = xp @ w_orig                               # original space
    np.testing.assert_allclose(lhs, rhs, atol=1e-4)
