"""Baseline algorithms agree with the QP oracle / each other."""

import numpy as np
import pytest

from repro.baselines import (dist_gilbert, gilbert, hogwild, mdm, pegasos,
                             qp_nusvm)


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(0)
    d = 24
    xp = rng.normal(size=(50, d)).astype(np.float32) * 0.1 + 0.3 / np.sqrt(d)
    xm = rng.normal(size=(60, d)).astype(np.float32) * 0.1 - 0.3 / np.sqrt(d)
    return xp, xm


def test_gilbert_vs_qp(problem, qp_oracle):
    xp, xm = problem
    opt = qp_oracle(xp, xm, nu=1.0)
    res = gilbert.solve(xp, xm, num_iters=3000)
    assert res.history[-1][1] <= opt * 1.05 + 1e-8
    assert res.history[-1][1] >= opt - 1e-6


def test_gilbert_weights_track_z(problem):
    xp, xm = problem
    res = gilbert.solve(xp, xm, num_iters=200)
    st = res.state
    z_from_weights = np.asarray(st.eta) @ xp - np.asarray(st.xi) @ xm
    np.testing.assert_allclose(z_from_weights, np.asarray(st.z), atol=1e-4)
    assert abs(np.asarray(st.eta).sum() - 1) < 1e-5
    assert abs(np.asarray(st.xi).sum() - 1) < 1e-5


def test_qp_nusvm_capped(problem, qp_oracle):
    xp, xm = problem
    nu = 1.0 / (0.75 * 50)
    opt = qp_oracle(xp, xm, nu=nu)
    st, hist = qp_nusvm.solve(xp, xm, nu=nu, num_iters=3000)
    assert hist[-1][1] <= opt * 1.03 + 1e-8
    eta = np.asarray(st.eta)
    assert eta.max() <= nu + 1e-6 and abs(eta.sum() - 1) < 1e-5


def test_project_capped_simplex_exact():
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    y = rng.normal(size=40)
    nu = 0.08
    v = np.asarray(qp_nusvm.project_capped_simplex(jnp.asarray(
        y, jnp.float32), nu))
    assert abs(v.sum() - 1) < 1e-5
    assert v.min() >= -1e-7 and v.max() <= nu + 1e-6
    # KKT: entries strictly inside (0, nu) share a common shift y_i - v_i
    inner = (v > 1e-6) & (v < nu - 1e-6)
    if inner.sum() >= 2:
        shifts = y[inner] - v[inner]
        assert np.ptp(shifts) < 1e-4


def test_mdm_vs_gilbert_min_norm(problem):
    xp, xm = problem
    pts = xp - xm.mean(0)
    _, hist_m = mdm.solve(pts, num_iters=3000)
    res_g = gilbert.solve(pts, np.zeros((1, pts.shape[1]), np.float32),
                          num_iters=3000)
    assert abs(hist_m[-1][1] - res_g.history[-1][1]) < 2e-3


def test_pegasos_separates(problem):
    xp, xm = problem
    x = np.vstack([xp, xm])
    y = np.r_[np.ones(len(xp)), -np.ones(len(xm))]
    st, hist = pegasos.solve(x, y, num_iters=3000, lam=1e-3)
    assert hist[-1][2] >= 0.95      # training accuracy


def test_dist_gilbert_matches_serial(problem):
    xp, xm = problem
    res = gilbert.solve(xp, xm, num_iters=500)
    st, hist, comm = dist_gilbert.solve(xp, xm, k=6, num_iters=500)
    assert abs(hist[-1][2] - res.history[-1][1]) < 1e-4
    # O(kd) per iteration (Liu et al.) -- vs Saddle-DSVC's O(k)
    assert comm.scalars_per_iteration() == 3 * 6 * xp.shape[1]


def test_hogwild_learns(problem):
    xp, xm = problem
    x = np.vstack([xp, xm])
    y = np.r_[np.ones(len(xp)), -np.ones(len(xm))]
    st, hist, comm = hogwild.solve(x, y, k=4, num_iters=2000)
    assert hist[-1][2] >= 0.9
    assert comm.scalars_per_iteration() == 2 * 4 * x.shape[1]
