"""Deliverable (f): per-architecture smoke tests.  Each assigned arch is
instantiated as a REDUCED variant of the same family (<=2 periods,
d_model<=512, <=4 experts) and runs one forward + one train step on CPU,
asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tf
from repro.train import optimizer as opt
from repro.train import steps

# LM-side model/system tests dominate the full-suite runtime; the fast
# CI tier (scripts/ci.sh) deselects them with -m 'not slow'
pytestmark = pytest.mark.slow

ASSIGNED = [
    "qwen2-vl-7b", "chatglm3-6b", "xlstm-125m", "recurrentgemma-2b",
    "deepseek-v2-236b", "deepseek-v2-lite-16b", "gemma-7b",
    "deepseek-67b", "whisper-medium", "h2o-danube-1.8b",
]


def _batch(cfg, key, b=2, s=16):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if cfg.vision_embeds:
        batch["vision_embeds"] = jnp.full((b, s, cfg.d_model), 0.01,
                                          jnp.float32)
        batch["vision_mask"] = jnp.zeros((b, s), bool).at[:, :4].set(True)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, s))
    if cfg.is_encoder_decoder:
        batch["enc_frames"] = jnp.full((b, cfg.enc_frames, cfg.d_model),
                                       0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    params = tf.init_lm(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    kw = {k: v for k, v in batch.items() if k not in ("tokens", "targets")}
    logits, _, aux = tf.forward(params, cfg, batch["tokens"], **kw)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=2)
    state = steps.init_train_state(jax.random.key(0), cfg, ocfg)
    ts = jax.jit(steps.make_train_step(cfg, ocfg))
    batch = _batch(cfg, jax.random.key(2))
    state, metrics = ts(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # a second step must also be finite (optimizer state valid)
    state, metrics = ts(state, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b", "xlstm-125m",
                                  "recurrentgemma-2b", "gemma-7b-swa"])
def test_reduced_bounds(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512
    assert cfg.num_layers <= 4          # <= 2 periods
    if cfg.moe_num_experts:
        assert cfg.moe_num_experts <= 4


def test_unrolled_matches_scanned():
    """cfg.scan_layers=False (roofline mode) is numerically identical."""
    import dataclasses
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = tf.init_lm(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0,
                              cfg.vocab_size)
    a, _, _ = tf.forward(params, cfg, toks)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    b, _, _ = tf.forward(params, cfg2, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
