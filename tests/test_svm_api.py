"""High-level SaddleSVC / SaddleNuSVC behaviour (fit/predict/b offset)."""

import numpy as np
import pytest

from repro.core.svm import SaddleNuSVC, SaddleSVC, split_classes


def test_hard_margin_separable(blobs_separable):
    ds = blobs_separable
    clf = SaddleSVC(eps=1e-3, beta=0.1, num_iters=8000).fit(ds.x, ds.y)
    assert clf.score(ds.x, ds.y) >= 0.99
    assert clf.margin_ > 0


def test_offset_bisects_closest_points(blobs_separable):
    """Footnote 2: b = w.(A eta + B xi)/2 -- the decision boundary sits
    midway between the two closest (weighted) hull points."""
    ds = blobs_separable
    clf = SaddleSVC(eps=1e-3, beta=0.1, num_iters=8000).fit(ds.x, ds.y)
    xp = ds.x[ds.y > 0]
    xm = ds.x[ds.y < 0]
    p_near = clf.eta_ @ xp
    q_near = clf.xi_ @ xm
    fp = p_near @ clf.w_ - clf.b_
    fm = q_near @ clf.w_ - clf.b_
    np.testing.assert_allclose(fp, -fm, rtol=0.05, atol=1e-4)
    assert fp > 0 > fm


def test_nu_svm_overlapping(blobs_overlapping):
    ds = blobs_overlapping
    clf = SaddleNuSVC(alpha=0.85, eps=1e-3, beta=0.1,
                      num_iters=6000).fit(ds.x, ds.y)
    # gap=0.4/spread=0.5 blobs overlap heavily; Bayes accuracy ~0.78
    assert clf.score(ds.x, ds.y) >= 0.7
    nu = 1.0 / (0.85 * min((ds.y > 0).sum(), (ds.y < 0).sum()))
    assert clf.eta_.max() <= nu + 1e-5


def test_generalization(blobs_separable):
    tr, te = blobs_separable.split(test_frac=0.25, seed=3)
    clf = SaddleSVC(eps=1e-3, beta=0.1, num_iters=6000).fit(tr.x, tr.y)
    assert clf.score(te.x, te.y) >= 0.95


def test_explicit_nu():
    from repro.data import synthetic
    ds = synthetic.blobs(30, 30, 8, gap=0.5, spread=0.4, seed=7)
    clf = SaddleNuSVC(nu=0.1, num_iters=3000).fit(ds.x, ds.y)
    assert clf.eta_.max() <= 0.1 + 1e-5


def test_single_class_y_fails_fast():
    """A single-class y must raise a clear ValueError up front, not a
    shape blow-up inside pack_points."""
    x = np.random.default_rng(0).normal(size=(20, 4)).astype(np.float32)
    with pytest.raises(ValueError, match="both classes"):
        split_classes(x, np.ones(20))
    with pytest.raises(ValueError, match="both classes"):
        SaddleSVC(num_iters=10).fit(x, -np.ones(20))


def test_use_kernels_plumbed_through_fit(blobs_separable):
    """fit(use_kernels=True) must reach the Pallas backend and agree
    with the jnp backend (the engines are parity-tested; here we pin
    that the FRONT END actually forwards the flag)."""
    ds = blobs_separable
    a = SaddleSVC(num_iters=400, seed=3).fit(ds.x, ds.y)
    b = SaddleSVC(num_iters=400, seed=3, use_kernels=True).fit(ds.x, ds.y)
    np.testing.assert_allclose(a.w_, b.w_, atol=1e-5)
    np.testing.assert_allclose(a.b_, b.b_, atol=1e-5)
