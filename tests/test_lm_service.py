"""Continuous-batching LM service (repro.serve.lm_service): mid-decode
admission into freed KV lanes must reproduce solo ``generate``
token-for-token (full-attention caches: GQA and MLA absorbed decode),
lane reuse must not leak KV state, non-bucketable cache families must
take the exact fallback path, and warm services must never retrace."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tf
from repro.serve import engine
from repro.serve.lm_service import LMService

# LM-side tests dominate the full-suite runtime; the fast CI tier
# deselects them (the lm_serve bench covers this path in ci.sh fast)
pytestmark = [pytest.mark.slow, pytest.mark.serve]


def _model(arch):
    cfg = get_config(arch).reduced()
    return cfg, tf.init_lm(jax.random.key(0), cfg)


def _solo(params, cfg, prompt, steps, seed, temperature=0.0):
    return np.asarray(engine.generate(
        params, cfg, jnp.asarray(prompt, jnp.int32)[None], steps=steps,
        temperature=temperature, seed=seed))[0]


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, s) for s in lens]


@pytest.mark.parametrize("arch", ["gemma-7b", "deepseek-v2-lite-16b"])
def test_mid_decode_admission_matches_solo(arch):
    """A sequence admitted into a freed lane BETWEEN decode chunks --
    while another sequence is mid-decode -- must generate exactly the
    tokens of a solo ``generate`` at the same seed and prompt bucket,
    for both full-attention cache families (GQA, MLA absorbed)."""
    cfg, params = _model(arch)
    assert engine._can_bucket(cfg)
    p1, p2, p3 = _prompts(cfg, [6, 7, 11])      # buckets 8, 8, 16
    svc = LMService(params, cfg, num_slots=2, chunk_steps=4, max_len=48)
    assert svc.slot_mode
    r1 = svc.submit(p1, steps=12, seed=3)
    assert svc.step() == []                     # chunk 1: only r1 runs
    r2 = svc.submit(p2, steps=8, seed=5)        # joins mid-decode
    r3 = svc.submit(p3, steps=6, seed=7)        # waits for a freed lane
    res = svc.run()
    for rid, p, steps, seed in [(r1, p1, 12, 3), (r2, p2, 8, 5),
                                (r3, p3, 6, 7)]:
        np.testing.assert_array_equal(res[rid].tokens,
                                      _solo(params, cfg, p, steps, seed))
    assert res[r2].admitted_chunk > 0           # genuinely mid-decode
    assert res[r3].admitted_chunk > res[r2].admitted_chunk


def test_freed_lane_reuse_leaks_no_kv_state():
    """With ONE lane, the second request reuses the first's lane; the
    admit-time overwrite (cache, index, position, PRNG chain) must
    make it indistinguishable from a fresh service."""
    cfg, params = _model("gemma-7b")
    p1, p2 = _prompts(cfg, [5, 13], seed=1)     # different buckets too
    svc = LMService(params, cfg, num_slots=1, chunk_steps=4, max_len=48)
    a = svc.generate(p1, 8, seed=11)
    b = svc.generate(p2, 8, seed=12)
    np.testing.assert_array_equal(a.tokens, _solo(params, cfg, p1, 8, 11))
    np.testing.assert_array_equal(b.tokens, _solo(params, cfg, p2, 8, 12))


def test_temperature_sampling_replays_solo_chain():
    """temperature > 0: each lane's per-slot PRNG chain must replay
    the solo sampling schedule (one split per token), not just match
    greedily."""
    cfg, params = _model("gemma-7b")
    p1, p2 = _prompts(cfg, [6, 7], seed=2)
    svc = LMService(params, cfg, num_slots=2, chunk_steps=3, max_len=32,
                    temperature=0.7)
    r1 = svc.submit(p1, steps=9, seed=21)
    svc.step()
    r2 = svc.submit(p2, steps=5, seed=22)       # mid-decode
    res = svc.run()
    for rid, p, steps, seed in [(r1, p1, 9, 21), (r2, p2, 5, 22)]:
        np.testing.assert_array_equal(
            res[rid].tokens,
            _solo(params, cfg, p, steps, seed, temperature=0.7))


def test_zero_recompiles_after_warmup():
    """After one pass has warmed the decode chunk and every prompt
    bucket, further traffic -- including mid-decode admissions and
    idle eviction/re-creation of the lane table -- must be 100%
    compile-cache hits."""
    cfg, params = _model("gemma-7b")
    p1, p2 = _prompts(cfg, [6, 12], seed=3)     # buckets 8 and 16
    svc = LMService(params, cfg, num_slots=2, chunk_steps=4, max_len=48)
    svc.submit(p1, steps=8, seed=0)
    svc.submit(p2, steps=6, seed=1)
    svc.run()                                   # warm-up
    compiles = svc.stats["compiles"]
    snap = dict(engine.trace_counts)
    svc.submit(p1, steps=8, seed=4)
    svc.step()
    svc.submit(p2, steps=6, seed=5)             # mid-decode admission
    svc.run()
    assert svc.stats["compiles"] == compiles
    delta = {k: v - snap.get(k, 0) for k, v in engine.trace_counts.items()
             if v != snap.get(k, 0)}
    assert delta == {}, f"recompile after warm-up: {delta}"
    calls = svc.stats
    assert calls["cache_hits"] == calls["chunk_calls"] - compiles


def test_fallback_families_route_through_solo_generate():
    """Ring-buffer / recurrent / enc-dec caches cannot take the
    slot-granular path; the service must fall back to exact solo
    generation while preserving scheduler queue order."""
    cfg, params = _model("recurrentgemma-2b")
    assert not engine._can_bucket(cfg)
    p1, p2 = _prompts(cfg, [6, 9], seed=4)
    svc = LMService(params, cfg, num_slots=2, chunk_steps=4)
    assert not svc.slot_mode
    r1 = svc.submit(p1, steps=5, seed=8)
    r2 = svc.submit(p2, steps=5, seed=9, deadline=1.0)  # jumps the queue
    res = svc.run()
    np.testing.assert_array_equal(res[r1].tokens,
                                  _solo(params, cfg, p1, 5, 8))
    np.testing.assert_array_equal(res[r2].tokens,
                                  _solo(params, cfg, p2, 5, 9))
    done = [rid for rid, _ in svc.latencies]
    assert done.index(r2) < done.index(r1)      # deadline served first


def test_capacity_validated_at_submit():
    cfg, params = _model("gemma-7b")
    svc = LMService(params, cfg, num_slots=2, chunk_steps=4, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        svc.submit(np.zeros(9, np.int32), steps=9)   # bucket 16 + 9 > 16
    with pytest.raises(ValueError, match="1-D"):
        svc.submit(np.zeros((1, 4), np.int32), steps=2)


def test_deadline_request_admitted_before_slack_backlog():
    """Scheduler urgency flows through the LM adapter: with one lane
    and a backlog, a deadline-tagged request is admitted next even
    though it arrived last."""
    cfg, params = _model("gemma-7b")
    p = _prompts(cfg, [5, 5, 5], seed=5)
    svc = LMService(params, cfg, num_slots=1, chunk_steps=4, max_len=32)
    r0 = svc.submit(p[0], steps=4, seed=0)
    svc.step()                                  # r0 occupies the lane
    svc.submit(p[1], steps=4, seed=1)           # slack backlog
    rid_d = svc.submit(p[2], steps=4, seed=2, deadline=0.5)
    svc.run()
    done = [rid for rid, _ in svc.latencies]
    assert done == [r0, rid_d, done[-1]]        # jumps the slack queue
