"""Scheduler-core properties (repro.serve.scheduler): urgency order
(FIFO within equal priority, deadline before slack, priority classes),
starvation-freedom of the oldest-first policy under sustained backlog,
round-robin bit-compatibility with PR 4's ``_pick_batch``, admission /
release / eviction lifecycle, latency stamps and compile accounting.

Property tests use hypothesis when installed (see
tests/_hypothesis_stub.py); each property also has a deterministic
anchor test so the invariants stay covered on the bare seed image.
"""

import collections

import pytest

from _hypothesis_stub import given, settings, st
from repro.serve.scheduler import (CompileStats, OldestFirstPolicy,
                                   RoundRobinPolicy, Scheduler)

pytestmark = pytest.mark.serve


# ---------------------------------------------------------------- helpers
class _RefRoundRobin:
    """PR 4's ``SolverService._pick_batch`` verbatim (cursor over the
    insertion-ordered batch list), kept as the compatibility oracle."""

    def __init__(self):
        self._rr = 0

    def pick(self, has_work: list[bool]):
        for i in range(len(has_work)):
            j = (self._rr + i) % len(has_work)
            if has_work[j]:
                self._rr = j + 1
                return j
        return None


def _drain_order(sched, key="g"):
    """Admit every queued ticket of one group through a 1-lane cycle;
    returns rids in admission order."""
    order = []
    g = sched.group(key)
    while g.has_work():
        for lane, t in sched.admit(g):
            order.append(t.rid)
            sched.release(g, lane)
    return order


# ------------------------------------------------------- admission order
def test_fifo_within_equal_priority():
    sched = Scheduler(num_slots=1)
    for rid in range(7):
        sched.submit("g", rid)
    assert _drain_order(sched) == list(range(7))


def test_deadline_tagged_never_after_slack():
    sched = Scheduler(num_slots=1)
    sched.submit("g", 0)                       # slack, arrives first
    sched.submit("g", 1, deadline=9.0)
    sched.submit("g", 2)
    sched.submit("g", 3, deadline=2.0)
    # all deadline-tagged first (earliest deadline first), then FIFO
    assert _drain_order(sched) == [3, 1, 0, 2]


def test_priority_orders_within_deadline_class():
    sched = Scheduler(num_slots=1)
    sched.submit("g", 0, priority=0)
    sched.submit("g", 1, priority=5)
    sched.submit("g", 2, priority=5)
    sched.submit("g", 3, priority=1)
    assert _drain_order(sched) == [1, 2, 3, 0]


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.booleans()),
                min_size=1, max_size=40))
def test_admission_order_properties(reqs):
    """For ANY mix of priorities and deadline tags: (a) every
    deadline-tagged ticket is admitted before every slack one, (b)
    admission is FIFO within (deadline-tag, priority) classes."""
    sched = Scheduler(num_slots=1)
    info = {}
    for rid, (prio, tagged) in enumerate(reqs):
        sched.submit("g", rid, priority=prio,
                     deadline=1.0 if tagged else None)
        info[rid] = (prio, tagged)
    order = _drain_order(sched)
    assert sorted(order) == sorted(info)
    seen_slack = False
    last_in_class = {}
    for rid in order:
        prio, tagged = info[rid]
        if not tagged:
            seen_slack = True
        assert not (tagged and seen_slack), \
            f"deadline-tagged {rid} scheduled after a slack ticket"
        cls = (tagged, prio)
        assert last_in_class.get(cls, -1) < rid, \
            f"FIFO violated within class {cls}: {order}"
        last_in_class[cls] = rid


# ------------------------------------------------- starvation / fairness
def _backlogged_rounds(policy, groups=3, rounds=60):
    """Sustained backlog on every group: each scheduling round runs one
    group's 'chunk' (completing its running ticket) and immediately
    refills that group's queue.  Returns the picked group keys."""
    sched = Scheduler(num_slots=1, policy=policy)
    rid = 0
    for gk in range(groups):
        for _ in range(2):
            sched.submit(gk, rid)
            rid += 1
    picked = []
    for _ in range(rounds):
        g = sched.next_group()
        assert g is not None
        picked.append(g.key)
        sched.admit(g)
        for lane in list(g.slots):
            sched.release(g, lane)
        sched.submit(g.key, rid)      # the backlog never drains
        rid += 1
    return picked


@pytest.mark.parametrize("policy", ["oldest", "round_robin"])
def test_no_group_starves_under_sustained_backlog(policy):
    picked = _backlogged_rounds(policy, groups=3, rounds=60)
    counts = collections.Counter(picked)
    assert set(counts) == {0, 1, 2}, counts
    # every group keeps getting turns in every window, not just once
    for start in range(0, 60, 10):
        window = collections.Counter(picked[start:start + 10])
        assert set(window) == {0, 1, 2}, (start, window)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 5), st.integers(0, 1))
def test_backlog_starvation_property(groups, policy_idx):
    """Under sustained backlog no group waits more than ~2*groups
    rounds between turns, for BOTH policies."""
    policy = ["oldest", "round_robin"][policy_idx]
    picked = _backlogged_rounds(policy, groups=groups,
                                rounds=12 * groups)
    last = {gk: -1 for gk in range(groups)}
    for i, gk in enumerate(picked):
        for other, seen in last.items():
            assert i - seen <= 2 * groups + 1, \
                f"group {other} starved around round {i}: {picked}"
        last[gk] = i


def test_oldest_first_prefers_globally_oldest_group():
    sched = Scheduler(num_slots=2, policy="oldest")
    sched.submit("a", 0)
    sched.submit("b", 1)
    sched.submit("a", 2)
    assert sched.next_group().key == "a"       # rid 0 is oldest
    g = sched.group("a")
    sched.admit(g)
    for lane in list(g.slots):
        sched.release(g, lane)
    sched.evict_idle(g)
    assert sched.next_group().key == "b"


def test_oldest_first_runs_running_work_without_queue():
    """A group with running slots but an empty queue still gets
    chunks (its running tickets carry their urgency)."""
    sched = Scheduler(num_slots=1, policy="oldest")
    sched.submit("a", 0)
    g = sched.group("a")
    sched.admit(g)
    assert g.queued == 0 and g.fill == 1
    assert sched.next_group() is g


# ------------------------------------------------ round-robin bit-compat
def _compare_rr(script):
    """Replay an add/drain/refill script against both the policy and
    the PR 4 reference; the picked indices must match exactly."""
    sched = Scheduler(num_slots=1, policy="round_robin")
    ref = _RefRoundRobin()
    keys = []
    rid = 0
    for action in script:
        if action == -1 or not keys:           # add a new group
            k = len(keys)
            keys.append(k)
            sched.submit(k, rid)
            rid += 1
            continue
        gk = keys[action % len(keys)]
        if action % 2:                          # refill that group
            sched.submit(gk, rid)
            rid += 1
        # one scheduling round
        groups = sched.groups
        has_work = [g.has_work() for g in groups]
        want = ref.pick(has_work)
        got = sched.next_group()
        if want is None:
            assert got is None
        else:
            assert got is groups[want], (has_work, want)
            sched.admit(got)
            for lane in list(got.slots):       # complete => may drain
                sched.release(got, lane)


def test_round_robin_reproduces_pr4_pick_batch():
    _compare_rr([-1, 0, -1, 1, 0, -1, 2, 2, 1, 0, 4, 3, 5, 1])


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(-1, 7), min_size=1, max_size=60))
def test_round_robin_bit_compat_property(script):
    _compare_rr(script)


def test_round_robin_skips_empty_groups_and_advances_cursor():
    sched = Scheduler(num_slots=1, policy="round_robin")
    for gk in (0, 1, 2):
        sched.submit(gk, gk)
    picks = []
    for _ in range(6):
        g = sched.next_group()
        picks.append(g.key)                   # queues never drain here
    assert picks == [0, 1, 2, 0, 1, 2]


# --------------------------------------------------- lifecycle / stats
def test_admit_fills_free_lanes_in_order_and_caps_at_slots():
    sched = Scheduler(num_slots=2)
    for rid in range(5):
        sched.submit("g", rid)
    g = sched.group("g")
    got = sched.admit(g)
    assert [(lane, t.rid) for lane, t in got] == [(0, 0), (1, 1)]
    assert sched.admit(g) == []               # no free lane
    sched.release(g, 0)
    got = sched.admit(g)
    assert [(lane, t.rid) for lane, t in got] == [(0, 2)]


def test_release_records_latency_and_eviction_drops_group():
    sched = Scheduler(num_slots=1)
    sched.submit("g", 7)
    g = sched.group("g")
    sched.admit(g)
    assert not sched.evict_idle(g)            # still has running work
    t = sched.release(g, 0)
    assert t.rid == 7
    assert [rid for rid, _ in sched.latencies] == [7]
    assert sched.latencies[0][1] >= 0.0
    assert sched.evict_idle(g) and not sched.groups
    assert sched.latency_percentiles(50.0)    # non-empty after release


def test_compile_stats_attribute_only_own_deltas():
    counter = collections.Counter()
    stats = CompileStats()
    with stats.chunk("k", counter):
        counter["k"] += 1                     # a compile we caused
    counter["k"] += 5                         # someone else's traces
    with stats.chunk("k", counter):
        pass                                  # cache hit
    assert stats.as_dict() == {"chunk_calls": 2, "compiles": 1,
                               "cache_hits": 1}


def test_policy_objects_accepted_directly():
    assert Scheduler(1, policy=OldestFirstPolicy()).next_group() is None
    assert Scheduler(1, policy=RoundRobinPolicy()).next_group() is None
