"""Training runtime: loss decreases, optimizer math, checkpoint
round-trip."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.train import checkpoint, optimizer as opt, steps
import pytest

# LM-side model/system tests dominate the full-suite runtime; the fast
# CI tier (scripts/ci.sh) deselects them with -m 'not slow'
pytestmark = pytest.mark.slow


def test_loss_decreases_on_fixed_batch():
    cfg = get_config("chatglm3-6b").reduced()
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=4)
    state = steps.init_train_state(jax.random.key(0), cfg, ocfg)
    ts = jax.jit(steps.make_train_step(cfg, ocfg))
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    losses = []
    for _ in range(10):
        state, m = ts(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7


def test_adamw_direction():
    """Single-parameter sanity: AdamW moves against the gradient."""
    params = {"w": jnp.asarray([1.0, -2.0])}
    ocfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    st = opt.init(params, ocfg)
    grads = {"w": jnp.asarray([1.0, -1.0])}
    new, st, gnorm = opt.apply(grads, st, params, ocfg)
    assert new["w"][0] < params["w"][0]
    assert new["w"][1] > params["w"][1]
    assert abs(float(gnorm) - np.sqrt(2)) < 1e-5


def test_grad_clip():
    params = {"w": jnp.zeros((3,))}
    ocfg = opt.AdamWConfig(lr=1.0, grad_clip=0.5, weight_decay=0.0,
                           warmup_steps=1)
    st = opt.init(params, ocfg)
    grads = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, gnorm = opt.apply(grads, st, params, ocfg)
    assert float(gnorm) == 100.0       # reported pre-clip


def test_bf16_state_dtype():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    ocfg = opt.AdamWConfig(state_dtype="bfloat16")
    st = opt.init(params, ocfg)
    assert st.m["w"].dtype == jnp.bfloat16
    assert st.master["w"].dtype == jnp.float32


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("xlstm-125m").reduced()
    ocfg = opt.AdamWConfig()
    state = steps.init_train_state(jax.random.key(0), cfg, ocfg)
    path = str(tmp_path / "ckpt.npz")
    checkpoint.save(path, state.params)
    like = jax.tree.map(jnp.zeros_like, state.params)
    back = checkpoint.restore(path, like)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_cross_entropy_masks_padded_vocab():
    logits = jnp.zeros((1, 2, 8))
    # logits uniform over 8, but only 5 real classes -> ce = log 5
    targets = jnp.asarray([[0, 4]])
    ce = steps.cross_entropy(logits, targets, vocab_size=5)
    assert abs(float(ce) - np.log(5)) < 1e-5
