"""Continuous batching over the slot-batched solver engine: mid-run
admission parity, slot-reuse isolation, gap early stop, compile-cache
discipline (repro.serve.solver_service)."""

import jax
import numpy as np
import pytest

from repro.core import engine
from repro.core import preprocess as pp
from repro.core import saddle
from repro.core.svm import recover_hyperplane, split_classes
from repro.data import synthetic
from repro.serve.scheduler import RequestFailure, Status
from repro.serve.solver_service import (FitRequest, SolverService,
                                        UpdateRequest)

pytestmark = pytest.mark.serve

C = 40      # service chunk length == solo record_every (parity contract)


def _solo(x, y, seed, nu, num_iters):
    """Reference: solo saddle.solve at the SAME bucket and chunk
    schedule as the service, through the same svm.py recovery path."""
    xp, xm = split_classes(x, y)
    k_pre, _ = jax.random.split(jax.random.key(seed))
    pre = pp.preprocess(xp, xm, k_pre)
    n_b, d_b = pp.bucket_shape(len(xp) + len(xm), pre.xp.shape[1])
    res = saddle.solve(pre.xp, pre.xm, nu=nu, num_iters=num_iters,
                       record_every=C, seed=seed, n_pad=n_b, d_pad=d_b)
    st = res.state
    eta = np.exp(np.asarray(st.log_eta))
    xi = np.exp(np.asarray(st.log_xi))
    w, b, *_ = recover_hyperplane(pre, eta, xi, pre.xp, pre.xm)
    return w, b


@pytest.fixture(scope="module")
def two_problems():
    ds1 = synthetic.blobs(40, 50, 16, gap=1.2, spread=0.15, seed=0)
    ds2 = synthetic.blobs(35, 45, 16, gap=0.8, spread=0.3, seed=2)
    return ds1, ds2       # both land in the (128, 16) bucket


@pytest.mark.parametrize("nu_frac", [0.0, 0.85])
def test_midrun_admission_parity(two_problems, nu_frac):
    """A request admitted into a PARTIALLY-BUSY batch mid-run must
    return the same (w, b) as a solo saddle.solve at the same seed and
    bucket -- for hard margin and nu-SVM."""
    ds1, ds2 = two_problems
    nu1 = nu_frac and 1.0 / (nu_frac * 40)
    nu2 = nu_frac and 1.0 / (nu_frac * 35)
    svc = SolverService(num_slots=4, chunk_steps=C)
    rid1 = svc.submit(FitRequest(x=ds1.x, y=ds1.y, num_iters=6 * C,
                                 seed=1, nu=nu1))
    assert svc.step() == []               # chunk 1: only request 1 runs
    rid2 = svc.submit(FitRequest(x=ds2.x, y=ds2.y, num_iters=3 * C,
                                 seed=9, nu=nu2))
    results = svc.run()
    for rid, ds, seed, nu, iters in [(rid1, ds1, 1, nu1, 6 * C),
                                     (rid2, ds2, 9, nu2, 3 * C)]:
        w, b = _solo(ds.x, ds.y, seed, nu, iters)
        np.testing.assert_allclose(results[rid].w, w, atol=1e-5)
        np.testing.assert_allclose(results[rid].b, b, atol=1e-5)
        assert results[rid].iterations == iters


def test_freed_slot_reuse_leaks_no_state(two_problems):
    """A lane freed by a finished request and reused by a NEW request
    must behave exactly like a fresh lane: same (w, b) as solo."""
    ds1, ds2 = two_problems
    svc = SolverService(num_slots=1, chunk_steps=C)   # forces reuse
    r1 = svc.fit(ds1.x, ds1.y, num_iters=2 * C, seed=11)
    r2 = svc.fit(ds2.x, ds2.y, num_iters=2 * C, seed=12)
    w2, b2 = _solo(ds2.x, ds2.y, 12, 0.0, 2 * C)
    np.testing.assert_allclose(r2.w, w2, atol=1e-5)
    np.testing.assert_allclose(r2.b, b2, atol=1e-5)
    # ...and the first occupant was not disturbed either
    w1, b1 = _solo(ds1.x, ds1.y, 11, 0.0, 2 * C)
    np.testing.assert_allclose(r1.w, w1, atol=1e-5)
    # reuse rode the warm executable: at most one compile for the whole
    # session (ZERO when a solo solve already warmed the key -- an S=1
    # service shares saddle.solve's executable, the "one engine" goal)
    assert svc.stats["compiles"] <= 1
    assert svc.stats["cache_hits"] >= svc.stats["chunk_calls"] - 1


def test_slot_batched_equals_sequential_batch(two_problems):
    """S requests solved CONCURRENTLY (one slot-batched executable)
    equal the same requests solved one at a time."""
    ds1, ds2 = two_problems
    svc = SolverService(num_slots=4, chunk_steps=C)
    rids = [svc.submit(FitRequest(x=ds.x, y=ds.y, num_iters=3 * C,
                                  seed=s))
            for ds, s in [(ds1, 0), (ds2, 1), (ds1, 2), (ds2, 3)]]
    results = svc.run()
    for rid, (ds, s) in zip(rids, [(ds1, 0), (ds2, 1), (ds1, 2),
                                   (ds2, 3)]):
        w, b = _solo(ds.x, ds.y, s, 0.0, 3 * C)
        np.testing.assert_allclose(results[rid].w, w, atol=1e-5)


def test_gap_early_stop_frees_slot(two_problems):
    """gap_tol > 0: an easy request converges and frees its lane well
    before its iteration budget; the result is still a good fit."""
    ds1, _ = two_problems
    svc = SolverService(num_slots=2, chunk_steps=C)
    res = svc.fit(ds1.x, ds1.y, num_iters=200 * C, seed=0, gap_tol=0.2)
    assert res.iterations < 200 * C
    acc = np.mean(np.where(ds1.x @ res.w - res.b >= 0, 1, -1) == ds1.y)
    assert acc >= 0.95


def test_fit_preserves_co_drained_results(two_problems):
    """fit() drains the whole queue; results of OTHER requests
    completed by that drain must stay claimable via result()."""
    ds1, ds2 = two_problems
    svc = SolverService(num_slots=2, chunk_steps=C)
    rid1 = svc.submit(FitRequest(x=ds1.x, y=ds1.y, num_iters=C, seed=4))
    r2 = svc.fit(ds2.x, ds2.y, num_iters=C, seed=5)
    r1 = svc.result(rid1)                   # must not raise
    assert r1.request_id == rid1 and r2.request_id != rid1
    # batches drained -> their device buffers were evicted
    assert not svc._batches


def test_single_class_rejected_at_submit(two_problems):
    ds1, _ = two_problems
    svc = SolverService(num_slots=2, chunk_steps=C)
    with pytest.raises(ValueError, match="both classes"):
        svc.submit(FitRequest(x=ds1.x, y=np.ones(len(ds1.y))))


def test_infeasible_nu_rejected_at_submit(two_problems):
    ds1, _ = two_problems
    svc = SolverService(num_slots=2, chunk_steps=C)
    with pytest.raises(ValueError, match="infeasible"):
        svc.submit(FitRequest(x=ds1.x, y=ds1.y, nu=1.0 / 200))


# ================================================================
# Streaming updates (warm starts)
# ================================================================
#
# Warm-vs-cold parity requires TRUE convergence: unlike the
# service-vs-solo pairs above (bit-identical trajectories at the same
# seed), a warm and a cold update follow DIFFERENT trajectories, so
# they only agree where the solver's fixed point is well attracting.
# Two regimes provide that:
#
#  * nu = 0 at eps = 1e-2: the larger entropy smoothing makes the MWU
#    fixed point strongly attracting -- warm and cold land ~2e-6 apart
#    in w.  (At the default eps=1e-3 the f32 last iterate freezes at
#    trajectory-dependent points ~4e-5 apart, and two COLD solves at
#    different seeds disagree by as much -- parity there would pin
#    solver noise, not the warm start.)
#  * nu = 1/min(n1, n2): the capped simplex degenerates to the single
#    point with every dual AT the cap, so the projection is active
#    every round and the optimum is unique -- warm and cold agree to
#    f32 exactness.  The update re-pins nu = 1/n_new, exercising the
#    per-update nu override.

def _stream_fit_then_update(ds, extra, *, nu0, nu1, iters, eps, warm,
                            seed=5, chunk=512):
    svc = SolverService(num_slots=2, chunk_steps=chunk)
    rid = svc.submit(FitRequest(x=ds.x, y=ds.y, seed=seed, nu=nu0,
                                eps=eps, num_iters=iters, stream=True))
    svc.run()
    ru = svc.submit_update(UpdateRequest(tenant=rid, x=extra.x,
                                         y=extra.y, warm=warm, nu=nu1,
                                         num_iters=iters))
    return svc.run()[ru], svc, rid


@pytest.mark.parametrize("case", ["nu0", "nu0_jump", "nu_pin",
                                  "nu_pin_jump"])
def test_streaming_warm_parity(case):
    """A warm-started update matches a cold re-fit of the SAME edited
    problem within the serving tolerance (atol 1e-5), for nu=0 and
    nu>0, in-bucket AND across a pow-2 rung jump (the *_jump cases
    start at 120+ points on the 128 rung and the append crosses into
    the 256 rung)."""
    if case in ("nu0", "nu_pin"):
        ds = synthetic.blobs(20 if case == "nu0" else 24, 24, 8,
                             gap=1.5, spread=0.12, seed=1)
        extra = synthetic.blobs(2, 2, 8, gap=1.5, spread=0.12, seed=7)
    else:
        ds = synthetic.blobs(60, 64, 8, gap=1.5, spread=0.12, seed=1)
        extra = synthetic.blobs(3, 3, 8, gap=1.5, spread=0.12, seed=7)
        assert pp.bucket_length(len(ds.x)) == 128            # rung 0
        assert pp.bucket_length(len(ds.x) + len(extra.x)) == 256
    cfg = {
        "nu0": dict(nu0=0.0, nu1=None, iters=40_000, eps=1e-2),
        "nu0_jump": dict(nu0=0.0, nu1=None, iters=60_000, eps=1e-2),
        "nu_pin": dict(nu0=1 / 24, nu1=1 / 26, iters=20_000, eps=1e-3),
        "nu_pin_jump": dict(nu0=1 / 60, nu1=1 / 63, iters=30_000,
                            eps=1e-2),
    }[case]
    res_w, _, _ = _stream_fit_then_update(ds, extra, warm=True, **cfg)
    res_c, _, _ = _stream_fit_then_update(ds, extra, warm=False, **cfg)
    np.testing.assert_allclose(res_w.w, res_c.w, atol=1e-5)
    np.testing.assert_allclose(res_w.b, res_c.b, atol=1e-5)
    # both ran the update round's own full budget (t was reset)
    assert res_w.iterations == res_c.iterations == cfg["iters"]


def test_streaming_update_zero_recompile_contract():
    """trace_counts is UNCHANGED across update rounds: an in-bucket
    re-pack adds no trace immediately; a rung jump traces its (warmed)
    target-rung executable once and every later round -- in either
    rung -- adds nothing.  Also: an update landing EXACTLY on the
    bucket boundary stays in its rung."""
    ds = synthetic.blobs(60, 64, 8, gap=1.5, spread=0.12, seed=1)
    svc = SolverService(num_slots=2, chunk_steps=C)

    def upd(rid, m, seed):
        ex = synthetic.blobs(m, m, 8, gap=1.5, spread=0.12, seed=seed)
        ru = svc.submit_update(UpdateRequest(tenant=rid, x=ex.x,
                                             y=ex.y, num_iters=2 * C))
        res = svc.run()[ru]
        assert not isinstance(res, RequestFailure)
        return ru

    rid = svc.submit(FitRequest(x=ds.x, y=ds.y, seed=3, num_iters=2 * C,
                                stream=True))
    svc.run()
    snap0 = dict(engine.trace_counts)
    upd(rid, 1, 11)                     # 124 + 2 = 126: in-bucket
    upd(rid, 1, 12)                     # 128 EXACTLY: boundary, no jump
    assert dict(engine.trace_counts) == snap0, \
        "in-bucket update rounds must not trace anything new"
    upd(rid, 1, 13)                     # 130: jumps to the 256 rung
    snap1 = dict(engine.trace_counts)
    upd(rid, 2, 14)                     # post-jump rounds: pinned again
    upd(rid, 2, 15)
    assert dict(engine.trace_counts) == snap1, \
        "post-rung-jump update rounds must not trace anything new"


def test_streaming_warm_update_converges_faster():
    """The tentpole's point: with a duality-gap stop, a warm-started
    small append converges in far fewer iterations than a cold re-fit
    of the same edited problem."""
    ds = synthetic.blobs(20, 24, 8, gap=1.5, spread=0.12, seed=1)
    extra = synthetic.blobs(1, 1, 8, gap=1.5, spread=0.12, seed=7)
    iters = {}
    for warm in (True, False):
        svc = SolverService(num_slots=2, chunk_steps=256)
        rid = svc.submit(FitRequest(x=ds.x, y=ds.y, seed=5,
                                    num_iters=40_960, gap_tol=0.05,
                                    stream=True))
        svc.run()
        ru = svc.submit_update(UpdateRequest(tenant=rid, x=extra.x,
                                             y=extra.y, warm=warm))
        iters[warm] = svc.run()[ru].iterations
    assert iters[False] > 2 * iters[True], iters
    assert iters[True] < 40_960 and iters[False] < 40_960, \
        f"gap stop never fired, ratio is meaningless: {iters}"


def test_update_overflowing_ladder_fails_fast(two_problems):
    """An update that would overflow the service's bucket ladder is a
    fail-fast ValueError NAMING max_points at submit_update -- nothing
    is enqueued, no lane is quarantined, and the tenant keeps serving
    (its dataset unchanged by the rejected edit)."""
    ds1, _ = two_problems                      # 90 points, d=16
    svc = SolverService(num_slots=2, chunk_steps=C, max_points=128)
    rid = svc.submit(FitRequest(x=ds1.x, y=ds1.y, seed=1,
                                num_iters=2 * C, stream=True))
    svc.run()
    big = synthetic.blobs(30, 30, 16, gap=1.2, spread=0.15, seed=9)
    with pytest.raises(ValueError, match="max_points"):
        svc.submit_update(UpdateRequest(tenant=rid, x=big.x, y=big.y))
    assert not svc._sched.has_work()           # nothing enqueued
    small = synthetic.blobs(2, 2, 16, gap=1.2, spread=0.15, seed=9)
    ru = svc.submit_update(UpdateRequest(tenant=rid, x=small.x,
                                         y=small.y, num_iters=2 * C))
    assert not isinstance(svc.run()[ru], RequestFailure)


def test_update_nu_refeasibility(two_problems):
    """nu feasibility is RE-validated against the post-edit class
    sizes: an infeasible per-update override fails fast, and a replace
    that shrinks a class under the tenant's inherited cap fails fast;
    the rejected edit leaves the dataset untouched."""
    ds1, _ = two_problems                      # (40, 50)
    svc = SolverService(num_slots=2, chunk_steps=C)
    rid = svc.submit(FitRequest(x=ds1.x, y=ds1.y, seed=1, num_iters=C,
                                nu=1.0 / (0.85 * 40), stream=True))
    svc.run()
    ex = synthetic.blobs(2, 2, 16, gap=1.2, spread=0.15, seed=9)
    with pytest.raises(ValueError, match="infeasible"):
        svc.submit_update(UpdateRequest(tenant=rid, x=ex.x, y=ex.y,
                                        nu=1.0 / 200))
    tiny = synthetic.blobs(5, 5, 16, gap=1.2, spread=0.15, seed=9)
    with pytest.raises(ValueError, match="infeasible"):
        # inherited nu ~= 1/34 needs min class >= 34; replace gives 5
        svc.submit_update(UpdateRequest(tenant=rid, x=tiny.x, y=tiny.y,
                                        mode="replace"))
    assert not svc._sched.has_work()
    # the tenant still serves a pure warm re-fit of its ORIGINAL data
    ru = svc.submit_update(UpdateRequest(tenant=rid, num_iters=C))
    assert not isinstance(svc.run()[ru], RequestFailure)


def test_update_supersedes_inflight_request(two_problems):
    """A new update SUPERSEDES the tenant's in-flight request --
    queued or already running -- with a terminal SUPERSEDED status
    whose failure record names the superseding rid; the newest
    revision completes normally."""
    ds1, _ = two_problems
    ex = synthetic.blobs(2, 2, 16, gap=1.2, spread=0.15, seed=9)
    svc = SolverService(num_slots=1, chunk_steps=C)
    rid = svc.submit(FitRequest(x=ds1.x, y=ds1.y, seed=1,
                                num_iters=4 * C, stream=True))
    # still QUEUED (never stepped) -> superseded from the queue
    r2 = svc.submit_update(UpdateRequest(tenant=rid, x=ex.x, y=ex.y,
                                         num_iters=4 * C))
    assert svc.status(rid) is Status.SUPERSEDED
    f = svc.result(rid)
    assert isinstance(f, RequestFailure)
    assert f.status is Status.SUPERSEDED and f.attempts == 0
    assert f"superseded by update request {r2}" in f.reason
    # r2 RUNNING mid-budget -> superseded from its lane
    assert svc.step() == []
    assert svc.status(r2) is Status.RUNNING
    r3 = svc.submit_update(UpdateRequest(tenant=rid, num_iters=C))
    assert svc.status(r2) is Status.SUPERSEDED
    assert f"superseded by update request {r3}" in svc.result(r2).reason
    res = svc.run()[r3]
    assert not isinstance(res, RequestFailure)
    assert res.iterations == C                 # newest revision ran


def test_update_unknown_tenant_and_close_stream(two_problems):
    ds1, _ = two_problems
    svc = SolverService(num_slots=2, chunk_steps=C)
    with pytest.raises(KeyError, match="tenant"):
        svc.submit_update(UpdateRequest(tenant=123))
    # a NON-stream fit is not a tenant
    rid = svc.submit(FitRequest(x=ds1.x, y=ds1.y, seed=1, num_iters=C))
    svc.run()
    with pytest.raises(KeyError, match="tenant"):
        svc.submit_update(UpdateRequest(tenant=rid))
    # close_stream forgets the tenant's retained transform + state
    rs = svc.submit(FitRequest(x=ds1.x, y=ds1.y, seed=1, num_iters=C,
                               stream=True))
    svc.run()
    assert svc.close_stream(rs)
    assert not svc.close_stream(rs)
    with pytest.raises(KeyError, match="tenant"):
        svc.submit_update(UpdateRequest(tenant=rs))


def test_replace_mode_resets_to_new_problem():
    """mode="replace" swaps the whole dataset: the re-fit (carried w,
    dual mass reset to uniform) converges to the NEW problem's optimum
    under the tenant's FIXED transform -- matching a cold replace on an
    identical tenant (NOT a fresh fit of the new data: that would
    re-derive scale/signs and solve a differently-conditioned problem;
    pinning the transform is the warm-start contract).  The replaced
    problem still classifies its own data perfectly."""
    ds_a = synthetic.blobs(20, 24, 8, gap=1.5, spread=0.12, seed=1)
    ds_b = synthetic.blobs(22, 20, 8, gap=1.5, spread=0.12, seed=4)
    res = {}
    for warm in (True, False):
        svc = SolverService(num_slots=2, chunk_steps=512)
        rid = svc.submit(FitRequest(x=ds_a.x, y=ds_a.y, seed=5,
                                    eps=1e-2, num_iters=40_000,
                                    stream=True))
        svc.run()
        ru = svc.submit_update(UpdateRequest(tenant=rid, x=ds_b.x,
                                             y=ds_b.y, mode="replace",
                                             warm=warm))
        res[warm] = svc.run()[ru]
    np.testing.assert_allclose(res[True].w, res[False].w, atol=1e-5)
    np.testing.assert_allclose(res[True].b, res[False].b, atol=1e-5)
    got = res[True]
    acc = np.mean(np.where(ds_b.x @ got.w - got.b >= 0, 1, -1) == ds_b.y)
    assert acc == 1.0
