"""Continuous batching over the slot-batched solver engine: mid-run
admission parity, slot-reuse isolation, gap early stop, compile-cache
discipline (repro.serve.solver_service)."""

import jax
import numpy as np
import pytest

from repro.core import engine
from repro.core import preprocess as pp
from repro.core import saddle
from repro.core.svm import recover_hyperplane, split_classes
from repro.data import synthetic
from repro.serve.solver_service import FitRequest, SolverService

pytestmark = pytest.mark.serve

C = 40      # service chunk length == solo record_every (parity contract)


def _solo(x, y, seed, nu, num_iters):
    """Reference: solo saddle.solve at the SAME bucket and chunk
    schedule as the service, through the same svm.py recovery path."""
    xp, xm = split_classes(x, y)
    k_pre, _ = jax.random.split(jax.random.key(seed))
    pre = pp.preprocess(xp, xm, k_pre)
    n_b, d_b = pp.bucket_shape(len(xp) + len(xm), pre.xp.shape[1])
    res = saddle.solve(pre.xp, pre.xm, nu=nu, num_iters=num_iters,
                       record_every=C, seed=seed, n_pad=n_b, d_pad=d_b)
    st = res.state
    eta = np.exp(np.asarray(st.log_eta))
    xi = np.exp(np.asarray(st.log_xi))
    w, b, *_ = recover_hyperplane(pre, eta, xi, pre.xp, pre.xm)
    return w, b


@pytest.fixture(scope="module")
def two_problems():
    ds1 = synthetic.blobs(40, 50, 16, gap=1.2, spread=0.15, seed=0)
    ds2 = synthetic.blobs(35, 45, 16, gap=0.8, spread=0.3, seed=2)
    return ds1, ds2       # both land in the (128, 16) bucket


@pytest.mark.parametrize("nu_frac", [0.0, 0.85])
def test_midrun_admission_parity(two_problems, nu_frac):
    """A request admitted into a PARTIALLY-BUSY batch mid-run must
    return the same (w, b) as a solo saddle.solve at the same seed and
    bucket -- for hard margin and nu-SVM."""
    ds1, ds2 = two_problems
    nu1 = nu_frac and 1.0 / (nu_frac * 40)
    nu2 = nu_frac and 1.0 / (nu_frac * 35)
    svc = SolverService(num_slots=4, chunk_steps=C)
    rid1 = svc.submit(FitRequest(x=ds1.x, y=ds1.y, num_iters=6 * C,
                                 seed=1, nu=nu1))
    assert svc.step() == []               # chunk 1: only request 1 runs
    rid2 = svc.submit(FitRequest(x=ds2.x, y=ds2.y, num_iters=3 * C,
                                 seed=9, nu=nu2))
    results = svc.run()
    for rid, ds, seed, nu, iters in [(rid1, ds1, 1, nu1, 6 * C),
                                     (rid2, ds2, 9, nu2, 3 * C)]:
        w, b = _solo(ds.x, ds.y, seed, nu, iters)
        np.testing.assert_allclose(results[rid].w, w, atol=1e-5)
        np.testing.assert_allclose(results[rid].b, b, atol=1e-5)
        assert results[rid].iterations == iters


def test_freed_slot_reuse_leaks_no_state(two_problems):
    """A lane freed by a finished request and reused by a NEW request
    must behave exactly like a fresh lane: same (w, b) as solo."""
    ds1, ds2 = two_problems
    svc = SolverService(num_slots=1, chunk_steps=C)   # forces reuse
    r1 = svc.fit(ds1.x, ds1.y, num_iters=2 * C, seed=11)
    r2 = svc.fit(ds2.x, ds2.y, num_iters=2 * C, seed=12)
    w2, b2 = _solo(ds2.x, ds2.y, 12, 0.0, 2 * C)
    np.testing.assert_allclose(r2.w, w2, atol=1e-5)
    np.testing.assert_allclose(r2.b, b2, atol=1e-5)
    # ...and the first occupant was not disturbed either
    w1, b1 = _solo(ds1.x, ds1.y, 11, 0.0, 2 * C)
    np.testing.assert_allclose(r1.w, w1, atol=1e-5)
    # reuse rode the warm executable: at most one compile for the whole
    # session (ZERO when a solo solve already warmed the key -- an S=1
    # service shares saddle.solve's executable, the "one engine" goal)
    assert svc.stats["compiles"] <= 1
    assert svc.stats["cache_hits"] >= svc.stats["chunk_calls"] - 1


def test_slot_batched_equals_sequential_batch(two_problems):
    """S requests solved CONCURRENTLY (one slot-batched executable)
    equal the same requests solved one at a time."""
    ds1, ds2 = two_problems
    svc = SolverService(num_slots=4, chunk_steps=C)
    rids = [svc.submit(FitRequest(x=ds.x, y=ds.y, num_iters=3 * C,
                                  seed=s))
            for ds, s in [(ds1, 0), (ds2, 1), (ds1, 2), (ds2, 3)]]
    results = svc.run()
    for rid, (ds, s) in zip(rids, [(ds1, 0), (ds2, 1), (ds1, 2),
                                   (ds2, 3)]):
        w, b = _solo(ds.x, ds.y, s, 0.0, 3 * C)
        np.testing.assert_allclose(results[rid].w, w, atol=1e-5)


def test_gap_early_stop_frees_slot(two_problems):
    """gap_tol > 0: an easy request converges and frees its lane well
    before its iteration budget; the result is still a good fit."""
    ds1, _ = two_problems
    svc = SolverService(num_slots=2, chunk_steps=C)
    res = svc.fit(ds1.x, ds1.y, num_iters=200 * C, seed=0, gap_tol=0.2)
    assert res.iterations < 200 * C
    acc = np.mean(np.where(ds1.x @ res.w - res.b >= 0, 1, -1) == ds1.y)
    assert acc >= 0.95


def test_fit_preserves_co_drained_results(two_problems):
    """fit() drains the whole queue; results of OTHER requests
    completed by that drain must stay claimable via result()."""
    ds1, ds2 = two_problems
    svc = SolverService(num_slots=2, chunk_steps=C)
    rid1 = svc.submit(FitRequest(x=ds1.x, y=ds1.y, num_iters=C, seed=4))
    r2 = svc.fit(ds2.x, ds2.y, num_iters=C, seed=5)
    r1 = svc.result(rid1)                   # must not raise
    assert r1.request_id == rid1 and r2.request_id != rid1
    # batches drained -> their device buffers were evicted
    assert not svc._batches


def test_single_class_rejected_at_submit(two_problems):
    ds1, _ = two_problems
    svc = SolverService(num_slots=2, chunk_steps=C)
    with pytest.raises(ValueError, match="both classes"):
        svc.submit(FitRequest(x=ds1.x, y=np.ones(len(ds1.y))))


def test_infeasible_nu_rejected_at_submit(two_problems):
    ds1, _ = two_problems
    svc = SolverService(num_slots=2, chunk_steps=C)
    with pytest.raises(ValueError, match="infeasible"):
        svc.submit(FitRequest(x=ds1.x, y=ds1.y, nu=1.0 / 200))
