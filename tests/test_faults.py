"""Fault-tolerant serving (repro.serve.faults + the services' status
contract): deterministic fault plans, slot quarantine with bit-for-bit
batch-mate invariance, bounded retry with backoff ordering, deadline
shedding, cancellation, intake validation and the status API.

The LM-side tests are additionally marked ``slow`` (model init
dominates); everything else runs in the fast tier and is re-run by the
``-m "faults and not slow"`` gate in scripts/ci.sh fast.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_stub import given, settings, st
from repro.configs import get_config
from repro.data import synthetic
from repro.models import transformer as tf
from repro.serve import engine as serve_engine
from repro.serve.faults import Fault, FaultInjector, FaultPlan
from repro.serve.lm_service import LMService
from repro.serve.scheduler import (RequestFailure, ResultNotReady,
                                   Scheduler, Status)
from repro.serve.solver_service import (FitRequest, SolverService,
                                        UpdateRequest)

pytestmark = [pytest.mark.faults, pytest.mark.serve]

C = 40      # service chunk length (same as tests/test_solver_service.py)


# ------------------------------------------------------------ fixtures
@pytest.fixture(scope="module")
def two_problems():
    ds1 = synthetic.blobs(40, 50, 16, gap=1.2, spread=0.15, seed=0)
    ds2 = synthetic.blobs(35, 45, 16, gap=0.8, spread=0.3, seed=2)
    return ds1, ds2       # both land in the (128, 16) bucket


def _nu(nu_frac, n1):
    return nu_frac and 1.0 / (nu_frac * n1)


def _run4(two_problems, nu_frac, injector=None, max_retries=0):
    """Four same-bucket requests through an S=3 service (the fourth
    waits for a freed lane).  Returns (rids, drained results, svc)."""
    ds1, ds2 = two_problems
    specs = [(ds1, 1, 40), (ds2, 9, 35), (ds1, 5, 40), (ds2, 13, 35)]
    svc = SolverService(num_slots=3, chunk_steps=C,
                        fault_injector=injector)
    rids = [svc.submit(FitRequest(x=ds.x, y=ds.y, num_iters=4 * C,
                                  seed=s, nu=_nu(nu_frac, n1),
                                  max_retries=max_retries))
            for ds, s, n1 in specs]
    return rids, svc.run(), svc


@pytest.fixture(scope="module")
def clean4(two_problems):
    """Fault-free reference runs of the _run4 workload, cached per
    nu_frac -- the bit-for-bit baseline the quarantine tests compare
    survivors against."""
    cache = {}

    def get(nu_frac):
        if nu_frac not in cache:
            rids, res, _ = _run4(two_problems, nu_frac)
            cache[nu_frac] = (rids, res)
        return cache[nu_frac]

    return get


def _assert_same_result(a, b):
    """Bit-for-bit equality of two FitResults (not allclose: lanes are
    vmapped independently, so a batch-mate's divergence must not move
    a single bit of anyone else's trajectory)."""
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
    assert a.b == b.b
    assert a.objective == b.objective
    assert a.iterations == b.iterations


# ----------------------------------------------------- fault plan/injector
def test_fault_plan_deterministic():
    """Same seed -> same plan, every time (replayable chaos); a
    different seed gives a different plan."""
    rids = list(range(24))
    kw = dict(poison_frac=0.5, delay_frac=0.5, max_chunk=3, max_delay=3)
    p1 = FaultPlan.generate(5, rids, **kw)
    assert p1 == FaultPlan.generate(5, rids, **kw)
    assert p1 != FaultPlan.generate(6, rids, **kw)
    assert p1.poisoned_rids() <= set(rids)
    for f in p1.faults:
        if f.kind == "poison":
            assert 0 <= f.at_chunk <= 3
    for delay in p1.delays().values():
        assert 1 <= delay <= 3
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("explode")


def test_injector_poison_fires_exactly_once():
    inj = FaultInjector(FaultPlan(
        seed=0, faults=(Fault("poison", rid=7, at_chunk=1),)))
    assert not inj.poison_due(7, 0)          # before its chunk
    assert not inj.poison_due(8, 5)          # untargeted rid
    assert inj.poison_due(7, 1)              # fires...
    assert not inj.poison_due(7, 2)          # ...once (one-shot)
    assert [f.rid for f in inj.fired] == [7]


# ------------------------------------------------- scheduler status core
def test_status_terminal_partition():
    assert not Status.PENDING.terminal and not Status.RUNNING.terminal
    for s in (Status.DONE, Status.FAILED, Status.CANCELLED,
              Status.DEADLINE_EXCEEDED):
        assert s.terminal


def test_scheduler_resubmit_is_backoff_ordering():
    """A resubmitted (quarantined) ticket re-queues BEHIND every ticket
    already waiting in its urgency class."""
    sched = Scheduler(num_slots=1)
    t1 = sched.submit("g", 1)
    t2 = sched.submit("g", 2)
    g = sched.group("g")
    [(lane, got)] = sched.admit(g)
    assert got is t1 and t1.status is Status.RUNNING and t1.attempts == 1
    sched.resubmit(g, lane, t1)
    assert t1.status is Status.PENDING
    [(lane, nxt)] = sched.admit(g)
    assert nxt is t2                         # waiting ticket goes first
    sched.release(g, lane)
    [(lane, again)] = sched.admit(g)
    assert again is t1 and t1.attempts == 2


def test_scheduler_sheds_only_queued_tickets():
    sched = Scheduler(num_slots=1)
    t1 = sched.submit("g", 1, deadline=1.0)
    g = sched.group("g")
    sched.admit(g)                           # t1 now RUNNING
    t2 = sched.submit("g", 2, deadline=1.0)
    t3 = sched.submit("g", 3)                # deadline-less: never sheds
    shed = sched.shed_expired(5.0)
    assert [t for _, t in shed] == [t2]
    assert t2.status is Status.DEADLINE_EXCEEDED
    assert t1.status is Status.RUNNING and t3.status is Status.PENDING


def test_scheduler_cancel_queued_skips_running():
    sched = Scheduler(num_slots=1)
    sched.submit("g", 1)
    t2 = sched.submit("g", 2)
    g = sched.group("g")
    sched.admit(g)
    assert sched.cancel_queued(1) is None    # running: not queue-cancellable
    grp, t = sched.cancel_queued(2)
    assert grp is g and t is t2 and t2.status is Status.CANCELLED
    assert sched.cancel_queued(2) is None


# -------------------------------------------------------------- intake
def test_solver_intake_validation(two_problems):
    """Malformed requests fail fast at submit with a ValueError naming
    the offending field -- nothing is enqueued, no lane is poisoned."""
    ds1, _ = two_problems
    svc = SolverService(num_slots=2, chunk_steps=C)
    bad_x = ds1.x.copy()
    bad_x[3, 5] = np.nan
    with pytest.raises(ValueError, match=r"FitRequest\.x.*non-finite"):
        svc.submit(FitRequest(x=bad_x, y=ds1.y))
    bad_y = ds1.y.astype(np.float64).copy()
    bad_y[0] = np.inf
    with pytest.raises(ValueError, match=r"FitRequest\.y.*non-finite"):
        svc.submit(FitRequest(x=ds1.x, y=bad_y))
    with pytest.raises(ValueError, match="must be 2-D"):
        svc.submit(FitRequest(x=ds1.x[:, 0], y=ds1.y))
    with pytest.raises(ValueError, match=r"FitRequest\.y must be shape"):
        svc.submit(FitRequest(x=ds1.x, y=ds1.y[:-1]))
    small = SolverService(num_slots=2, chunk_steps=C, max_points=64)
    with pytest.raises(ValueError, match="bucket ladder"):
        small.submit(FitRequest(x=ds1.x, y=ds1.y))      # 90 points > 64
    narrow = SolverService(num_slots=2, chunk_steps=C, max_dim=8)
    with pytest.raises(ValueError, match="bucket ladder"):
        narrow.submit(FitRequest(x=ds1.x, y=ds1.y))     # d=16 > 8
    assert not svc._sched.has_work()


def test_lm_intake_validation():
    """LM intake checks run before any device work (no params
    needed)."""
    cfg = get_config("gemma-7b").reduced()
    svc = LMService(None, cfg, num_slots=2, chunk_steps=4, max_len=32)
    with pytest.raises(ValueError, match="must be 1-D"):
        svc.submit(np.zeros((2, 3), np.int32), steps=4)
    with pytest.raises(ValueError, match="integer token ids"):
        svc.submit(np.zeros(3, np.float32), steps=4)
    with pytest.raises(ValueError, match="must lie in"):
        svc.submit(np.array([0, cfg.vocab_size], np.int64), steps=4)
    with pytest.raises(ValueError, match="steps must be >= 1"):
        svc.submit(np.array([1, 2], np.int64), steps=0)
    with pytest.raises(ValueError, match="max_len"):
        svc.submit(np.arange(5) % cfg.vocab_size, steps=32)  # 8+32 > 32
    assert not svc._sched.has_work()


# ---------------------------------------------------------- status API
def test_status_api_and_result_not_ready(two_problems):
    ds1, _ = two_problems
    svc = SolverService(num_slots=1, chunk_steps=C)
    rid = svc.submit(FitRequest(x=ds1.x, y=ds1.y, num_iters=2 * C,
                                seed=3))
    assert svc.status(rid) is Status.PENDING
    with pytest.raises(ResultNotReady):
        svc.result(rid)
    with pytest.raises(KeyError):            # ResultNotReady IS a KeyError
        svc.result(rid)
    assert svc.step() == []                  # chunk 1 of 2
    assert svc.status(rid) is Status.RUNNING
    (res,) = svc.step()
    assert svc.status(rid) is Status.DONE
    assert svc.result(rid) is res
    with pytest.raises(KeyError):            # claimed: historical KeyError
        svc.result(rid)
    with pytest.raises(KeyError):
        svc.status(rid)
    with pytest.raises(KeyError):            # unknown rid: bare KeyError
        svc.result(12345)


# ---------------------------------------------------------- quarantine
@pytest.mark.parametrize("nu_frac", [0.0, 0.85])
def test_quarantine_bit_for_bit_invariance(two_problems, clean4, nu_frac):
    """Poisoning one slot mid-run must not move a single bit of any
    batch-mate's result (hard margin and nu-SVM), the victim gets a
    structured FAILED record, and its freed lane serves the next
    request (the fourth ran in it) with exact parity."""
    clean_rids, clean_res = clean4(nu_frac)
    victim = 1
    inj = FaultInjector(FaultPlan(
        seed=0, faults=(Fault("poison", rid=victim, at_chunk=1),)))
    rids, res, _svc = _run4(two_problems, nu_frac, injector=inj)
    f = res[rids[victim]]
    assert isinstance(f, RequestFailure)
    assert f.status is Status.FAILED and f.attempts == 1
    assert "non-finite solver state" in f.reason
    for i in (0, 2, 3):
        _assert_same_result(res[rids[i]], clean_res[clean_rids[i]])
    assert len(inj.fired) == 1


@settings(max_examples=6, deadline=None)
@given(victim=st.integers(min_value=0, max_value=3),
       chunk=st.integers(min_value=0, max_value=3))
def test_quarantine_invariance_property(two_problems, clean4, victim,
                                        chunk):
    """Property form: for ANY victim and ANY poison chunk, every
    co-tenant's result is bit-for-bit the fault-free one."""
    clean_rids, clean_res = clean4(0.0)
    inj = FaultInjector(FaultPlan(
        seed=0, faults=(Fault("poison", rid=victim, at_chunk=chunk),)))
    rids, res, _svc = _run4(two_problems, 0.0, injector=inj)
    for i, rid in enumerate(rids):
        if i == victim:
            assert isinstance(res[rid], RequestFailure)
            assert res[rid].status is Status.FAILED
        else:
            _assert_same_result(res[rid], clean_res[clean_rids[i]])


# --------------------------------------------------------------- retry
def test_retry_recovers_and_queues_behind_waiters(two_problems):
    """A transient fault (one-shot poison) within the retry budget:
    the victim re-queues BEHIND the waiting bystander (backoff
    ordering), then completes bit-for-bit clean."""
    ds1, ds2 = two_problems
    inj = FaultInjector(FaultPlan(
        seed=0, faults=(Fault("poison", rid=0, at_chunk=0),)))
    svc = SolverService(num_slots=1, chunk_steps=C, fault_injector=inj)
    rv = svc.submit(FitRequest(x=ds1.x, y=ds1.y, num_iters=C, seed=1,
                               max_retries=1))
    rb = svc.submit(FitRequest(x=ds2.x, y=ds2.y, num_iters=C, seed=2))
    assert svc.step() == []                  # victim poisoned+quarantined
    assert svc.status(rv) is Status.PENDING  # resubmitted, not failed
    assert [r.request_id for r in svc.step()] == [rb]   # bystander first
    (got,) = svc.step()                      # then the clean retry
    assert got.request_id == rv
    assert len(inj.fired) == 1
    clean = SolverService(num_slots=1, chunk_steps=C).fit(
        ds1.x, ds1.y, num_iters=C, seed=1)
    _assert_same_result(got, clean)


def test_retry_budget_exhausted_fails_structured(two_problems):
    ds1, _ = two_problems
    inj = FaultInjector(FaultPlan(
        seed=0, faults=(Fault("poison", rid=0, at_chunk=0),)))
    svc = SolverService(num_slots=2, chunk_steps=C, fault_injector=inj)
    rid = svc.submit(FitRequest(x=ds1.x, y=ds1.y, num_iters=2 * C,
                                seed=1))                # max_retries=0
    res = svc.run()
    f = res[rid]
    assert isinstance(f, RequestFailure) and f.status is Status.FAILED
    assert f.attempts == 1 and "attempts=1" in f.reason
    # the one-shot convenience path surfaces it as an exception
    inj2 = FaultInjector(FaultPlan(
        seed=0, faults=(Fault("poison", rid=0, at_chunk=0),)))
    svc2 = SolverService(num_slots=2, chunk_steps=C, fault_injector=inj2)
    with pytest.raises(RuntimeError, match="FAILED"):
        svc2.fit(ds1.x, ds1.y, num_iters=C, seed=1)


def _stream_update_run(two_problems, injector):
    """One streaming workload: a tenant's initial fit completes, then
    its warm UPDATE round shares the batch with a bystander request.
    rids are deterministic (0 = initial fit, 1 = update, 2 =
    bystander), so a plan poisoning rid 1 hits the update mid-round."""
    ds1, ds2 = two_problems
    extra = synthetic.blobs(2, 2, 16, gap=1.2, spread=0.15, seed=21)
    svc = SolverService(num_slots=2, chunk_steps=C,
                        fault_injector=injector)
    rt = svc.submit(FitRequest(x=ds1.x, y=ds1.y, num_iters=2 * C,
                               seed=1, stream=True))
    svc.run()                                # warm state harvested
    ru = svc.submit_update(UpdateRequest(tenant=rt, x=extra.x,
                                         y=extra.y, num_iters=2 * C,
                                         max_retries=1))
    rb = svc.submit(FitRequest(x=ds2.x, y=ds2.y, num_iters=2 * C,
                               seed=9))
    res = svc.run()
    return res[ru], res[rb]


def test_update_round_poison_retries_from_warm_state(two_problems):
    """Poison mid-update-round: the update's lane is quarantined and
    the retry RE-ENTERS FROM THE SAME WARM STATE (the admission stash
    is restored at quarantine, warm state included), completing
    bit-for-bit equal to a fault-free warm update; the batch-mate is
    bit-for-bit unchanged."""
    clean_u, clean_b = _stream_update_run(two_problems, None)
    inj = FaultInjector(FaultPlan(
        seed=0, faults=(Fault("poison", rid=1, at_chunk=0),)))
    got_u, got_b = _stream_update_run(two_problems, inj)
    assert not isinstance(got_u, RequestFailure)
    _assert_same_result(got_u, clean_u)
    _assert_same_result(got_b, clean_b)
    assert len(inj.fired) == 1


def test_update_round_poison_budget_exhausted_keeps_tenant(two_problems):
    """An update whose retries are exhausted FAILS structured -- and
    the tenant survives it: the dataset edit persists and the next
    update (which re-warms from the last GOOD completed state) still
    runs."""
    ds1, _ = two_problems
    extra = synthetic.blobs(2, 2, 16, gap=1.2, spread=0.15, seed=21)
    inj = FaultInjector(FaultPlan(
        seed=0, faults=(Fault("poison", rid=1, at_chunk=0),)))
    svc = SolverService(num_slots=2, chunk_steps=C, fault_injector=inj)
    rt = svc.submit(FitRequest(x=ds1.x, y=ds1.y, num_iters=2 * C,
                               seed=1, stream=True))
    svc.run()
    ru = svc.submit_update(UpdateRequest(tenant=rt, x=extra.x,
                                         y=extra.y, num_iters=2 * C))
    f = svc.run()[ru]                        # max_retries inherited: 0
    assert isinstance(f, RequestFailure) and f.status is Status.FAILED
    r2 = svc.submit_update(UpdateRequest(tenant=rt, num_iters=2 * C))
    assert not isinstance(svc.run()[r2], RequestFailure)


# ----------------------------------------------------------- deadlines
def test_deadline_shedding_with_clock(two_problems):
    """With an injected clock, queued tickets past their deadline are
    shed (DEADLINE_EXCEEDED, attempts=0: never ran); RUNNING tickets
    finish their budget; without a clock, deadlines stay pure urgency
    ordering."""
    ds1, ds2 = two_problems
    now = [0.0]
    svc = SolverService(num_slots=2, chunk_steps=C, clock=lambda: now[0])
    r1 = svc.submit(FitRequest(x=ds1.x, y=ds1.y, num_iters=C, seed=1),
                    deadline=5.0)
    r2 = svc.submit(FitRequest(x=ds2.x, y=ds2.y, num_iters=C, seed=2))
    now[0] = 10.0                            # r1 expires while queued
    res = svc.run()
    f = res[r1]
    assert isinstance(f, RequestFailure)
    assert f.status is Status.DEADLINE_EXCEEDED and f.attempts == 0
    assert not isinstance(res[r2], RequestFailure)
    # a ticket that got a lane before expiry is NOT shed mid-run
    now[0] = 0.0
    r3 = svc.submit(FitRequest(x=ds1.x, y=ds1.y, num_iters=2 * C,
                               seed=3), deadline=5.0)
    assert svc.step() == []                  # admitted while now < deadline
    now[0] = 10.0
    res = svc.run()
    assert not isinstance(res[r3], RequestFailure)
    # no clock -> the historical contract: deadlines only order
    svc2 = SolverService(num_slots=1, chunk_steps=C)
    r4 = svc2.submit(FitRequest(x=ds2.x, y=ds2.y, num_iters=C, seed=4),
                     deadline=0.5)
    assert not isinstance(svc2.run()[r4], RequestFailure)


# -------------------------------------------------------------- cancel
def test_cancel_queued_and_running(two_problems):
    ds1, ds2 = two_problems
    svc = SolverService(num_slots=1, chunk_steps=C)
    r1 = svc.submit(FitRequest(x=ds1.x, y=ds1.y, num_iters=4 * C,
                               seed=1))
    r2 = svc.submit(FitRequest(x=ds2.x, y=ds2.y, num_iters=C, seed=2))
    assert svc.step() == []                  # r1 RUNNING, r2 queued
    assert svc.cancel(r2)
    assert svc.status(r2) is Status.CANCELLED
    f2 = svc.result(r2)
    assert f2.attempts == 0 and "queued" in f2.reason
    assert svc.cancel(r1)
    f1 = svc.result(r1)
    assert f1.status is Status.CANCELLED and f1.attempts == 1
    assert "running" in f1.reason
    assert not svc.cancel(r1)                # terminal: no-op
    assert not svc.cancel(999)               # unknown: no-op
    assert not svc._sched.has_work()
    assert not svc._batches                  # device buffers evicted
    # the service stays fully usable after cancellations
    res = svc.fit(ds1.x, ds1.y, num_iters=C, seed=7)
    assert res.iterations == C


# ------------------------------------------------------------- LM side
def _lm_model():
    cfg = get_config("gemma-7b").reduced()
    return cfg, tf.init_lm(jax.random.key(0), cfg)


def _lm_solo(params, cfg, prompt, steps, seed, temperature):
    return np.asarray(serve_engine.generate(
        params, cfg, jnp.asarray(prompt, jnp.int32)[None], steps=steps,
        temperature=temperature, seed=seed))[0]


@pytest.mark.slow
@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_lm_quarantine_batchmates_token_for_token(temperature):
    """Poisoned logits on one decode lane: the victim is quarantined
    with a structured FAILED record, the batch-mate's tokens match
    solo generate EXACTLY (greedy and temperature sampling), and the
    freed lane serves the next prompt with exact parity."""
    cfg, params = _lm_model()
    rng = np.random.default_rng(0)
    p1, p2, p3 = (rng.integers(0, cfg.vocab_size, s) for s in (6, 7, 5))
    inj = FaultInjector(FaultPlan(
        seed=0, faults=(Fault("poison", rid=0, at_chunk=1),)))
    svc = LMService(params, cfg, num_slots=2, chunk_steps=4, max_len=48,
                    temperature=temperature, fault_injector=inj)
    rv = svc.submit(p1, steps=12, seed=3)
    rb = svc.submit(p2, steps=12, seed=5)
    res = svc.run()
    f = res[rv]
    assert isinstance(f, RequestFailure) and f.status is Status.FAILED
    assert "non-finite logits" in f.reason and f.attempts == 1
    np.testing.assert_array_equal(
        res[rb].tokens, _lm_solo(params, cfg, p2, 12, 5, temperature))
    r3 = svc.generate(p3, 8, seed=7)
    np.testing.assert_array_equal(
        r3.tokens, _lm_solo(params, cfg, p3, 8, 7, temperature))
    assert len(inj.fired) == 1


@pytest.mark.slow
def test_lm_retry_recovers_transient_fault():
    cfg, params = _lm_model()
    rng = np.random.default_rng(1)
    p = rng.integers(0, cfg.vocab_size, 6)
    inj = FaultInjector(FaultPlan(
        seed=0, faults=(Fault("poison", rid=0, at_chunk=0),)))
    svc = LMService(params, cfg, num_slots=2, chunk_steps=4, max_len=48,
                    fault_injector=inj)
    rid = svc.submit(p, steps=8, seed=3, max_retries=1)
    res = svc.run()
    out = res[rid]
    assert not isinstance(out, RequestFailure)
    np.testing.assert_array_equal(
        out.tokens, _lm_solo(params, cfg, p, 8, 3, 0.0))
    assert len(inj.fired) == 1
