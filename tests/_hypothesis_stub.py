"""Import shim so property-based tests degrade to per-test skips instead
of module-level collection errors when ``hypothesis`` is not installed
(the seed image ships without it; see requirements-dev.txt).

Usage in a test module::

    from _hypothesis_stub import given, settings, st

With hypothesis installed these are the real objects; without it,
``@given(...)`` marks the test skipped and the strategy expressions
evaluate to inert placeholders.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -r "
                       "requirements-dev.txt)")(fn)
        return deco

    class _InertStrategies:
        """st.integers(...) etc. evaluate at decoration time; return
        inert placeholders so module import succeeds."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _InertStrategies()
