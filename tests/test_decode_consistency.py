"""Serving correctness: prefill + step-by-step decode must reproduce the
full-forward logits for every cache family (KV, compressed-KV/MLA, SWA
ring buffer incl. wraparound, mLSTM/sLSTM/RG-LRU recurrent state)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tf
from repro.serve import engine

# LM-side model/system tests dominate the full-suite runtime; the fast
# CI tier (scripts/ci.sh) deselects them with -m 'not slow'
pytestmark = pytest.mark.slow

FAMILIES = [
    ("h2o-danube-1.8b", {}),              # GQA + SWA ring
    ("gemma-7b", {}),                     # GQA full cache
    ("chatglm3-6b", {}),                  # partial rope
    ("deepseek-v2-lite-16b", {}),         # MLA absorbed decode
    ("xlstm-125m", {}),                   # mLSTM + sLSTM state
    ("recurrentgemma-2b", {}),            # RG-LRU + local attn
    ("qwen2-vl-7b", {}),                  # M-RoPE
    ("whisper-medium", {"encdec": True}),  # cross-attention cache
]


@pytest.mark.parametrize("arch,flags", FAMILIES)
def test_decode_matches_full(arch, flags):
    cfg = get_config(arch).reduced()
    params = tf.init_lm(jax.random.key(0), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, S), 0,
                              cfg.vocab_size)
    kw = {}
    if flags.get("encdec"):
        kw["enc_frames"] = jax.random.normal(
            jax.random.key(2), (B, cfg.enc_frames, cfg.d_model)) * 0.05
    full, _, _ = tf.forward(params, cfg, toks, **kw)
    st = engine.prefill(params, cfg, toks[:, :S - 4], max_len=S + 2,
                        cache_dtype=jnp.float32, **kw)
    for i in range(S - 4, S):
        st = engine.decode_step(params, cfg, toks[:, i:i + 1], st)
    got = np.asarray(st.last_logits)
    want = np.asarray(full[:, -1])
    scale = np.abs(want).max() + 1e-9
    assert np.abs(got - want).max() / scale < 1e-4


def test_swa_ring_wraparound():
    cfg = dataclasses.replace(get_config("h2o-danube-1.8b").reduced(),
                              window=16)
    params = tf.init_lm(jax.random.key(0), cfg)
    B, S = 1, 40
    toks = jax.random.randint(jax.random.key(1), (B, S), 0,
                              cfg.vocab_size)
    full, _, _ = tf.forward(params, cfg, toks)
    st = engine.prefill(params, cfg, toks[:, :30], max_len=S + 8,
                        cache_dtype=jnp.float32)
    for i in range(30, S):
        st = engine.decode_step(params, cfg, toks[:, i:i + 1], st)
    want = np.asarray(full[:, -1])
    got = np.asarray(st.last_logits)
    assert np.abs(got - want).max() / (np.abs(want).max() + 1e-9) < 1e-4


def test_generate_greedy_deterministic():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = tf.init_lm(jax.random.key(0), cfg)
    prompt = jax.random.randint(jax.random.key(3), (2, 6), 0,
                                cfg.vocab_size)
    a = engine.generate(params, cfg, prompt, steps=5)
    b = engine.generate(params, cfg, prompt, steps=5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 5)
    assert (np.asarray(a) < cfg.padded_vocab).all()
