"""Serving correctness: prefill + step-by-step decode must reproduce the
full-forward logits for every cache family (KV, compressed-KV/MLA, SWA
ring buffer incl. wraparound, mLSTM/sLSTM/RG-LRU recurrent state)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tf
from repro.serve import engine

# LM-side model/system tests dominate the full-suite runtime; the fast
# CI tier (scripts/ci.sh) deselects them with -m 'not slow'
pytestmark = pytest.mark.slow

FAMILIES = [
    ("h2o-danube-1.8b", {}),              # GQA + SWA ring
    ("gemma-7b", {}),                     # GQA full cache
    ("chatglm3-6b", {}),                  # partial rope
    ("deepseek-v2-lite-16b", {}),         # MLA absorbed decode
    ("xlstm-125m", {}),                   # mLSTM + sLSTM state
    ("recurrentgemma-2b", {}),            # RG-LRU + local attn
    ("qwen2-vl-7b", {}),                  # M-RoPE
    ("whisper-medium", {"encdec": True}),  # cross-attention cache
]


@pytest.mark.parametrize("arch,flags", FAMILIES)
def test_decode_matches_full(arch, flags):
    cfg = get_config(arch).reduced()
    params = tf.init_lm(jax.random.key(0), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, S), 0,
                              cfg.vocab_size)
    kw = {}
    if flags.get("encdec"):
        kw["enc_frames"] = jax.random.normal(
            jax.random.key(2), (B, cfg.enc_frames, cfg.d_model)) * 0.05
    full, _, _ = tf.forward(params, cfg, toks, **kw)
    st = engine.prefill(params, cfg, toks[:, :S - 4], max_len=S + 2,
                        cache_dtype=jnp.float32, **kw)
    for i in range(S - 4, S):
        st = engine.decode_step(params, cfg, toks[:, i:i + 1], st)
    got = np.asarray(st.last_logits)
    want = np.asarray(full[:, -1])
    scale = np.abs(want).max() + 1e-9
    assert np.abs(got - want).max() / scale < 1e-4


def test_swa_ring_wraparound():
    cfg = dataclasses.replace(get_config("h2o-danube-1.8b").reduced(),
                              window=16)
    params = tf.init_lm(jax.random.key(0), cfg)
    B, S = 1, 40
    toks = jax.random.randint(jax.random.key(1), (B, S), 0,
                              cfg.vocab_size)
    full, _, _ = tf.forward(params, cfg, toks)
    st = engine.prefill(params, cfg, toks[:, :30], max_len=S + 8,
                        cache_dtype=jnp.float32)
    for i in range(30, S):
        st = engine.decode_step(params, cfg, toks[:, i:i + 1], st)
    want = np.asarray(full[:, -1])
    got = np.asarray(st.last_logits)
    assert np.abs(got - want).max() / (np.abs(want).max() + 1e-9) < 1e-4


@pytest.mark.parametrize("arch", ["gemma-7b", "deepseek-v2-lite-16b"])
def test_prefill_bucketing_exact_and_single_trace(arch):
    """Prompts of different lengths inside one pow-2 bucket must (a)
    generate EXACTLY the tokens of the unbucketed path -- right-pad +
    causal mask + index rewind is exact for full-attention caches --
    and (b) share ONE prefill trace (engine.trace_counts)."""
    cfg = get_config(arch).reduced()
    params = tf.init_lm(jax.random.key(0), cfg)
    assert engine._can_bucket(cfg)
    snap = dict(engine.trace_counts)
    for s in (5, 7):                        # both bucket to 8
        prompt = jax.random.randint(jax.random.key(s), (2, s), 0,
                                    cfg.vocab_size)
        a = engine.generate(params, cfg, prompt, steps=4)
        b = engine.generate(params, cfg, prompt, steps=4,
                            bucket_prompts=False)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    delta = {k: v - snap.get(k, 0)
             for k, v in engine.trace_counts.items()
             if v != snap.get(k, 0)}
    assert delta == {(cfg.name, 8, 8 + 4): 1}, delta


def test_explicit_small_max_len_falls_back_to_exact_prefill():
    """An explicit max_len below the prompt's pow-2 bucket was always a
    valid call (max_len >= s + steps); it must keep working by routing
    through the exact-length prefill instead of crashing on a
    bucket-sized cache write."""
    cfg = get_config("gemma-7b").reduced()
    params = tf.init_lm(jax.random.key(0), cfg)
    prompt = jax.random.randint(jax.random.key(4), (1, 5), 0,
                                cfg.vocab_size)
    a = engine.generate(params, cfg, prompt, steps=2, max_len=7)
    b = engine.generate(params, cfg, prompt, steps=2,
                        bucket_prompts=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bucketing_gate_excludes_order_dependent_caches():
    """SWA ring buffers and recurrent state absorb prompts
    order-dependently: those configs must fall back to exact-length
    prefill."""
    assert not engine._can_bucket(get_config("h2o-danube-1.8b").reduced())
    assert not engine._can_bucket(get_config("xlstm-125m").reduced())
    assert not engine._can_bucket(get_config("whisper-medium").reduced())


def test_generate_greedy_deterministic():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = tf.init_lm(jax.random.key(0), cfg)
    prompt = jax.random.randint(jax.random.key(3), (2, 6), 0,
                                cfg.vocab_size)
    a = engine.generate(params, cfg, prompt, steps=5)
    b = engine.generate(params, cfg, prompt, steps=5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 5)
    assert (np.asarray(a) < cfg.padded_vocab).all()
