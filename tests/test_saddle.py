"""Saddle-SVC: convergence to the C-Hull / RC-Hull optimum, parameter
formulas (Algorithm 1 line 4), kernel-backend parity, and the
device-resident driver's history/gap-stop invariants (host-loop
parity, single host transfer, no warm retrace)."""

import jax
import numpy as np
import pytest

from repro.core import engine
from repro.core import preprocess as pp
from repro.core import saddle
from repro.core.svm import split_classes


@pytest.fixture(scope="module")
def small_problem(request):
    rng = np.random.default_rng(0)
    d = 16
    xp = rng.normal(size=(30, d)).astype(np.float32) * 0.25 + 0.4
    xm = rng.normal(size=(40, d)).astype(np.float32) * 0.25 - 0.4
    pre = pp.preprocess(xp, xm, jax.random.key(1))
    return np.asarray(pre.xp), np.asarray(pre.xm)


def test_params_formulas():
    p = saddle.make_params(n=1000, d=64, eps=1e-3, beta=0.1)
    import math
    gamma = 1e-3 * 0.1 / (2 * math.log(1000))
    assert abs(p.gamma - gamma) < 1e-12
    q = math.sqrt(math.log(1000))
    assert abs(p.tau - 0.5 / q * math.sqrt(64 / gamma)) < 1e-9
    assert abs(p.sigma - 0.5 / q * math.sqrt(64 * gamma)) < 1e-9
    assert abs(p.theta - (1 - 1 / (64 + q * math.sqrt(64 / gamma)))) < 1e-12


def test_hm_converges_to_qp(small_problem, qp_oracle):
    xp, xm = small_problem
    opt = qp_oracle(xp, xm, nu=1.0)
    res = saddle.solve(xp, xm, eps=1e-3, beta=0.1, num_iters=6000)
    obj = res.history[-1][1]
    assert obj >= opt - 1e-6                   # primal feasible
    assert obj <= opt * 1.10 + 1e-6            # within 10%


def test_nu_converges_to_qp(small_problem, qp_oracle):
    xp, xm = small_problem
    nu = 1.0 / (0.8 * 30)
    opt = qp_oracle(xp, xm, nu=nu)
    res = saddle.solve(xp, xm, eps=1e-3, beta=0.1, nu=nu, num_iters=6000)
    obj = res.history[-1][1]
    assert obj >= opt - 1e-6
    assert obj <= opt * 1.15 + 1e-5


def test_nu_infeasible_raises(small_problem):
    xp, xm = small_problem
    with pytest.raises(ValueError):
        saddle.solve(xp, xm, nu=1.0 / (2 * len(xp)))


def test_dual_iterates_feasible(small_problem):
    xp, xm = small_problem
    nu = 1.0 / (0.7 * 30)
    res = saddle.solve(xp, xm, nu=nu, num_iters=300)
    eta = np.exp(np.asarray(res.state.log_eta))
    xi = np.exp(np.asarray(res.state.log_xi))
    assert abs(eta.sum() - 1) < 1e-4 and abs(xi.sum() - 1) < 1e-4
    assert eta.max() <= nu + 1e-5 and xi.max() <= nu + 1e-5


def test_gap_tol_stops_early_without_record_every(small_problem):
    """gap_tol alone must actually fire: with no record_every the chunk
    defaults to GAP_CHECK_EVERY so the duality-gap check runs before
    the whole budget is spent."""
    xp, xm = small_problem
    res = saddle.solve(xp, xm, eps=1e-3, beta=0.1, num_iters=50000,
                       gap_tol=0.5)
    stopped_at = res.history[-1][0]
    assert stopped_at < 50000
    assert stopped_at == int(res.state.t)


def _hist(res):
    return [(int(m), float(o)) for m, o in res.history]


@pytest.mark.parametrize("driver", ["host", "device"])
def test_history_marks_with_partial_final_chunk(small_problem, driver):
    """(marks, objs) invariants under both drivers: marks strictly
    increasing, the partial final chunk (103 % 25) recorded at its true
    iteration, last mark == the state's iteration counter."""
    xp, xm = small_problem
    res = saddle.solve(xp, xm, num_iters=103, record_every=25,
                       driver=driver)
    marks = [m for m, _ in res.history]
    assert marks == [25, 50, 75, 100, 103]
    assert all(np.isfinite(o) for _, o in res.history)
    assert marks[-1] == int(res.state.t)


@pytest.mark.parametrize("driver", ["host", "device"])
def test_gap_stop_last_mark_is_stop_iteration(small_problem, driver):
    xp, xm = small_problem
    res = saddle.solve(xp, xm, num_iters=50000, record_every=256,
                       gap_tol=0.5, driver=driver)
    marks = [m for m, _ in res.history]
    assert all(b > a for a, b in zip(marks, marks[1:]))
    assert marks[-1] < 50000
    assert marks[-1] == int(res.state.t)


@pytest.mark.parametrize("kw", [
    dict(num_iters=103, record_every=25),        # partial final chunk
    dict(num_iters=60, record_every=100),        # single (clamped) chunk
    dict(num_iters=50000, record_every=256, gap_tol=0.5),   # gap stop
    dict(num_iters=160, record_every=32, block_size=4,
         nu=1.0 / (0.8 * 30)),                   # nu>0 block mode
])
def test_device_driver_bit_equal_to_host(small_problem, kw):
    """The transition contract: the device-resident while_loop driver
    replays the host chunk loop bit for bit -- same history, same
    final state -- because both drive the same chunk body with the
    same (state, num_steps) sequence and key schedule."""
    xp, xm = small_problem
    a = saddle.solve(xp, xm, driver="host", **kw)
    b = saddle.solve(xp, xm, driver="device", **kw)
    assert _hist(a) == _hist(b)
    for la, lb in zip(a.state, b.state):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@pytest.mark.parametrize("driver", ["host", "device"])
def test_gap_stop_prefix_bit_equal_to_gap_disabled(small_problem, driver):
    """Turning the gap check ON changes when a run stops, never what it
    computes: the stopped history must be a bit-equal prefix of the
    gap-disabled trajectory at the same record cadence."""
    xp, xm = small_problem
    stopped = saddle.solve(xp, xm, num_iters=50000, record_every=256,
                           gap_tol=0.5, driver=driver)
    stop_at = stopped.history[-1][0]
    assert stop_at % 256 == 0        # the gap only fires at boundaries
    ref = saddle.solve(xp, xm, num_iters=stop_at, record_every=256,
                       driver=driver)
    assert _hist(stopped) == _hist(ref)


def test_device_solve_single_host_transfer(small_problem, monkeypatch):
    """Regression pin for the ISSUE 8 driver: a warm device-driver
    solve performs exactly ONE device_get -- the end-of-solve history
    harvest -- with the gap check off AND on (the host loop needed one
    blocking poll per boundary when the gap was enabled)."""
    xp, xm = small_problem
    kw = dict(num_iters=103, record_every=25)
    saddle.solve(xp, xm, **kw)                       # warm, gap off
    saddle.solve(xp, xm, gap_tol=1e-12, **kw)        # warm, gap on
    real = jax.device_get
    calls = []

    def counting(*a, **k):
        calls.append(a)
        return real(*a, **k)

    monkeypatch.setattr(jax, "device_get", counting)
    saddle.solve(xp, xm, **kw)
    assert len(calls) == 1
    calls.clear()
    saddle.solve(xp, xm, gap_tol=1e-12, **kw)
    assert len(calls) == 1


def test_device_solve_no_retrace_when_warm(small_problem):
    """Second warm solve must not retrace any engine executable."""
    xp, xm = small_problem
    kw = dict(num_iters=103, record_every=25)
    saddle.solve(xp, xm, **kw)
    before = dict(engine.trace_counts)
    saddle.solve(xp, xm, **kw)
    assert dict(engine.trace_counts) == before


def test_kernel_backend_parity(small_problem):
    xp, xm = small_problem
    a = saddle.solve(xp, xm, num_iters=80)
    b = saddle.solve(xp, xm, num_iters=80, use_kernels=True)
    np.testing.assert_allclose(np.asarray(a.state.w),
                               np.asarray(b.state.w), atol=1e-5)


def test_block_mode_converges(small_problem, qp_oracle):
    """Beyond-paper block-coordinate mode reaches the same optimum."""
    xp, xm = small_problem
    opt = qp_oracle(xp, xm, nu=1.0)
    res = saddle.solve(xp, xm, eps=1e-3, beta=0.1, block_size=4,
                       num_iters=6000)
    assert res.history[-1][1] <= opt * 1.10 + 1e-6


def test_saddle_value_equals_polytope_distance(small_problem):
    """Lemma 2: max_w min phi == 0.5 ||closest difference point||^2.
    At the optimum, g(w) == OPT == objective."""
    xp, xm = small_problem
    res = saddle.solve(xp, xm, eps=1e-3, beta=0.05, num_iters=8000)
    obj = res.history[-1][1]
    gap = float(saddle.saddle_gap(res.state, xp, xm))
    # g(w) <= OPT <= obj, both within a few percent at convergence
    assert gap <= obj + 1e-5
    assert gap >= obj * 0.85 - 1e-4
