"""Saddle-DSVC: distributed == serial, communication accounting
(Theorem 8), shard_map runner on a real (host-device) mesh."""

import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed as dist
from repro.core import engine
from repro.core import preprocess as pp
from repro.core import saddle


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(0)
    d = 16
    xp = rng.normal(size=(37, d)).astype(np.float32) * 0.3 + 0.4
    xm = rng.normal(size=(53, d)).astype(np.float32) * 0.3 - 0.4
    pre = pp.preprocess(xp, xm, jax.random.key(1))
    return np.asarray(pre.xp), np.asarray(pre.xm)


@pytest.mark.parametrize("k", [1, 4, 7])
def test_distributed_matches_serial_hm(problem, k):
    xp, xm = problem
    ser = saddle.solve(xp, xm, num_iters=400)
    d = dist.solve_distributed(xp, xm, k=k, num_iters=400)
    np.testing.assert_allclose(np.asarray(ser.state.w),
                               np.asarray(d.state.w[0]), atol=1e-4)
    # every client holds the same w (paper: server broadcasts)
    for c in range(1, k):
        np.testing.assert_allclose(np.asarray(d.state.w[0]),
                                   np.asarray(d.state.w[c]), atol=1e-6)


def test_distributed_matches_serial_nu(problem):
    xp, xm = problem
    nu = 1.0 / (0.8 * 37)
    ser = saddle.solve(xp, xm, nu=nu, num_iters=300)
    d = dist.solve_distributed(xp, xm, k=5, nu=nu, num_iters=300)
    np.testing.assert_allclose(np.asarray(ser.state.w),
                               np.asarray(d.state.w[0]), atol=1e-4)
    eta, xi = dist.gather_duals(d.state, 37, 53, 5)
    np.testing.assert_allclose(np.exp(np.asarray(ser.state.log_eta)),
                               eta, atol=1e-4)


def test_comm_model_matches_theorem8():
    """Communication ~ O(k) per iteration (paper Theorem 8): scalar
    counts scale linearly in k, independent of n and d."""
    c10 = dist.CommModel(k=10, nu_rounds_per_iter=0)
    c20 = dist.CommModel(k=20, nu_rounds_per_iter=0)
    assert c20.scalars_per_iteration() == 2 * c10.scalars_per_iteration()
    cn = dist.CommModel(k=10, nu_rounds_per_iter=2)
    assert cn.scalars_per_iteration() > c10.scalars_per_iteration()
    # total for T iterations
    assert c10.total(100) == 100 * c10.scalars_per_iteration()


def test_shard_points_roundtrip():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(23, 4)).astype(np.float32)
    sh, mask = dist.shard_points(x, 5)
    assert sh.shape == (5, 5, 4) and mask.shape == (5, 5)
    assert mask.sum() == 23
    # inverse of the round-robin layout recovers the original points:
    # shard c, slot j holds original index j*5 + c
    recovered = np.transpose(sh, (1, 0, 2)).reshape(-1, 4)[:23]
    np.testing.assert_allclose(recovered, x)
    rec_mask = np.transpose(mask, (1, 0)).reshape(-1)
    assert rec_mask[:23].all() and not rec_mask[23:].any()


@pytest.mark.faults
@pytest.mark.dist
def test_drop_client_survivors_converge(problem):
    """Losing one client mid-solve (drop_client injection): the dropped
    shard's dual mass goes to EXACTLY zero, the survivors' mass is
    renormalized to 1 by the next MWU normalizer round (the recovery
    rule -- no host-side repair), and the k-1 solve converges ON THE
    SURVIVOR PROBLEM (the round-robin complement of the dropped shard)
    at the same rate as a from-scratch survivor-only serial solve."""
    xp, xm = problem
    n1, n2 = xp.shape[0], xm.shape[0]
    k, c, iters = 5, 2, 4800
    res = dist.solve_distributed(xp, xm, k=k, num_iters=iters,
                                 record_every=800,
                                 drop_client=(c, iters // 3))
    eta, xi = dist.gather_duals(res.state, n1, n2, k)
    # round-robin sharding: original index j*k + c lives on client c
    drop_p = np.arange(n1) % k == c
    drop_m = np.arange(n2) % k == c
    assert eta[drop_p].sum() == 0.0 and xi[drop_m].sum() == 0.0
    np.testing.assert_allclose(eta[~drop_p].sum(), 1.0, rtol=1e-5)
    np.testing.assert_allclose(xi[~drop_m].sum(), 1.0, rtol=1e-5)
    # relative duality gap ON the survivor problem, from the survivor
    # iterates (every live client holds the same w)
    pts = pp.pack_points(xp[~drop_p], xm[~drop_m])

    def rel_gap(w, lam):
        log_lam = np.full(pts.sign.shape[0], engine.NEG_INF, np.float32)
        log_lam[:lam.shape[0]] = np.log(np.maximum(lam, 1e-30))
        obj = float(engine.objective_from_duals(
            jnp.asarray(log_lam), jnp.asarray(pts.x_t),
            jnp.asarray(pts.sign)))
        gap = float(engine.saddle_gap_packed(
            jnp.asarray(w), jnp.asarray(pts.x_t), jnp.asarray(pts.sign),
            jnp.asarray(1.0)))
        return (obj - gap) / max(obj, 1e-12)

    r_drop = rel_gap(np.asarray(res.state.w[(c + 1) % k]),
                     np.concatenate([eta[~drop_p], xi[~drop_m]]))
    assert r_drop <= 0.25                    # 0.17 measured; see below
    # no convergence penalty vs solving the survivor set from scratch
    # with the same budget (0.17 vs 0.19 measured -- deterministic
    # seeds; the 1.5x headroom covers cross-platform float wobble)
    ser = saddle.solve(xp[~drop_p], xm[~drop_m], num_iters=iters)
    lam_ser = np.concatenate([np.exp(np.asarray(ser.state.log_eta)),
                              np.exp(np.asarray(ser.state.log_xi))])
    r_ser = rel_gap(np.asarray(ser.state.w), lam_ser)
    assert r_drop <= 1.5 * r_ser


def test_drop_client_rejects_mesh_mode(problem):
    xp, xm = problem
    with pytest.raises(ValueError, match="simulation-only"):
        dist.solve_distributed(xp, xm, k=2, num_iters=10,
                               mesh="not-none", drop_client=(0, 5))


def test_shard_map_runner_multidevice():
    """Production path: shard_map over a real 8-device host mesh in a
    subprocess (device count must be set before jax init)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
import sys
sys.path.insert(0, "src")
from repro.core import distributed as dist, saddle, preprocess as pp

rng = np.random.default_rng(0)
xp = rng.normal(size=(32, 8)).astype(np.float32)*0.3 + 0.4
xm = rng.normal(size=(40, 8)).astype(np.float32)*0.3 - 0.4
pre = pp.preprocess(xp, xm, jax.random.key(1))
XP, XM = np.asarray(pre.xp), np.asarray(pre.xm)
mesh = jax.make_mesh((8,), (dist.CLIENT_AXIS,))
ser = saddle.solve(XP, XM, num_iters=200)
res = dist.solve_distributed(XP, XM, k=8, num_iters=200, mesh=mesh)
w_ser = np.asarray(ser.state.w)
w_dist = np.asarray(res.state.w[0])
assert np.allclose(w_ser, w_dist, atol=1e-4), np.abs(w_ser-w_dist).max()
print("SHARD_MAP_OK")
"""
    env = dict(os.environ)
    # pin the subprocess to CPU: with JAX_PLATFORMS unset, a libtpu
    # build probes TPU metadata for minutes before falling back, and
    # --xla_force_host_platform_device_count only applies to cpu anyway
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         env=env, timeout=300)
    assert "SHARD_MAP_OK" in out.stdout, out.stdout + out.stderr
