"""Sharding rules: divisibility guards, param spec table, HLO collective
parser."""

import numpy as np

from repro.utils import hlo_analysis as hlo


def test_collective_parser():
    text = """
  %ag = bf16[16,1024] all-gather(%x), replica_groups={}
  %ar.1 = f32[256] all-reduce(%y), to_apply=%sum
  %rs = bf16[8,128] reduce-scatter(%z), dimensions={0}
  %a2a = f32[4,64] all-to-all(%w)
  %cp = bf16[32] collective-permute(%v)
  %dot = f32[128,128] dot(%a, %b)
"""
    stats = hlo.collective_stats(text)
    assert stats.count_by_op == {"all-gather": 1, "all-reduce": 1,
                                 "reduce-scatter": 1, "all-to-all": 1,
                                 "collective-permute": 1}
    assert stats.bytes_by_op["all-gather"] == 16 * 1024 * 2
    assert stats.bytes_by_op["all-reduce"] == 256 * 4
    assert stats.total_bytes == (16 * 1024 * 2 + 256 * 4 + 8 * 128 * 2
                                 + 4 * 64 * 4 + 32 * 2)


def test_collective_parser_tuple_shapes():
    text = "%ar = (f32[8], f32[8]) all-reduce(%a, %b), to_apply=%sum"
    stats = hlo.collective_stats(text)
    assert stats.bytes_by_op["all-reduce"] == 64


def test_spec_divisibility_guard():
    """Axes that do not divide a dim are dropped (e.g. 28 heads on a
    16-way model axis)."""
    import subprocess
    import sys
    import os
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax
from repro.models import sharding as shd

mesh = jax.make_mesh((4, 2), ("data", "model"))
shd.set_mesh_axes(mesh)
# heads=28 not divisible by model=2? 28 % 2 == 0 -> sharded
s = shd.spec_for(["batch", None, "heads", None], (8, 1, 28, 64))
assert s[2] == "model", s
# heads=7 NOT divisible by 2 -> dropped
s = shd.spec_for(["batch", None, "heads", None], (8, 1, 7, 64))
assert s[2] is None, s
# batch=2 not divisible by data=4 -> dropped
s = shd.spec_for(["batch", None], (2, 16))
assert s[0] is None, s
# no double-use of a physical axis
s = shd.spec_for(["heads", "mlp"], (4, 4))
assert not (s[0] == "model" and s[1] == "model"), s
print("SPEC_OK")
"""
    env = dict(os.environ)
    # pin the subprocess to CPU: with JAX_PLATFORMS unset, a libtpu
    # build probes TPU metadata for minutes before falling back, and
    # --xla_force_host_platform_device_count only applies to cpu anyway
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         env=env, timeout=120)
    assert "SPEC_OK" in out.stdout, out.stdout + out.stderr


def test_no_mesh_is_noop():
    from repro.models import sharding as shd
    import jax.numpy as jnp
    shd.set_mesh_axes(None)
    x = jnp.ones((4, 4))
    y = shd.shard(x, "batch", "mlp")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
