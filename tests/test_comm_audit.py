"""Theorem 8 as a tested invariant: the collectives XLA ACTUALLY emits
for the sharded packed step must equal the analytic CommModel --
per-iteration launch count and payload independent of n, d and k, for
both nu regimes and both backends (repro.utils.comm_audit).

All measurements lower + compile real post-SPMD modules on forced
host-device meshes, so they run in ONE subprocess (jax pins the device
count at first init); the module-scoped fixture batches every spec
through a single `collect_audits` call and the tests assert against
the returned records.
"""

import pytest

from repro.core import distributed as dist
from repro.core import projections
from repro.utils import comm_audit

pytestmark = pytest.mark.dist

KS = (2, 8, 32)
BASE = dict(n1=96, n2=112, d=32, block_size=4)
NU = 1.0 / (0.8 * BASE["n1"])


def _specs():
    specs = []
    for k in KS:
        for nu in (0.0, NU):
            specs.append({"k": k, "nu": nu, **BASE,
                          # full production-chunk audit at one k per nu
                          "runner": k == 8, "chunk_steps": 5})
    # n/d variation (bytes must not scale with n or d) at one k
    specs.append({"k": 2, "nu": NU, "n1": 768, "n2": 896, "d": 128,
                  "block_size": 4})
    # pallas-interpret backend stability at one k per nu
    for nu in (0.0, NU):
        specs.append({"k": 2, "nu": nu, **BASE, "backend": "pallas"})
    specs += _serve_specs()
    return specs


SERVE_BASE = dict(n1=96, n2=112, d=32, chunk_steps=5)


def _serve_specs():
    """The SERVING chunk (engine.run_chunk_slots_sharded): lanes
    placements must be collective-free, point-sharded placements must
    match ServeCommModel -- per iteration AND per chunk."""
    specs = []
    for k in (2, 8):
        for nu in (0.0, NU):
            specs.append({"kind": "serve", "k": k, "nu": nu,
                          "num_slots": 2 * k, "block_size": 1,
                          "sharded": False, **SERVE_BASE})
            specs.append({"kind": "serve", "k": k, "nu": nu,
                          "num_slots": 2, "block_size": 4,
                          "sharded": True, **SERVE_BASE})
    # pallas through the sharded serve step at a real k
    specs.append({"kind": "serve", "k": 2, "nu": NU, "num_slots": 2,
                  "block_size": 4, "sharded": True,
                  "backend": "pallas", **SERVE_BASE})
    return specs


@pytest.fixture(scope="module")
def all_audits():
    recs = comm_audit.collect_audits(_specs())
    assert recs, "audit subprocess returned nothing"
    return recs


@pytest.fixture(scope="module")
def audits(all_audits):
    """Solver-step records only (the serve records have their own
    shape and their own assertions below)."""
    return [r for r in all_audits if r.get("kind") != "serve"]


@pytest.fixture(scope="module")
def serve_audits(all_audits):
    return [r for r in all_audits if r.get("kind") == "serve"]


def _find(audits, **want):
    out = [r for r in audits
           if all(r.get(k) == v for k, v in want.items())]
    assert out, f"no audit record matching {want}"
    return out


def _model(k, nu):
    rounds = float(projections.BISECT_ROUNDS_SOLVER) if nu > 0 else 0.0
    return dist.CommModel(k=k, nu_rounds_per_iter=rounds)


# --------------------------------------------------- count == CommModel
@pytest.mark.parametrize("k", KS)
@pytest.mark.parametrize("nu", [0.0, NU], ids=["hm", "nu"])
def test_measured_equals_model(audits, k, nu):
    """The measured post-SPMD per-iteration collective multiset is
    EXACTLY the CommModel prediction, for every k and both regimes."""
    rec = _find(audits, k=k, nu=nu, backend="jnp",
                n1=BASE["n1"])[0]
    model = _model(k, nu)
    assert rec["measured"] == rec["predicted"], rec
    assert rec["match"] is True
    assert rec["per_iteration_count"] == \
        model.collectives_per_iteration(BASE["block_size"])


@pytest.mark.parametrize("nu", [0.0, NU], ids=["hm", "nu"])
def test_count_independent_of_k(audits, nu):
    """Per-DEVICE launch count and payload are k-invariant (each launch
    just spans more devices) -- this is what makes total traffic
    exactly O(k) x payload (Theorem 8)."""
    recs = _find(audits, nu=nu, backend="jnp", n1=BASE["n1"])
    counts = {r["per_iteration_count"] for r in recs}
    payloads = {r["per_iteration_bytes"] for r in recs}
    assert len(counts) == 1 and len(payloads) == 1, (counts, payloads)


def test_count_and_bytes_independent_of_n_d(audits):
    """Scalar-round collective counts AND bytes must not move when n
    grows 8x and d grows 4x: per-iteration traffic is O(B + rounds),
    NOT O(n*d) -- the regression this whole subsystem exists to catch
    (an accidental per-point all-gather would explode this)."""
    small = _find(audits, k=2, nu=NU, n1=BASE["n1"], backend="jnp")[0]
    big = _find(audits, k=2, nu=NU, n1=768)[0]
    assert big["n1"] * big["n2"] * big["d"] > \
        8 * small["n1"] * small["n2"] * small["d"]
    assert small["measured"] == big["measured"]
    assert small["per_iteration_bytes"] == big["per_iteration_bytes"]


def test_bytes_are_o_block_not_o_nd(audits):
    """Per-iteration payload == the model's closed form
    4 * (B + 2 + 2 [+ 2 + 2R + 4]) bytes -- orders of magnitude below
    one row of the data (4*n*d), let alone O(n*d)."""
    for rec in audits:
        model = _model(rec["k"], rec["nu"])
        want = 4 * model.payload_elements_per_iteration(
            rec["block_size"])
        assert rec["per_iteration_bytes"] == want, rec
        assert rec["per_iteration_bytes"] < 4 * rec["n1"], rec


# ------------------------------------------------- backend / chunk parity
@pytest.mark.parametrize("nu", [0.0, NU], ids=["hm", "nu"])
def test_backend_stable(audits, nu):
    """jnp and pallas-interpret backends must emit the SAME collective
    multiset (the kernels change compute layout, never communication)."""
    jnp_rec = _find(audits, k=2, nu=nu, backend="jnp",
                    n1=BASE["n1"])[0]
    pl_rec = _find(audits, k=2, nu=nu, backend="pallas")[0]
    assert jnp_rec["measured"] == pl_rec["measured"]
    assert pl_rec["match"] is True


@pytest.mark.parametrize("nu", [0.0, NU], ids=["hm", "nu"])
def test_production_chunk_matches_single_step(audits, nu):
    """The full production runner (distributed.sharded_run_fn -- the
    multi-pod dry-run path) adds NOTHING inside the step loop: its
    loop-body multiset equals the single-step lowering, and the only
    out-of-loop collective is the once-per-chunk objective psum
    (f32[d])."""
    rec = _find(audits, k=8, nu=nu, backend="jnp")[0]
    assert rec["runner_match"] is True
    assert rec["runner_matches_step"] is True
    assert rec["runner_per_chunk"] == {
        f"all-reduce|add|{BASE['d']}": 1}, rec["runner_per_chunk"]


# ------------------------------------------------- serving chunk budget
@pytest.mark.parametrize("k", (2, 8))
@pytest.mark.parametrize("nu", [0.0, NU], ids=["hm", "nu"])
def test_serve_lanes_collective_free(serve_audits, k, nu):
    """The lane-parallel serving placement (slot axis sharded, whole
    lanes per device) must compile with ZERO collectives ANYWHERE --
    not just in the loop: admission, stepping and harvest of unsharded
    slots are entirely device-local."""
    rec = _find(serve_audits, k=k, nu=nu, sharded=False)[0]
    assert rec["measured"] == {} and rec["measured_per_chunk"] == {}
    assert rec["match"] is True
    assert rec["per_iteration_count"] == 0
    assert rec["per_iteration_bytes"] == 0


@pytest.mark.parametrize("k", (2, 8))
@pytest.mark.parametrize("nu", [0.0, NU], ids=["hm", "nu"])
def test_serve_points_match_model(serve_audits, k, nu):
    """The point-sharded serving chunk's collectives equal
    ServeCommModel EXACTLY -- Theorem-8 launch counts per iteration
    (payloads vmap-batched by S) plus the two chunk-boundary psums."""
    rec = _find(serve_audits, k=k, nu=nu, sharded=True,
                backend="jnp")[0]
    rounds = (float(projections.BISECT_ROUNDS_SOLVER) if nu > 0
              else 0.0)
    model = dist.ServeCommModel(k=k, num_slots=rec["num_slots"],
                                nu_rounds_per_iter=rounds)
    assert rec["measured"] == comm_audit.multiset_to_json(
        model.collective_multiset(rec["block_size"]))
    assert rec["measured_per_chunk"] == comm_audit.multiset_to_json(
        model.per_chunk_multiset(rec["d"]))
    assert rec["match"] is True
    assert rec["per_iteration_count"] == \
        model.collectives_per_iteration(rec["block_size"])


def test_serve_backend_stable(serve_audits):
    """jnp and pallas backends emit the SAME serve-chunk multisets."""
    jr = _find(serve_audits, k=2, nu=NU, sharded=True,
               backend="jnp")[0]
    pr = _find(serve_audits, k=2, nu=NU, sharded=True,
               backend="pallas")[0]
    assert jr["measured"] == pr["measured"]
    assert jr["measured_per_chunk"] == pr["measured_per_chunk"]
    assert pr["match"] is True


def test_scalar_model_linear_in_k():
    """The paper-convention scalar count is exactly linear in k and
    independent of n, d (Theorem 8's O(k) per iteration)."""
    for rounds in (0.0, float(projections.BISECT_ROUNDS_SOLVER)):
        per_k = [dist.CommModel(k=k, nu_rounds_per_iter=rounds)
                 .scalars_per_iteration() / k for k in (1, 5, 20, 256)]
        assert len(set(per_k)) == 1, per_k


# --------------------------------------------- production-mesh lowering
@pytest.mark.slow
@pytest.mark.parametrize("mesh", ["16x16", "2x16x16"])
def test_dryrun_saddle_dsvc_lowers(mesh):
    """launch/dryrun.py's saddle-dsvc entry lowers + compiles on the
    production meshes and the audited collectives match the model
    (run_one_saddle raises on mismatch).  Subprocess: 256/512 forced
    host devices."""
    import os
    import subprocess
    import sys

    code = (
        "import os, sys, json\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=512'\n"
        "os.environ['JAX_PLATFORMS'] = 'cpu'\n"
        "sys.path.insert(0, 'src')\n"
        "from repro.launch import dryrun\n"
        "rec = dryrun.run_one_saddle('svm_1m_nu', "
        f"multi_pod={mesh == '2x16x16'})\n"
        "assert rec['comm_audit']['match'] is True\n"
        "print('SADDLE_DRYRUN_OK', rec['mesh'], "
        "rec['comm_audit']['per_iteration_count'])\n")
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, timeout=600)
    assert "SADDLE_DRYRUN_OK" in out.stdout, \
        out.stdout[-2000:] + out.stderr[-4000:]


@pytest.mark.slow
@pytest.mark.parametrize("mesh", ["16x16", "2x16x16"])
def test_dryrun_saddle_serve_lowers(mesh):
    """launch/dryrun.py's saddle-serve entry lowers + compiles both
    serving shapes (lane-parallel 512-slot, point-sharded 1M-point) on
    the production meshes with the audited collectives matching the
    model (run_one_saddle_serve raises on mismatch).  Subprocess:
    256/512 forced host devices."""
    import os
    import subprocess
    import sys

    code = (
        "import os, sys\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=512'\n"
        "os.environ['JAX_PLATFORMS'] = 'cpu'\n"
        "sys.path.insert(0, 'src')\n"
        "from repro.launch import dryrun\n"
        "for shape in ('serve_lanes_512', 'serve_points_1m'):\n"
        "    rec = dryrun.run_one_saddle_serve(shape, "
        f"multi_pod={mesh == '2x16x16'})\n"
        "    assert rec['comm_audit']['match'] is True, rec\n"
        "print('SERVE_DRYRUN_OK')\n")
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, timeout=600)
    assert "SERVE_DRYRUN_OK" in out.stdout, \
        out.stdout[-2000:] + out.stderr[-4000:]


# ----------------------------------------------------- model self-checks
def test_model_multiset_totals_consistent():
    for k in (1, 8):
        for rounds in (0.0, 24.0):
            m = dist.CommModel(k=k, nu_rounds_per_iter=rounds)
            for b in (1, 4, 128):
                ms = m.collective_multiset(b)
                assert sum(ms.values()) == \
                    m.collectives_per_iteration(b)
                assert sum(e * c for (_, _, e), c in ms.items()) == \
                    m.payload_elements_per_iteration(b)
            want = 3 if rounds == 0 else 5 + int(rounds)
            assert m.collectives_per_iteration(1) == want


def test_audit_hlo_rejects_unknown_dynamic_loop():
    """A collective inside a while with no known trip count (below the
    step loop) must fail loudly, not undercount."""
    hlo = """\
HloModule m

%region_add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add.1 = f32[] add(f32[] %a, f32[] %b)
}

%body.1 (p: (s32[], f32[2])) -> (s32[], f32[2]) {
  %p = (s32[], f32[2]) parameter(0)
  %x = f32[2]{0} get-tuple-element((s32[], f32[2]) %p), index=1
  %ar = f32[2]{0} all-reduce(f32[2]{0} %x), to_apply=%region_add
  ROOT %t = (s32[], f32[2]) tuple(s32[] %c, f32[2]{0} %ar)
}

ENTRY %main (p0: (s32[], f32[2])) -> (s32[], f32[2]) {
  %p0 = (s32[], f32[2]) parameter(0)
  ROOT %w = (s32[], f32[2]) while((s32[], f32[2]) %p0), condition=%cond.1, body=%body.1
}
"""
    with pytest.raises(ValueError, match="known_trip_count"):
        comm_audit.audit_hlo(hlo, has_step_loop=False)
    # with the step loop flagged, that SAME dynamic loop is the
    # iteration boundary and the body is the per-iteration multiset
    counts = comm_audit.audit_hlo(hlo, has_step_loop=True)
    assert counts.per_iteration == {("all-reduce", "add", 2): 1}
    assert counts.per_chunk == {}
