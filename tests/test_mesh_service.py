"""Mesh-sharded serving (repro.serve.solver_service with a device
mesh): 1-device-mesh bit-for-bit parity with the meshless service,
point-sharded admission at k=1 and k=8, pallas through the sharded slot
step, and the sharded-slot fault paths (quarantine/cancel isolation,
shard-loss recovery via the renormalized-mass rule).

The in-process tests use a 1-device mesh -- shard_map over one device
must reproduce the meshless driver bit-for-bit, so every assertion here
is exact equality, not allclose.  Multi-device coverage (a real 8-way
point shard with live collectives) runs in subprocesses because the
host device count must be forced before jax initializes, exactly like
tests/test_distributed.py.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.data import synthetic
from repro.serve.solver_service import FitRequest, SolverService

pytestmark = pytest.mark.serve

C = 40      # service chunk length (same as tests/test_solver_service.py)


def _mesh1():
    # two axes of one device each: exercises the full axis plumbing
    # (multi-axis slot placement, tuple axis_name) with serial semantics
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def two_problems():
    ds1 = synthetic.blobs(40, 50, 16, gap=1.2, spread=0.15, seed=0)
    ds2 = synthetic.blobs(35, 45, 16, gap=0.8, spread=0.3, seed=2)
    return ds1, ds2       # both land in the (128, 16) bucket


def _drain(svc, reqs):
    rids = [svc.submit(FitRequest(**r)) for r in reqs]
    results = svc.run()
    return [results[r] for r in rids]


def _assert_bitexact(a, b):
    assert np.array_equal(np.asarray(a.w), np.asarray(b.w))
    assert float(a.b) == float(b.b)
    assert float(a.objective) == float(b.objective)
    assert a.iterations == b.iterations
    assert a.bucket == b.bucket
    assert np.array_equal(np.asarray(a.history, dtype=np.float64),
                          np.asarray(b.history, dtype=np.float64))


@pytest.mark.parametrize("nu_frac", [0.0, 0.85])
def test_one_device_mesh_bitexact(two_problems, nu_frac):
    """A 1-device mesh service must be indistinguishable from the
    meshless service: same w, b, objective, history, bit for bit --
    the regression gate for the shard_map-wrapped chunk path."""
    ds1, ds2 = two_problems
    reqs = [dict(x=ds1.x, y=ds1.y, num_iters=3 * C, seed=1,
                 nu=nu_frac and 1.0 / (nu_frac * 40)),
            dict(x=ds2.x, y=ds2.y, num_iters=2 * C, seed=9,
                 nu=nu_frac and 1.0 / (nu_frac * 35))]
    plain = _drain(SolverService(num_slots=4, chunk_steps=C), reqs)
    mesh = _drain(SolverService(num_slots=4, chunk_steps=C,
                                mesh=_mesh1()), reqs)
    for a, b in zip(plain, mesh):
        _assert_bitexact(a, b)


def test_point_sharded_k1_bitexact(two_problems):
    """shard_points_above=0 routes EVERY request into a point-sharded
    group; with k=1 the shard bucket degenerates to the plain bucket
    (1 * bucket_length(n) == bucket_length(n)) and the in-step
    collectives are identity, so results must still be bit-exact."""
    ds1, ds2 = two_problems
    reqs = [dict(x=ds1.x, y=ds1.y, num_iters=3 * C, seed=1),
            dict(x=ds2.x, y=ds2.y, num_iters=3 * C, seed=9,
                 nu=1.0 / (0.85 * 35))]
    plain = _drain(SolverService(num_slots=2, chunk_steps=C), reqs)
    sharded = _drain(SolverService(num_slots=2, chunk_steps=C,
                                   mesh=_mesh1(), shard_points_above=0,
                                   shard_num_slots=2), reqs)
    for a, b in zip(plain, sharded):
        _assert_bitexact(a, b)


def test_pallas_interpret_one_device_mesh_parity(two_problems):
    """backend="pallas" through the SHARDED slot step (interpret mode
    on CPU): the point-sharded 1-device group must match the meshless
    pallas service bit-for-bit and the jnp mesh service numerically."""
    ds1, _ = two_problems
    reqs = [dict(x=ds1.x, y=ds1.y, num_iters=C, seed=3)]
    plain = _drain(SolverService(num_slots=2, chunk_steps=C,
                                 backend="pallas"), reqs)
    mesh = _drain(SolverService(num_slots=2, chunk_steps=C,
                                backend="pallas", mesh=_mesh1(),
                                shard_points_above=0,
                                shard_num_slots=2), reqs)
    _assert_bitexact(plain[0], mesh[0])
    jnp_mesh = _drain(SolverService(num_slots=2, chunk_steps=C,
                                    mesh=_mesh1(), shard_points_above=0,
                                    shard_num_slots=2), reqs)
    np.testing.assert_allclose(mesh[0].w, jnp_mesh[0].w, atol=1e-5)
    np.testing.assert_allclose(mesh[0].objective, jnp_mesh[0].objective,
                               atol=1e-5)


def _run_subprocess(code, timeout=600):
    env = dict(os.environ)
    # pin the subprocess to CPU: --xla_force_host_platform_device_count
    # only applies there, and a libtpu build would probe TPU metadata
    # for minutes before falling back (see tests/test_distributed.py)
    env["JAX_PLATFORMS"] = "cpu"
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."), env=env,
        timeout=timeout)


_COMMON_PREAMBLE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax
import numpy as np
from repro.data import synthetic
from repro.serve.solver_service import FitRequest, SolverService

C = 40
mesh = jax.make_mesh((8,), ("data",))
ds1 = synthetic.blobs(40, 50, 16, gap=1.2, spread=0.15, seed=0)
ds2 = synthetic.blobs(35, 45, 16, gap=0.8, spread=0.3, seed=2)
big = synthetic.blobs(300, 280, 16, gap=1.0, spread=0.25, seed=5)
NU_BIG = 1.0 / (0.8 * 300)

def drain(svc, reqs):
    rids = [svc.submit(FitRequest(**r)) for r in reqs]
    results = svc.run()
    return rids, [results[r] for r in rids]
"""


def test_mesh_service_multidevice_parity():
    """Production path on a real 8-device host mesh: lane-parallel
    groups match the meshless service, a point-sharded large-n fit
    (live Theorem-8 collectives) matches a solo solve at the same
    bucket."""
    code = _COMMON_PREAMBLE + r"""
from repro.core import preprocess as pp
from repro.core import saddle
from repro.core.svm import recover_hyperplane, split_classes

# ---- lane-parallel parity: S=8 over 8 devices (1 whole lane each).
# Not bit-exact vs meshless: the chunk body is traced at the 1-slot
# per-device extent, so XLA fuses differently (reassociation-level
# noise only; bit-exactness is pinned by the 1-device-mesh tests).
reqs = [dict(x=ds1.x, y=ds1.y, num_iters=3 * C, seed=1),
        dict(x=ds2.x, y=ds2.y, num_iters=3 * C, seed=9,
             nu=1.0 / (0.85 * 35))]
_, plain = drain(SolverService(num_slots=8, chunk_steps=C), reqs)
_, lanes = drain(SolverService(num_slots=8, chunk_steps=C, mesh=mesh),
                 reqs)
for a, b in zip(plain, lanes):
    assert np.allclose(a.w, b.w, atol=1e-6), \
        np.abs(np.asarray(a.w) - np.asarray(b.w)).max()
    assert abs(float(a.objective) - float(b.objective)) < 1e-6
print("LANES_PARITY_OK")

# ---- point-sharded fit: k=8 shard bucket happens to equal the plain
# bucket at n=580 (8 * bucket_length(73) == bucket_length(580) == 1024),
# so a solo solve at the same bucket replays the same block schedule;
# only collective reassociation separates the trajectories.
svc = SolverService(num_slots=8, chunk_steps=C, mesh=mesh,
                    shard_points_above=256, shard_num_slots=2)
_, (res_big,) = drain(svc, [dict(x=big.x, y=big.y, num_iters=3 * C,
                                 seed=5, nu=NU_BIG)])
assert res_big.bucket[0] == 8 * pp.bucket_length(-(-580 // 8))

xp, xm = split_classes(big.x, big.y)
k_pre, _ = jax.random.split(jax.random.key(5))
pre = pp.preprocess(xp, xm, k_pre)
n_b, d_b = res_big.bucket
ser = saddle.solve(pre.xp, pre.xm, nu=NU_BIG, num_iters=3 * C,
                   record_every=C, seed=5, n_pad=n_b, d_pad=d_b)
eta = np.exp(np.asarray(ser.state.log_eta))
xi = np.exp(np.asarray(ser.state.log_xi))
w_ref, b_ref, *_ = recover_hyperplane(pre, eta, xi, pre.xp, pre.xm)
assert np.allclose(res_big.w, w_ref, atol=1e-4), \
    np.abs(np.asarray(res_big.w) - w_ref).max()
assert np.allclose(res_big.b, b_ref, atol=1e-4)
print("POINTS_PARITY_OK")
"""
    out = _run_subprocess(code)
    assert "LANES_PARITY_OK" in out.stdout, out.stdout + out.stderr
    assert "POINTS_PARITY_OK" in out.stdout, out.stdout + out.stderr


@pytest.mark.faults
def test_sharded_slot_fault_paths():
    """Fault paths of POINT-SHARDED slots on a real 8-device mesh:
    poison -> structured FAILED and cancel both leave the unsharded
    batch-mates bit-identical to a run that never saw the sharded
    request; losing one shard of a running slot follows the
    renormalized-mass recovery rule of core.distributed
    (tests/test_distributed.py), with the co-resident slot untouched."""
    code = _COMMON_PREAMBLE + r"""
import jax.numpy as jnp
from repro.core import distributed as dist
from repro.core import engine
from repro.core import preprocess as pp
from repro.core import saddle
from repro.core.svm import split_classes
from repro.serve.faults import Fault, FaultInjector, FaultPlan
from repro.serve.scheduler import RequestFailure, Status

LANE_REQS = [dict(x=ds1.x, y=ds1.y, num_iters=3 * C, seed=1),
             dict(x=ds2.x, y=ds2.y, num_iters=3 * C, seed=9,
                  nu=1.0 / (0.85 * 35))]
BIG_REQ = dict(x=big.x, y=big.y, num_iters=3 * C, seed=5, nu=NU_BIG)

def mesh_svc(injector=None):
    return SolverService(num_slots=8, chunk_steps=C, mesh=mesh,
                         shard_points_above=256, shard_num_slots=2,
                         fault_injector=injector)

# baseline: lanes only, no sharded request ever admitted
_, base = drain(mesh_svc(), LANE_REQS)

# ---- poison the sharded slot at chunk 1 (rids are sequential: the
# big request is rid 2) -> quarantine -> FAILED at max_retries=0
plan = FaultPlan(seed=0, faults=(Fault("poison", rid=2, at_chunk=1),))
svc = mesh_svc(FaultInjector(plan))
rids, res = drain(svc, LANE_REQS + [BIG_REQ])
assert rids[2] == 2
assert isinstance(res[2], RequestFailure)
assert res[2].status is Status.FAILED
for a, b in zip(base, res[:2]):
    assert np.array_equal(np.asarray(a.w), np.asarray(b.w))
    assert float(a.objective) == float(b.objective)
print("POISON_ISOLATION_OK")

# ---- cancel the sharded request mid-run
svc = mesh_svc()
r_lanes = [svc.submit(FitRequest(**r)) for r in LANE_REQS]
r_big = svc.submit(FitRequest(**BIG_REQ))
svc.step()                       # one chunk: everything is running
assert svc.cancel(r_big)
assert svc.status(r_big) is Status.CANCELLED
results = svc.run()
failure = results[r_big]
assert isinstance(failure, RequestFailure)
assert failure.status is Status.CANCELLED
for a, rid in zip(base, r_lanes):
    assert np.array_equal(np.asarray(a.w), np.asarray(results[rid].w))
print("CANCEL_ISOLATION_OK")

# ---- shard loss: drop one of 8 shards of a RUNNING sharded slot.
# Engine-level replay of tests/test_distributed.py's drop_client
# semantics on the serving layout: dropped columns carry exactly zero
# dual mass forever, the next MWU normalizer round rescales each
# class's surviving mass back to 1, and the co-resident slot is
# bit-identical to a run without the drop.  Hard margin: the sum-to-1
# normalizer IS the repair (a nu cap can be left infeasible by a drop
# -- surviving support below 1/nu pins the class mass at nu*support).
xp, xm = split_classes(big.x, big.y)
pre = pp.preprocess(xp, xm, jax.random.key(7))
n1, n2 = len(xp), len(xm)
k = 8
n_pad = k * pp.bucket_length(-(-(n1 + n2) // k))
d = pre.xp.shape[1]
pkd = pp.pack_points_to(pre.xp, pre.xm, n_pad, d)
p = saddle.make_params(n1 + n2, d, eps=1e-3, beta=0.1, nu=0.0,
                       block_size=1)
row = engine.slot_params_row(p)
S = 2
sp = engine.SlotParams(*(jnp.full((S,), v) for v in row))

def run_chunks(num, state, x_t, sign):
    for _ in range(num):
        state, obj, healthy = engine.run_chunk_slots_sharded(
            state, x_t, sign, sp, C, mesh=mesh, slot_axes=(),
            point_axes=("data",), chunk_steps=C, d=d, block_size=1,
            project=False)
    return state, obj, healthy

def fresh():
    st = engine.init_slot_state(S, n_pad, d)
    for slot in range(S):
        ps = engine.init_packed_state(pkd.sign, n1, n2, d)
        _, k_run = jax.random.split(jax.random.key(20 + slot))
        st = engine.admit_into_slot(st, jnp.int32(slot), ps, k_run,
                                    10**6)
    x_t = jnp.stack([pkd.x_t] * S)
    sign = jnp.stack([pkd.sign] * S)
    return st, x_t, sign

# with the drop: 1 warm chunk, lose shard 2 of slot 1, 2 more chunks
st, x_t, sign = fresh()
st, _, _ = run_chunks(1, st, x_t, sign)
st, sign = dist.drop_slot_shard(st, sign, jnp.int32(1), jnp.int32(2),
                                num_shards=k)
st, obj, healthy = run_chunks(2, st, x_t, sign)
lam = np.exp(np.asarray(st.log_lam))
sgn = np.asarray(sign)
m = n_pad // k
assert lam[1, 2 * m:3 * m].sum() == 0.0          # lost shard: zero mass
np.testing.assert_allclose(lam[1][sgn[1] > 0].sum(), 1.0, rtol=1e-5)
np.testing.assert_allclose(lam[1][sgn[1] < 0].sum(), 1.0, rtol=1e-5)
assert bool(healthy[1]) and np.isfinite(float(obj[1]))

# without the drop: slot 0 must be bit-identical either way
st0, x_t0, sign0 = fresh()
st0, _, _ = run_chunks(3, st0, x_t0, sign0)
assert np.array_equal(np.asarray(st.w[0]), np.asarray(st0.w[0]))
assert np.array_equal(np.asarray(st.log_lam[0]),
                      np.asarray(st0.log_lam[0]))
print("SHARD_DROP_OK")
"""
    out = _run_subprocess(code)
    for sentinel in ("POISON_ISOLATION_OK", "CANCEL_ISOLATION_OK",
                     "SHARD_DROP_OK"):
        assert sentinel in out.stdout, out.stdout + out.stderr
