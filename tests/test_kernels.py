"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the pure-jnp
oracles in repro.kernels.ref."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [1, 5, 33, 100, 257])
@pytest.mark.parametrize("d", [8, 64, 256])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_kernel_sweep(n, d, dtype):
    rng = np.random.default_rng(n * d)
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    got = ops.fwht(x)
    want = ref.fwht_ref(x.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=tol, rtol=tol)


@pytest.mark.parametrize("tile_n", [3, 7, 13])
def test_fwht_odd_tile_n_parity(tile_n):
    """tile_n need not divide n or be a power of two: _fwht_jit pads
    rows to the tile, and the result must still match the oracle
    exactly (padding rows never leak into real rows)."""
    from repro.kernels.fwht import fwht_pallas
    rng = np.random.default_rng(tile_n)
    x = jnp.asarray(rng.normal(size=(50, 64)), jnp.float32)
    got = fwht_pallas(x, tile_n=tile_n)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.fwht_ref(x)), atol=1e-4)


def test_fwht_1d_squeeze_parity():
    """ops.fwht on a 1-D vector: batched internally, squeezed back."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=128), jnp.float32)
    got = ops.fwht(x)
    assert got.shape == (128,)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.fwht_ref(x[None]))[0],
                               atol=1e-4)


@pytest.mark.parametrize("d", [0, 3, 12, 100])
def test_fwht_non_pow2_d_fails_fast(d):
    """A non-power-of-two feature dim must raise BEFORE any tracing --
    the butterfly would silently compute garbage on it."""
    from repro.kernels.fwht import fwht_pallas
    x = jnp.zeros((4, d), jnp.float32)
    with pytest.raises(ValueError, match="power of two"):
        fwht_pallas(x)
    with pytest.raises(ValueError, match="power of two"):
        ops.fwht(jnp.zeros((d,), jnp.float32)[None])


def test_interpret_default_resolves_off_tpu():
    """interpret=None resolves via the backend: the interpreter
    everywhere except real TPU (this container is CPU-only)."""
    from repro.kernels import default_interpret
    assert default_interpret() == (jax.default_backend() != "tpu")


@pytest.mark.parametrize("n,b", [(17, 1), (256, 1), (1000, 4), (513, 128)])
def test_momentum_dot_sweep(n, b):
    rng = np.random.default_rng(n + b)
    cols = jnp.asarray(rng.normal(size=(n, b)), jnp.float32)
    ll = jnp.asarray(rng.normal(size=n) - 3, jnp.float32)
    lp = jnp.asarray(rng.normal(size=n) - 3, jnp.float32)
    got = ops.momentum_dot(cols, ll, lp, 0.95)
    want = ref.momentum_dot_ref(cols, jnp.exp(ll), jnp.exp(lp), 0.95)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4)


@pytest.mark.parametrize("n,b", [(17, 1), (512, 1), (1025, 8), (2048, 128)])
@pytest.mark.parametrize("sign", [1.0, -1.0])
def test_mwu_update_sweep(n, b, sign):
    rng = np.random.default_rng(n * 7 + b)
    cols = jnp.asarray(rng.normal(size=(n, b)), jnp.float32)
    ll = jnp.asarray(np.log(np.ones(n) / n), jnp.float32)
    u = jnp.asarray(rng.normal(size=n) * 0.1, jnp.float32)
    dw = jnp.asarray(rng.normal(size=b) * 0.01, jnp.float32)
    gamma, tau, d_eff = 1e-3, 40.0, 128.0
    got_log, got_u = ops.mwu_update(cols, ll, u, dw, sign=sign,
                                    gamma=gamma, tau=tau, d_eff=d_eff)
    want_log, want_u = ref.mwu_update_ref(cols, ll, u, dw, sign, gamma,
                                          tau, d_eff)
    want_log = want_log - jax.scipy.special.logsumexp(want_log)
    np.testing.assert_allclose(np.asarray(got_log), np.asarray(want_log),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_u), np.asarray(want_u),
                               atol=1e-5)


def _packed_problem(rng, n_pad, n1, n2, d, b):
    """Packed operand with lane padding + per-class log weights."""
    NEG = -1e30
    x = rng.normal(size=(n_pad, d)).astype(np.float32)
    x[n1 + n2:] = 0.0
    sign = np.zeros(n_pad, np.float32)
    sign[:n1] = 1.0
    sign[n1:n1 + n2] = -1.0
    log_lam = np.full(n_pad, NEG, np.float32)
    log_lam[:n1] = -np.log(n1) + 0.1 * rng.normal(size=n1)
    log_lam[n1:n1 + n2] = -np.log(n2) + 0.1 * rng.normal(size=n2)
    idx = rng.choice(d, b, replace=False).astype(np.int32)
    return (jnp.asarray(np.ascontiguousarray(x.T)), jnp.asarray(sign),
            jnp.asarray(log_lam), jnp.asarray(idx))


@pytest.mark.parametrize("n_pad,n1,n2,b", [(128, 40, 50, 1),
                                           (1024, 500, 490, 8),
                                           (2176, 1000, 1100, 128)])
def test_momentum_dot_packed_sweep(n_pad, n1, n2, b):
    """Packed signed momentum sweep (in-kernel gather from the
    column-major mirror) vs the jnp oracle, with lane padding active."""
    rng = np.random.default_rng(n_pad + b)
    d = 256
    x_t, sign, ll, idx = _packed_problem(rng, n_pad, n1, n2, d, b)
    lp = ll + jnp.asarray(0.05 * rng.normal(size=n_pad), jnp.float32) * (
        sign != 0)
    got = ops.momentum_dot_packed(x_t, idx, ll, lp, sign, 0.95)
    want = ref.momentum_dot_packed_ref(x_t, idx, ll, lp, sign, 0.95)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4)


@pytest.mark.parametrize("n_pad,n1,n2,b", [(128, 40, 50, 1),
                                           (1024, 500, 490, 8),
                                           (2176, 1000, 1100, 128)])
def test_mwu_update_packed_sweep(n_pad, n1, n2, b):
    """Packed fused dual update vs the jnp oracle: log weights, u, and
    BOTH per-class logsumexp normalizers from one sweep."""
    rng = np.random.default_rng(n_pad * 3 + b)
    d = 256
    x_t, sign, ll, idx = _packed_problem(rng, n_pad, n1, n2, d, b)
    u = jnp.asarray(rng.normal(size=n_pad).astype(np.float32) * 0.1)
    dw = jnp.asarray(rng.normal(size=b).astype(np.float32) * 0.01)
    gamma, tau, d_eff = 1e-3, 40.0, float(d)
    got = ops.mwu_update_packed(x_t, idx, ll, u, dw, sign, gamma=gamma,
                                tau=tau, d_eff=d_eff)
    want = ref.mwu_update_packed_ref(x_t, idx, ll, u, dw, sign, gamma,
                                     tau, d_eff)
    # real slots of log_new; padding slots only need to stay hugely
    # negative (their magnitude is ~1e30 where float error is ~1e24)
    n = n1 + n2
    for g, w, tol in [(got[0][:n], want[0][:n], 1e-4),
                      (got[1], want[1], 1e-5)]:
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=tol)
    assert np.asarray(got[0][n:] < -1e20).all()
    # per-class normalizers agree as full logsumexps
    for (m_g, s_g), (m_w, s_w) in [((got[2], got[3]), (want[2], want[3])),
                                   ((got[4], got[5]), (want[4], want[5]))]:
        lse_g = float(m_g) + np.log(float(s_g))
        lse_w = float(m_w) + np.log(float(s_w))
        np.testing.assert_allclose(lse_g, lse_w, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 300), st.sampled_from([1, 2, 16]),
       st.integers(0, 9999))
def test_mwu_update_property(n, b, seed):
    """Kernel output is a normalized log-distribution for any input."""
    rng = np.random.default_rng(seed)
    cols = jnp.asarray(rng.normal(size=(n, b)), jnp.float32)
    lam = rng.exponential(size=n)
    ll = jnp.asarray(np.log(lam / lam.sum()), jnp.float32)
    u = jnp.asarray(rng.normal(size=n), jnp.float32)
    dw = jnp.asarray(rng.normal(size=b) * 0.1, jnp.float32)
    log_new, _ = ops.mwu_update(cols, ll, u, dw, sign=1.0, gamma=1e-2,
                                tau=10.0, d_eff=float(max(n // 2, 1)))
    assert abs(float(jnp.exp(log_new).sum()) - 1.0) < 1e-4
