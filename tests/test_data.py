"""Data pipeline: synthetic generators (Appendix D), libsvm round-trip,
token pipeline."""

import numpy as np

from repro.data import libsvm, synthetic
from repro.data.tokens import TokenPipeline


def test_separable_is_separable():
    ds = synthetic.separable(400, 16, seed=0)
    # verify with a quick perceptron-ish check: the generating normal w
    # is unknown, so run a few passes of perceptron
    w = np.zeros(16)
    b = 0.0
    for _ in range(200):
        margins = ds.y * (ds.x @ w - b)
        bad = np.where(margins <= 0)[0]
        if len(bad) == 0:
            break
        i = bad[0]
        w += ds.y[i] * ds.x[i]
        b -= ds.y[i]
    assert (ds.y * (ds.x @ w - b) > 0).all()


def test_non_separable_has_flips():
    ds = synthetic.non_separable(2000, 8, beta2=0.4, seed=1)
    assert set(np.unique(ds.y)) == {-1, 1}
    assert len(ds.y) == 2000


def test_sparse_nnz():
    ds = synthetic.sparse_non_separable(50, 32, nnz=5, seed=2)
    nnz = (ds.x != 0).sum(axis=1)
    assert (nnz <= 5).all()


def test_split_disjoint():
    ds = synthetic.blobs(40, 40, 4, seed=0)
    tr, te = ds.split(0.25, seed=1)
    assert len(tr.y) + len(te.y) == 80
    assert len(te.y) == 20


def test_libsvm_roundtrip(tmp_path):
    ds = synthetic.sparse_non_separable(20, 10, nnz=3, seed=3)
    p = str(tmp_path / "data.libsvm")
    libsvm.save_libsvm(p, ds)
    back = libsvm.load_libsvm(p, n_features=10)
    np.testing.assert_allclose(back.x, ds.x, atol=1e-5)
    np.testing.assert_array_equal(back.y, ds.y)


def test_token_pipeline_shapes():
    pipe = TokenPipeline(vocab_size=1000, seq_len=64, batch_size=4, seed=0)
    b = pipe.next_batch()
    assert b.tokens.shape == (4, 64) and b.targets.shape == (4, 64)
    assert (b.tokens >= 0).all() and (b.tokens < 1000).all()
    np.testing.assert_array_equal(b.tokens[:, 1:], b.targets[:, :-1])
