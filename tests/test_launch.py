"""Launch-layer units that don't need a big mesh: input specs, shape
applicability, mesh constructors (shape math only), config registry."""

import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_configs
from repro.launch import specs
from repro.launch.shapes import SHAPES, applicability

ASSIGNED = [
    "qwen2-vl-7b", "chatglm3-6b", "xlstm-125m", "recurrentgemma-2b",
    "deepseek-v2-236b", "deepseek-v2-lite-16b", "gemma-7b",
    "deepseek-67b", "whisper-medium", "h2o-danube-1.8b",
]


def test_all_assigned_registered():
    known = set(list_configs())
    for a in ASSIGNED:
        assert a in known
    assert "gemma-7b-swa" in known      # the dense->SWA variant


def test_exact_assigned_dimensions():
    """Configs carry the exact dimensions from the assignment table."""
    spec = {
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 10944, 102400),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
    }
    for name, (L, d, h, kv, dff, v) in spec.items():
        c = get_config(name)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, h, kv, dff, v), name


def test_moe_configs():
    c = get_config("deepseek-v2-236b")
    assert (c.moe_num_experts, c.moe_top_k, c.moe_num_shared,
            c.moe_d_ff, c.mla_kv_lora) == (160, 6, 2, 1536, 512)
    c = get_config("deepseek-v2-lite-16b")
    assert (c.moe_num_experts, c.moe_top_k, c.mla_q_lora) == (64, 6, 0)


def test_input_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"] == ("prefill_32k", "prefill", 32768, 32)
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long500k_applicability():
    runs = {a: applicability(get_config(a), SHAPES["long_500k"])[0]
            for a in ASSIGNED + ["gemma-7b-swa"]}
    assert runs == {
        "qwen2-vl-7b": False, "chatglm3-6b": False,
        "xlstm-125m": True, "recurrentgemma-2b": True,
        "deepseek-v2-236b": False, "deepseek-v2-lite-16b": False,
        "gemma-7b": False, "deepseek-67b": False,
        "whisper-medium": False, "h2o-danube-1.8b": True,
        "gemma-7b-swa": True,
    }


@pytest.mark.parametrize("arch", ["qwen2-vl-7b", "whisper-medium",
                                  "gemma-7b"])
@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k"])
def test_input_specs_shapes(arch, shape):
    cfg = get_config(arch)
    sp = specs.input_specs(arch, shape)
    b = SHAPES[shape].global_batch
    s = SHAPES[shape].seq_len
    assert sp["tokens"].shape == (b, s)
    assert sp["tokens"].dtype == jnp.int32
    if shape == "train_4k":
        assert sp["targets"].shape == (b, s)
    if cfg.vision_embeds:
        assert sp["vision_embeds"].shape == (b, s, cfg.d_model)
        assert sp["positions"].shape == (3, b, s)
    if cfg.is_encoder_decoder:
        assert sp["enc_frames"].shape == (b, cfg.enc_frames, cfg.d_model)


def test_padded_vocab_divisible_by_mesh():
    for a in ASSIGNED:
        assert get_config(a).padded_vocab % 256 == 0, a


def test_cache_layout_prefers_heads_over_seq():
    """P4 regression: the GQA cache must NOT shard the sequence dim over
    'model' (a dynamic-update-slice at a traced index then reshards the
    whole cache via all-to-all every decode step -- measured 14 GiB on
    gemma-7b decode_32k). kv_heads takes 'model'; seq only data/pod."""
    from repro.launch.specs import _leaf_logical
    spec = _leaf_logical("blocks/0/self/k", (24, 128, 32768, 16, 256)[1:])
    assert spec == ["batch", "kv_seq_bp", "kv_heads", None]
    from repro.models.sharding import DEFAULT_RULES
    assert "model" not in DEFAULT_RULES["kv_seq_bp"]
    assert DEFAULT_RULES["kv_heads"] == ("model",)
    # MLA caches keep seq-over-model (no heads dim; memory forces it)
    assert _leaf_logical("blocks/0/self/c_kv", (128, 32768, 512)) == \
        ["batch", "kv_seq", None]
