"""The fused solver engine (repro.core.engine): backend/mode parity,
block-sampling correctness, and the compile-once chunk driver."""

import jax
import numpy as np
import pytest

from repro.core import distributed as dist
from repro.core import engine
from repro.core import preprocess as pp
from repro.core import saddle


@pytest.fixture(scope="module")
def problem():
    """Non-divisible n (37, 53): every k > 1 exercises padding points."""
    rng = np.random.default_rng(0)
    d = 16
    xp = rng.normal(size=(37, d)).astype(np.float32) * 0.3 + 0.4
    xm = rng.normal(size=(53, d)).astype(np.float32) * 0.3 - 0.4
    pre = pp.preprocess(xp, xm, jax.random.key(1))
    return np.asarray(pre.xp), np.asarray(pre.xm)


# ------------------------------------------------------------ parity
@pytest.mark.parametrize("nu_frac", [0.0, 0.8])
def test_serial_dist_kernel_parity(problem, nu_frac):
    """Serial, distributed-sim, and Pallas-kernel backends are the SAME
    engine step, so their iterates must coincide -- for nu = 0 and
    nu > 0, with padding points active (n1=37, n2=53 not divisible by
    k=5)."""
    xp, xm = problem
    nu = nu_frac and 1.0 / (nu_frac * xp.shape[0])
    ser = saddle.solve(xp, xm, nu=nu, num_iters=300)
    ker = saddle.solve(xp, xm, nu=nu, num_iters=300, use_kernels=True)
    d5 = dist.solve_distributed(xp, xm, k=5, nu=nu, num_iters=300)
    w = np.asarray(ser.state.w)
    np.testing.assert_allclose(w, np.asarray(ker.state.w), atol=1e-5)
    np.testing.assert_allclose(w, np.asarray(d5.state.w[0]), atol=1e-5)
    # dual parity through the round-robin unshard (padding dropped)
    eta, xi = dist.gather_duals(d5.state, xp.shape[0], xm.shape[0], 5)
    np.testing.assert_allclose(np.exp(np.asarray(ser.state.log_eta)),
                               eta, atol=1e-5)
    np.testing.assert_allclose(np.exp(np.asarray(ser.state.log_xi)),
                               xi, atol=1e-5)


def test_gather_duals_rejects_wrong_k(problem):
    xp, xm = problem
    d5 = dist.solve_distributed(xp, xm, k=5, num_iters=10)
    with pytest.raises(ValueError):
        dist.gather_duals(d5.state, xp.shape[0], xm.shape[0], 4)


# ------------------------------------------- block sampling correctness
def test_sample_block_without_replacement():
    """Coordinate blocks must be duplicate-free: a repeated index makes
    w.at[idx].set last-write-wins while cols @ dw double-counts the
    column in u (the seed bug)."""
    d, b = 32, 8
    for seed in range(50):
        idx = np.asarray(engine.sample_block(jax.random.key(seed), d, b))
        assert len(np.unique(idx)) == b
        assert idx.min() >= 0 and idx.max() < d


@pytest.mark.parametrize("use_kernels", [False, True])
def test_block_mode_u_invariant(problem, use_kernels):
    """u_p == xp @ w exactly (up to float error) after many block steps:
    the incremental rank-B update stays consistent only when sampling is
    without replacement."""
    xp, xm = problem
    res = saddle.solve(xp, xm, num_iters=200, block_size=4,
                       use_kernels=use_kernels)
    w = np.asarray(res.state.w)
    np.testing.assert_allclose(np.asarray(res.state.u_p), xp @ w,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(res.state.u_m), xm @ w,
                               atol=2e-4)


def test_block_mode_u_invariant_distributed(problem):
    """Same invariant per client shard in the distributed simulation."""
    xp, xm = problem
    res = dist.solve_distributed(xp, xm, k=5, num_iters=200, block_size=4)
    xp_sh, _ = dist.shard_points(xp, 5)
    w = np.asarray(res.state.w[0])
    for c in range(5):
        np.testing.assert_allclose(np.asarray(res.state.u_p[c]),
                                   xp_sh[c] @ w, atol=2e-4)


def test_block_size_exceeding_d_rejected(problem):
    """Without-replacement sampling caps the block at d coordinates, so
    a larger request is a configuration error, not a silent truncation."""
    xp, xm = problem
    with pytest.raises(ValueError):
        saddle.solve(xp, xm, num_iters=10, block_size=xp.shape[1] + 1)


# ------------------------------------------------- compile-once driver
def test_run_chunk_compiles_once_with_partial_final_chunk(problem):
    """A record_every-chunked solve whose final chunk is partial (250 =
    97 + 97 + 56) must trace/compile the chunk exactly once: the trip
    count is dynamic, only the key shape is static."""
    xp, xm = problem
    snap = dict(engine.trace_counts)
    res = saddle.solve(xp, xm, num_iters=250, record_every=97)
    delta = {k: v - snap.get(k, 0) for k, v in engine.trace_counts.items()
             if v != snap.get(k, 0)}
    assert delta == {(None, "jnp", 97): 1}, delta
    assert [h[0] for h in res.history] == [97, 194, 250]
    # the partial chunk really ran only 56 steps
    assert int(res.state.t) == 250


def test_partial_chunk_matches_stepwise_replay(problem):
    """A partial chunk (56 of 97) runs exactly the first 56 of the
    pre-split keys -- no more, no fewer, none of the padded tail."""
    import jax.numpy as jnp
    xp, xm = problem
    params = saddle.make_params(xp.shape[0] + xm.shape[0], xp.shape[1],
                                1e-3, 0.1)
    key = jax.random.key(7)
    xp_j, xm_j = jnp.asarray(xp), jnp.asarray(xm)

    st = saddle.init_state(xp.shape[0], xm.shape[0], xp.shape[1], xp, xm)
    got, _ = engine.run_chunk(st, key, xp_j, xm_j, 56, params=params,
                              chunk_steps=97)

    want = saddle.init_state(xp.shape[0], xm.shape[0], xp.shape[1],
                             xp, xm)
    for k in jax.random.split(key, 97)[:56]:
        want = engine.step(want, k, xp_j, xm_j, params)
    np.testing.assert_allclose(np.asarray(got.w), np.asarray(want.w),
                               atol=1e-6)
    assert int(got.t) == 56 == int(want.t)


def test_history_recorded_on_device(problem):
    """History objectives agree with the host-side recomputation."""
    xp, xm = problem
    res = saddle.solve(xp, xm, num_iters=120, record_every=60)
    want = float(saddle.objective(res.state.log_eta, res.state.log_xi,
                                  xp, xm))
    assert res.history[-1][0] == 120
    assert abs(res.history[-1][1] - want) < 1e-6
