"""The fused solver engine (repro.core.engine): backend/mode parity,
block-sampling correctness, and the compile-once chunk driver."""

import jax
import numpy as np
import pytest

from repro.core import distributed as dist
from repro.core import engine
from repro.core import preprocess as pp
from repro.core import saddle


@pytest.fixture(scope="module")
def problem():
    """Non-divisible n (37, 53): every k > 1 exercises padding points."""
    rng = np.random.default_rng(0)
    d = 16
    xp = rng.normal(size=(37, d)).astype(np.float32) * 0.3 + 0.4
    xm = rng.normal(size=(53, d)).astype(np.float32) * 0.3 - 0.4
    pre = pp.preprocess(xp, xm, jax.random.key(1))
    return np.asarray(pre.xp), np.asarray(pre.xm)


# ------------------------------------------------------------ parity
@pytest.mark.parametrize("nu_frac", [0.0, 0.8])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_packed_matches_reference_serial(problem, backend, nu_frac):
    """The packed single-sweep step must reproduce the unpacked
    reference engine (same keys, same sampler): serial x jnp/pallas x
    nu=0/nu>0."""
    import jax.numpy as jnp
    xp, xm = problem
    n1, n2 = xp.shape[0], xm.shape[0]
    nu = nu_frac and 1.0 / (nu_frac * n1)
    iters = 80
    params = saddle.make_params(n1 + n2, xp.shape[1], 1e-3, 0.1, nu=nu)
    xp_j, xm_j = jnp.asarray(xp), jnp.asarray(xm)
    # drive() splits one key per chunk off key(seed); replicate it for
    # the reference so both paths see identical step keys
    key = jax.random.split(jax.random.key(0))[1]

    ref = saddle.init_state(n1, n2, xp.shape[1], xp, xm)
    ref, _ = engine.run_chunk(ref, key, xp_j, xm_j, iters, params=params,
                              chunk_steps=iters, backend=backend)

    res = saddle.solve(xp, xm, nu=nu, num_iters=iters,
                       use_kernels=(backend == "pallas"))
    got = res.state
    np.testing.assert_allclose(np.asarray(got.w), np.asarray(ref.w),
                               atol=1e-5)
    for a, b in [(got.log_eta, ref.log_eta), (got.log_xi, ref.log_xi)]:
        np.testing.assert_allclose(np.exp(np.asarray(a)),
                                   np.exp(np.asarray(b)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(got.u_p), np.asarray(ref.u_p),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got.u_m), np.asarray(ref.u_m),
                               atol=1e-5)


@pytest.mark.parametrize("nu_frac", [0.0, 0.8])
def test_packed_matches_reference_distributed(problem, nu_frac):
    """Packed distributed (vmap sim) vs the REFERENCE unpacked
    distributed chunk, k=5 with round-robin padding active."""
    xp, xm = problem
    n1, n2 = xp.shape[0], xm.shape[0]
    nu = nu_frac and 1.0 / (nu_frac * n1)
    iters = 80
    k = 5
    params = saddle.make_params(n1 + n2, xp.shape[1], 1e-3, 0.1, nu=nu)
    key = jax.random.split(jax.random.key(0))[1]

    xp_sh, mask_p = dist.shard_points(xp, k)
    xm_sh, mask_m = dist.shard_points(xm, k)
    ref = dist.init_sharded_state(n1, n2, xp.shape[1], mask_p, mask_m)
    import jax.numpy as jnp
    ref, _ = dist.run_chunk_sim(ref, key, jnp.asarray(xp_sh),
                                jnp.asarray(xm_sh), iters, params=params,
                                chunk_steps=iters)

    res = dist.solve_distributed(xp, xm, k=k, nu=nu, num_iters=iters)
    np.testing.assert_allclose(np.asarray(res.state.w),
                               np.asarray(ref.w), atol=1e-5)
    for a, b in [(res.state.log_eta, ref.log_eta),
                 (res.state.log_xi, ref.log_xi)]:
        np.testing.assert_allclose(np.exp(np.asarray(a)),
                                   np.exp(np.asarray(b)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(res.state.u_p),
                               np.asarray(ref.u_p), atol=1e-5)


@pytest.mark.parametrize("nu_frac", [0.0, 0.8])
def test_serial_dist_kernel_parity(problem, nu_frac):
    """Serial, distributed-sim, and Pallas-kernel backends are the SAME
    engine step, so their iterates must coincide -- for nu = 0 and
    nu > 0, with padding points active (n1=37, n2=53 not divisible by
    k=5)."""
    xp, xm = problem
    nu = nu_frac and 1.0 / (nu_frac * xp.shape[0])
    ser = saddle.solve(xp, xm, nu=nu, num_iters=200)
    ker = saddle.solve(xp, xm, nu=nu, num_iters=200, use_kernels=True)
    d5 = dist.solve_distributed(xp, xm, k=5, nu=nu, num_iters=200)
    w = np.asarray(ser.state.w)
    np.testing.assert_allclose(w, np.asarray(ker.state.w), atol=1e-5)
    np.testing.assert_allclose(w, np.asarray(d5.state.w[0]), atol=1e-5)
    # dual parity through the round-robin unshard (padding dropped)
    eta, xi = dist.gather_duals(d5.state, xp.shape[0], xm.shape[0], 5)
    np.testing.assert_allclose(np.exp(np.asarray(ser.state.log_eta)),
                               eta, atol=1e-5)
    np.testing.assert_allclose(np.exp(np.asarray(ser.state.log_xi)),
                               xi, atol=1e-5)


def test_gather_duals_rejects_wrong_k(problem):
    xp, xm = problem
    d5 = dist.solve_distributed(xp, xm, k=5, num_iters=10)
    with pytest.raises(ValueError):
        dist.gather_duals(d5.state, xp.shape[0], xm.shape[0], 4)


# ------------------------------------------- block sampling correctness
def test_sample_block_without_replacement():
    """Coordinate blocks must be duplicate-free: a repeated index makes
    w.at[idx].set last-write-wins while cols @ dw double-counts the
    column in u (the seed bug)."""
    d, b = 32, 8
    keys = jax.random.split(jax.random.key(0), 50)
    idx = np.asarray(jax.vmap(
        lambda k: engine.sample_block(k, d, b))(keys))
    for row in idx:
        assert len(np.unique(row)) == b
        assert row.min() >= 0 and row.max() < d


def test_sample_block_distribution_equivalence():
    """The partial Fisher--Yates sampler must match the uniform
    without-replacement distribution (what the old full-permutation
    sampler drew): marginal inclusion b/d per coordinate, pairwise
    inclusion b(b-1)/(d(d-1)), and all ordered b-tuples distinct."""
    d, b, trials = 8, 3, 6000
    keys = jax.random.split(jax.random.key(42), trials)
    idx = np.asarray(jax.vmap(
        lambda k: engine.sample_block(k, d, b))(keys))        # (T, b)
    assert idx.shape == (trials, b)
    # marginal inclusion probability: every coordinate in b/d of draws
    inc = np.zeros(d)
    for c in range(d):
        inc[c] = (idx == c).any(axis=1).mean()
    p1 = b / d
    se1 = np.sqrt(p1 * (1 - p1) / trials)
    np.testing.assert_allclose(inc, p1, atol=6 * se1)
    # pairwise inclusion: P(i and j both drawn) = b(b-1)/(d(d-1))
    p2 = b * (b - 1) / (d * (d - 1))
    se2 = np.sqrt(p2 * (1 - p2) / trials)
    for i, j in [(0, 1), (2, 5), (3, 7), (6, 4)]:
        pij = ((idx == i).any(axis=1) & (idx == j).any(axis=1)).mean()
        assert abs(pij - p2) < 6 * se2, (i, j, pij, p2)
    # position uniformity: each SLOT of the draw is marginally uniform
    # (Fisher-Yates guarantees exchangeability the prefix-slice of a
    # sorted top-k would not)
    for slot in range(b):
        freq = np.bincount(idx[:, slot], minlength=d) / trials
        se = np.sqrt((1 / d) * (1 - 1 / d) / trials)
        np.testing.assert_allclose(freq, 1 / d, atol=6 * se)


@pytest.mark.parametrize("nu_frac", [0.0, 0.8])
def test_distributed_kernels_parity(problem, nu_frac):
    """ROADMAP gap: distributed + Pallas composition.  The packed
    kernels run under the vmap client simulation (interpret mode) and
    must match the jnp distributed path exactly -- nu=0 and nu>0, with
    round-robin padding active."""
    xp, xm = problem
    nu = nu_frac and 1.0 / (nu_frac * xp.shape[0])
    dj = dist.solve_distributed(xp, xm, k=5, nu=nu, num_iters=60)
    dk = dist.solve_distributed(xp, xm, k=5, nu=nu, num_iters=60,
                                use_kernels=True)
    np.testing.assert_allclose(np.asarray(dj.state.w),
                               np.asarray(dk.state.w), atol=1e-5)
    eta_j, xi_j = dist.gather_duals(dj.state, xp.shape[0], xm.shape[0], 5)
    eta_k, xi_k = dist.gather_duals(dk.state, xp.shape[0], xm.shape[0], 5)
    np.testing.assert_allclose(eta_j, eta_k, atol=1e-5)
    np.testing.assert_allclose(xi_j, xi_k, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dj.state.u_p),
                               np.asarray(dk.state.u_p), atol=1e-5)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_block_mode_u_invariant(problem, use_kernels):
    """u_p == xp @ w exactly (up to float error) after many block steps:
    the incremental rank-B update stays consistent only when sampling is
    without replacement."""
    xp, xm = problem
    res = saddle.solve(xp, xm, num_iters=200, block_size=4,
                       use_kernels=use_kernels)
    w = np.asarray(res.state.w)
    np.testing.assert_allclose(np.asarray(res.state.u_p), xp @ w,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(res.state.u_m), xm @ w,
                               atol=2e-4)


def test_block_mode_u_invariant_distributed(problem):
    """Same invariant per client shard in the distributed simulation."""
    xp, xm = problem
    res = dist.solve_distributed(xp, xm, k=5, num_iters=200, block_size=4)
    xp_sh, _ = dist.shard_points(xp, 5)
    w = np.asarray(res.state.w[0])
    for c in range(5):
        np.testing.assert_allclose(np.asarray(res.state.u_p[c]),
                                   xp_sh[c] @ w, atol=2e-4)


def test_block_size_exceeding_d_rejected(problem):
    """Without-replacement sampling caps the block at d coordinates, so
    a larger request is a configuration error, not a silent truncation."""
    xp, xm = problem
    with pytest.raises(ValueError):
        saddle.solve(xp, xm, num_iters=10, block_size=xp.shape[1] + 1)


# ------------------------------------------------ padding edge cases
@pytest.mark.parametrize("k", [2, 3, 7])
def test_parity_n_not_divisible_by_k(problem, k):
    """Parity matrix extension: k values where NEITHER class count
    (37, 53) divides evenly, so every shard carries round-robin padding
    points -- nu>0 so the capped projection runs over the padded
    layout."""
    xp, xm = problem
    nu = 1.0 / (0.8 * xp.shape[0])
    ser = saddle.solve(xp, xm, nu=nu, num_iters=120)
    dk = dist.solve_distributed(xp, xm, k=k, nu=nu, num_iters=120)
    np.testing.assert_allclose(np.asarray(ser.state.w),
                               np.asarray(dk.state.w[0]), atol=1e-5)
    eta, xi = dist.gather_duals(dk.state, xp.shape[0], xm.shape[0], k)
    np.testing.assert_allclose(np.exp(np.asarray(ser.state.log_eta)),
                               eta, atol=1e-5)
    np.testing.assert_allclose(np.exp(np.asarray(ser.state.log_xi)),
                               xi, atol=1e-5)


def test_nu_caps_no_mass_leak_into_lane_padding(problem):
    """n_pad > n with nu > 0: the capped-simplex projection must NEVER
    move mass into lane-padding slots -- they stay at NEG_INF exactly
    (exp == 0) and each class still sums to 1 over its REAL slots with
    every weight below the cap."""
    import jax.numpy as jnp
    from repro.core import preprocess as ppm
    xp, xm = problem
    n1, n2 = xp.shape[0], xm.shape[0]
    nu = 1.0 / (0.6 * n1)
    params = saddle.make_params(n1 + n2, xp.shape[1], 1e-3, 0.1, nu=nu)
    pts = ppm.pack_points(xp, xm)
    assert pts.n_pad > n1 + n2          # lane padding is actually active
    st = engine.init_packed_state(pts.sign, n1, n2, xp.shape[1])
    st, _ = engine.run_chunk_packed(st, jax.random.key(3), pts.x_t,
                                    pts.sign, 150, params=params,
                                    chunk_steps=150)
    lam = np.asarray(st.log_lam)
    assert (lam[n1 + n2:] == engine.NEG_INF).all()
    eta = np.exp(lam[:n1])
    xi = np.exp(lam[n1:n1 + n2])
    assert abs(eta.sum() - 1.0) < 1e-4 and abs(xi.sum() - 1.0) < 1e-4
    assert eta.max() <= nu + 1e-5 and xi.max() <= nu + 1e-5
    # distributed: round-robin padding slots (sign 0) must stay NEG_INF
    k = 3
    xp_sh, mask_p = dist.shard_points(xp, k)
    xm_sh, mask_m = dist.shard_points(xm, k)
    x_t, sign = dist.pack_shards(xp_sh, mask_p, xm_sh, mask_m)
    dst = engine.init_packed_state(jnp.asarray(sign), n1, n2,
                                   xp.shape[1])
    dst, _ = dist.run_chunk_sim_packed(
        dst, jax.random.key(3), jnp.asarray(x_t), jnp.asarray(sign),
        150, params=params, chunk_steps=150)
    dlam = np.asarray(dst.log_lam)
    pad = sign == 0
    assert (dlam[pad] == engine.NEG_INF).all()
    assert abs(np.exp(dlam[sign > 0]).sum() - 1.0) < 1e-4
    assert np.exp(dlam[sign != 0]).max() <= nu + 1e-5


def test_k1_distributed_equals_serial_bit_for_bit(problem):
    """k=1 is the degenerate client: the ONLY difference from serial is
    the size-1 psum/pmax, which must be exact -- every state leaf
    bit-for-bit equal, nu=0 and nu>0."""
    xp, xm = problem
    for nu_frac in (0.0, 0.8):
        nu = nu_frac and 1.0 / (nu_frac * xp.shape[0])
        ser = saddle.solve(xp, xm, nu=nu, num_iters=120)
        d1 = dist.solve_distributed(xp, xm, k=1, nu=nu, num_iters=120)
        np.testing.assert_array_equal(np.asarray(ser.state.w),
                                      np.asarray(d1.state.w[0]))
        for a, b in [(ser.state.log_eta, d1.state.log_eta[0]),
                     (ser.state.log_xi, d1.state.log_xi[0]),
                     (ser.state.u_p, d1.state.u_p[0]),
                     (ser.state.u_m, d1.state.u_m[0])]:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- compile-once driver
def test_run_chunk_compiles_once_with_partial_final_chunk(problem):
    """A record_every-chunked solve whose final chunk is partial (250 =
    97 + 97 + 56) must trace/compile the chunk exactly once: the trip
    count is dynamic, only the key shape is static."""
    xp, xm = problem
    snap = dict(engine.trace_counts)
    res = saddle.solve(xp, xm, num_iters=250, record_every=97)
    delta = {k: v - snap.get(k, 0) for k, v in engine.trace_counts.items()
             if v != snap.get(k, 0)}
    n_pad = pp.packed_length(xp.shape[0] + xm.shape[0])
    want = engine.slot_trace_key(1, n_pad, xp.shape[1], 1, 97,
                                 False, False, "jnp")
    assert delta == {want: 1}, delta
    assert [h[0] for h in res.history] == [97, 194, 250]
    # the partial chunk really ran only 56 steps
    assert int(res.state.t) == 250


def test_partial_chunk_matches_stepwise_replay(problem):
    """A partial chunk (56 of 97) runs exactly the first 56 of the
    pre-split keys -- no more, no fewer, none of the padded tail."""
    import jax.numpy as jnp
    xp, xm = problem
    params = saddle.make_params(xp.shape[0] + xm.shape[0], xp.shape[1],
                                1e-3, 0.1)
    key = jax.random.key(7)
    xp_j, xm_j = jnp.asarray(xp), jnp.asarray(xm)

    st = saddle.init_state(xp.shape[0], xm.shape[0], xp.shape[1], xp, xm)
    got, _ = engine.run_chunk(st, key, xp_j, xm_j, 56, params=params,
                              chunk_steps=97)

    want = saddle.init_state(xp.shape[0], xm.shape[0], xp.shape[1],
                             xp, xm)
    for k in jax.random.split(key, 97)[:56]:
        want = engine.step(want, k, xp_j, xm_j, params)
    np.testing.assert_allclose(np.asarray(got.w), np.asarray(want.w),
                               atol=1e-6)
    assert int(got.t) == 56 == int(want.t)


def test_history_recorded_on_device(problem):
    """History objectives agree with the host-side recomputation."""
    xp, xm = problem
    res = saddle.solve(xp, xm, num_iters=120, record_every=60)
    want = float(saddle.objective(res.state.log_eta, res.state.log_xi,
                                  xp, xm))
    assert res.history[-1][0] == 120
    assert abs(res.history[-1][1] - want) < 1e-6
