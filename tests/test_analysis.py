"""Static-analysis layers (repro.analysis): the auditor must PASS every
real kernel program and CATCH every seeded violation with the right
rule ID -- a detector that never fires proves nothing, so each rule is
exercised from both sides.  Also covers the hlo_analysis shape-parsing
fixes the lint rules stand on."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro.analysis import hlo_lint, pallas_audit as pa
from repro.utils import hlo_analysis as ha

pytestmark = pytest.mark.analysis


def _rules(findings):
    return {f.rule for f in findings}


def _fake_prog(name, **kw):
    base = dict(name=name, num_scalar_prefetch=0, prefetch_length=None,
                prefetch_bound=None, scratch_shapes=[], scratch_bytes=0,
                extra_vmem_bytes=0, accum_axes={})
    base.update(kw)
    return base


# ==================================================================
# Layer 1: the real kernel registry passes, seeded violations fail
# ==================================================================

def test_registry_covers_every_pallas_kernel():
    """Every pallas_call site in the kernels package must be built
    from a registered program (the registry IS the audit surface)."""
    assert set(pa.registry()) == {
        "momentum_dot", "mwu_update", "momentum_dot_packed",
        "mwu_update_packed", "fwht"}


def test_full_sweep_clean():
    """All registered kernels x all serving rungs x both dry-run mesh
    client shapes x adversarial prefetch vectors: zero findings."""
    records, findings = pa.audit_all()
    assert findings == []
    # the sweep really covers both dry-run meshes and all five kernels
    cases = " | ".join(r["case"] for r in records)
    assert "k=256" in cases and "k=512" in cases
    assert {r["kernel"] for r in records} == set(pa.registry())
    # packed kernels really get the adversarial idx treatment
    assert any(r["idx_variants"] == 5 for r in records)


def test_seeded_out_of_bounds_index_map_block_001():
    prog = _fake_prog(
        "bad_block", grid=(4,),
        in_shapes=[(512,)],
        in_specs=[pl.BlockSpec((128,), lambda i: (i + 1,))],
        out_shapes=[(512,)],
        out_specs=[pl.BlockSpec((128,), lambda i: (i,))])
    assert _rules(pa.audit_program(prog, case="seed")) == {"BLOCK-001"}


def test_seeded_prefetch_out_of_bounds_block_001():
    """An off-by-one on the scalar-prefetched row index is only
    reachable when idx contains d-1 -- exactly what the adversarial
    vectors inject."""
    prog = _fake_prog(
        "bad_prefetch", grid=(2, 4), num_scalar_prefetch=1,
        prefetch_length=4, prefetch_bound=16,
        in_shapes=[(16, 256)],
        in_specs=[pl.BlockSpec((1, 128),
                               lambda i, j, idx: (idx[j] + 1, i))],
        out_shapes=[(2, 4)],
        out_specs=[pl.BlockSpec((1, 1), lambda i, j, idx: (i, j))])
    findings = pa.audit_program(prog, case="seed")
    assert "BLOCK-001" in _rules(findings)
    assert any("idx=" in f.detail for f in findings)


def test_seeded_uncovered_output_cover_001():
    prog = _fake_prog(
        "bad_cover", grid=(4,),
        in_shapes=[(512,)],
        in_specs=[pl.BlockSpec((128,), lambda i: (i,))],
        out_shapes=[(1024,)],        # twice the grid's reach
        out_specs=[pl.BlockSpec((128,), lambda i: (i,))])
    assert "COVER-001" in _rules(pa.audit_program(prog, case="seed"))


def test_seeded_racing_output_blockspec_race_001():
    """A packed-style (i,)-only output map revisited along grid axis 1
    WITHOUT declaring accumulation is a write-write race."""
    prog = _fake_prog(
        "bad_race", grid=(4, 8),
        in_shapes=[(512,)],
        in_specs=[pl.BlockSpec((128,), lambda i, j: (i,))],
        out_shapes=[(4,)],
        out_specs=[pl.BlockSpec((1,), lambda i, j: (i,))])
    assert _rules(pa.audit_program(prog, case="seed")) == {"RACE-001"}


def test_real_packed_accumulation_is_not_a_race():
    """mwu_update_packed revisits every output along the b-walk; with
    its declared accum_axes it must pass, and stripping the
    declaration must turn exactly that revisit into RACE-001."""
    from repro.kernels.saddle_update import mwu_update_packed_program
    prog = mwu_update_packed_program(n_pad=512, d=32, b=8, tile=128)
    assert pa.audit_program(prog, case="real") == []
    tampered = dict(prog, accum_axes={})
    assert _rules(pa.audit_program(tampered, case="tampered")) == \
        {"RACE-001"}


def test_seeded_oversized_block_vmem_001():
    spec = pl.BlockSpec((4096, 4096), lambda i: (0, 0))
    prog = _fake_prog(
        "bad_vmem", grid=(1,),
        in_shapes=[(4096, 4096)], in_specs=[spec],
        out_shapes=[(4096, 4096)], out_specs=[spec])
    assert _rules(pa.audit_program(prog, case="seed")) == {"VMEM-001"}


def test_partial_race_group_is_flagged():
    """A revisit group SMALLER than the declared accumulation extent
    (output touched by only some j) is still a finding -- declared
    accumulation must be exact, not a blanket waiver."""
    prog = _fake_prog(
        "bad_partial", grid=(2, 4),
        in_shapes=[(256,)],
        in_specs=[pl.BlockSpec((128,), lambda i, j: (i,))],
        out_shapes=[(8,)],
        # grid point (i, j) -> block 2i + (j & 1): each block revisited
        # only twice, not the declared 4-wide j extent
        out_specs=[pl.BlockSpec((1,),
                                lambda i, j: (2 * i + (j % 2),))],
        accum_axes={0: (1,)})
    assert "RACE-001" in _rules(pa.audit_program(prog, case="seed"))


# ==================================================================
# Layer 2 rules, each fed a seeded violation
# ==================================================================

@pytest.mark.filterwarnings(
    "ignore:Some donated buffers were not usable")
def test_dropped_donation_flagged_donate_001():
    """A donated buffer whose shape cannot alias the output loses its
    input_output_alias entry -- the exact regression DONATE-001 exists
    to catch."""
    fn = jax.jit(lambda x: x[:1] + 1.0, donate_argnums=0)
    hlo = fn.lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile().as_text()
    findings = hlo_lint.check_donation(hlo, "seed", 1)
    assert [f.rule for f in findings] == ["DONATE-001"]


def test_surviving_donation_passes_donate_001():
    fn = jax.jit(lambda x: x + 1.0, donate_argnums=0)
    hlo = fn.lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile().as_text()
    assert hlo_lint.donated_params(hlo) == {0}
    assert hlo_lint.check_donation(hlo, "seed", 1) == []


_SEED_HLO = """\
HloModule seed, entry_computation_layout={(f32[8]{0})->f32[8]{0}}

%body (p.1: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p.1 = (s32[], f32[8]) parameter(0)
  %tok = token[] after-all()
  %of = token[] outfeed((s32[], f32[8]) %p.1, token[] %tok)
  ROOT %r.1 = (s32[], f32[8]) tuple()
}

%cond (p.2: (s32[], f32[8])) -> pred[] {
  %p.2 = (s32[], f32[8]) parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main (arg: f32[8]) -> f32[8] {
  %arg = f32[8]{0} parameter(0)
  %init = (s32[], f32[8]) tuple()
  %w = (s32[], f32[8]) while((s32[], f32[8]) %init), \
condition=%cond, body=%body
  %wide = f64[8]{0} convert(f32[8]{0} %arg)
  ROOT %out = f32[8]{0} convert(f64[8]{0} %wide)
}
"""


def test_injected_f64_op_flagged_dtype_001():
    findings = hlo_lint.check_dtype(_SEED_HLO, "seed")
    assert [f.rule for f in findings] == ["DTYPE-001"]
    assert "f64" in findings[0].detail


def test_outfeed_in_while_body_flagged_host_001():
    findings = hlo_lint.check_host(_SEED_HLO, "seed")
    assert [f.rule for f in findings] == ["HOST-001"]
    assert "outfeed" in findings[0].detail


def test_clean_hlo_passes_dtype_and_host():
    fn = jax.jit(lambda x: x * 2.0)
    hlo = fn.lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile().as_text()
    assert hlo_lint.check_dtype(hlo, "clean") == []
    assert hlo_lint.check_host(hlo, "clean") == []
    assert hlo_lint.check_comm_serial(hlo, "clean") == []


def test_collective_in_serial_target_flagged_comm_001():
    hlo = _SEED_HLO.replace(
        "%tok = token[] after-all()",
        "%ar = f32[8]{0} all-reduce(f32[8]{0} %arg), to_apply=%cond")
    findings = hlo_lint.check_comm_serial(hlo, "seed")
    assert [f.rule for f in findings] == ["COMM-001"]


def test_lost_static_trip_flagged_trip_001():
    """The seed module's while has no known_trip_count: expecting a
    static chunk scan must fail, and so must its dynamic-while count
    when the design allows none."""
    findings = hlo_lint.check_trips(_SEED_HLO, "seed",
                                    static_trips=(4,),
                                    max_dynamic_whiles=0)
    assert [f.rule for f in findings] == ["TRIP-001", "TRIP-001"]
    assert hlo_lint.check_trips(_SEED_HLO, "seed", static_trips=(),
                                max_dynamic_whiles=1) == []


def test_suppressions_require_justification():
    f = hlo_lint.Finding("DTYPE-001", "t", "seeded")
    with pytest.raises(ValueError, match="justification"):
        hlo_lint.apply_suppressions(
            [f], (hlo_lint.Suppression("DTYPE-001", "t", "  "),))
    live, waived = hlo_lint.apply_suppressions(
        [f], (hlo_lint.Suppression("DTYPE-001", "t", "known, tracked"),))
    assert live == [] and len(waived) == 1
    assert waived[0]["justification"] == "known, tracked"
    # a non-matching suppression must not eat the finding
    live, _ = hlo_lint.apply_suppressions(
        [f], (hlo_lint.Suppression("HOST-001", "t", "other rule"),))
    assert live == [f]


# ==================================================================
# hlo_analysis shape parsing (the substrate the rules stand on)
# ==================================================================

def test_shape_bytes_tuple_shapes():
    assert ha._shape_bytes("f32[4,2]") == 32
    assert ha._shape_bytes("(f32[2], s32[4])") == 8 + 16
    assert ha._shape_bytes("(f32[128]{0}, token[])") == 512


def test_shape_bytes_zero_dim_and_pred():
    assert ha._shape_bytes("f32[]") == 4          # scalar: one element
    assert ha._shape_elements("f32[]") == 1
    assert ha._shape_bytes("pred[8]") == 8
    assert ha._shape_bytes("bf16[2,3]") == 12


def test_unknown_dtype_is_an_error_not_a_skip():
    with pytest.raises(ValueError, match="unknown HLO dtype"):
        ha._shape_bytes("f128[4]")
    with pytest.raises(ValueError, match="unknown HLO dtype"):
        ha._shape_elements("(f32[2], f128[4])")


def test_fp8_dtypes_counted():
    assert ha._shape_bytes("f8e4m3fn[16]") == 16
    assert ha._shape_bytes("f8e5m2[16]") == 16


# ==================================================================
# The gate itself (compiles the hot paths: slow tier)
# ==================================================================

@pytest.mark.slow
def test_lint_default_targets_clean():
    """In-process lint of every target the current device count can
    lower (the k=8 sharded runner needs forced host devices, which
    only the subprocess gate -- run.py sets XLA_FLAGS before jax
    imports -- can provide; jax pins the count at first init)."""
    targets = [t for t in hlo_lint.default_targets()
               if "k=8" not in t.name or jax.device_count() >= 8]
    assert len(targets) >= 4
    records, findings = hlo_lint.lint_all(targets)
    assert findings == []
    assert [r["target"] for r in records] == [t.name for t in targets]


@pytest.mark.slow
def test_gate_subprocess_green(tmp_path):
    """The CI entry point end to end: exit 0, JSON report written,
    zero unsuppressed findings."""
    out = tmp_path / "BENCH_analysis.json"
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + (
        ":" + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.run",
         "--json", str(out)],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(out.read_text())
    assert report["unsuppressed_count"] == 0
    assert len(report["kernel_cases"]) > 100
    assert len(report["hlo_targets"]) == 9
