import os

# keep unit tests on the single real device; only dryrun subprocesses
# force 512 host devices (see src/repro/launch/dryrun.py)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np           # noqa: E402
import pytest                # noqa: E402


@pytest.fixture(scope="session")
def blobs_separable():
    from repro.data import synthetic
    return synthetic.blobs(40, 50, 16, gap=1.2, spread=0.15, seed=0)


@pytest.fixture(scope="session")
def blobs_overlapping():
    from repro.data import synthetic
    return synthetic.blobs(45, 55, 12, gap=0.4, spread=0.5, seed=1)


@pytest.fixture(scope="session")
def qp_oracle():
    """Exact-ish RC-Hull solver via scipy SLSQP (small instances)."""
    import scipy.optimize as so

    def solve(xp, xm, nu=1.0):
        xp = np.asarray(xp, np.float64)
        xm = np.asarray(xm, np.float64)
        n1, n2 = len(xp), len(xm)

        def f(z):
            diff = z[:n1] @ xp - z[n1:] @ xm
            return 0.5 * diff @ diff

        cons = [{"type": "eq", "fun": lambda z: z[:n1].sum() - 1},
                {"type": "eq", "fun": lambda z: z[n1:].sum() - 1}]
        z0 = np.r_[np.ones(n1) / n1, np.ones(n2) / n2]
        r = so.minimize(f, z0, bounds=[(0, nu)] * (n1 + n2),
                        constraints=cons, options={"maxiter": 500})
        return r.fun

    return solve
