"""Roofline knob helpers: executable differencing (``delta``) and the
cost-source-agnostic choosers behind the engine_bench predict-then-
verify study (``pick_block_size``, ``gap_check_cadence``)."""

import pytest

from repro.utils import roofline


def _rf(flops=0.0, hbm=0.0, coll=0.0):
    return roofline.Roofline(
        flops=flops, hbm_bytes=hbm, collective_bytes=coll,
        collectives=None,
        compute_s=flops / roofline.PEAK_FLOPS,
        memory_s=hbm / roofline.HBM_BW,
        collective_s=coll / (roofline.ICI_BW * roofline.ICI_LINKS))


def test_delta_isolates_extra_work():
    a = _rf(flops=2e12, hbm=3e9)
    b = _rf(flops=1.5e12, hbm=1e9)
    d = roofline.delta(a, b)
    assert d.flops == pytest.approx(0.5e12)
    assert d.hbm_bytes == pytest.approx(2e9)
    assert d.step_time_s == pytest.approx(
        max(0.5e12 / roofline.PEAK_FLOPS, 2e9 / roofline.HBM_BW))


def test_delta_clamps_at_zero():
    d = roofline.delta(_rf(flops=1.0), _rf(flops=5.0, hbm=1.0))
    assert d.flops == 0.0 and d.hbm_bytes == 0.0
    assert d.step_time_s == 0.0


def test_pick_block_size_minimizes_per_coordinate_time():
    # step cost sublinear in B -> largest block amortizes best
    assert roofline.pick_block_size({1: 1.0, 32: 2.0, 128: 4.0}) == 128
    # step cost superlinear in B -> bigger blocks do not pay
    assert roofline.pick_block_size({32: 1.0, 64: 3.0}) == 32
    with pytest.raises(ValueError):
        roofline.pick_block_size({})


def test_gap_check_cadence_tracks_sqrt_optimum():
    # c* = sqrt(2 * T * check / step) = sqrt(2e6) ~ 1414 -> 1024 rung
    assert roofline.gap_check_cadence(1e-6, 1e-4, 10000) == 1024
    # free check: overshoot dominates, check as often as possible
    assert roofline.gap_check_cadence(1e-3, 0.0, 10000) == 32
    # ruinous check: evaluate as rarely as the ladder allows
    assert roofline.gap_check_cadence(1e-9, 1.0, 10000) == 2048


def test_gap_check_cadence_rejects_degenerate_costs():
    with pytest.raises(ValueError):
        roofline.gap_check_cadence(0.0, 1.0, 10)
    with pytest.raises(ValueError):
        roofline.gap_check_cadence(1e-6, -1.0, 10)
    with pytest.raises(ValueError):
        roofline.gap_check_cadence(1e-6, 1.0, 0)
