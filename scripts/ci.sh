#!/usr/bin/env bash
# Two-tier test driver.
#
#   scripts/ci.sh fast   -- SVM/solver tier (<3 min): everything not
#                           marked `slow` (see pytest.ini).  Run on
#                           every change.
#   scripts/ci.sh full   -- the whole suite including the LM-side
#                           model/system tests (>10 min on CPU).
#                           Nightly-style.
#
# No PYTHONPATH gymnastics needed: tests/conftest.py inserts src/ into
# sys.path, so a plain `python -m pytest` works from the repo root.
# Extra args are forwarded to pytest (e.g. scripts/ci.sh fast -k engine).
set -euo pipefail
cd "$(dirname "$0")/.."

tier="${1:-fast}"
shift || true

case "$tier" in
  fast)
    # lint: guarded -- the container image does not bake ruff in
    # (requirements-dev.txt + ruff.toml when it is available)
    if command -v ruff >/dev/null 2>&1; then
      ruff check src tests benchmarks scripts
    fi
    python -m pytest -q -m "not slow" "$@"
    # fault-injection gate: the robustness suite (quarantine,
    # deadline shedding, cancellation, retry, chaos plans, client
    # drop) must be green on its own -- an explicit signal that the
    # failure-handling paths were exercised, not just not-deselected.
    python -m pytest -q -m "faults and not slow"
    # static analysis gate: BlockSpec/race/VMEM audit of every Pallas
    # kernel program (all serving rungs + both dry-run mesh client
    # shapes) and the rule-based compiled-HLO lint of the hot paths
    # (donation, host transfers, f64, CommModel budget, trip counts).
    # Fails on any unsuppressed finding.  BENCH_analysis.json is
    # gitignored; add --dryrun-meshes for the k=256/512 lowerings.
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      python -m repro.analysis.run --json BENCH_analysis.json
    # perf smoke: quick engine bench with machine-readable metrics so
    # the perf trajectory (packed-step speedup, driver overhead) is
    # tracked from every fast run.  BENCH_engine.json is gitignored.
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      python -m benchmarks.run --only engine --json BENCH_engine.json
    # communication audit (Theorem 8): measured post-SPMD collective
    # counts vs the CommModel for k in {2,8,32}; fails on mismatch.
    # BENCH_comm.json is gitignored.
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      python -m benchmarks.theory_iters_comm --json BENCH_comm.json
    # serving smoke: continuous-batching throughput at S in {1,4,8}
    # vs the sequential fit loop + queue-to-result latency percentiles
    # per scheduler policy; FAILS on any recompile after bucket
    # warm-up (the speedup floor only warns in quick mode).
    # BENCH_serve.json is gitignored.
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      python -m benchmarks.run --only serve --json BENCH_serve.json
    # LM serving smoke: slot-granular decode with mid-decode admission
    # vs the sequential generate loop; same zero-recompiles-after-
    # warm-up hard assertion.  BENCH_lm_serve.json is gitignored.
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      python -m benchmarks.run --only lm_serve --json BENCH_lm_serve.json
    ;;
  full)
    python -m pytest -q "$@"
    # perf gate (enforcing): full-size engine bench.  Unlike the fast
    # tier's warn-only smoke, this FAILS if the packed single-sweep
    # step or the fused device-resident driver miss their 1.5x floors
    # (run.py exits 1 on a suite AssertionError).
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      python -m benchmarks.run --only engine --full --json BENCH_engine.json
    # serving gate (enforcing): the same serve bench as the fast tier
    # but with the floors promoted from warnings to failures -- the
    # S=8 speedup/sharding floors, the chaos goodput floor, and the
    # streaming warm-start floor (warm update iterations <= 0.7x cold,
    # rung jump included).
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      python -m benchmarks.run --only serve --full --json BENCH_serve.json
    # LM serving gate (enforcing): S=4 speedup >= 1x and the S=1
    # slot-driver-overhead floor (>= 0.7x sequential) fail here.
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      python -m benchmarks.run --only lm_serve --full --json BENCH_lm_serve.json
    ;;
  *)    echo "usage: scripts/ci.sh [fast|full] [pytest args...]" >&2
        exit 2 ;;
esac
