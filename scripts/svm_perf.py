"""SVM-side performance iterations for EXPERIMENTS.md section Perf.

Baseline = the paper-faithful Saddle-SVC/DSVC (block_size=1).  Each
iteration follows hypothesis -> change -> measure -> validate; results
are printed as markdown rows.

    PYTHONPATH=src python scripts/svm_perf.py
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.baselines import qp_nusvm
from repro.core import distributed as dist
from repro.core import preprocess as pp
from repro.core import saddle
from repro.data import synthetic


def iters_to_target(XP, XM, opt, *, block_size, scaling="lane",
                    tol=1.05, max_iters=40000, record=500):
    import jax.numpy as jnp

    from repro.core import engine

    params = saddle.make_params(XP.shape[0] + XM.shape[0], XP.shape[1],
                                1e-3, 0.1, block_size=block_size,
                                block_scaling=scaling)
    st = saddle.init_state(XP.shape[0], XM.shape[0], XP.shape[1],
                           None, None)
    xp_j, xm_j = jnp.asarray(XP), jnp.asarray(XM)
    key = jax.random.key(0)
    t0 = time.perf_counter()
    done = 0
    obj = np.inf
    while done < max_iters:
        key, sub = jax.random.split(key)
        # fused engine chunk: donated state, objective computed on device
        # (the convergence check is the only per-chunk host sync)
        st, obj_dev = engine.run_chunk(st, sub, xp_j, xm_j, record,
                                       params=params, chunk_steps=record)
        done += record
        obj = float(obj_dev)
        if obj <= opt * tol + 1e-9:
            break
    wall = time.perf_counter() - t0
    return done * block_size, done, wall, obj


def main() -> None:
    rng_seed = 0
    n, d = 4000, 256
    ds = synthetic.separable(n, d, seed=rng_seed)
    xp, xm = ds.x[ds.y > 0], ds.x[ds.y < 0]
    pre = pp.preprocess(xp, xm, jax.random.key(0))
    XP, XM = np.asarray(pre.xp), np.asarray(pre.xm)
    _, hist = qp_nusvm.solve(XP, XM, nu=1.0, num_iters=4000)
    opt = hist[-1][1]
    print(f"problem: n={n} d={d} (padded {XP.shape[1]}), QP opt={opt:.6f}")
    print()
    print("| mode | coordinate-updates to 1.05xOPT | outer iters | "
          "comm scalars (k=20) | wall s (1-core CPU) | final obj |")
    print("|---|---|---|---|---|---|")

    k = 20
    comm_per_iter = dist.CommModel(k=k, nu_rounds_per_iter=0) \
        .scalars_per_iteration()
    cases = [(1, "lane", "paper-faithful (B=1)"),
             (32, "scaled", "block B=32, naive d/B rescale (REFUTED)"),
             (32, "lane", "block B=32, lane scaling"),
             (128, "lane", "block B=128, lane scaling")]
    for b, scaling, label in cases:
        coord, outer, wall, fin = iters_to_target(XP, XM, opt,
                                                  block_size=b,
                                                  scaling=scaling)
        comm = outer * comm_per_iter
        print(f"| {label} | {coord} | {outer} | {comm:.0f} | "
              f"{wall:.1f} | {fin:.6f} |")

    print()
    print("distributed collective count per iteration (from the "
          "Algorithm-4 step): 2 delta psums + 2 normalizer psums "
          "+ 2 pmax = 6 scalar all-reduces over the client axis, "
          "independent of B -- so block mode divides scalars-per-"
          "coordinate-progress by ~B.")


if __name__ == "__main__":
    main()
