import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Perf-iteration harness (EXPERIMENTS.md section Perf): lower+compile one
# (arch x shape) with config overrides and report the roofline delta
# against the unrolled baseline.
#
#   PYTHONPATH=src python scripts/hillclimb.py --arch deepseek-v2-lite-16b \
#       --shape train_4k --tag zero2 --set fsdp_params=False

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402

from repro.configs import get_config                      # noqa: E402
from repro.launch import specs as specs_mod               # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.launch.shapes import SHAPES                    # noqa: E402
from repro.utils import roofline as rl                    # noqa: E402


def parse_value(v: str):
    if v in ("True", "False"):
        return v == "True"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg overrides key=value")
    ap.add_argument("--layers-per-scan", type=int, default=0)
    ap.add_argument("--out", default="experiments/hillclimb")
    ap.add_argument("--top-collectives", type=int, default=0,
                    help="print the N largest collective ops by shape")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_value(v)
    if args.layers_per_scan:
        overrides["block_pattern"] = (cfg.block_pattern
                                      * args.layers_per_scan)
    overrides["scan_layers"] = False        # roofline-accurate
    cfg = dataclasses.replace(cfg, **overrides)

    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=False)
    t0 = time.time()
    with mesh:
        fn, fargs = specs_mod.build_lowerable(cfg, shape, mesh)
        compiled = jax.jit(fn).lower(*fargs).compile()
        roof = rl.analyze(compiled)
        mem = compiled.memory_analysis()
        if args.top_collectives:
            import collections
            import re as _re
            from repro.utils.hlo_analysis import _shape_bytes
            agg = collections.Counter()
            for line in compiled.as_text().splitlines():
                m = _re.match(
                    r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
                    r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                    r"collective-permute)\(", line)
                if m:
                    mm = _re.search(r'op_name="([^"]{0,90})', line)
                    where = mm.group(1) if mm else "?"
                    agg[f"{m.group(2)} {m.group(1)[:48]} @ {where}"] += \
                        _shape_bytes(m.group(1))
            for k, v in agg.most_common(args.top_collectives):
                print(f"  {v / 2**30:8.2f} GiB  {k}")
    rec = {
        "arch": args.arch, "shape": args.shape, "tag": args.tag,
        "overrides": {k: str(v) for k, v in overrides.items()},
        "compile_s": round(time.time() - t0, 1),
        "compute_s": roof.compute_s, "memory_s": roof.memory_s,
        "collective_s": roof.collective_s,
        "collective_breakdown": roof.collectives.bytes_by_op,
        "hlo_flops": roof.flops, "hlo_bytes": roof.hbm_bytes,
        "arg_bytes": int(mem.argument_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
    }
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out,
                        f"{args.arch}_{args.shape}_{args.tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
