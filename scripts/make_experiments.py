"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSON artifacts.

    PYTHONPATH=src python scripts/make_experiments.py > /tmp/tables.md
"""

from __future__ import annotations

import glob
import json
import os
import sys

SCAN_DIR = "experiments/dryrun"
UNROLL_DIR = "experiments/dryrun_unrolled"


def load(d):
    recs = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        with open(f) as fh:
            r = json.load(fh)
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def main() -> None:
    scanned = load(SCAN_DIR)
    unrolled = load(UNROLL_DIR)

    print("### Dry-run matrix (lower + compile, scanned layers)\n")
    print("| arch | shape | mesh | status | args GiB/dev | "
          "alloc GiB/dev (no-reuse UB) | compile s |")
    print("|---|---|---|---|---|---|---|")
    for key in sorted(scanned):
        r = scanned[key]
        if not r.get("applicable", True):
            print(f"| {key[0]} | {key[1]} | {key[2]} | SKIP "
                  f"({r['reason'][6:40]}...) | | | |")
            continue
        if r.get("error"):
            print(f"| {key[0]} | {key[1]} | {key[2]} | **ERROR** | | | |")
            continue
        m = r["memory"]
        print(f"| {key[0]} | {key[1]} | {key[2]} | OK | "
              f"{fmt_bytes(m['argument_size_in_bytes'])} | "
              f"{fmt_bytes(m['temp_size_in_bytes'])} | "
              f"{r['compile_s']:.1f} |")

    print("\n### Roofline (single-pod 16x16, layers unrolled)\n")
    print("mem(meas) is the HLO bytes-accessed upper bound (the CPU "
          "backend reports UNFUSED traffic); mem(adj) is the fused "
          "lower bound 2 x resident-bytes / HBM_bw.  The bottleneck "
          "column classifies with mem(adj) -- see EXPERIMENTS.md "
          "methodology.\n")
    print("| arch | shape | compute ms | mem(meas) ms | mem(adj) ms | "
          "collective ms | bottleneck | useful/HLO flops | MFU bound |")
    print("|---|---|---|---|---|---|---|---|---|")
    hbm = 819e9
    for key in sorted(unrolled):
        if key[2] != "16x16":
            continue
        r = unrolled[key]
        if not r.get("applicable", True) or r.get("error"):
            continue
        args_b = r["memory"]["argument_size_in_bytes"]
        mem_adj = 2.0 * args_b / hbm
        terms = {"compute": r["compute_s"], "memory": mem_adj,
                 "collective": r["collective_s"]}
        bott = max(terms, key=terms.get)
        step = max(terms.values())
        mfu = (r.get("model_flops_per_device", 0.0)
               / (step * 197e12)) if step else 0.0
        print(f"| {key[0]} | {key[1]} | {r['compute_s'] * 1e3:.2f} | "
              f"{r['memory_s'] * 1e3:.2f} | {mem_adj * 1e3:.2f} | "
              f"{r['collective_s'] * 1e3:.2f} | {bott} | "
              f"{r.get('useful_flops_ratio', 0):.3f} | "
              f"{mfu * 100:.1f}% |")


if __name__ == "__main__":
    main()
